"""Benchmark harness: one bench per paper table/figure + system benches.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--scale S] [--only name,...]

Benches:
    paper_tables  — Tables 2 and 3 (I/O bytes and ops, 3 strategy sets)
    chain_sweep   — section 5.7.3 chain-limit trade-off
    lifecycle     — Fig. 8 stream state distribution
    search_speed  — section 6.1 additional-index speedups
    search_batched — batched SearchService qps vs per-query loop
    search_sharded — 4-shard scatter/gather vs unsharded (qps + read bytes)
    search_topk   — top-k early-termination vs exhaustive (read-bytes ratio)
    search_ranked — score-ordered (WAND) top-k vs exhaustive ranked scan
    search_hot_traffic — concurrent hot-vocabulary queries through the
                    cross-query chunk pool vs per-query cursors
    search_replicas — replica read tier: capacity vs replica count,
                    failover sweep across backends × shard counts
    update_speed  — live per-shard update streams: targeted invalidation
                    vs whole-namespace drops under interleaved updates
    durability    — repro.store: WAL fsync cost, recovery time vs WAL
                    length, read bytes before/after compaction
    paged_kv      — TPU adaptation: paged KV allocator behaviour
    kernels       — Pallas kernel microbenches (interpret mode) vs refs
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def _bench_paper_tables(scale):
    from benchmarks import paper_tables

    rows = paper_tables.run(scale)
    verdicts = paper_tables.check_claims(rows)
    return rows, verdicts


def _bench_chain_sweep(scale):
    from benchmarks import chain_sweep

    rows = chain_sweep.run(min(scale, 0.5))
    ok = all(r["max_chain_segments"] <= r["chain_limit"] for r in rows)
    return rows, [f"{'PASS' if ok else 'FAIL'}  chain length bounded by limit"]


def _bench_lifecycle(scale):
    from benchmarks import lifecycle

    rows = lifecycle.run(min(scale, 0.5))
    ok1 = all(r.get("state_sr0", 0) == 0 for r in rows if r["set"] == "set1")
    ok2 = all(r.get("state_part", 0) == 0 for r in rows if r["set"] == "set2")
    return rows, [f"{'PASS' if (ok1 and ok2) else 'FAIL'}  Fig. 8 lifecycle paths"]


def _bench_search_speed(scale):
    from benchmarks import search_speed

    rows = search_speed.run(min(scale, 0.5))
    ok = all(r["agree"] for r in rows)
    fast = [
        r["scan_speedup"]
        for r in rows
        if r["class"] in ("stop_pair", "stop_triple", "freq_other", "freq_freq")
    ]
    ok &= min(fast) > 3
    return rows, [
        f"{'PASS' if ok else 'FAIL'}  additional-index speedup "
        f"(min {min(fast):.0f}x, max {max(fast):.0f}x)"
    ]


def _bench_search_batched(scale):
    from benchmarks import search_speed

    rows = search_speed.run_batched(min(scale, 0.5))
    ok = all(r["identical"] for r in rows)
    best = max(r["batch_speedup"] for r in rows)
    ok &= best > 1.0
    return rows, [
        f"{'PASS' if ok else 'FAIL'}  batched SearchService beats the "
        f"per-query loop (best {best:.2f}x) with identical results"
    ]


def _bench_search_sharded(scale):
    from benchmarks import search_speed

    rows = search_speed.run_sharded(min(scale, 0.5), n_shards=4)
    agg = rows[-1]
    # scale-invariant bytes gate: marginal overhead per extra shard must
    # stay within the fixed per-lookup dictionary budget (the raw ratio
    # is recorded in the trajectory but tracks corpus size, not
    # regressions — at tiny scales duplicated fixed costs dominate it)
    ok = agg["identical"] and (
        agg["overhead_bytes"] <= agg["overhead_budget_bytes"]
    )
    return rows, [
        f"{'PASS' if ok else 'FAIL'}  4-shard scatter/gather identical to "
        f"unsharded (sharding overhead {agg['overhead_bytes']:,} B <= "
        f"fixed per-lookup budget {agg['overhead_budget_bytes']:,} B; "
        f"raw bytes ratio {agg['bytes_ratio']:.3f} recorded, not gated — "
        f"not scale-invariant)"
    ]


def _bench_search_topk(scale):
    from benchmarks import search_speed

    rows = search_speed.run_topk(min(scale, 0.5), top_k=10, n_queries=32)
    r = rows[0]
    ok = (
        r["identical"]
        and r["chunks_skipped"] > 0
        and r["topk_read_bytes"] < r["ex_read_bytes"]
    )
    return rows, [
        f"{'PASS' if ok else 'FAIL'}  top-10 streaming head identical to "
        f"exhaustive at {r['bytes_ratio']:.3f}x read bytes "
        f"({r['chunks_skipped']} chunks skipped)"
    ]


def _bench_search_ranked(scale):
    from benchmarks import search_speed

    rows = search_speed.run_ranked(min(scale, 0.5), top_k=10, n_queries=24)
    r = rows[0]
    ok = (
        r["identical"]
        and r["chunks_skipped"] > 0
        and r["ranked_read_bytes"] < r["ex_read_bytes"]
    )
    return rows, [
        f"{'PASS' if ok else 'FAIL'}  ranked top-10 head identical to the "
        f"exhaustive score-then-sort scan at {r['bytes_ratio']:.3f}x read "
        f"bytes ({r['chunks_skipped']} chunks skipped, "
        f"{r['threshold_stops']} threshold stops)"
    ]


def _bench_search_hot_traffic(scale):
    from benchmarks import search_speed

    rows = search_speed.run_hot_traffic(min(scale, 0.5), n_queries=96)
    r = rows[0]
    ok = (
        r["identical"]
        and r["chunks_shared"] > 0
        and r["bytes_ratio"] <= 0.5
        and r["dedup_many_bytes"] < 2 * max(1, r["dedup_one_bytes"])
    )
    return rows, [
        f"{'PASS' if ok else 'FAIL'}  hot-traffic chunk pool identical to "
        f"per-query cursors at {r['bytes_ratio']:.3f}x read bytes "
        f"({r['chunks_shared']} chunk replays over {r['chunks_fetched']} "
        f"unique fetches)"
    ]


def _bench_search_replicas(scale):
    from benchmarks import search_speed

    s = min(scale, 0.5)
    world = search_speed.make_world(s)
    rows = search_speed.run_replicas(s, world=world, n_replicas=3,
                                     n_queries=48)
    summary = rows[-1]
    sweep = search_speed.run_replica_identity_sweep(s, world=world,
                                                    n_replicas=2)
    ok = (
        summary["identical"]
        and all(r["identical"] for r in sweep)
        and all(r["failovers"] >= 1 for r in sweep)
        and summary["capacity_ratio"] >= 1.5
    )
    return rows + sweep, [
        f"{'PASS' if ok else 'FAIL'}  3-replica fabric identical to the "
        f"single-reader path across backends x shard counts "
        f"(incl. {sum(r['failovers'] for r in sweep)} injected failovers) "
        f"at {summary['capacity_ratio']:.2f}x single-replica capacity, "
        f"p99 {summary['p99_ms']:.2f} ms"
    ]


def _bench_update_speed(scale):
    from benchmarks import update_speed

    rows = update_speed.run(min(scale, 0.5))
    t = next(r for r in rows if r["mode"] == "targeted")
    b = next(r for r in rows if r["mode"] == "namespace_drop")
    ok = (
        t["identical"]
        and t["invalidations"] < b["invalidations"]
        and t["full_drops"] < b["full_drops"]
        and t["read_bytes"] < b["read_bytes"]
    )
    return rows, [
        f"{'PASS' if ok else 'FAIL'}  interleaved updates served "
        f"stale-free and identical to a rebuild; targeted invalidation "
        f"dropped {t['invalidations']} cache entries vs "
        f"{b['invalidations']} whole-namespace"
    ]


def _bench_durability(scale):
    from benchmarks import durability

    rows = durability.run(min(scale, 0.5))
    by_mode = {r["mode"]: r for r in rows}
    a = by_mode["apply_wal_fsync"]
    ck = by_mode["checkpoint_reopen"]
    co = by_mode["compaction"]
    ok = (
        a["charge_parity"]
        and a["wal_syncs"] == a["parts"]
        and ck["identical"]
        and co["identical"]
        and co["compacted_streams"] >= 1
        and co["read_bytes_after"] <= co["read_bytes_before"]
    )
    return rows, [
        f"{'PASS' if ok else 'FAIL'}  durable store charged zero simulated "
        f"bytes; recovery served identical results "
        f"({ck['speedup']}x faster from checkpoint); compaction folded "
        f"{co['compacted_streams']} stream(s) at {co['bytes_ratio']}x "
        f"cold read bytes"
    ]


def _bench_paged_kv(scale):
    from benchmarks import paged_kv_bench

    return paged_kv_bench.run(scale)


def _bench_kernels(scale):
    from benchmarks import kernel_bench

    return kernel_bench.run(scale)


def _append_trajectory(path, scale, all_rows, verdicts):
    """Append one run record to the BENCH_search.json trajectory.

    The artifact is a JSON list — one record per harness run — so
    successive PRs accumulate a qps / read-bytes / p99 baseline per
    search scenario instead of overwriting it.  Scalar perf fields are
    harvested by name (qps, bytes, p99, ratios, speedups); everything
    else stays in the per-run --json dump.
    """
    scenarios = {}
    for r in all_rows:
        bench = str(r.get("bench", ""))
        if not bench.startswith(("search", "update")):
            continue
        scen = scenarios.setdefault(bench, {})
        for k, v in r.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            kl = k.lower()
            if ("qps" in kl or "bytes" in kl or "p99" in kl
                    or kl.endswith("_ratio") or "speedup" in kl):
                scen[k] = round(v, 4) if isinstance(v, float) else v
    scenarios = {k: v for k, v in scenarios.items() if v}
    record = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "scale": scale,
        "scenarios": scenarios,
        "verdicts": [f"{name}: {v}" for name, v in verdicts],
    }
    try:
        with open(path) as f:
            history = json.load(f)
        if not isinstance(history, list):
            history = [history]
    except (OSError, ValueError):
        history = []
    history.append(record)
    with open(path, "w") as f:
        json.dump(history, f, indent=1, default=str)
        f.write("\n")
    return record


BENCHES = {
    "paper_tables": _bench_paper_tables,
    "chain_sweep": _bench_chain_sweep,
    "lifecycle": _bench_lifecycle,
    "search_speed": _bench_search_speed,
    "search_batched": _bench_search_batched,
    "search_sharded": _bench_search_sharded,
    "search_topk": _bench_search_topk,
    "search_ranked": _bench_search_ranked,
    "search_hot_traffic": _bench_search_hot_traffic,
    "search_replicas": _bench_search_replicas,
    "update_speed": _bench_update_speed,
    "durability": _bench_durability,
    "paged_kv": _bench_paged_kv,
    "kernels": _bench_kernels,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--json", type=str, default="")
    ap.add_argument("--trajectory", type=str, default="BENCH_search.json",
                    help="perf-trajectory artifact to append to "
                         "('' disables)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)

    all_rows = []
    verdicts = []
    failed = []
    for name in names:
        fn = BENCHES[name]
        print(f"\n=== bench: {name} (scale={args.scale}) " + "=" * 30)
        t0 = time.time()
        try:
            rows, vds = fn(args.scale)
        except Exception:
            traceback.print_exc()
            failed.append(name)
            continue
        dt = time.time() - t0
        for r in rows:
            all_rows.append(r)
            compact = {
                k: v for k, v in r.items() if not isinstance(v, dict)
            }
            print("  " + json.dumps(compact, default=str))
        for v in vds:
            print("  " + v)
            verdicts.append((name, v))
        print(f"  [{dt:.1f}s]")

    print("\n=== summary " + "=" * 40)
    for name, v in verdicts:
        print(f"{name:14s} {v}")
    n_fail = len(failed) + sum(1 for _, v in verdicts if v.startswith("FAIL"))
    print(f"\n{len(verdicts)} claims checked, {n_fail} failures"
          + (f" (errored: {failed})" if failed else ""))
    if args.trajectory:
        rec = _append_trajectory(args.trajectory, args.scale,
                                 all_rows, verdicts)
        print(f"trajectory: appended {len(rec['scenarios'])} scenario(s) "
              f"to {args.trajectory}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_rows, f, default=str, indent=1)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
