"""Shared corpus/world construction for the paper-reproduction benchmarks."""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.lexicon import Lexicon, make_lexicon
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig, TextIndexSet


@dataclasses.dataclass
class World:
    lexicon: Lexicon
    parts: List[Tuple[np.ndarray, np.ndarray]]  # (tokens, offsets) per part
    doc_starts: List[int]

    @property
    def total_tokens(self) -> int:
        return sum(t.shape[0] for t, _ in self.parts)


def make_world(scale: float = 1.0, seed: int = 0, n_parts: int = 2) -> World:
    """Multi-part collection (paper 6.4: build part 1, update in place with
    the following parts; the paper's headline experiment uses two parts).

    scale=1 is CI-size (~0.8M tokens).  The paper's 71.5 GB collection is
    roughly scale=12000; I/O *ratios* between strategy sets are the
    reproduced quantity at any scale.
    """
    lex = make_lexicon(
        n_words=60_000,
        n_lemmas=26_000,
        n_stop=70,
        n_frequent=1_000,
        seed=1234 + seed,
    )
    n_docs = max(40, int(1200 * scale))
    parts = []
    doc_starts = []
    doc0 = 0
    for p in range(n_parts):
        toks, offs = generate_cached(lex, n_docs, 350, doc0, seed=100 + p)
        parts.append((toks, offs))
        doc_starts.append(doc0)
        doc0 += n_docs
    return World(lexicon=lex, parts=parts, doc_starts=doc_starts)


# hot-regime index geometry for the top-k early-termination bench AND the
# tier-1 effectiveness regression (tests/test_topk.py): small clusters and
# EM limit push the hot keys' lists into multi-chunk stream storage even at
# CI corpus sizes — the ONE definition both consumers share, so tuning the
# regime can never silently leave the other un-tuned
HOT_GEOMETRY = dict(cluster_size=256, em_limit=8, tag_extract_bytes=512)


def make_hot_world(scale: float = 1.0, seed: int = 0, n_parts: int = 2) -> World:
    """A *hot-vocabulary* collection for the top-k early-termination bench:
    a tiny lexicon makes every k-word tuple recur across many documents, so
    multi-component keys carry long stream-backed posting lists — the
    regime where a best-k search can stop far before the lists end.  (The
    standard :func:`make_world` vocabulary is so large that phrase keys
    rarely repeat, which leaves nothing for early termination to skip.)"""
    lex = make_lexicon(
        n_words=8, n_lemmas=5, n_stop=1, n_frequent=2,
        unknown_fraction=0.15, seed=7 + seed,
    )
    n_docs = max(80, int(800 * scale))
    parts = []
    doc_starts = []
    doc0 = 0
    for p in range(n_parts):
        toks, offs = generate_cached(lex, n_docs, 250, doc0, seed=300 + p)
        parts.append((toks, offs))
        doc_starts.append(doc0)
        doc0 += n_docs
    return World(lexicon=lex, parts=parts, doc_starts=doc_starts)


_GEN_CACHE: Dict[tuple, Tuple[np.ndarray, np.ndarray]] = {}


def generate_cached(lex, n_docs, avg_len, doc0, seed):
    from repro.data.corpus import generate_part

    key = (id(lex), n_docs, avg_len, doc0, seed)
    if key not in _GEN_CACHE:
        _GEN_CACHE[key] = generate_part(lex, n_docs, avg_len, doc0, seed)
    return _GEN_CACHE[key]


def bench_index_config(
    setname: str,
    cluster_size: int = 1024,
    build_ordinary_all: bool = False,
    fl_area_clusters: int = 4096,
    multi_k=3,
    **strategy_kw,
) -> IndexSetConfig:
    """Benchmark geometry: the CI corpus is ~10^4x smaller than the paper's
    71.5 GB, so the cluster geometry is scaled to keep the *postings-per-key
    vs cluster-size* regime comparable (1 KB clusters, 16 B EM limit, 64 B
    SR blocks, 2 KB TAG extraction).  All ratios between strategy sets are
    geometry-consistent with the paper's 32 KB/64 B/128 B/8 KB settings.

    The ONE config builder for sharded and unsharded benchmark substrates:
    benches that compare the two (``search_speed --shards``) rely on both
    being constructed from an identical ``IndexSetConfig``."""
    strategy_kw.setdefault("em_limit", 16)
    strategy_kw.setdefault("sr_block", 64)
    strategy_kw.setdefault("tag_extract_bytes", 2048)
    strategy = getattr(StrategyConfig, setname)(
        cluster_size=cluster_size, **strategy_kw
    )
    return IndexSetConfig(
        strategy=strategy,
        build_ordinary_all=build_ordinary_all,
        fl_area_clusters=fl_area_clusters,
        multi_k=multi_k,
    )


def build_index_set(world: World, setname: str, **cfg_kw) -> TextIndexSet:
    ts = TextIndexSet(bench_index_config(setname, **cfg_kw), world.lexicon,
                      seed=0)
    for (toks, offs), doc0 in zip(world.parts, world.doc_starts):
        ts.add_documents(toks, offs, doc0)
    return ts


def build_sharded_index_set(world: World, setname: str, n_shards: int,
                            **cfg_kw):
    """Identical :func:`bench_index_config` geometry as
    :func:`build_index_set`, partitioned by doc hash across ``n_shards``
    full per-shard substrates."""
    from repro.core.sharded_set import ShardedTextIndexSet

    sts = ShardedTextIndexSet(
        bench_index_config(setname, **cfg_kw), world.lexicon,
        n_shards=n_shards, seed=0,
    )
    for (toks, offs), doc0 in zip(world.parts, world.doc_starts):
        sts.add_documents(toks, offs, doc0)
    return sts


def timeit(fn, *args, repeats: int = 3, **kw) -> Tuple[float, object]:
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out  # microseconds
