"""Tables 2 and 3 (paper section 6.5): construction I/O per index type,
for the three strategy sets:

  1. C1+EM+PART+S+FL+TAG
  2. set 1 + CH + SR
  3. set 2 + DS

The collection is indexed in two parts (build + in-place update), exactly
like the paper's experiment.  Reported per measured index: total bytes
moved and total I/O operations.  The reproduced *claims* (checked by
``run.py`` and the test suite):

  * set2 bytes   < set1 bytes       (CH+SR cut FL waste and tail re-reads)
  * set2 ops     < set1 ops         (coalesced chains, full-cluster writes)
  * set3 write_ops << set2 write_ops (DS packs scattered small writes)
  * set3 bytes   ~= set2 bytes      (DS barely changes byte volume)
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import World, build_index_set, make_world
from repro.core.text_index import INDEX_NAMES

SETS = ("set1", "set2", "set3")


def run(scale: float = 1.0, world: World = None) -> List[Dict]:
    world = world or make_world(scale)
    rows: List[Dict] = []
    per_set = {}
    for setname in SETS:
        ts = build_index_set(world, setname, multi_k=None)  # paper tables never query the multi index
        table = ts.table_rows()
        per_set[setname] = table
        census = ts.census()
        for index_name in INDEX_NAMES:
            r = table[index_name]
            rows.append(
                {
                    "bench": "paper_tables",
                    "set": setname,
                    "index": index_name,
                    "total_bytes": r["total_bytes"],
                    "total_ops": r["total_ops"],
                    "read_ops": r["read_ops"],
                    "write_ops": r["write_ops"],
                    "states": dict(census[index_name]),
                }
            )
    return rows


def check_claims(rows: List[Dict]) -> List[str]:
    """Assert the paper's qualitative claims; return human-readable verdicts."""
    agg = {}
    for r in rows:
        a = agg.setdefault(r["set"], {"bytes": 0, "ops": 0, "write_ops": 0})
        a["bytes"] += r["total_bytes"]
        a["ops"] += r["total_ops"]
        a["write_ops"] += r["write_ops"]
    verdicts = []

    def claim(name, ok):
        verdicts.append(f"{'PASS' if ok else 'FAIL'}  {name}")
        return ok

    claim(
        f"Table2: set2 bytes < set1 bytes "
        f"({agg['set2']['bytes']:,} < {agg['set1']['bytes']:,})",
        agg["set2"]["bytes"] < agg["set1"]["bytes"],
    )
    claim(
        f"Table3: set2 ops < set1 ops "
        f"({agg['set2']['ops']:,} < {agg['set1']['ops']:,})",
        agg["set2"]["ops"] < agg["set1"]["ops"],
    )
    claim(
        f"Table3: set3 write_ops < set2 write_ops "
        f"({agg['set3']['write_ops']:,} < {agg['set2']['write_ops']:,})",
        agg["set3"]["write_ops"] < agg["set2"]["write_ops"],
    )
    ratio = agg["set3"]["bytes"] / max(1, agg["set2"]["bytes"])
    claim(
        f"Table2: set3 bytes ~= set2 bytes (ratio {ratio:.3f})",
        0.9 < ratio < 1.15,
    )
    return verdicts


def main(scale: float = 1.0) -> None:
    rows = run(scale)
    hdr = f"{'set':6s} {'index':9s} {'bytes':>14s} {'ops':>10s} {'r_ops':>8s} {'w_ops':>8s}"
    print(hdr)
    for r in rows:
        print(
            f"{r['set']:6s} {r['index']:9s} {r['total_bytes']:>14,} "
            f"{r['total_ops']:>10,} {r['read_ops']:>8,} {r['write_ops']:>8,}"
        )
    for v in check_claims(rows):
        print(v)


if __name__ == "__main__":
    import sys

    main(float(sys.argv[1]) if len(sys.argv) > 1 else 1.0)
