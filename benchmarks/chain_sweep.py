"""Chain-length limit sweep (paper 5.7.3).

The CH limit bounds search read operations per stream.  Sweeping the limit
shows the trade-off the paper describes: higher limits defer CH→S
conversions (cheaper construction) at the price of more read ops per
search, until the limit where "search time is not changed" (the paper
picked 9).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import World, build_index_set, make_world


def run(scale: float = 0.25, world: World = None) -> List[Dict]:
    # many parts => many in-place updates => chains actually grow (5.7.3)
    world = world or make_world(scale, n_parts=6)
    rows: List[Dict] = []
    for limit in (2, 3, 5, 9, 15):
        ts = build_index_set(world, "set2", chain_limit=limit, multi_k=None)  # paper tables never query the multi index
        idx = ts.indexes["known"]
        build_ops = idx.mgr.device.stats.total_ops
        ch_ops, all_ops = [], []
        for key, e in idx.dict.entries.items():
            if e.kind == "em":
                continue
            n = idx.lookup_ops(key)
            all_ops.append(n)
            if e.kind == "own" and idx.mgr.streams[e.sid].state == "ch":
                ch_ops.append(n)
        tagged_ch = [
            len(s.segments)
            for s in idx.mgr.streams.values()
            if s.state == "ch"
        ]
        conv = idx.mgr.transitions.get(("ch", "s"), 0)
        rows.append(
            {
                "bench": "chain_sweep",
                "chain_limit": limit,
                "build_ops": build_ops,
                "mean_search_ops": float(np.mean(all_ops)) if all_ops else 0.0,
                "max_chain_segments": int(np.max(tagged_ch)) if tagged_ch else 0,
                "ch_to_s_conversions": conv,
            }
        )
    return rows


def main(scale: float = 0.25) -> None:
    rows = run(scale)
    print(
        f"{'limit':>5s} {'build_ops':>10s} {'mean_search':>12s} "
        f"{'max_chain_seg':>14s} {'CH->S':>6s}"
    )
    for r in rows:
        print(
            f"{r['chain_limit']:>5d} {r['build_ops']:>10,} "
            f"{r['mean_search_ops']:>12.2f} {r['max_chain_segments']:>14d} "
            f"{r['ch_to_s_conversions']:>6d}"
        )
    # 5.7.3: the number of segments in any chain never exceeds the limit,
    # and lower limits force more CH->S conversions
    assert all(r["max_chain_segments"] <= r["chain_limit"] for r in rows), rows
    assert rows[0]["ch_to_s_conversions"] >= rows[-1]["ch_to_s_conversions"], rows
    print("PASS  chain length bounded by limit; conversions fall as limit rises")


if __name__ == "__main__":
    main()
