"""Search speed: additional indexes vs ordinary index (paper 6.1).

The paper's motivating claim: proximity queries containing frequently used
words are orders of magnitude cheaper through the (w,v) and stop-sequence
indexes than through the ordinary inverted index.  We measure postings
scanned, search I/O ops, and wall time per query class.

``--batched`` adds the multi-user serving view: the same mixed query
stream through ``SearchService.search_batch`` (planned, deduplicated,
JAX-bucketed joins) vs a per-query ``ProximityEngine.search`` loop,
reported as queries/sec per join backend.

``--multi`` compares the multi-component key route (arXiv:1812.07640)
against the ordinary-index join path on a stream of k-word phrase
queries: same results, strictly fewer posting bytes read (the k-word key
fetches only the phrase's own occurrences; the join path drags in every
occurrence of every queried lemma).

``--topk N`` measures the top-k early-termination streaming executor
(arXiv:2009.02684) against the exhaustive multi route on a
hot-vocabulary phrase stream: identical best-k heads (verified across
join backends and shard counts), strictly fewer posting bytes read, and
the chunks-skipped ledger from ``last_trace``.

``--hot-traffic C`` floods the streaming executor with C concurrent
hot-vocabulary top-k/ranked queries cycling a handful of phrases: the
cross-query chunk pool vs one private cursor per query — identical
results, read bytes scaling with unique chunks instead of queries
(ledgered as ``chunks_shared`` vs ``chunks_fetched`` in ``last_trace``),
and a dedup gate pinning N identical queries to < 2x one query's bytes.

``--shards N`` runs the same batched mixed stream through a
``ShardedTextIndexSet`` (document-hash sharding, scatter/gather
``SearchService``) vs the unsharded set, reporting per-shard and
aggregate queries/sec and read bytes.  The acceptance gate: sharding
must NOT inflate aggregate read I/O (per-shard posting subsets usually
land in *cheaper* storage tiers, so the sharded aggregate tends to come
in below the unsharded bytes).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (
    HOT_GEOMETRY,
    World,
    build_index_set,
    build_sharded_index_set,
    make_hot_world,
    make_world,
)
from repro.core.lexicon import FREQUENT, OTHER, STOP
from repro.core.proximity import ProximityEngine
from repro.search import ROUTE_MULTI, Query, SearchService


def _words_of_class(lex, cls, n, rng):
    ids = [
        int(w)
        for w in range(lex.n_words)
        if lex.lemma1[w] >= 0 and lex.lemma_class[lex.lemma1[w]] == cls
    ]
    rng.shuffle(ids)
    return ids[:n]


def run(scale: float = 0.5, world: World = None) -> List[Dict]:
    world = world or make_world(scale)
    ts = build_index_set(world, "set2", build_ordinary_all=True,
                         multi_k=None)  # no phrase queries in this bench
    eng = ProximityEngine(ts, window=3)
    lex = world.lexicon
    rng = np.random.RandomState(7)
    stop = _words_of_class(lex, STOP, 12, rng)
    freq = _words_of_class(lex, FREQUENT, 12, rng)
    other = _words_of_class(lex, OTHER, 12, rng)

    classes = {
        "stop_pair": [[stop[i], stop[i + 1]] for i in range(0, 10, 2)],
        "stop_triple": [[stop[i], stop[i + 1], stop[i + 2]] for i in range(0, 9, 3)],
        "freq_other": [[freq[i], other[i]] for i in range(5)],
        "freq_freq": [[freq[i], freq[i + 1]] for i in range(0, 10, 2)],
        "other_other": [[other[i], other[i + 1]] for i in range(0, 10, 2)],
    }
    rows: List[Dict] = []
    for cname, queries in classes.items():
        scan_add = scan_ord = t_add = t_ord = 0.0
        agree = True
        for q in queries:
            t0 = time.perf_counter()
            r1 = eng.search(q)
            t_add += time.perf_counter() - t0
            t0 = time.perf_counter()
            r2 = eng.search_ordinary(q)
            t_ord += time.perf_counter() - t0
            scan_add += r1.postings_scanned
            scan_ord += r2.postings_scanned
            agree &= set(r1.docs.tolist()) == set(r2.docs.tolist())
        n = len(queries)
        rows.append(
            {
                "bench": "search_speed",
                "class": cname,
                "queries": n,
                "add_scanned": int(scan_add / n),
                "ord_scanned": int(scan_ord / n),
                "scan_speedup": scan_ord / max(1.0, scan_add),
                "add_us": t_add / n * 1e6,
                "ord_us": t_ord / n * 1e6,
                "agree": agree,
            }
        )
    return rows


def _mixed_stream(lex, n_queries: int, rng) -> List[List[int]]:
    """A mixed multi-user query stream over all three planner routes, with
    the repeat structure of real traffic (hot keys recur across users)."""
    stop = _words_of_class(lex, STOP, 12, rng)
    freq = _words_of_class(lex, FREQUENT, 12, rng)
    other = _words_of_class(lex, OTHER, 12, rng)
    qs: List[List[int]] = []
    while len(qs) < n_queries:
        kind = len(qs) % 4
        if kind == 0:
            qs.append([rng.choice(stop), rng.choice(stop)])
        elif kind == 1:
            qs.append([rng.choice(stop), rng.choice(stop), rng.choice(stop)])
        elif kind == 2:
            qs.append([rng.choice(freq), rng.choice(other)])
        else:
            qs.append([rng.choice(other), rng.choice(other)])
    return [[int(w) for w in q] for q in qs]


def run_batched(
    scale: float = 0.5,
    world: World = None,
    n_queries: int = 64,
    backends=("numpy", "jax", "pallas"),
    repeats: int = 3,
) -> List[Dict]:
    """Per-query loop vs ``search_batch`` on the same query stream."""
    if n_queries < 1:
        raise ValueError(f"--queries must be >= 1, got {n_queries}")
    world = world or make_world(scale)
    ts = build_index_set(world, "set2", build_ordinary_all=False,
                         multi_k=None)  # no phrase queries in this bench
    lex = world.lexicon
    queries = _mixed_stream(lex, n_queries, np.random.RandomState(7))

    rows: List[Dict] = []
    for backend in backends:
        eng = ProximityEngine(ts, window=3, join=backend)
        svc = SearchService(ts, window=3, backend=backend)
        # warm both paths: jit compilation + posting cache fill, so the
        # timed section measures steady-state serving throughput
        loop_ref = [eng.search(q) for q in queries]
        batch_ref = svc.search_batch(queries)
        identical = all(
            np.array_equal(ref.docs, got.docs)
            and np.array_equal(ref.witnesses, got.witnesses)
            for ref, got in zip(loop_ref, batch_ref)
        )
        t_loop = min(
            _timed(lambda: [eng.search(q) for q in queries])
            for _ in range(repeats)
        )
        t_batch = min(
            _timed(lambda: svc.search_batch(queries)) for _ in range(repeats)
        )
        rows.append(
            {
                "bench": "search_speed_batched",
                "backend": backend,
                "queries": len(queries),
                "loop_qps": len(queries) / t_loop,
                "batch_qps": len(queries) / t_batch,
                "batch_speedup": t_loop / t_batch,
                "identical": identical,
            }
        )
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# ------------------------------------------------- multi-component route --
def _phrase_stream(world: World, n_queries: int, k: int, rng) -> List[Query]:
    """k-word phrase queries lifted from the real token stream (so they
    have occurrences), skipping all-stop windows (those take the even
    cheaper stop-sequence route, not the one under test)."""
    lex = world.lexicon
    toks, offs = world.parts[0]
    out: List[Query] = []
    while len(out) < n_queries:
        s = int(rng.randint(0, toks.shape[0] - k))
        words = tuple(int(t) for t in toks[s : s + k])
        _, cls = lex.classify_words(np.asarray(words, np.int64))
        if all(int(c) == STOP for c in cls):
            continue
        out.append(Query(words, phrase=True))
    return out


def _read_bytes(ts) -> int:
    return sum(s.read_bytes for s in ts.search_io().values())


def run_multi(
    scale: float = 0.5,
    world: World = None,
    n_queries: int = 64,
    repeats: int = 3,
) -> List[Dict]:
    """ROUTE_MULTI vs the ordinary-index join path on phrase queries.

    Both services run the numpy (oracle) backend with the posting cache
    disabled, so the reader ``search_io`` deltas are the true per-batch
    posting traffic of each path.
    """
    if n_queries < 1:
        raise ValueError(f"--queries must be >= 1, got {n_queries}")
    world = world or make_world(scale)
    ts = build_index_set(world, "set2", build_ordinary_all=False)
    k = ts.indexes["multi"].k
    queries = _phrase_stream(world, n_queries, k, np.random.RandomState(11))

    svc_multi = SearchService(ts, window=3, backend="numpy", cache_bytes=0)
    svc_ord = SearchService(ts, window=3, backend="numpy", cache_bytes=0,
                            use_multi=False)

    b0 = _read_bytes(ts)
    res_multi = svc_multi.search_batch(queries)
    multi_bytes = _read_bytes(ts) - b0
    b0 = _read_bytes(ts)
    res_ord = svc_ord.search_batch(queries)
    ord_bytes = _read_bytes(ts) - b0

    # identical answers (the ordinary path may carry duplicate witness
    # rows when a token's two lemma readings coincide — compare sets)
    identical = all(
        rm.route == ROUTE_MULTI
        and ro.route == "ordinary"
        and np.array_equal(rm.docs, ro.docs)
        and {tuple(x) for x in rm.witnesses.tolist()}
        == {tuple(x) for x in ro.witnesses.tolist()}
        for rm, ro in zip(res_multi, res_ord)
    )
    t_multi = min(
        _timed(lambda: svc_multi.search_batch(queries)) for _ in range(repeats)
    )
    t_ord = min(
        _timed(lambda: svc_ord.search_batch(queries)) for _ in range(repeats)
    )
    scanned_multi = sum(r.postings_scanned for r in res_multi)
    scanned_ord = sum(r.postings_scanned for r in res_ord)
    return [
        {
            "bench": "search_speed_multi",
            "queries": len(queries),
            "k": k,
            "multi_qps": len(queries) / t_multi,
            "ord_qps": len(queries) / t_ord,
            "multi_read_bytes": int(multi_bytes),
            "ord_read_bytes": int(ord_bytes),
            "bytes_ratio": ord_bytes / max(1, multi_bytes),
            "multi_scanned": int(scanned_multi),
            "ord_scanned": int(scanned_ord),
            "identical": identical,
        }
    ]


def main_multi(scale: float = 0.5, n_queries: int = 64) -> None:
    rows = run_multi(scale, n_queries=n_queries)
    r = rows[0]
    print(f"{'route':10s} {'qps':>10s} {'read_bytes':>12s} {'scanned':>10s}")
    print(f"{'multi':10s} {r['multi_qps']:>10,.0f} {r['multi_read_bytes']:>12,} "
          f"{r['multi_scanned']:>10,}")
    print(f"{'ordinary':10s} {r['ord_qps']:>10,.0f} {r['ord_read_bytes']:>12,} "
          f"{r['ord_scanned']:>10,}")
    print(f"{r['queries']} {r['k']}-word phrase queries; "
          f"bytes ratio ord/multi = {r['bytes_ratio']:.1f}x")
    assert r["identical"], "ROUTE_MULTI diverged from the ordinary-join oracle"
    assert r["multi_read_bytes"] < r["ord_read_bytes"], (
        "multi route must read strictly fewer posting bytes"
    )
    print("PASS  multi route matches the ordinary join and reads fewer bytes")


# ------------------------------------------------- top-k early termination --
def run_topk(
    scale: float = 0.5,
    world: World = None,
    n_queries: int = 64,
    top_k: int = 10,
    repeats: int = 3,
    verify_backends=("numpy", "jax", "pallas"),
    verify_shards=(1, 2, 4),
) -> List[Dict]:
    """``Query(top_k=N)`` streaming execution vs the exhaustive multi
    route on a hot-vocabulary phrase stream (arXiv:2009.02684).

    Both services run the numpy oracle backend with the posting cache
    disabled, so the reader ``search_io`` deltas are the true per-batch
    posting traffic; the acceptance gate is read bytes STRICTLY below the
    exhaustive path (early termination must actually skip chunks, not
    degrade to a full scan), with the top-k head element-wise identical
    across every join backend and shard count in ``verify_*``.
    """
    if n_queries < 1:
        raise ValueError(f"--queries must be >= 1, got {n_queries}")
    if top_k < 1:
        raise ValueError(f"--topk must be >= 1, got {top_k}")
    world = world or make_hot_world(scale)
    # hot-corpus geometry: small clusters/EM limit keep per-key lists
    # spanning several cursor chunks even at CI scale
    cfg_kw = HOT_GEOMETRY
    ts = build_index_set(world, "set2", **cfg_kw)
    k = ts.indexes["multi"].k
    base = _phrase_stream(world, n_queries, k, np.random.RandomState(11))
    topk_queries = [
        Query(q.words, phrase=True, top_k=top_k) for q in base
    ]

    svc_topk = SearchService(ts, window=3, backend="numpy", cache_bytes=0)
    svc_ex = SearchService(ts, window=3, backend="numpy", cache_bytes=0)

    b0 = _read_bytes(ts)
    res_topk = svc_topk.search_batch(topk_queries)
    topk_bytes = _read_bytes(ts) - b0
    trace = dict(svc_topk.last_trace["topk"])
    b0 = _read_bytes(ts)
    res_ex = svc_ex.search_batch(base)
    ex_bytes = _read_bytes(ts) - b0

    # the streamed head must equal the exhaustive head element-wise
    identical = all(
        rt.route == ROUTE_MULTI
        and np.array_equal(rt.docs, re.docs[:top_k])
        and np.array_equal(
            rt.witnesses,
            re.witnesses[np.isin(re.witnesses[:, 0], re.docs[:top_k])],
        )
        and np.array_equal(rt.scores, re.scores[:top_k])
        for rt, re in zip(res_topk, res_ex)
    )

    # ... and stay identical across join backends and shard counts
    verify_queries = topk_queries[: min(len(topk_queries), 16)]
    ref = res_topk[: len(verify_queries)]
    for n_shards in verify_shards:
        if n_shards == 1:
            substrate = ts
        else:
            substrate = build_sharded_index_set(
                world, "set2", n_shards=n_shards, **cfg_kw
            )
        for backend in verify_backends:
            svc = SearchService(substrate, window=3, backend=backend,
                                cache_bytes=0)
            got = svc.search_batch(verify_queries)
            identical &= all(
                np.array_equal(r.docs, g.docs)
                and np.array_equal(r.witnesses, g.witnesses)
                and np.array_equal(r.scores, g.scores)
                for r, g in zip(ref, got)
            )

    t_topk = min(
        _timed(lambda: svc_topk.search_batch(topk_queries))
        for _ in range(repeats)
    )
    t_ex = min(
        _timed(lambda: svc_ex.search_batch(base)) for _ in range(repeats)
    )
    return [
        {
            "bench": "search_speed_topk",
            "queries": len(base),
            "top_k": top_k,
            "topk_qps": len(base) / t_topk,
            "ex_qps": len(base) / t_ex,
            "topk_read_bytes": int(topk_bytes),
            "ex_read_bytes": int(ex_bytes),
            "bytes_ratio": topk_bytes / max(1, ex_bytes),
            "chunks_fetched": trace["chunks_fetched"],
            "chunks_skipped": trace["chunks_skipped"],
            "early_terminated": trace["early_terminated"],
            "identical": identical,
        }
    ]


def main_topk(scale: float = 0.5, n_queries: int = 64,
              top_k: int = 10) -> None:
    r = run_topk(scale, n_queries=n_queries, top_k=top_k)[0]
    print(f"{'mode':10s} {'qps':>10s} {'read_bytes':>12s}")
    print(f"{'top-' + str(r['top_k']):10s} {r['topk_qps']:>10,.0f} "
          f"{r['topk_read_bytes']:>12,}")
    print(f"{'exhaustive':10s} {r['ex_qps']:>10,.0f} "
          f"{r['ex_read_bytes']:>12,}")
    print(f"{r['queries']} phrase queries; read-bytes ratio "
          f"topk/exhaustive = {r['bytes_ratio']:.3f}; "
          f"{r['chunks_skipped']} chunks skipped "
          f"({r['early_terminated']} queries early-terminated)")
    assert r["identical"], (
        "top-k head diverged from the exhaustive sorted head"
    )
    assert r["chunks_skipped"] > 0, (
        "early termination must skip chunks, not degrade to a full scan"
    )
    assert r["topk_read_bytes"] < r["ex_read_bytes"], (
        "top-k must read strictly fewer posting bytes than exhaustive"
    )
    print("PASS  top-k head identical to exhaustive with strictly fewer "
          "read bytes")


# ----------------------------------------------------- ranked (WAND) top-k --
def run_ranked(
    scale: float = 0.5,
    world: World = None,
    n_queries: int = 48,
    top_k: int = 10,
    repeats: int = 3,
    verify_backends=("numpy", "jax", "pallas"),
    verify_shards=(1, 2, 4),
) -> List[Dict]:
    """``Query(top_k=N, rank="prox")`` — score-ordered best-k with the
    WAND-style threshold stop — vs the exhaustive ranked scan
    (arXiv:2108.00410 on top of the streaming executor).

    The exhaustive reference is the SAME ranked executor asked for a
    head larger than the collection: the threshold can never settle, so
    it drains every cursor, scores every match and sorts — an on-line
    exhaustive score-then-sort oracle.  Both services run numpy with the
    posting cache disabled so the reader ``search_io`` deltas are the
    true posting traffic; the acceptance gate is the ranked head
    element-wise identical (docs, scores, tie order, witnesses) at
    STRICTLY fewer read bytes, verified across every join backend and
    shard count in ``verify_*``.
    """
    if n_queries < 1:
        raise ValueError(f"--queries must be >= 1, got {n_queries}")
    if top_k < 1:
        raise ValueError(f"--ranked must be >= 1, got {top_k}")
    world = world or make_hot_world(scale)
    cfg_kw = HOT_GEOMETRY
    ts = build_index_set(world, "set2", **cfg_kw)
    k = ts.indexes["multi"].k
    base = _phrase_stream(world, n_queries, k, np.random.RandomState(13))
    ranked_queries = [
        Query(q.words, phrase=True, top_k=top_k, rank="prox") for q in base
    ]
    drain_k = 1 << 30  # >= any match count: the full ranked scan
    drain_queries = [
        Query(q.words, phrase=True, top_k=drain_k, rank="prox") for q in base
    ]

    svc_rk = SearchService(ts, window=3, backend="numpy", cache_bytes=0)
    svc_ex = SearchService(ts, window=3, backend="numpy", cache_bytes=0)

    b0 = _read_bytes(ts)
    res_rk = svc_rk.search_batch(ranked_queries)
    rk_bytes = _read_bytes(ts) - b0
    svc_rk.check_trace_complete()
    trace = dict(svc_rk.last_trace["topk"])
    b0 = _read_bytes(ts)
    res_ex = svc_ex.search_batch(drain_queries)
    ex_bytes = _read_bytes(ts) - b0

    # the pruned head must equal the exhaustive ranked scan's prefix
    # element-wise: docs, scores, tie order, and the head's witnesses
    identical = all(
        rt.route == ROUTE_MULTI
        and np.array_equal(rt.docs, re.docs[:top_k])
        and np.array_equal(rt.scores, re.scores[:top_k])
        and np.array_equal(
            rt.witnesses,
            re.witnesses[np.isin(re.witnesses[:, 0], re.docs[:top_k])],
        )
        for rt, re in zip(res_rk, res_ex)
    )

    # ... and stay identical across join backends and shard counts
    verify_queries = ranked_queries[: min(len(ranked_queries), 16)]
    ref = res_rk[: len(verify_queries)]
    for n_shards in verify_shards:
        if n_shards == 1:
            substrate = ts
        else:
            substrate = build_sharded_index_set(
                world, "set2", n_shards=n_shards, **cfg_kw
            )
        for backend in verify_backends:
            svc = SearchService(substrate, window=3, backend=backend,
                                cache_bytes=0)
            got = svc.search_batch(verify_queries)
            svc.check_trace_complete()
            identical &= all(
                np.array_equal(r.docs, g.docs)
                and np.array_equal(r.witnesses, g.witnesses)
                and np.array_equal(r.scores, g.scores)
                for r, g in zip(ref, got)
            )

    t_rk = min(
        _timed(lambda: svc_rk.search_batch(ranked_queries))
        for _ in range(repeats)
    )
    t_ex = min(
        _timed(lambda: svc_ex.search_batch(drain_queries))
        for _ in range(repeats)
    )
    return [
        {
            "bench": "search_speed_ranked",
            "queries": len(base),
            "top_k": top_k,
            "ranked_qps": len(base) / t_rk,
            "ex_qps": len(base) / t_ex,
            "ranked_read_bytes": int(rk_bytes),
            "ex_read_bytes": int(ex_bytes),
            "bytes_ratio": rk_bytes / max(1, ex_bytes),
            "chunks_fetched": trace["chunks_fetched"],
            "chunks_skipped": trace["chunks_skipped"],
            "threshold_stops": trace["threshold_stops"],
            "threshold_checks": trace["threshold_checks"],
            "identical": identical,
        }
    ]


def main_ranked(scale: float = 0.5, n_queries: int = 48,
                top_k: int = 10) -> None:
    r = run_ranked(scale, n_queries=n_queries, top_k=top_k)[0]
    print(f"{'mode':12s} {'qps':>10s} {'read_bytes':>12s}")
    print(f"{'ranked-' + str(r['top_k']):12s} {r['ranked_qps']:>10,.0f} "
          f"{r['ranked_read_bytes']:>12,}")
    print(f"{'full scan':12s} {r['ex_qps']:>10,.0f} "
          f"{r['ex_read_bytes']:>12,}")
    print(f"{r['queries']} ranked phrase queries; read-bytes ratio "
          f"ranked/exhaustive = {r['bytes_ratio']:.3f}; "
          f"{r['chunks_skipped']} chunks skipped "
          f"({r['threshold_stops']} threshold stops / "
          f"{r['threshold_checks']} checks)")
    assert r["identical"], (
        "ranked head diverged from the exhaustive score-then-sort scan"
    )
    assert r["chunks_skipped"] > 0, (
        "the WAND threshold stop must skip chunks, not drain every list"
    )
    assert r["ranked_read_bytes"] < r["ex_read_bytes"], (
        "ranked top-k must read strictly fewer posting bytes than the "
        "exhaustive ranked scan"
    )
    print("PASS  ranked head identical to the exhaustive ranked scan with "
          "strictly fewer read bytes")


# ------------------------------------------- hot-traffic chunk sharing --
def run_hot_traffic(
    scale: float = 0.5,
    world: World = None,
    n_queries: int = 256,
    top_k: int = 10,
    repeats: int = 3,
    n_distinct: int = 8,
    verify_backends=("numpy", "jax", "pallas"),
    verify_shards=(1, 2, 4),
) -> List[Dict]:
    """Hundreds of concurrent hot-vocabulary best-k queries: the
    cross-query :class:`~repro.search.pool.ChunkPool` vs one private
    cursor per query.

    The stream cycles ``n_distinct`` hot phrases (mixing plain top-k and
    ranked queries), so every batch hammers the same few multi-key
    posting streams — the regime where per-query cursors re-read the
    same chunks N times.  Both services run cache-disabled numpy so the
    reader ``search_io`` deltas are pure posting traffic; acceptance:

      * results element-wise identical to the unpooled baseline (and,
        for the first queries, across every backend × shard count with
        device decode on);
      * pooled read bytes <= 0.5x the baseline (hot batches must scale
        with unique chunks, not queries);
      * the dedup gate — a batch of N identical queries reads < 2x the
        bytes of a single-query batch, not Nx;
      * ``last_trace`` ledgers the sharing (``chunks_shared`` replays vs
        ``chunks_fetched`` unique fetches) and stays complete under the
        extended ``check_trace_complete`` partition.
    """
    if n_queries < 1:
        raise ValueError(f"--hot-traffic must be >= 1, got {n_queries}")
    world = world or make_hot_world(scale)
    cfg_kw = HOT_GEOMETRY
    ts = build_index_set(world, "set2", **cfg_kw)
    k = ts.indexes["multi"].k
    distinct = _phrase_stream(world, n_distinct, k,
                              np.random.RandomState(17))
    queries = [
        Query(distinct[i % len(distinct)].words, phrase=True, top_k=top_k,
              rank="prox" if i % 3 == 0 else None)
        for i in range(n_queries)
    ]

    svc_base = SearchService(ts, window=3, backend="numpy", cache_bytes=0,
                             share_chunks=False, device_decode=False)
    svc_pool = SearchService(ts, window=3, backend="numpy", cache_bytes=0,
                             share_chunks=True, device_decode=False)

    b0 = _read_bytes(ts)
    res_base = svc_base.search_batch(queries)
    base_bytes = _read_bytes(ts) - b0
    b0 = _read_bytes(ts)
    res_pool = svc_pool.search_batch(queries)
    pool_bytes = _read_bytes(ts) - b0
    trace = dict(svc_pool.last_trace["topk"])

    identical = all(
        np.array_equal(rb.docs, rp.docs)
        and np.array_equal(rb.witnesses, rp.witnesses)
        and np.array_equal(rb.scores, rp.scores)
        for rb, rp in zip(res_base, res_pool)
    )

    # ... and identical with the device decoder + device cache tier on,
    # across every join backend and shard count
    verify_queries = queries[: min(len(queries), 12)]
    ref = res_base[: len(verify_queries)]
    for n_shards in verify_shards:
        if n_shards == 1:
            substrate = ts
        else:
            substrate = build_sharded_index_set(
                world, "set2", n_shards=n_shards, **cfg_kw
            )
        for backend in verify_backends:
            svc = SearchService(substrate, window=3, backend=backend,
                                cache_bytes=1 << 20, share_chunks=True,
                                device_decode=backend in ("jax", "pallas"))
            got = svc.search_batch(verify_queries)
            svc.check_trace_complete()
            identical &= all(
                np.array_equal(r.docs, g.docs)
                and np.array_equal(r.witnesses, g.witnesses)
                and np.array_equal(r.scores, g.scores)
                for r, g in zip(ref, got)
            )

    # dedup gate: N identical hot queries must cost ~1x one query's I/O
    one = [queries[0]]
    many = [queries[0]] * max(8, min(n_queries, 64))
    svc1 = SearchService(ts, window=3, backend="numpy", cache_bytes=0,
                         share_chunks=True, device_decode=False)
    b0 = _read_bytes(ts)
    svc1.search_batch(one)
    b1 = _read_bytes(ts) - b0
    svcN = SearchService(ts, window=3, backend="numpy", cache_bytes=0,
                         share_chunks=True, device_decode=False)
    b0 = _read_bytes(ts)
    svcN.search_batch(many)
    bN = _read_bytes(ts) - b0

    # per-query latency: element-wise best over repeats (noise floor),
    # p99 across the batch's queries
    def _query_s(svc) -> np.ndarray:
        per_rep = []
        for _ in range(repeats):
            svc.search_batch(queries)
            per_rep.append(np.asarray(svc.last_trace["topk"]["query_s"]))
        return np.min(np.stack(per_rep), axis=0)

    base_s = _query_s(svc_base)
    pool_s = _query_s(svc_pool)
    p99_base = float(np.percentile(base_s, 99))
    p99_pool = float(np.percentile(pool_s, 99))

    return [
        {
            "bench": "search_speed_hot_traffic",
            "queries": n_queries,
            "distinct": len(distinct),
            "top_k": top_k,
            "base_read_bytes": int(base_bytes),
            "pool_read_bytes": int(pool_bytes),
            "bytes_ratio": pool_bytes / max(1, base_bytes),
            "chunks_fetched": trace["chunks_fetched"],
            "chunks_shared": trace["chunks_shared"],
            "pool_streams": trace["pool_streams"],
            "dedup_one_bytes": int(b1),
            "dedup_many": len(many),
            "dedup_many_bytes": int(bN),
            "p99_base_us": p99_base * 1e6,
            "p99_pool_us": p99_pool * 1e6,
            "identical": identical,
        }
    ]


def main_hot(scale: float = 0.5, n_queries: int = 256,
             top_k: int = 10) -> None:
    r = run_hot_traffic(scale, n_queries=n_queries, top_k=top_k)[0]
    print(f"{'mode':12s} {'read_bytes':>12s} {'p99_us':>10s}")
    print(f"{'per-query':12s} {r['base_read_bytes']:>12,} "
          f"{r['p99_base_us']:>10,.0f}")
    print(f"{'pooled':12s} {r['pool_read_bytes']:>12,} "
          f"{r['p99_pool_us']:>10,.0f}")
    print(f"{r['queries']} hot queries over {r['distinct']} distinct "
          f"phrases; bytes ratio pooled/per-query = {r['bytes_ratio']:.3f}; "
          f"{r['chunks_shared']} chunk replays over {r['chunks_fetched']} "
          f"unique fetches ({r['pool_streams']} pooled streams); "
          f"{r['dedup_many']} identical queries read {r['dedup_many_bytes']:,}"
          f" bytes vs {r['dedup_one_bytes']:,} for one")
    assert r["identical"], (
        "pooled results diverged from the per-query-cursor baseline"
    )
    assert r["chunks_shared"] > 0, (
        "a hot batch must replay pooled chunks, not open private drains"
    )
    assert r["bytes_ratio"] <= 0.5, (
        f"pooled read bytes must be <= 0.5x the per-query baseline, got "
        f"{r['bytes_ratio']:.3f}"
    )
    assert r["dedup_many_bytes"] < 2 * max(1, r["dedup_one_bytes"]), (
        f"{r['dedup_many']} identical queries read "
        f"{r['dedup_many_bytes']} bytes — more than 2x one query's "
        f"{r['dedup_one_bytes']}"
    )
    # device reads are SIMULATED (byte-accounted, zero wall time), so the
    # pool's wall-clock edge is only the skipped host decode work — gate
    # p99 against a real regression, not strict improvement in the noise
    if n_queries >= 100:
        assert r["p99_pool_us"] <= 1.10 * r["p99_base_us"], (
            f"pooled p99 {r['p99_pool_us']:.0f}us regressed over baseline "
            f"{r['p99_base_us']:.0f}us"
        )
    print("PASS  hot-traffic batch shares chunks across queries with "
          "identical results and <= 0.5x read bytes")


# ------------------------------------------------------ sharded substrate --
# Sharded read-I/O gate.  A raw sharded/unsharded byte RATIO is not
# scale-invariant: with doc-hash sharding every shard serves every
# (index, key) lookup, so the per-lookup FIXED costs — the 24-byte
# dictionary entry header, the key bytes, the shard's per-wave
# dictionary-group read — duplicate across shards while the posting
# payload splits.  At tiny corpora the duplicated fixed cost dominates
# (the old <= 1.1 ratio assert read 1.56 at trajectory scale and still
# 1.15-1.37 at 0.5 scale, tracking query mix, not regressions).  The
# honest, scale-invariant bound is on the MARGINAL overhead per extra
# shard per executed lookup: measured ~70-85 bytes across scales
# (entry header + key + amortized group-dictionary bytes), budgeted at
# 128 to leave headroom.  A real regression — duplicated posting
# payload, uncharged re-fetches — scales with payload bytes and blows
# through a fixed per-lookup budget at any corpus size, so this gate
# runs (and fails loudly) at trajectory scale too.
SHARDED_OVERHEAD_BUDGET_PER_LOOKUP = 128


def run_sharded(
    scale: float = 0.5,
    world: World = None,
    n_shards: int = 4,
    n_queries: int = 64,
    backend: str = "jax",
    repeats: int = 3,
) -> List[Dict]:
    """Sharded scatter/gather serving vs the unsharded set, same stream.

    Both services run with the posting cache disabled so the search-device
    deltas are the true per-batch posting traffic of each substrate; the
    sharded service uses the pipelined prefetch fetch stage (its default).
    """
    if n_shards < 1:
        raise ValueError(f"--shards must be >= 1, got {n_shards}")
    if n_queries < 1:
        raise ValueError(f"--queries must be >= 1, got {n_queries}")
    world = world or make_world(scale)
    ts = build_index_set(world, "set2", build_ordinary_all=False,
                         multi_k=None)  # mixed stream has no phrase queries
    sts = build_sharded_index_set(world, "set2", n_shards=n_shards,
                                  multi_k=None)
    queries = _mixed_stream(world.lexicon, n_queries, np.random.RandomState(7))

    svc_u = SearchService(ts, window=3, backend=backend, cache_bytes=0)
    svc_s = SearchService(sts, window=3, backend=backend, cache_bytes=0)

    b0 = _read_bytes(ts)
    ref = svc_u.search_batch(queries)  # also warms jit
    unsharded_bytes = _read_bytes(ts) - b0
    b0 = _read_bytes(sts)
    got = svc_s.search_batch(queries)
    sharded_bytes = _read_bytes(sts) - b0
    per_shard_bytes = [
        sum(s.read_bytes for s in shard_io.values())
        for shard_io in sts.search_io_per_shard()
    ]

    identical = all(
        np.array_equal(r.docs, g.docs)
        and np.array_equal(r.witnesses, g.witnesses)
        and r.lookups == g.lookups
        and r.postings_scanned == g.postings_scanned
        for r, g in zip(ref, got)
    )
    t_u = min(_timed(lambda: svc_u.search_batch(queries))
              for _ in range(repeats))
    t_s = min(_timed(lambda: svc_s.search_batch(queries))
              for _ in range(repeats))
    # per-shard serving rate: the batch size over the seconds THAT shard's
    # device fetches took inside the pipelined scatter stage (traced by the
    # service) — the balance view across shards
    shard_fetch_s = svc_s.last_trace.get("shard_fetch_s", [0.0] * n_shards)
    # per-lookup fixed-overhead budget for the bytes gate: each of the
    # n_shards-1 EXTRA shards re-pays the fixed dictionary cost of every
    # executed lookup (the posting payload itself splits across shards)
    # planned = fetched + deferred-to-streaming; both end up paying the
    # per-shard fixed dictionary cost once
    lookups_fetched = int(svc_s.last_trace.get("lookups_planned", 0))
    overhead_budget = (
        (n_shards - 1) * lookups_fetched * SHARDED_OVERHEAD_BUDGET_PER_LOOKUP
    )
    rows: List[Dict] = [
        {
            "bench": "search_speed_sharded",
            "shard": s,
            "n_shards": n_shards,
            "queries": len(queries),
            "shard_qps": len(queries) / max(1e-9, shard_fetch_s[s]),
            "read_bytes": int(per_shard_bytes[s]),
        }
        for s in range(n_shards)
    ]
    rows.append(
        {
            "bench": "search_speed_sharded",
            "shard": "aggregate",
            "n_shards": n_shards,
            "queries": len(queries),
            "sharded_qps": len(queries) / t_s,
            "unsharded_qps": len(queries) / t_u,
            "sharded_read_bytes": int(sharded_bytes),
            "unsharded_read_bytes": int(unsharded_bytes),
            "bytes_ratio": sharded_bytes / max(1, unsharded_bytes),
            "overhead_bytes": int(sharded_bytes - unsharded_bytes),
            "overhead_budget_bytes": int(overhead_budget),
            "overhead_per_lookup_per_shard": round(
                (sharded_bytes - unsharded_bytes)
                / max(1, (n_shards - 1) * lookups_fetched), 1),
            "prefetched_waves": svc_s.last_trace.get("prefetched_waves", 0),
            "identical": identical,
        }
    )
    return rows


def main_sharded(scale: float = 0.5, n_queries: int = 64,
                 n_shards: int = 4, backend: str = "jax") -> None:
    rows = run_sharded(scale, n_shards=n_shards, n_queries=n_queries,
                       backend=backend)
    agg = rows[-1]
    print(f"{'shard':>9s} {'qps':>12s} {'read_bytes':>12s}")
    for r in rows[:-1]:
        print(f"{r['shard']:>9d} {r['shard_qps']:>12,.0f} "
              f"{r['read_bytes']:>12,}")
    print(f"{'aggregate':>9s} {agg['sharded_qps']:>12,.0f} "
          f"{agg['sharded_read_bytes']:>12,}")
    print(f"unsharded baseline: {agg['unsharded_qps']:,.0f} qps, "
          f"{agg['unsharded_read_bytes']:,} read bytes "
          f"(sharded/unsharded bytes ratio {agg['bytes_ratio']:.3f}, "
          f"{agg['prefetched_waves']} prefetched waves)")
    assert agg["identical"], "sharded results diverged from unsharded"
    assert agg["overhead_bytes"] <= agg["overhead_budget_bytes"], (
        f"sharding inflated read I/O beyond the fixed per-shard "
        f"dictionary overhead: {agg['overhead_bytes']:,} extra bytes > "
        f"budget {agg['overhead_budget_bytes']:,} "
        f"({SHARDED_OVERHEAD_BUDGET_PER_LOOKUP} B x {n_shards - 1} extra "
        f"shards x planned lookups); payload bytes are duplicating, not "
        f"splitting"
    )
    print(f"PASS  {n_shards}-shard scatter/gather matches unsharded "
          f"results; sharding overhead {agg['overhead_bytes']:,} B is "
          f"within the fixed per-lookup budget "
          f"{agg['overhead_budget_bytes']:,} B "
          f"({agg['overhead_per_lookup_per_shard']} B/lookup/extra-shard; "
          f"raw bytes ratio {agg['bytes_ratio']:.3f} is recorded for the "
          f"trajectory but not gated — it is not scale-invariant)")


# ------------------------------------------------------ replica fabric --
def _fault_after(n: int):
    """One-shot injected fault: the replica serves ``n`` more ops, then
    dies mid-batch (the fabric must fail the batch over to a sibling)."""
    from repro.search import ReplicaDeadError

    served = [0]

    def fault(rep, op):
        served[0] += 1
        if served[0] > n:
            raise ReplicaDeadError(f"injected after {n} serves")

    return fault


def run_replicas(
    scale: float = 0.5,
    world: World = None,
    n_replicas: int = 3,
    n_queries: int = 64,
    backend: str = "numpy",
    repeats: int = 3,
) -> List[Dict]:
    """Replica read tier: N replicas per shard behind the fabric scatter.

    Capacity model: every replica accumulates the REAL seconds it spends
    serving (``busy_s``); with the writer's work fixed, the serving
    capacity of the tier is ``queries / max-per-replica busy`` — the
    slowest replica is the bottleneck, so balanced routing over N
    replicas multiplies capacity by ~N.  Caches are off so the charge
    model is deterministic and every replica pays its own device reads
    (the bytes-balance secondary signal).

    Identity: the fabric batch — including a replica killed mid-batch by
    an injected fault — must stay element-wise identical to the plain
    single-reader path.
    """
    from repro.search import ReplicaSetReader

    if n_replicas < 1:
        raise ValueError(f"--replicas must be >= 1, got {n_replicas}")
    world = world or make_world(scale)
    ts = build_index_set(world, "set2", multi_k=None)
    queries = _mixed_stream(world.lexicon, n_queries,
                            np.random.RandomState(7))
    ref = SearchService(ts, window=3, backend="numpy",
                        cache_bytes=0).search_batch(queries)

    def identical(got):
        return all(
            np.array_equal(r.docs, g.docs)
            and np.array_equal(r.witnesses, g.witnesses)
            and r.postings_scanned == g.postings_scanned
            for r, g in zip(ref, got)
        )

    rows: List[Dict] = []
    capacity: Dict[int, float] = {}
    for n in sorted({1, n_replicas}):
        fab = ReplicaSetReader(ts, n_replicas=n, cache_bytes=0)
        svc = SearchService(fab, window=3, backend=backend, cache_bytes=0)
        ok = identical(svc.search_batch(queries))  # also warms jit
        for row in fab.replicas:
            for rep in row:
                rep.busy_s = 0.0
        t_wall = 0.0
        for _ in range(repeats):
            t_wall += _timed(lambda: svc.search_batch(queries))
        busy = [rep.busy_s for row in fab.replicas for rep in row]
        cap = repeats * len(queries) / max(1e-9, max(busy))
        capacity[n] = cap
        per_rep_bytes = [b for row in fab.read_bytes_per_replica()
                         for b in row]
        rows.append({
            "bench": "search_speed_replicas",
            "n_replicas": n,
            "queries": len(queries),
            "capacity_qps": cap,
            "wall_qps": repeats * len(queries) / t_wall,
            "busy_s_per_replica": [round(b, 4) for b in busy],
            "read_bytes_per_replica": per_rep_bytes,
            "bytes_balance": max(per_rep_bytes) / max(1.0, (
                sum(per_rep_bytes) / len(per_rep_bytes)
            )),
            "failovers": fab.failovers,
            "identical": ok,
        })

    # per-query latency distribution through the full fabric (p99 is the
    # serving-tier health number the trajectory artifact tracks)
    fab = ReplicaSetReader(ts, n_replicas=n_replicas, cache_bytes=0)
    svc = SearchService(fab, window=3, backend=backend, cache_bytes=0)
    svc.search_batch(queries)
    lat = sorted(_timed(lambda q=q: svc.search_batch([q])) for q in queries)
    p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))]

    summary = {
        "bench": "search_speed_replicas",
        "n_replicas": "summary",
        "queries": len(queries),
        "capacity_qps_1": capacity[1],
        "capacity_qps_n": capacity[n_replicas],
        "capacity_ratio": capacity[n_replicas] / max(1e-9, capacity[1]),
        "p99_ms": p99 * 1e3,
        "identical": all(r["identical"] for r in rows),
    }
    rows.append(summary)
    return rows


def run_replica_identity_sweep(
    scale: float = 0.5,
    world: World = None,
    n_queries: int = 16,
    n_replicas: int = 2,
    backends=("numpy", "jax", "pallas"),
    shard_counts=(1, 2, 4),
) -> List[Dict]:
    """The failover oracle sweep: every backend × shard count serves the
    same stream through the fabric WITH one replica killed mid-batch —
    results must stay element-wise identical to the unsharded numpy
    single-reader reference."""
    from repro.search import ReplicaSetReader

    world = world or make_world(scale)
    queries = _mixed_stream(world.lexicon, n_queries,
                            np.random.RandomState(11))
    ts = build_index_set(world, "set2", multi_k=None)
    ref = SearchService(ts, window=3, backend="numpy",
                        cache_bytes=0).search_batch(queries)
    subs = {1: ts}
    for n in shard_counts:
        if n > 1:
            subs[n] = build_sharded_index_set(world, "set2", n_shards=n,
                                              multi_k=None)
    rows: List[Dict] = []
    for n_shards in shard_counts:
        for backend in backends:
            fab = ReplicaSetReader(subs[n_shards], n_replicas=n_replicas,
                                   cache_bytes=0)
            svc = SearchService(fab, window=3, backend=backend,
                                cache_bytes=0)
            fab.replicas[0][0].fault = _fault_after(3)
            got = svc.search_batch(queries)
            ok = all(
                np.array_equal(r.docs, g.docs)
                and np.array_equal(r.witnesses, g.witnesses)
                for r, g in zip(ref, got)
            )
            rows.append({
                "bench": "search_speed_replica_sweep",
                "n_shards": n_shards,
                "backend": backend,
                "failovers": fab.failovers,
                "dead": sum(not rep.live for row in fab.replicas
                            for rep in row),
                "identical": ok,
            })
    return rows


def main_replicas(scale: float = 0.5, n_queries: int = 64,
                  n_replicas: int = 3, backend: str = "numpy") -> None:
    world = make_world(scale)
    rows = run_replicas(scale, world=world, n_replicas=n_replicas,
                        n_queries=n_queries, backend=backend)
    summary = rows[-1]
    print(f"{'replicas':>8s} {'capacity_qps':>13s} {'wall_qps':>10s} "
          f"{'bytes_bal':>9s} {'identical':>9s}")
    for r in rows[:-1]:
        print(f"{r['n_replicas']:>8d} {r['capacity_qps']:>13,.0f} "
              f"{r['wall_qps']:>10,.0f} {r['bytes_balance']:>9.2f} "
              f"{str(r['identical']):>9s}")
    print(f"capacity ratio x{n_replicas}/x1: "
          f"{summary['capacity_ratio']:.2f} "
          f"(p99 {summary['p99_ms']:.2f} ms)")

    sweep = run_replica_identity_sweep(scale, world=world,
                                       n_replicas=max(2, n_replicas // 2 + 1))
    for r in sweep:
        print(f"  sweep shards={r['n_shards']} backend={r['backend']:6s} "
              f"failovers={r['failovers']} identical={r['identical']}")

    assert summary["identical"], "fabric results diverged from single-reader"
    assert all(r["identical"] for r in sweep), (
        "failover sweep diverged from the reference"
    )
    assert all(r["failovers"] >= 1 for r in sweep), (
        "the injected fault must actually force a failover"
    )
    # capacity gate: balanced routing over N replicas must multiply the
    # serving capacity — >= 1.5x at N=3 (the acceptance gate), and at
    # least a clear win for any N > 1
    gate = 1.5 if n_replicas >= 3 else 1.2
    assert summary["capacity_ratio"] >= gate, (
        f"capacity ratio {summary['capacity_ratio']:.2f} < {gate} "
        f"at {n_replicas} replicas"
    )
    print(f"PASS  {n_replicas}-replica fabric serves identical results "
          f"(incl. mid-batch failover) at {summary['capacity_ratio']:.2f}x "
          f"the single-replica capacity")


def main_batched(scale: float = 0.5, n_queries: int = 64) -> None:
    rows = run_batched(scale, n_queries=n_queries)
    print(f"{'backend':8s} {'queries':>8s} {'loop_qps':>10s} {'batch_qps':>10s} "
          f"{'speedup':>8s}")
    for r in rows:
        print(
            f"{r['backend']:8s} {r['queries']:>8d} {r['loop_qps']:>10,.0f} "
            f"{r['batch_qps']:>10,.0f} {r['batch_speedup']:>8.2f}"
        )
    assert all(r["identical"] for r in rows), (
        "search_batch diverged from the per-query loop"
    )
    assert max(r["batch_speedup"] for r in rows) > 1.0, (
        "batched execution should beat the per-query loop"
    )
    print("PASS  search_batch matches the per-query loop and is faster")


def main(scale: float = 0.5) -> None:
    rows = run(scale)
    print(
        f"{'class':12s} {'add_scan':>9s} {'ord_scan':>9s} {'speedup':>8s} "
        f"{'add_us':>9s} {'ord_us':>9s} {'agree':>6s}"
    )
    for r in rows:
        print(
            f"{r['class']:12s} {r['add_scanned']:>9,} {r['ord_scanned']:>9,} "
            f"{r['scan_speedup']:>8.1f} {r['add_us']:>9.0f} {r['ord_us']:>9.0f} "
            f"{str(r['agree']):>6s}"
        )
    assert all(r["agree"] for r in rows)
    fast = [r for r in rows if r["class"] in ("stop_pair", "stop_triple", "freq_other", "freq_freq")]
    assert min(r["scan_speedup"] for r in fast) > 3
    print("PASS  additional indexes agree with, and scan far less than, ordinary")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--batched", action="store_true",
                    help="batched SearchService qps vs per-query loop")
    ap.add_argument("--multi", action="store_true",
                    help="multi-component key route vs ordinary join "
                         "on phrase queries")
    ap.add_argument("--topk", type=int, default=0,
                    help="N: top-k early-termination streaming executor "
                         "vs the exhaustive multi route on a hot phrase "
                         "stream (qps + read-bytes ratio; verifies the "
                         "head across backends and shard counts)")
    ap.add_argument("--ranked", type=int, default=0,
                    help="N: score-ordered (rank='prox') top-k with the "
                         "WAND threshold stop vs the exhaustive ranked "
                         "scan on a hot phrase stream (qps + read-bytes "
                         "ratio; head identity-verified across backends "
                         "and shard counts)")
    ap.add_argument("--hot-traffic", type=int, default=0,
                    help="C: C concurrent hot-vocabulary top-k/ranked "
                         "queries through the cross-query chunk pool vs "
                         "one private cursor per query (read-bytes + p99 "
                         "latency; identity-verified across backends and "
                         "shard counts, dedup gate on identical queries)")
    ap.add_argument("--shards", type=int, default=0,
                    help="N-shard scatter/gather SearchService vs the "
                         "unsharded set, both through search_batch; "
                         "composes with --batched (the sharded bench IS "
                         "the batched comparison)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="N: N-replica read fabric vs a single reader — "
                         "per-replica busy-seconds capacity model, "
                         "bytes-balance, p99, and the failover oracle "
                         "sweep (every backend × shard count with one "
                         "replica killed mid-batch)")
    ap.add_argument("--backend", default="jax",
                    help="join backend for --shards (numpy/jax/pallas)")
    ap.add_argument("--queries", type=int, default=64)
    args = ap.parse_args()
    if args.replicas:
        main_replicas(args.scale, n_queries=args.queries,
                      n_replicas=args.replicas, backend=args.backend)
    elif args.shards:
        # --shards compares batched serving on both substrates, so
        # `--shards N --batched` is the canonical spelling; --batched
        # alone keeps its loop-vs-batch meaning below
        main_sharded(args.scale, n_queries=args.queries,
                     n_shards=args.shards, backend=args.backend)
    elif args.batched:
        main_batched(args.scale, n_queries=args.queries)
    elif args.multi:
        main_multi(args.scale, n_queries=args.queries)
    elif args.topk:
        main_topk(args.scale, n_queries=args.queries, top_k=args.topk)
    elif args.ranked:
        main_ranked(args.scale, n_queries=args.queries, top_k=args.ranked)
    elif args.hot_traffic:
        main_hot(args.scale, n_queries=args.hot_traffic)
    else:
        main(args.scale)
