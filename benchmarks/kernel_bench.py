"""Kernel microbench: Pallas (interpret mode) vs jnp reference.

Interpret mode runs the kernel body in Python, so wall-times here are NOT
TPU estimates — correctness deltas and the ref-path timings are the
useful numbers on this container; the same harness runs on TPU unchanged.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(scale: float = 1.0) -> Tuple[List[Dict], List[str]]:
    rng = np.random.RandomState(0)
    rows: List[Dict] = []

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref

    B, H, S, D = 1, 2, 256, 64
    q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    err = float(jnp.abs(
        flash_attention(q, k, v) - flash_attention_ref(q, k, v)
    ).max())
    rows.append({
        "bench": "kernels", "kernel": "flash_attention",
        "shape": f"B{B}H{H}S{S}D{D}",
        "ref_us": _time(flash_attention_ref, q, k, v),
        "max_err": err,
    })

    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref

    q1 = jnp.asarray(rng.randn(4, 8, 64), jnp.float32)
    kp = jnp.asarray(rng.randn(32, 16, 64), jnp.float32)
    vp = jnp.asarray(rng.randn(32, 16, 64), jnp.float32)
    bt = jnp.asarray(rng.choice(32, size=(4, 6)), jnp.int32)
    ln = jnp.asarray([90, 40, 96, 10], jnp.int32)
    err = float(jnp.abs(
        paged_attention(q1, kp, vp, bt, ln)
        - paged_attention_ref(q1, kp, vp, bt, ln)
    ).max())
    rows.append({
        "bench": "kernels", "kernel": "paged_attention",
        "shape": "B4H8D64P16", "ref_us": _time(paged_attention_ref, q1, kp, vp, bt, ln),
        "max_err": err,
    })

    from repro.kernels.embedding_bag.ops import embedding_bag_fixed
    from repro.kernels.embedding_bag.ref import embedding_bag_fixed_ref

    tb = jnp.asarray(rng.randn(1000, 128), jnp.float32)
    ids = jnp.asarray(rng.randint(0, 1000, (64, 8)), jnp.int32)
    w = jnp.asarray(rng.rand(64, 8), jnp.float32)
    err = float(jnp.abs(
        embedding_bag_fixed(tb, ids, w) - embedding_bag_fixed_ref(tb, ids, w)
    ).max())
    rows.append({
        "bench": "kernels", "kernel": "embedding_bag",
        "shape": "V1000D128B64K8",
        "ref_us": _time(embedding_bag_fixed_ref, tb, ids, w),
        "max_err": err,
    })

    from repro.kernels.intersect.ops import intersect_sorted
    from repro.kernels.intersect.ref import intersect_sorted_ref

    a = jnp.asarray(np.unique(rng.randint(0, 100_000, 4096)), jnp.int32)
    b = jnp.asarray(np.unique(rng.randint(0, 100_000, 8192)), jnp.int32)
    agree = bool(
        (np.asarray(intersect_sorted(a, b))
         == np.asarray(intersect_sorted_ref(a, b))).all()
    )
    rows.append({
        "bench": "kernels", "kernel": "intersect",
        "shape": f"N{a.shape[0]}M{b.shape[0]}",
        "ref_us": _time(intersect_sorted_ref, a, b),
        "max_err": 0.0 if agree else 1.0,
    })

    ok = all(r["max_err"] < 2e-2 for r in rows)
    return rows, [
        f"{'PASS' if ok else 'FAIL'}  all Pallas kernels match their oracles"
    ]


def main():
    rows, verdicts = run()
    for r in rows:
        print(r)
    for v in verdicts:
        print(v)


if __name__ == "__main__":
    main()
