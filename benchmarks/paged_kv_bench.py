"""Paged-KV bench (beyond-paper: the TPU adaptation of CH/S/SR).

Measures, under a simulated serving workload (Poisson arrivals, random
lengths), how the paper's strategies control the serving-side analogues
of its I/O metrics:

  * gather depth (== bounded chain length, paper 5.7.3),
  * fragmentation (contiguity, the S-strategy objective),
  * compaction traffic (CH->S conversion cost),

for several chain limits — the serving twin of ``chain_sweep``.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.paged_kv import PagedKVManager


def simulate(chain_limit: int, seed: int = 0,
             steps: int = 2000) -> Dict[str, float]:
    rng = np.random.RandomState(seed)
    m = PagedKVManager(n_pages=8192, page_size=16, chain_limit=chain_limit)
    next_id = 0
    active: List[int] = []
    depth_samples = []
    for t in range(steps):
        # arrivals
        if len(active) < 48 and rng.rand() < 0.5:
            m.new_sequence(next_id)
            active.append(next_id)
            next_id += 1
        # decode progress: every active sequence appends a few tokens
        for s in list(active):
            m.append_tokens(s, int(rng.randint(1, 9)))
            if rng.rand() < 0.01:  # completion
                m.free_sequence(s)
                active.remove(s)
        if active and t % 20 == 0:
            depth_samples.append(
                np.mean([m.gather_depth(s) for s in active])
            )
    return {
        "chain_limit": chain_limit,
        "mean_gather_depth": float(np.mean(depth_samples)),
        "max_gather_depth": m.stats.max_gather_depth,
        "fragmentation": m.fragmentation(),
        "compactions": m.stats.compactions,
        "compaction_pages_moved": m.stats.compaction_pages_moved,
        "pages_allocated": m.stats.pages_allocated,
    }


def run(scale: float = 1.0) -> Tuple[List[Dict], List[str]]:
    rows = []
    for limit in (2, 4, 9, 16):
        r = simulate(limit)
        r["bench"] = "paged_kv"
        rows.append(r)
    ok_bound = all(r["max_gather_depth"] <= r["chain_limit"] for r in rows)
    # trade-off direction: higher limit -> fewer compaction moves,
    # deeper gathers
    moves = [r["compaction_pages_moved"] for r in rows]
    depths = [r["mean_gather_depth"] for r in rows]
    ok_trade = moves[0] >= moves[-1] and depths[0] <= depths[-1] + 1e-9
    verdicts = [
        f"{'PASS' if ok_bound else 'FAIL'}  gather depth bounded by chain limit",
        f"{'PASS' if ok_trade else 'FAIL'}  compaction/gather trade-off moves "
        f"with the limit (paper 5.7.3 on device)",
    ]
    return rows, verdicts


def main():
    rows, verdicts = run()
    for r in rows:
        print(r)
    for v in verdicts:
        print(v)


if __name__ == "__main__":
    main()
