"""Durability cost model: WAL fsync, crash recovery, compaction payoff.

Three questions the on-disk backend (:mod:`repro.store`) must answer
with numbers:

  * **What does durability cost at apply time?**  The same part
    sequence lands in a plain in-memory substrate, a WAL-fed store
    without fsync, and one with fsync — and because serving I/O never
    routes through the store, the simulated build charges must be
    IDENTICAL across all three (the parity-by-construction gate).
  * **What does recovery cost?**  Replay reopen time is measured after
    every part (recovery work vs WAL length), then a checkpoint is
    published and the checkpoint+tail reopen is timed against the full
    replay it replaces.  The recovered store must serve element-wise
    identical results.
  * **What does compaction buy?**  A cold query sweep is charged
    before and after one background-compaction cycle: the folded
    layout must never read MORE simulated bytes, while results stay
    identical.

Usage:
    PYTHONPATH=src python -m benchmarks.durability \
        [--scale S] [--queries N] [--parts P] [--shards K]
"""

from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from benchmarks.common import World, bench_index_config, make_world
from benchmarks.search_speed import _mixed_stream
from repro.core.sharded_set import ShardedTextIndexSet
from repro.search import SearchService
from repro.store import DurableIndexStore


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _io_sig(report) -> dict:
    return {
        name: (st.read_bytes, st.read_ops, st.write_bytes, st.write_ops)
        for name, st in report.items()
    }


def _cold_serve(sub, queries, backend):
    """One cold-cache batch; returns (results, simulated read bytes)."""
    svc = SearchService(sub, window=3, backend=backend, cache_bytes=1)
    before = sum(st.read_bytes for st in sub.search_io().values())
    res = svc.search_batch(queries)
    return res, sum(st.read_bytes for st in sub.search_io().values()) - before


def _same(a, b) -> bool:
    return all(
        np.array_equal(r.docs, g.docs)
        and np.array_equal(r.witnesses, g.witnesses)
        for r, g in zip(a, b)
    )


def run(
    scale: float = 0.5,
    world: World = None,
    n_queries: int = 32,
    n_parts: int = 4,
    n_shards: int = 2,
    backend: str = "numpy",
    workdir: str = None,
) -> List[Dict]:
    if n_parts < 2:
        raise ValueError(f"--parts must be >= 2, got {n_parts}")
    world = world or make_world(scale, n_parts=n_parts)
    # same no-multi rationale as update_speed; the smaller cluster and
    # TAG extraction threshold push hot keys into dedicated multi-unit
    # streams even at tier-1 smoke scale — compaction needs something
    # to fold
    cfg = bench_index_config("set2", multi_k=None, cluster_size=512,
                             tag_extract_bytes=512)
    lex = world.lexicon
    queries = _mixed_stream(lex, n_queries, np.random.RandomState(7))
    root = Path(workdir or tempfile.mkdtemp(prefix="repro-durability-"))
    rows: List[Dict] = []
    try:
        # ---- apply cost: sim vs WAL vs WAL+fsync -------------------------
        subs = {
            "sim": ShardedTextIndexSet(cfg, lex, n_shards=n_shards, seed=0),
            "wal": DurableIndexStore(root / "wal", cfg, lex,
                                     n_shards=n_shards, fsync=False),
            "wal_fsync": DurableIndexStore(root / "fsync", cfg, lex,
                                           n_shards=n_shards, fsync=True),
        }
        apply_s = {}
        for mode, sub in subs.items():
            def land(sub=sub):
                for part, d0 in zip(world.parts, world.doc_starts):
                    sub.add_documents(*part, d0)
            apply_s[mode] = _timed(land)
        parity = all(
            _io_sig(subs[m].build_io()) == _io_sig(subs["sim"].build_io())
            for m in ("wal", "wal_fsync")
        )
        for mode, sub in subs.items():
            st = sub.stats() if hasattr(sub, "stats") else {}
            rows.append({
                "bench": "durability", "mode": f"apply_{mode}",
                "shards": n_shards, "parts": len(world.parts),
                "apply_s": round(apply_s[mode], 4),
                "fsync_overhead": round(
                    apply_s[mode] / max(1e-9, apply_s["wal"]), 2),
                "wal_bytes": st.get("wal_bytes", 0),
                "wal_syncs": st.get("wal_syncs", 0),
                "charge_parity": parity,
            })
        subs["wal_fsync"].close()

        # ---- recovery time vs WAL length ---------------------------------
        # the "wal" store's directory is reopened read-side after every
        # part-count prefix: replay work grows with the log
        writer = DurableIndexStore(root / "grow", cfg, lex,
                                   n_shards=n_shards, fsync=False)
        replay_s = []
        for i, (part, d0) in enumerate(zip(world.parts, world.doc_starts)):
            writer.add_documents(*part, d0)
            re = {}
            replay_s.append(_timed(lambda: re.setdefault("s", DurableIndexStore(
                root / "grow", cfg, lex, n_shards=n_shards, fsync=False,
                recovery="replay"))))
            re["s"].close()
            rows.append({
                "bench": "durability", "mode": "replay_reopen",
                "shards": n_shards, "parts": i + 1,
                "wal_bytes": writer.wal.tell(),
                "reopen_s": round(replay_s[-1], 4),
            })
        # final replay reopen must serve element-wise what the writer does
        reopened = DurableIndexStore(root / "grow", cfg, lex,
                                     n_shards=n_shards, fsync=False,
                                     recovery="replay")
        recovered_identical = (
            reopened.generation_vector() == writer.generation_vector()
            and _io_sig(reopened.build_io()) == _io_sig(writer.build_io())
            and _same(_cold_serve(reopened, queries, backend)[0],
                      _cold_serve(writer, queries, backend)[0])
        )
        reopened.close()

        writer.checkpoint()
        ck = {}
        ckpt_s = _timed(lambda: ck.setdefault("s", DurableIndexStore(
            root / "grow", cfg, lex, n_shards=n_shards, fsync=False)))
        ckpt_identical = (
            ck["s"].recovery_info["from_checkpoint"]
            and _same(_cold_serve(ck["s"], queries, backend)[0],
                      _cold_serve(writer, queries, backend)[0])
        )
        ck["s"].close()
        rows.append({
            "bench": "durability", "mode": "checkpoint_reopen",
            "shards": n_shards, "parts": len(world.parts),
            "wal_bytes": writer.wal.tell(),
            "reopen_s": round(ckpt_s, 4),
            "replay_s": round(replay_s[-1], 4),
            "speedup": round(replay_s[-1] / max(1e-9, ckpt_s), 2),
            "identical": recovered_identical and ckpt_identical,
        })

        # ---- compaction payoff: cold read bytes before vs after ----------
        ref, bytes_before = _cold_serve(writer, queries, backend)
        writer.compact()
        comp = writer.compaction_stats()
        got, bytes_after = _cold_serve(writer, queries, backend)
        rows.append({
            "bench": "durability", "mode": "compaction",
            "shards": n_shards, "parts": len(world.parts),
            "compactions": comp["compactions"],
            "compacted_streams": comp["compacted_streams"],
            "read_bytes_before": bytes_before,
            "read_bytes_after": bytes_after,
            "bytes_ratio": round(bytes_after / max(1, bytes_before), 4),
            "identical": _same(ref, got),
        })
        subs["wal"].close()
        writer.close()
    finally:
        if workdir is None:
            shutil.rmtree(root, ignore_errors=True)
    return rows


def main(scale: float = 0.5, n_queries: int = 32, n_parts: int = 4,
         n_shards: int = 2) -> None:
    rows = run(scale, n_queries=n_queries, n_parts=n_parts,
               n_shards=n_shards)
    by_mode = {r["mode"]: r for r in rows}
    print(f"{'mode':18s} {'parts':>5s} {'wal_bytes':>10s} "
          f"{'seconds':>8s} {'note':s}")
    for r in rows:
        note = ""
        if r["mode"].startswith("apply_"):
            note = f"{r['fsync_overhead']}x vs wal, {r['wal_syncs']} fsyncs"
            secs = r["apply_s"]
        elif "reopen" in r["mode"]:
            secs = r["reopen_s"]
            if r["mode"] == "checkpoint_reopen":
                note = f"{r['speedup']}x vs full replay"
        else:
            secs = 0.0
            note = (f"{r['compacted_streams']} stream(s) folded, "
                    f"{r['bytes_ratio']}x cold read bytes")
        print(f"{r['mode']:18s} {r['parts']:>5d} "
              f"{r.get('wal_bytes', 0):>10,} {secs:>8.3f} {note}")

    a = by_mode["apply_wal_fsync"]
    assert a["charge_parity"], (
        "durable stores must charge the simulated devices exactly like "
        "the in-memory substrate"
    )
    assert a["wal_syncs"] == a["parts"], (
        f"every part must fsync exactly once ({a['wal_syncs']} syncs for "
        f"{a['parts']} parts)"
    )
    ck = by_mode["checkpoint_reopen"]
    assert ck["identical"], (
        "recovered stores must serve element-wise identical results"
    )
    # a timing sanity bound, not a perf race: bulk-applying the snapshot
    # must be in the same ballpark as replay at CI scale, never a blowup
    assert ck["reopen_s"] < 2 * ck["replay_s"] + 0.5, (
        "checkpoint+tail reopen blew up vs a full WAL replay "
        f"({ck['reopen_s']:.3f}s vs {ck['replay_s']:.3f}s)"
    )
    co = by_mode["compaction"]
    assert co["identical"], "compaction must not change any result"
    assert co["compacted_streams"] >= 1, "the cycle must fold something"
    assert co["read_bytes_after"] <= co["read_bytes_before"], (
        "a folded layout must never read MORE simulated bytes "
        f"({co['read_bytes_after']} vs {co['read_bytes_before']})"
    )
    print(f"PASS  durability charged zero simulated bytes; recovery "
          f"identical ({ck['speedup']}x faster from checkpoint); "
          f"compaction folded {co['compacted_streams']} stream(s) at "
          f"{co['bytes_ratio']}x cold read bytes")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--shards", type=int, default=2)
    args = ap.parse_args()
    main(args.scale, n_queries=args.queries, n_parts=args.parts,
         n_shards=args.shards)
