"""Live-update serving: qps + cache invalidations while updates land.

The paper's Tables 2–3 measure how cheaply the index *absorbs* updates;
this bench measures how cheaply the read side *survives* them.  One
sharded substrate is served continuously while collection parts land
through the per-shard update streams, by two otherwise-identical
readers:

  * **targeted**  — refresh invalidates only the (shard, index, key)
    cache entries named by the writers' touched-key digests;
  * **namespace_drop** — the old behaviour: a generation change drops
    the whole (shard, index) cache namespace.

Both must return element-wise identical results every round (and match
a from-scratch rebuild at the end); the acceptance gate is that the
targeted reader drops STRICTLY fewer cache entries — stale-free warmth,
not staleness — which is what shows up as a higher hit rate and qps
under interleaved update/search traffic.

Usage:
    PYTHONPATH=src python -m benchmarks.update_speed \
        [--scale S] [--queries N] [--parts P] [--shards K]
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import World, bench_index_config, make_world
from benchmarks.search_speed import _mixed_stream
from repro.core.sharded_set import ShardedTextIndexSet
from repro.search import SearchService


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _read_bytes(sts) -> int:
    return sum(st.read_bytes for st in sts.search_io().values())


def run(
    scale: float = 0.5,
    world: World = None,
    n_queries: int = 48,
    n_parts: int = 4,
    n_shards: int = 2,
    backend: str = "numpy",
    cache_bytes: int = 8 << 20,
) -> List[Dict]:
    """Interleave update parts with query batches; report per-mode qps
    and cache-invalidation counts, plus the identity verdicts."""
    if n_parts < 2:
        raise ValueError(f"--parts must be >= 2, got {n_parts}")
    if n_queries < 1:
        raise ValueError(f"--queries must be >= 1, got {n_queries}")
    world = world or make_world(scale, n_parts=n_parts)
    # mixed stream has no phrase queries: skip the multi index, whose
    # per-part digests at bench scale exceed DIGEST_MAX_KEYS (nearly
    # every sliding k-gram is unique) and would legitimately take the
    # whole-namespace fallback this bench uses as its failure signal
    cfg = bench_index_config("set2", multi_k=None)
    lex = world.lexicon
    sts = ShardedTextIndexSet(cfg, lex, n_shards=n_shards, seed=0)
    sts.add_documents(*world.parts[0], world.doc_starts[0])

    queries = _mixed_stream(lex, n_queries, np.random.RandomState(7))
    services = {
        "targeted": SearchService(
            sts.reader(cache_bytes=cache_bytes, targeted=True),
            window=3, backend=backend,
        ),
        "namespace_drop": SearchService(
            sts.reader(cache_bytes=cache_bytes, targeted=False),
            window=3, backend=backend,
        ),
    }

    # untimed warm-up: both services pay planner/jit/first-touch costs
    # and enter the timed rounds with equally warm caches
    for svc in services.values():
        svc.search_batch(queries)

    seconds = {m: 0.0 for m in services}
    read_bytes = {m: 0 for m in services}
    batches = 0
    identical = True
    last = {}

    def round_trip():
        # alternate execution order so neither mode always runs on the
        # colder allocator/branch state right after an update; both
        # readers charge the substrate's shared search devices, so
        # per-mode read traffic is the device delta around each batch
        order = list(services.items())
        if batches % 2:
            order.reverse()
        for mode, svc in order:
            b0 = _read_bytes(sts)
            seconds[mode] += _timed(
                lambda svc=svc, mode=mode: last.__setitem__(
                    mode, svc.search_batch(queries))
            )
            read_bytes[mode] += _read_bytes(sts) - b0
        return _same(last["targeted"], last["namespace_drop"])

    for p in range(1, len(world.parts)):
        identical &= round_trip()
        batches += 1
        sts.add_documents(*world.parts[p], world.doc_starts[p])
        if p == len(world.parts) // 2:
            # one background-compaction cycle mid-stream: published as a
            # generation advance + digest, it must invalidate only the
            # folded keys on the targeted reader (the namespace_drop
            # baseline sweeps as usual) and never perturb results
            sts.compact()
    # post-update round: the invalidations of the LAST part land here
    identical &= round_trip()
    batches += 1

    # from-scratch rebuild oracle: the live readers' final answers must
    # equal a cold service over a substrate that never saw an update
    fresh = ShardedTextIndexSet(cfg, lex, n_shards=n_shards, seed=0)
    for part, d0 in zip(world.parts, world.doc_starts):
        fresh.add_documents(*part, d0)
    ref = SearchService(fresh, window=3, backend=backend,
                        cache_bytes=cache_bytes).search_batch(queries)
    identical &= all(_same(last[m], ref) for m in services)

    n = batches * len(queries)
    comp = sts.compaction_stats()
    rows = []
    for mode, svc in services.items():
        st = svc.reader.cache.stats
        rows.append({
            "bench": "update_speed",
            "mode": mode,
            "shards": n_shards,
            "parts": len(world.parts),
            "batches": batches,
            "queries_per_batch": len(queries),
            "qps": n / max(1e-9, seconds[mode]),
            "read_bytes": read_bytes[mode],
            "invalidations": st.invalidations,
            "full_drops": st.full_drops,
            "hits": st.hits,
            "misses": st.misses,
            "hit_rate": round(st.hit_rate, 4),
            "pool_hits": st.pool_hits,
            "device_hits": st.device_hits,
            "partial_admits": st.partial_admits,
            "snapshot": svc.last_trace["snapshot"],
            "compactions": comp["compactions"],
            "compacted_streams": comp["compacted_streams"],
            "trace_full_drops": svc.last_trace["cache"]["full_drops"],
            "identical": identical,
        })
    return rows


def _same(a, b) -> bool:
    return all(
        np.array_equal(r.docs, g.docs)
        and np.array_equal(r.witnesses, g.witnesses)
        for r, g in zip(a, b)
    )


def main(scale: float = 0.5, n_queries: int = 48, n_parts: int = 4,
         n_shards: int = 2) -> None:
    rows = run(scale, n_queries=n_queries, n_parts=n_parts,
               n_shards=n_shards)
    by_mode = {r["mode"]: r for r in rows}
    print(f"{'mode':16s} {'qps':>10s} {'read_bytes':>12s} "
          f"{'invalidated':>12s} {'full_drops':>10s} {'hit_rate':>9s} "
          f"{'pool_hits':>9s} {'dev_hits':>8s} {'partials':>8s}")
    for mode, r in by_mode.items():
        print(f"{mode:16s} {r['qps']:>10,.0f} {r['read_bytes']:>12,} "
              f"{r['invalidations']:>12,} {r['full_drops']:>10,} "
              f"{r['hit_rate']:>9.3f} {r['pool_hits']:>9,} "
              f"{r['device_hits']:>8,} {r['partial_admits']:>8,}")
    t, b = by_mode["targeted"], by_mode["namespace_drop"]
    print(f"{t['batches']} batches x {t['queries_per_batch']} queries over "
          f"{t['parts']} live parts on {t['shards']} shards; final snapshot "
          f"generations {t['snapshot']}; {t['compactions']} compaction "
          f"cycle(s) folded {t['compacted_streams']} stream(s)")
    assert t["identical"], (
        "live readers diverged from the from-scratch rebuild"
    )
    assert t["invalidations"] < b["invalidations"], (
        "targeted invalidation must drop strictly fewer cache entries "
        f"({t['invalidations']} vs {b['invalidations']})"
    )
    # oversized digests (a part touching more keys than DIGEST_MAX_KEYS,
    # e.g. the (w, v) pair indexes at big part sizes) legitimately fall
    # back to a namespace sweep — but the targeted reader can never
    # sweep MORE than the baseline, which sweeps on every refresh
    assert t["full_drops"] < b["full_drops"], (
        "targeted refresh must sweep strictly fewer whole namespaces "
        f"({t['full_drops']} vs {b['full_drops']})"
    )
    assert t["read_bytes"] < b["read_bytes"], (
        "the kept-warm cache must save actual device reads "
        f"({t['read_bytes']} vs {b['read_bytes']})"
    )
    print("PASS  interleaved updates served stale-free: identical to "
          f"rebuild, {t['invalidations']} targeted drops vs "
          f"{b['invalidations']} namespace drops, "
          f"{t['read_bytes'] / max(1, b['read_bytes']):.2f}x read bytes")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--queries", type=int, default=48)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--shards", type=int, default=2)
    args = ap.parse_args()
    main(args.scale, n_queries=args.queries, n_parts=args.parts,
         n_shards=args.shards)
