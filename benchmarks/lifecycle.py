"""Stream lifecycle distribution (paper Fig. 8).

Shows how streams distribute over the strategy states after build+update,
and the transition counts — evidence the state machine follows the figure:
EM -> SR0/PART -> CH -> S (SR path in sets 2-3, PART path in set 1).
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import World, build_index_set, make_world
from repro.core.text_index import INDEX_NAMES


def run(scale: float = 0.5, world: World = None) -> List[Dict]:
    world = world or make_world(scale)
    rows: List[Dict] = []
    for setname in ("set1", "set2"):
        ts = build_index_set(world, setname, multi_k=None)  # paper tables never query the multi index
        for name in INDEX_NAMES:
            idx = ts.indexes[name]
            census = idx.mgr.state_census()
            kinds: Dict[str, int] = {}
            for e in idx.dict.entries.values():
                kinds[e.kind] = kinds.get(e.kind, 0) + 1
            rows.append(
                {
                    "bench": "lifecycle",
                    "set": setname,
                    "index": name,
                    **{f"state_{k}": v for k, v in census.items()},
                    **{f"key_{k}": v for k, v in kinds.items()},
                    "transitions": {
                        f"{a}->{b}": n
                        for (a, b), n in idx.mgr.transitions.items()
                    },
                }
            )
    return rows


def main(scale: float = 0.5) -> None:
    rows = run(scale)
    for r in rows:
        states = {
            k[6:]: v for k, v in r.items() if k.startswith("state_") and v
        }
        print(f"{r['set']} {r['index']:9s} states={states} trans={r['transitions']}")
    # Fig. 8 path check: set1 must use PART (no SR), set2 must use SR0 (no PART)
    for r in rows:
        if r["set"] == "set1":
            assert r.get("state_sr0", 0) == 0
        if r["set"] == "set2":
            assert r.get("state_part", 0) == 0
    print("PASS  lifecycle follows Fig. 8 per strategy set")


if __name__ == "__main__":
    main()
