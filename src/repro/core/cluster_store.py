"""Cluster arena: the paper's "data file organized as a sequence of blocks".

All blocks (clusters) have the same size, fixed at construction (paper
section 3; 32 KB default).  The arena provides:

  * single-cluster allocation (chains, PART clusters, FL area),
  * contiguous *segment* allocation (strategy S) via a first-fit extent
    allocator with coalescing free — segments must be physically sequential
    so that reading a segment is ONE device operation,
  * a free-clusters list (paper section 5.7.1 step 4: freed chain clusters
    are recycled),
  * byte-accurate cluster payloads, so search results can be validated
    against a ground-truth oracle, not just counted.

Cluster payloads are Python ``bytearray``s; the *device traffic* is what is
measured, through the :class:`~repro.core.io_sim.BlockDevice` passed in.
A link slot of ``LINK_BYTES`` is reserved at the end of any cluster that
participates in a linked structure (paper Fig. 1: "the small black box").
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.io_sim import BlockDevice

LINK_BYTES = 8  # reserved link slot at the end of linked clusters


class ExtentAllocator:
    """First-fit extent allocator over cluster ids with free coalescing."""

    def __init__(self, initial_clusters: int = 0):
        # sorted list of (start, length) free extents
        self._free: List[Tuple[int, int]] = []
        self._frontier = 0  # next never-used cluster id
        self.capacity_high_water = 0
        if initial_clusters:
            self._free.append((0, initial_clusters))
            self._frontier = initial_clusters

    def alloc(self, length: int) -> int:
        """Allocate ``length`` physically contiguous clusters, return start id."""
        assert length > 0
        for i, (start, flen) in enumerate(self._free):
            if flen >= length:
                if flen == length:
                    self._free.pop(i)
                else:
                    self._free[i] = (start + length, flen - length)
                return start
        # extend the file
        start = self._frontier
        self._frontier += length
        self.capacity_high_water = max(self.capacity_high_water, self._frontier)
        return start

    def free(self, start: int, length: int) -> None:
        if length <= 0:
            return
        entry = (start, length)
        idx = bisect.bisect_left(self._free, entry)
        self._free.insert(idx, entry)
        self._coalesce(idx)

    def _coalesce(self, idx: int) -> None:
        # merge with previous
        if idx > 0:
            ps, pl = self._free[idx - 1]
            s, l = self._free[idx]
            if ps + pl == s:
                self._free[idx - 1] = (ps, pl + l)
                self._free.pop(idx)
                idx -= 1
        # merge with next
        if idx + 1 < len(self._free):
            s, l = self._free[idx]
            ns, nl = self._free[idx + 1]
            if s + l == ns:
                self._free[idx] = (s, l + nl)
                self._free.pop(idx + 1)

    @property
    def free_clusters(self) -> int:
        return sum(l for _, l in self._free)


@dataclasses.dataclass
class ClusterMeta:
    """Host-side metadata for one allocated cluster."""

    used: int = 0          # payload bytes in use (excluding link slot)
    link: int = -1         # linked cluster id (-1: none); direction is owner-defined
    is_part: bool = False  # PART cluster (subdivided)


class ClusterStore:
    """The data file: payloads + allocator + metadata + device accounting."""

    def __init__(self, device: BlockDevice, cluster_size: Optional[int] = None):
        self.device = device
        self.cluster_size = int(cluster_size or device.cluster_size)
        self.alloc = ExtentAllocator()
        self.payload: Dict[int, bytearray] = {}
        self.meta: Dict[int, ClusterMeta] = {}

    # capacity of a linked cluster's payload area
    @property
    def linked_capacity(self) -> int:
        return self.cluster_size - LINK_BYTES

    # ------------------------------------------------------------------ alloc --
    def alloc_cluster(self) -> int:
        cid = self.alloc.alloc(1)
        self.payload[cid] = bytearray()
        self.meta[cid] = ClusterMeta()
        return cid

    def alloc_segment(self, length: int) -> int:
        start = self.alloc.alloc(length)
        for cid in range(start, start + length):
            self.payload[cid] = bytearray()
            self.meta[cid] = ClusterMeta()
        return start

    def free_clusters(self, ids: List[int]) -> None:
        """Return clusters to the free list (paper 5.7.1 step 4)."""
        for cid in ids:
            self.payload.pop(cid, None)
            self.meta.pop(cid, None)
        # coalesce adjacent ids into extents before freeing
        for start, length in _id_runs(sorted(set(ids))):
            self.alloc.free(start, length)

    # ------------------------------------------------------------------- data --
    def append_bytes(self, cid: int, data: bytes, linked: bool = True) -> int:
        """Append as much of ``data`` into cluster ``cid`` as fits.

        Returns the number of bytes consumed.  No device traffic is charged
        here — the cache layer decides when clusters actually move.
        """
        cap = self.linked_capacity if linked else self.cluster_size
        meta = self.meta[cid]
        room = cap - meta.used
        take = min(room, len(data))
        if take > 0:
            self.payload[cid] += data[:take]
            meta.used += take
        return take

    def read_payload(self, cid: int) -> bytes:
        return bytes(self.payload[cid])

    def set_link(self, cid: int, target: int) -> None:
        self.meta[cid].link = target

    def used(self, cid: int) -> int:
        return self.meta[cid].used


def _id_runs(sorted_ids: List[int]) -> List[Tuple[int, int]]:
    runs: List[Tuple[int, int]] = []
    start = prev = None
    for cid in sorted_ids:
        if start is None:
            start = prev = cid
            continue
        if cid == prev + 1:
            prev = cid
            continue
        runs.append((start, prev - start + 1))
        start = prev = cid
    if start is not None:
        runs.append((start, prev - start + 1))
    return runs
