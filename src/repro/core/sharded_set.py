"""Sharded index substrate: N per-shard :class:`TextIndexSet` partitions.

The paper's easily updatable index is built for a growing collection;
serving production traffic additionally needs the collection *partitioned*
so shards can be fetched (and eventually updated and replicated)
independently.  :class:`ShardedTextIndexSet` partitions documents by a
multiplicative hash of the doc id across ``n_shards`` full
:class:`~repro.core.text_index.TextIndexSet` substrates:

  * every shard owns complete build/search/dictionary devices, so the
    paper's I/O tables report **per shard and in aggregate** (the
    ``*_per_shard`` variants vs the merged defaults);
  * postings keep their **global** doc ids — a shard stores the doc-subset
    of every key's posting list, sorted by (doc, pos) exactly like the
    unsharded list.  Document-hash sharding therefore preserves the
    property all four planner routes rely on: per-key posting fetches are
    independent across documents, so a whole-set lookup is the disjoint
    union of per-shard lookups and gathers **losslessly** by merge;
  * extraction runs ONCE per part (same vectorized pass as unsharded) and
    the resulting posting maps are scattered row-wise by doc hash, so a
    sharded build indexes byte-for-byte the same postings as an unsharded
    one.

The read side lives in :mod:`repro.search.reader`
(``ShardedIndexSetReader``) and :mod:`repro.search.service` (the
plan → scatter-fetch → join → gather pipeline).
"""

from __future__ import annotations

from typing import Dict, Hashable, List, Optional

import numpy as np

from repro.core.inverted_index import InvertedIndex
from repro.core.io_sim import IOStats
from repro.core.lexicon import Lexicon
from repro.core.text_index import (
    MULTI_INDEX,
    IndexSetConfig,
    IndexSetLike,
    TextIndexSet,
)
from repro.data.corpus import extract_postings

_EMPTY = np.zeros((0, 2), dtype=np.int64)

# Fibonacci multiplier (2^64 / phi, odd): a multiplicative mix so shard
# assignment is insensitive to doc-id striding (plain modulo would send
# every even doc of a 2-part collection to the same shard, say)
_MIX = 0x9E3779B97F4A7C15
_MASK64 = (1 << 64) - 1


def shard_of(doc_id: int, n_shards: int) -> int:
    """Shard owning one doc id (deterministic multiplicative hash)."""
    return int(((doc_id * _MIX) & _MASK64) >> 33) % n_shards


def shard_of_docs(doc_ids: np.ndarray, n_shards: int) -> np.ndarray:
    """Vectorized :func:`shard_of` over a doc-id column."""
    d = np.asarray(doc_ids).astype(np.uint64)
    mixed = (d * np.uint64(_MIX)) >> np.uint64(33)
    return (mixed % np.uint64(n_shards)).astype(np.int64)


def merge_io_reports(dicts: List[Dict[str, IOStats]]) -> Dict[str, IOStats]:
    """Fold per-shard {index name → IOStats} reports into one aggregate
    (the shared merge for set- and reader-side per-shard reporting)."""
    out: Dict[str, IOStats] = {}
    for d in dicts:
        for name, st in d.items():
            out[name] = out[name].merged(st) if name in out else st
    return out


def merge_shard_postings(arrs: List[np.ndarray]) -> np.ndarray:
    """Gather per-shard (N,2) posting/witness arrays into the unsharded
    order.

    Shard doc sets are disjoint and each per-shard array is the
    (doc, pos)-ordered subsequence of the unsharded array, so a STABLE
    sort on the doc column alone reconstructs the unsharded array
    element-wise (within-doc row order is preserved from the owning
    shard)."""
    arrs = [a for a in arrs if a.shape[0]]
    if not arrs:
        return _EMPTY
    if len(arrs) == 1:
        return arrs[0]
    cat = np.concatenate(arrs, axis=0)
    return cat[np.argsort(cat[:, 0], kind="stable")]


def merge_shard_chunks(chunk_runs: List[List[np.ndarray]]) -> np.ndarray:
    """Gather per-shard lazy-cursor chunk runs into unsharded (doc, pos)
    order — the scatter/gather-aware merge of the streaming top-k stage.

    Each inner list is the chunks ONE shard's cursor has delivered so
    far; their concatenation is a (doc, pos)-sorted run (sequential
    slices of that shard's posting list), and the runs merge across
    shards exactly like :func:`merge_shard_postings`: shard doc sets are
    disjoint, so a stable sort on the doc column reconstructs the
    unsharded prefix element-wise."""
    runs: List[np.ndarray] = []
    for chunks in chunk_runs:
        chunks = [c for c in chunks if c.shape[0]]
        if not chunks:
            continue
        runs.append(
            chunks[0] if len(chunks) == 1 else np.concatenate(chunks, axis=0)
        )
    return merge_shard_postings(runs)


class UpdateStream:
    """Independent live-update applier for ONE shard.

    The paper's defining property — in-place updatability — lifted to the
    sharded substrate: every shard owns an update stream that applies
    collection parts to that shard alone, so shards advance
    independently (a part whose documents all hash elsewhere never
    touches this shard, and a deployment can drain per-shard queues at
    different rates).  Each applied part:

      * runs ``add_part`` only on the indexes that actually received
        rows (an untouched index's generation stays put — its readers
        keep every cached posting);
      * bumps the shard's generation (derived from the per-index
        ``n_parts`` counters, so direct index writes are never missed);
      * publishes the part's *touched-key digest* — the exact
        ``{index → keys}`` set whose posting lists changed — which
        readers use to invalidate only the affected (shard, index, key)
        cache entries instead of dropping whole namespaces.
    """

    def __init__(self, shard_id: int, index_set):
        self.shard_id = int(shard_id)
        self.index_set = index_set
        self.parts_applied = 0
        self.rows_applied = 0
        self.compactions_applied = 0

    @property
    def generation(self) -> int:
        """This shard's scalar snapshot generation (see
        :attr:`~repro.core.text_index.TextIndexSet.generation`)."""
        return self.index_set.generation

    def generation_vector(self) -> List[int]:
        """This shard's per-index published generation vector — the
        alias-free snapshot coordinate replicas subscribe against."""
        return self.index_set.generation_vector()

    def digests_since(
        self, generation_vector: List[int]
    ) -> Optional[Dict[str, List[frozenset]]]:
        """Shard-level digest-stream subscription: the touched-key
        digests every index published after the subscriber's pinned
        per-index ``generation_vector``, as ``{index_name: [digest,
        ...]}`` (current indexes omitted).  ``None`` when ANY index's
        bounded history no longer reaches back that far — the subscriber
        must then take the whole-namespace catch-up path for the shard.
        This is the writer-side surface :class:`repro.search.replica.
        ReplicaReader` consumes."""
        names = list(self.index_set.indexes)
        if len(generation_vector) != len(names):
            return None
        out: Dict[str, List[frozenset]] = {}
        for name, gen in zip(names, generation_vector):
            digests = self.index_set.indexes[name].digests_since(gen)
            if digests is None:
                return None
            if digests:
                out[name] = digests
        return out

    def apply(self, maps) -> Dict[str, frozenset]:
        """Apply one scattered part to this shard; returns its
        touched-key digest (empty when the part carried no rows for the
        shard — in which case nothing, including the generation, moved)."""
        rows = sum(
            arr.shape[0] for by_key in maps.values() for arr in by_key.values()
        )
        digest = self.index_set.apply_part_maps(maps) if rows else {}
        if digest:
            self.parts_applied += 1
            self.rows_applied += rows
        return digest

    def compact(self) -> Dict[str, frozenset]:
        """One background-compaction cycle on this shard alone —
        published through the shard's generation/digest machinery like
        any other part (see :meth:`TextIndexSet.compact`).  Shards
        compact independently, exactly as they update independently."""
        digest = self.index_set.compact()
        if digest:
            self.compactions_applied += 1
        return digest


class ShardedTextIndexSet(IndexSetLike):
    """N document-hash shards, each a full :class:`TextIndexSet`."""

    def __init__(
        self,
        cfg: IndexSetConfig,
        lexicon: Lexicon,
        n_shards: int = 4,
        seed: int = 0,
    ):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.cfg = cfg
        self.lexicon = lexicon
        self.n_shards = int(n_shards)
        # identical seed per shard: dictionaries group keys identically, so
        # one shard-agnostic planner group_of serves the whole set
        self.shards: List[TextIndexSet] = [
            TextIndexSet(cfg, lexicon, seed=seed) for _ in range(n_shards)
        ]
        for s, shard in enumerate(self.shards):
            for idx in shard.indexes.values():
                idx.mgr.device.name = f"s{s}/{idx.mgr.device.name}"
            for dev in list(shard.dict_devices.values()) + list(
                shard.search_devices.values()
            ):
                dev.name = f"s{s}/{dev.name}"
        # one independent live-update stream per shard: `add_documents` is
        # the all-shards convenience path; callers that replay per-shard
        # queues drive `update_streams[s].apply(...)` directly
        self.update_streams: List[UpdateStream] = [
            UpdateStream(s, shard) for s, shard in enumerate(self.shards)
        ]

    # the planner/service capability view: all shards share index kinds,
    # key packing and multi_k, so shard 0 answers every capability question
    @property
    def indexes(self) -> Dict[str, InvertedIndex]:
        return self.shards[0].indexes

    # ------------------------------------------------------------- building --
    def add_documents(
        self, tokens: np.ndarray, offsets: np.ndarray, doc0: int
    ) -> None:
        """Index one collection part: extract once, scatter rows by doc
        hash, run each touched shard's update stream."""
        maps = extract_postings(
            self.lexicon, tokens, offsets, doc0, self.cfg.max_distance
        )
        if MULTI_INDEX in self.indexes:
            maps[MULTI_INDEX] = self.indexes[MULTI_INDEX].extract_part(
                self.lexicon, tokens, offsets, doc0
            )
        self.apply_part_maps(maps)

    def apply_part_maps(
        self, maps: Dict[str, Dict[Hashable, np.ndarray]]
    ) -> List[Dict[str, frozenset]]:
        """Scatter one whole-set extracted part by doc hash and run each
        touched shard's update stream — the primitive under
        :meth:`add_documents`, also driven directly by callers replaying
        a durable part log (``repro.store``).  Returns the per-shard
        touched-key digests (empty dict for untouched shards)."""
        if self.n_shards == 1:
            return [self.update_streams[0].apply(maps)]
        shard_maps: List[Dict[str, Dict[Hashable, np.ndarray]]] = [
            {name: {} for name in maps} for _ in range(self.n_shards)
        ]
        for name, by_key in maps.items():
            for key, arr in by_key.items():
                owner = shard_of_docs(arr[:, 0], self.n_shards)
                for s in range(self.n_shards):
                    rows = arr[owner == s]
                    if rows.size:
                        shard_maps[s][name][key] = rows
        # each shard's update stream applies ONLY what hashed to it: a
        # shard that received zero rows for this part keeps its
        # generation (previously every shard's every index got an
        # `add_part` call, bumping generations and forcing needless full
        # cache drops on untouched shards)
        return [
            self.update_streams[s].apply(shard_maps[s])
            for s in range(self.n_shards)
        ]

    def compact(self) -> List[Dict[str, frozenset]]:
        """One background-compaction cycle, every shard: each shard
        folds its scattered streams and publishes its own generation
        advance + digest (untouched shards publish nothing)."""
        return [us.compact() for us in self.update_streams]

    def compaction_stats(self) -> Dict[str, int]:
        """Aggregate background-compaction counters across all shards."""
        agg = {"compactions": 0, "compacted_streams": 0}
        for shard in self.shards:
            for k, v in shard.compaction_stats().items():
                agg[k] += v
        return agg

    def generation_vector(self) -> List[List[int]]:
        """Per-shard *per-index* published generations — what a
        snapshot-consistent batch pins (see
        ``SearchService.last_trace['snapshot']``).  Nested rather than
        summed per shard: a sum aliases (one index advancing while
        another folds/restores can leave it unchanged), the vector
        cannot."""
        return [shard.generation_vector() for shard in self.shards]

    # -------------------------------------------------------------- queries --
    def lookup(self, index_name: str, key: Hashable) -> np.ndarray:
        """Whole-set lookup: scatter to every shard, gather by merge."""
        return merge_shard_postings(
            [shard.lookup(index_name, key) for shard in self.shards]
        )

    def reader(self, cache_bytes: int = 8 << 20, targeted: bool = True):
        """Per-shard readers behind ONE byte-budgeted posting cache
        (namespaced by (shard, index, key) — see ``repro.search.reader``)."""
        from repro.search.reader import ShardedIndexSetReader

        return ShardedIndexSetReader(self, cache_bytes=cache_bytes,
                                     targeted=targeted)

    # -------------------------------------------------------------- reports --
    def build_io_per_shard(self) -> List[Dict[str, IOStats]]:
        return [shard.build_io() for shard in self.shards]

    def build_io(self) -> Dict[str, IOStats]:
        return merge_io_reports(self.build_io_per_shard())

    def search_io_per_shard(self) -> List[Dict[str, IOStats]]:
        return [shard.search_io() for shard in self.shards]

    def search_io(self) -> Dict[str, IOStats]:
        return merge_io_reports(self.search_io_per_shard())

    def table_rows_per_shard(self) -> List[Dict[str, Dict[str, int]]]:
        return [shard.table_rows() for shard in self.shards]

    def table_rows(self) -> Dict[str, Dict[str, int]]:
        rows: Dict[str, Dict[str, int]] = {}
        for shard_rows in self.table_rows_per_shard():
            for name, row in shard_rows.items():
                agg = rows.setdefault(name, {k: 0 for k in row})
                for k, v in row.items():
                    agg[k] += v
        return rows

    def census(self) -> Dict[str, Dict[str, int]]:
        out: Dict[str, Dict[str, int]] = {}
        for shard in self.shards:
            for name, counters in shard.census().items():
                agg = out.setdefault(name, {})
                for k, v in counters.items():
                    agg[k] = agg.get(k, 0) + v
        return out
