"""Proximity full-text search over the additional indexes (paper section 6).

Query model: a list of word ids; the answer is the set of documents where
the queried words occur near each other (within ``window`` positions),
with the witness positions.

This module is the backward-compatible single-query surface.  The actual
query processor is the Reader → Planner → Executor stack in
:mod:`repro.search` (see DESIGN_SEARCH.md): :class:`ProximityEngine` is a
thin wrapper that plans and executes each query through a
:class:`~repro.search.service.SearchService`, and the join functions
(``numpy_window_join``, ``jax_window_join``, ...) are re-exported from
:mod:`repro.search.join` for existing imports.

The planner mirrors the paper's three word classes:

  * two stop lemmas            → one ``stopseq`` lookup (the whole
    co-occurrence is precomputed in the index key),
  * FREQUENT lemma + any other → one extended ``(w, v)`` lookup,
  * otherwise                  → ordinary-index lookups + position join.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.lexicon import STOP
from repro.core.text_index import IndexSetLike
from repro.search.join import (
    JOIN_BACKENDS,
    jax_window_join,
    numpy_phrase_join,
    numpy_window_join,
    pallas_window_join,
)
from repro.search.plan import Query, QueryResult
from repro.search.service import SearchService

__all__ = [
    "ProximityEngine",
    "QueryResult",
    "jax_window_join",
    "numpy_phrase_join",
    "numpy_window_join",
    "pallas_window_join",
]


class ProximityEngine:
    """Single-query facade over :class:`~repro.search.SearchService`.

    ``join`` keeps the historical signature: a callable
    ``join(a, b, window)`` or one of the named backends; it is forwarded
    to the service as the join backend for the ordinary route.
    """

    def __init__(self, index_set: IndexSetLike, window: int = 3,
                 join=numpy_window_join, cache_bytes: int = 8 << 20):
        self.idx = index_set
        self.lex = index_set.lexicon
        self.window = min(window, index_set.cfg.max_distance)
        self.join = join
        backend = {id(f): name for name, f in JOIN_BACKENDS.items()}.get(
            id(join), join
        )
        self.service = SearchService(
            index_set, window=window, backend=backend, cache_bytes=cache_bytes
        )

    def search(self, words: List[int]) -> QueryResult:
        """Proximity search via the additional indexes (the paper's path)."""
        assert 2 <= len(words) <= 3, "benchmark queries are 2-3 words"
        return self.service.search(words)

    def search_ordinary(self, words: List[int]) -> QueryResult:
        """Baseline: the same query through the ordinary-all index only.
        All-stop queries use phrase semantics (to match the stop-sequence
        index); everything else uses the proximity window."""
        assert "ordinary_all" in self.idx.indexes, (
            "build TextIndexSet with build_ordinary_all=True for the baseline"
        )
        lemmas, classes = self.lex.classify_words(
            np.asarray(words, dtype=np.int64)
        )
        phrase = all(int(c) == STOP for c in classes)
        join = self.join if callable(self.join) else JOIN_BACKENDS[self.join]
        lists, lookups, scanned = [], [], 0
        for lemma in lemmas:
            lemma = int(lemma)
            posts = self.idx.lookup("ordinary_all", lemma)
            lists.append(posts)
            lookups.append(("ordinary_all", lemma))
            scanned += posts.shape[0]
        acc = lists[0]
        for k, nxt in enumerate(lists[1:], start=1):
            if phrase:
                acc = numpy_phrase_join(acc, nxt, k)
            else:
                acc = join(acc, nxt, self.window)
        # scores (match-occurrence counts) attach here too: QueryResult
        # equality requires both sides to carry them, so a facade result
        # must be comparable against the batched executor's
        docs, counts = np.unique(acc[:, 0], return_counts=True)
        return QueryResult(docs, acc, lookups, scanned, scores=counts)
