"""Proximity full-text search over the additional indexes (paper section 6).

Query model: a list of word ids; the answer is the set of documents where
the queried words occur near each other (within ``window`` positions),
with the witness positions.

The planner mirrors the paper's three word classes:

  * two stop lemmas            → one ``stopseq`` lookup (the whole
    co-occurrence is precomputed in the index key),
  * FREQUENT lemma + any other → one extended ``(w, v)`` lookup,
  * otherwise                  → ordinary-index lookups + position join.

The position join has three interchangeable implementations:
``numpy_window_join`` (oracle), ``jax_window_join`` (jit-compiled,
padded), and the Pallas kernel in ``repro.kernels.intersect`` (TPU tiles).
The paper's claim reproduced by ``benchmarks/search_speed.py`` is that the
planner's additional-index path touches orders of magnitude less data than
evaluating the same query through the ordinary index alone.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.lexicon import FREQUENT, Lexicon, OTHER, STOP
from repro.data.corpus import PAIR_SHIFT, SEQ2_FLAG, SEQ_SHIFT
from repro.core.text_index import TextIndexSet


# ------------------------------------------------------------ position join --
def numpy_window_join(
    a: np.ndarray, b: np.ndarray, window: int
) -> np.ndarray:
    """Rows of ``a`` having a row of ``b`` with the same doc and
    |pos_a - pos_b| <= window.  Both (N,2), sorted by (doc, pos)."""
    if a.size == 0 or b.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    scale = np.int64(1) << 32
    bkey = b[:, 0] * scale + b[:, 1]
    lo = np.searchsorted(bkey, a[:, 0] * scale + (a[:, 1] - window))
    hi = np.searchsorted(bkey, a[:, 0] * scale + (a[:, 1] + window), side="right")
    return a[hi > lo]


def numpy_phrase_join(a: np.ndarray, b: np.ndarray, dist: int) -> np.ndarray:
    """Rows of ``a`` where ``b`` has the same doc at exactly pos_a + dist
    (ordered adjacency — the stop-sequence index semantics)."""
    if a.size == 0 or b.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    scale = np.int64(1) << 32
    bkey = b[:, 0] * scale + b[:, 1]
    want = a[:, 0] * scale + (a[:, 1] + dist)
    i = np.searchsorted(bkey, want)
    i = np.minimum(i, bkey.shape[0] - 1)
    return a[bkey[i] == want]


@jax.jit
def _jax_window_join(a: jnp.ndarray, b: jnp.ndarray, window: jnp.ndarray) -> jnp.ndarray:
    scale = jnp.int64(1) << 32 if a.dtype == jnp.int64 else jnp.int32(1) << 24
    akey = a[:, 0] * scale + a[:, 1]
    bkey = b[:, 0] * scale + b[:, 1]
    lo = jnp.searchsorted(bkey, akey - window)
    hi = jnp.searchsorted(bkey, akey + window, side="right")
    return hi > lo


def jax_window_join(a: np.ndarray, b: np.ndarray, window: int) -> np.ndarray:
    """JAX path: pad to the next power of two, join, unpad."""
    if a.size == 0 or b.size == 0:
        return np.zeros((0, 2), dtype=np.int64)

    def pad(x: np.ndarray) -> np.ndarray:
        n = 1
        while n < x.shape[0]:
            n <<= 1
        fill = np.full((n - x.shape[0], 2), np.iinfo(np.int32).max // 2, np.int64)
        return np.concatenate([x, fill], axis=0)

    pa, pb = pad(a), pad(b)
    mask = np.asarray(_jax_window_join(jnp.asarray(pa), jnp.asarray(pb),
                                       jnp.int64(window)))
    return pa[mask & (np.arange(pa.shape[0]) < a.shape[0])]


# ---------------------------------------------------------------- the engine --
@dataclasses.dataclass
class QueryResult:
    docs: np.ndarray            # matched doc ids (unique, sorted)
    witnesses: np.ndarray       # (N,2) witness postings
    lookups: List[Tuple[str, int]]  # (index, key) lookups performed
    postings_scanned: int       # total postings decoded


class ProximityEngine:
    def __init__(self, index_set: TextIndexSet, window: int = 3,
                 join=numpy_window_join):
        self.idx = index_set
        self.lex = index_set.lexicon
        self.window = min(window, index_set.cfg.max_distance)
        self.join = join

    # -- planning -------------------------------------------------------------
    def _classify(self, word: int) -> Tuple[int, int]:
        """(lemma, class) for one query word; class OTHER for unknown."""
        l1, _ = self.lex.lemmatize(np.asarray([word], dtype=np.int64))
        lemma = int(l1[0])
        cls = int(self.lex.classes_of(np.asarray([lemma]))[0])
        return lemma, cls

    def search(self, words: List[int]) -> QueryResult:
        """Proximity search via the additional indexes (the paper's path)."""
        assert 2 <= len(words) <= 3, "benchmark queries are 2-3 words"
        lemmas_cls = [self._classify(w) for w in words]
        lemmas = [lc[0] for lc in lemmas_cls]
        classes = [lc[1] for lc in lemmas_cls]

        # all-stop: one stop-sequence lookup
        if all(c == STOP for c in classes):
            if len(lemmas) == 2:
                key = int(SEQ2_FLAG | (lemmas[0] << SEQ_SHIFT) | lemmas[1])
            else:
                key = int(
                    (lemmas[0] << (2 * SEQ_SHIFT))
                    | (lemmas[1] << SEQ_SHIFT)
                    | lemmas[2]
                )
            posts = self.idx.lookup("stopseq", key)
            return QueryResult(
                np.unique(posts[:, 0]), posts,
                [("stopseq", key)], posts.shape[0],
            )

        # a FREQUENT lemma pairs through the extended index
        freq_i = next((i for i, c in enumerate(classes) if c == FREQUENT), None)
        if freq_i is not None and len(words) == 2:
            w = lemmas[freq_i]
            vi = 1 - freq_i
            v = lemmas[vi]
            key = int((w << PAIR_SHIFT) | v)
            name = "wv_kk" if v < self.lex.n_lemmas else "wv_ku"
            posts = self.idx.lookup(name, key)
            return QueryResult(
                np.unique(posts[:, 0]), posts, [(name, key)], posts.shape[0],
            )

        # general: ordinary lookups + window join
        lists, lookups, scanned = [], [], 0
        for lemma, cls in lemmas_cls:
            name = "unknown" if lemma >= self.lex.n_lemmas else "known"
            posts = self.idx.lookup(name, lemma)
            lists.append(posts)
            lookups.append((name, lemma))
            scanned += posts.shape[0]
        acc = lists[0]
        for nxt in lists[1:]:
            acc = self.join(acc, nxt, self.window)
        return QueryResult(np.unique(acc[:, 0]), acc, lookups, scanned)

    def search_ordinary(self, words: List[int]) -> QueryResult:
        """Baseline: the same query through the ordinary-all index only.
        All-stop queries use phrase semantics (to match the stop-sequence
        index); everything else uses the proximity window."""
        assert "ordinary_all" in self.idx.indexes, (
            "build TextIndexSet with build_ordinary_all=True for the baseline"
        )
        classes = [self._classify(w)[1] for w in words]
        phrase = all(c == STOP for c in classes)
        lists, lookups, scanned = [], [], 0
        for w in words:
            l1, _ = self.lex.lemmatize(np.asarray([w], dtype=np.int64))
            lemma = int(l1[0])
            posts = self.idx.lookup("ordinary_all", lemma)
            lists.append(posts)
            lookups.append(("ordinary_all", lemma))
            scanned += posts.shape[0]
        acc = lists[0]
        for k, nxt in enumerate(lists[1:], start=1):
            if phrase:
                acc = numpy_phrase_join(acc, nxt, k)
            else:
                acc = self.join(acc, nxt, self.window)
        return QueryResult(np.unique(acc[:, 0]), acc, lookups, scanned)
