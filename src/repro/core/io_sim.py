"""Simulated block device with exact I/O accounting.

This module is the measurement substrate for reproducing the paper's
Tables 2 and 3: every read/write of index clusters during construction,
update and search goes through a :class:`BlockDevice`, which counts

  * the number of I/O *operations* (a contiguous run of clusters moved in one
    call is ONE operation — this is what makes the S strategy's contiguous
    segments cheaper than chains of scattered clusters), and
  * the number of *bytes* moved.

The DS strategy (paper section 5.9) is implemented as a wrapper device that
packs small writes (<= ``small_threshold`` bytes) into a large in-memory
buffer and flushes it with a single write operation, maintaining the
address mapping table the paper describes.

The device is deliberately host-side, single-threaded Python: the paper
measures *disk* behaviour of index construction, which is sequential host
logic.  The TPU-side adaptation of the same ideas lives in
``repro/core/paged_kv.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple


@dataclasses.dataclass
class IOStats:
    """Aggregate I/O accounting, split by direction."""

    read_ops: int = 0
    write_ops: int = 0
    read_bytes: int = 0
    write_bytes: int = 0

    @property
    def total_ops(self) -> int:
        return self.read_ops + self.write_ops

    @property
    def total_bytes(self) -> int:
        return self.read_bytes + self.write_bytes

    def snapshot(self) -> "IOStats":
        return IOStats(self.read_ops, self.write_ops, self.read_bytes, self.write_bytes)

    def delta(self, since: "IOStats") -> "IOStats":
        return IOStats(
            self.read_ops - since.read_ops,
            self.write_ops - since.write_ops,
            self.read_bytes - since.read_bytes,
            self.write_bytes - since.write_bytes,
        )

    def merged(self, other: "IOStats") -> "IOStats":
        return IOStats(
            self.read_ops + other.read_ops,
            self.write_ops + other.write_ops,
            self.read_bytes + other.read_bytes,
            self.write_bytes + other.write_bytes,
        )

    def as_dict(self) -> Dict[str, int]:
        return {
            "read_ops": self.read_ops,
            "write_ops": self.write_ops,
            "read_bytes": self.read_bytes,
            "write_bytes": self.write_bytes,
            "total_ops": self.total_ops,
            "total_bytes": self.total_bytes,
        }


def _runs(sorted_ids: Sequence[int]) -> List[Tuple[int, int]]:
    """Split a sorted id sequence into (start, length) contiguous runs."""
    runs: List[Tuple[int, int]] = []
    start = prev = None
    for cid in sorted_ids:
        if start is None:
            start = prev = cid
            continue
        if cid == prev + 1:
            prev = cid
            continue
        runs.append((start, prev - start + 1))
        start = prev = cid
    if start is not None:
        runs.append((start, prev - start + 1))
    return runs


class BlockDevice:
    """A flat array of fixed-size clusters with contiguity-aware accounting.

    ``read_clusters``/``write_clusters`` take cluster id iterables; ids that
    form contiguous runs are charged as a single operation per run (the disk
    analogy: one seek + sequential transfer).  ``read_small``/``write_small``
    model sub-cluster transfers (used by the SR strategy's 128-byte blocks
    and dictionary traffic) and are charged one op each unless the device is
    wrapped by :class:`PackedWriteDevice` (strategy DS).
    """

    def __init__(self, cluster_size: int = 32 * 1024, name: str = "dev"):
        self.cluster_size = int(cluster_size)
        self.name = name
        self.stats = IOStats()

    # -- cluster-granular traffic ------------------------------------------------
    def read_clusters(self, cluster_ids: Iterable[int]) -> None:
        ids = sorted(set(int(c) for c in cluster_ids))
        if not ids:
            return
        for _start, length in _runs(ids):
            self.stats.read_ops += 1
            self.stats.read_bytes += length * self.cluster_size

    def write_clusters(self, cluster_ids: Iterable[int]) -> None:
        ids = sorted(set(int(c) for c in cluster_ids))
        if not ids:
            return
        for _start, length in _runs(ids):
            self.stats.write_ops += 1
            self.stats.write_bytes += length * self.cluster_size

    # -- sub-cluster traffic -----------------------------------------------------
    def read_small(self, nbytes: int) -> None:
        if nbytes <= 0:
            return
        self.stats.read_ops += 1
        self.stats.read_bytes += int(nbytes)

    def write_small(self, nbytes: int) -> None:
        if nbytes <= 0:
            return
        self.stats.write_ops += 1
        self.stats.write_bytes += int(nbytes)

    # -- bulk sequential traffic (FL area load, SR file streaming) ----------------
    def read_sequential(self, nbytes: int) -> None:
        """One large sequential read of ``nbytes`` (one op)."""
        if nbytes <= 0:
            return
        self.stats.read_ops += 1
        self.stats.read_bytes += int(nbytes)

    def write_sequential(self, nbytes: int) -> None:
        if nbytes <= 0:
            return
        self.stats.write_ops += 1
        self.stats.write_bytes += int(nbytes)

    def flush(self) -> None:  # interface parity with PackedWriteDevice
        pass


class PackedWriteDevice(BlockDevice):
    """Strategy DS (section 5.9): pack small writes into large buffers.

    Writes of at most ``small_threshold`` bytes are appended to an in-memory
    pack buffer.  When the buffer reaches ``buffer_size`` it is flushed with
    a single sequential write.  A mapping table records, for each elided
    small write, the (buffer epoch, offset) where its data actually lives —
    faithful to the paper's ``A->a, B->b, C->c`` table.  Reads of relocated
    data are charged against the packed file (still one op, but the paper's
    point is the *write* op elision during construction, which dominates).
    """

    def __init__(
        self,
        cluster_size: int = 32 * 1024,
        small_threshold: int = 32 * 1024,
        buffer_size: int = 1024 * 1024,
        name: str = "ds-dev",
    ):
        super().__init__(cluster_size=cluster_size, name=name)
        self.small_threshold = int(small_threshold)
        self.buffer_size = int(buffer_size)
        self._buffered = 0
        self._epoch = 0
        # mapping table: sequential id of elided write -> (epoch, offset)
        self.mapping: Dict[int, Tuple[int, int]] = {}
        self._next_map_id = 0
        self.packed_flushes = 0

    def _pack(self, nbytes: int) -> None:
        if self._buffered + nbytes > self.buffer_size:
            self.flush()
        self.mapping[self._next_map_id] = (self._epoch, self._buffered)
        self._next_map_id += 1
        self._buffered += nbytes

    def flush(self) -> None:
        if self._buffered > 0:
            self.stats.write_ops += 1
            self.stats.write_bytes += self._buffered
            self.packed_flushes += 1
            self._buffered = 0
            self._epoch += 1

    def write_small(self, nbytes: int) -> None:
        if 0 < nbytes <= self.small_threshold:
            self._pack(int(nbytes))
        else:
            super().write_small(nbytes)

    def write_clusters(self, cluster_ids: Iterable[int]) -> None:
        ids = sorted(set(int(c) for c in cluster_ids))
        if not ids:
            return
        for _start, length in _runs(ids):
            nbytes = length * self.cluster_size
            if nbytes <= self.small_threshold:
                self._pack(nbytes)
            else:
                self.stats.write_ops += 1
                self.stats.write_bytes += nbytes
