"""Stream-of-clusters lifecycle engine (paper sections 4, 5, Fig. 8).

A *stream of clusters* stores one posting list (or, under TAG, the combined
posting list of a bucket of keys).  This module implements the full
strategy state machine:

    EM ──► SR0 ──► CH ──► S          (when SR is active: sets 2 and 3)
    EM ──► PART ──► [CH ──►] S       (when SR is off: set 1)

with the auxiliary strategies:

    C1  — per-phase cluster cache with a per-stream quota; indexing is
          phase-wise over key groups (caller drives begin/end_phase),
    FL  — bulk-loadable first-level tail clusters (whole clusters saved
          per phase — the waste the SR strategy eliminates),
    SR  — short-record tail accumulator in 128-byte blocks, streamed
          sequentially per phase; guarantees only FULL clusters enter
          chains (no tail read-modify-write),
    TAG — handled one level up (dictionary); streams just carry `tagged`,
    DS  — handled one level down (PackedWriteDevice).

I/O accounting policy (what reproduces Tables 2 and 3):

  * clusters that are *resident* (in the C1 cache) this phase cost nothing
    to touch; dirty residents are flushed at ``end_phase`` through
    ``BlockDevice.write_clusters`` which charges ONE op per physically
    contiguous run — this is why coalesced chains and contiguous segments
    are cheap and scattered tail clusters are expensive;
  * appending to a partial cluster written in an earlier phase requires
    reading it back first (read-modify-write) unless its bytes are covered
    by FL (bulk-loaded) or SR (tail never on disk, chain clusters full);
  * FL areas and SR files are loaded/saved sequentially once per phase:
    FL is charged whole clusters (its documented weakness), SR only its
    actual 128-byte-block bytes;
  * segment moves (S doubling, CH coalescing, CH→S conversion) read only
    non-resident source clusters and write through the cache.

Cluster *content* is tracked logically at the stream level (one byte
string per stream, plus exact per-cluster byte occupancy) — the device
traffic is what the paper measures, and search results are validated
against a posting-level oracle in the tests.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.cluster_store import LINK_BYTES, ExtentAllocator
from repro.core.io_sim import BlockDevice, IOStats
from repro.core.strategies import StrategyConfig

# stream lifecycle states (Fig. 8)
EM = "em"      # posting list embedded in the dictionary entry
SR0 = "sr0"    # SR-record only, no clusters allocated
PART = "part"  # 1/2^k sub-cluster part
CH = "ch"      # backward-linked bounded chain of segments
S = "s"        # power-of-two contiguous segments

ALL_STATES = (EM, SR0, PART, CH, S)


class DigestLog:
    """Bounded, generation-keyed history of touched-key digests.

    The writer-side publication surface of the live-update protocol:
    every published generation advance (update part, compaction fold)
    appends its touched-key digest here, and readers — local or replica
    — catch up with :meth:`since`.  The history is bounded in *entries*
    (``maxlen``) and implicitly in bytes (oversized digests are stored
    as ``None`` sentinels by the caller), so a subscriber further behind
    than the retained window gets ``None`` back and must fall back to
    the whole-namespace drop path.

    ``clear()`` exists for checkpoint restore: a reopened replica's
    bulk-applied state has no per-generation digests for the span the
    checkpoint collapsed, so the log must not answer for generations it
    cannot attribute."""

    def __init__(self, history: int):
        self._log: Deque[Tuple[int, Optional[frozenset]]] = deque(
            maxlen=max(1, int(history))
        )

    def publish(self, generation: int, digest: Optional[frozenset]) -> None:
        self._log.append((int(generation), digest))

    def since(
        self, generation: int, current: int
    ) -> Optional[List[frozenset]]:
        """Digests of every generation in ``(generation, current]`` —
        oldest first — or ``None`` when the bounded history no longer
        covers that span (or a covered digest was an oversized
        sentinel)."""
        missing = int(current) - int(generation)
        if missing <= 0:
            return []
        out = [d for g, d in self._log if g > generation]
        if len(out) != missing or any(d is None for d in out):
            return None
        return out

    def clear(self) -> None:
        self._log.clear()

    def __iter__(self):
        return iter(self._log)

    def __len__(self) -> int:
        return len(self._log)


@dataclasses.dataclass
class Segment:
    start: int        # first cluster id
    nclusters: int    # physically contiguous length
    used: int         # payload bytes stored in this segment

    @property
    def ids(self) -> range:
        return range(self.start, self.start + self.nclusters)


@dataclasses.dataclass
class Stream:
    sid: int
    group: int
    tagged: bool = False
    state: str = EM
    data: bytearray = dataclasses.field(default_factory=bytearray)
    # EM/SR0 hold everything in `data`; cluster states split `data` into
    # segment payloads + tail (FL or SR) bytes, tracked by byte counts.
    segments: List[Segment] = dataclasses.field(default_factory=list)
    part_cluster: int = -1
    part_size: int = 0
    fl_bytes: int = 0        # bytes currently in the FL tail cluster
    has_fl: bool = False
    sr_bytes: int = 0        # bytes currently in the SR record
    has_sr: bool = False
    chain_limit: int = 0     # per-stream CH limit (5.7.3 jitter)
    last_doc: int = 0        # delta-encoding continuation point
    n_keys: int = 1          # number of keys sharing this stream (TAG)

    @property
    def total_bytes(self) -> int:
        return len(self.data)

    def segment_bytes(self) -> int:
        return sum(s.used for s in self.segments)


class StreamManager:
    """Owns every stream; drives the lifecycle; charges all index I/O."""

    def __init__(
        self,
        cfg: StrategyConfig,
        device: BlockDevice,
        n_groups: int,
        name: str = "index",
        fl_area_clusters: int = 8192,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.device = device
        self.n_groups = max(1, int(n_groups))
        self.name = name
        self.alloc = ExtentAllocator()
        self.streams: Dict[int, Stream] = {}
        self._next_sid = 0
        self._rng = np.random.RandomState(seed)

        # FL area budget (whole area is bulk loaded/saved per phase, grouped
        # by key group so each phase touches only its own FL clusters).
        self.fl_area_clusters = int(fl_area_clusters) if cfg.use_fl else 0
        self._fl_used_clusters = 0
        self._fl_streams_by_group: Dict[int, List[int]] = {}

        # SR bookkeeping (5.8): RAM budget per phase; SR file per group.
        self._sr_streams_by_group: Dict[int, List[int]] = {}
        self._sr_group_bytes: Dict[int, int] = {}

        # PART clusters are shared: per (group, part_size) open clusters
        # with free slots.  {(group, size): [(cluster_id, [free slots])]}
        self._part_open: Dict[Tuple[int, int], List[Tuple[int, List[int]]]] = {}
        self._part_members: Dict[int, int] = {}  # cluster -> live part count

        # phase (C1) state
        self._phase_group: Optional[int] = None
        self._resident: Dict[int, set] = {}   # sid -> resident cluster ids
        self._dirty: Dict[int, set] = {}      # sid -> dirty cluster ids
        self._part_resident: set = set()      # shared PART clusters read/written
        self._part_dirty: set = set()

        # census of lifecycle transitions (for the Fig. 8 benchmark)
        self.transitions: Dict[Tuple[str, str], int] = {}

    # ------------------------------------------------------------ utilities --
    @property
    def cluster_size(self) -> int:
        return self.cfg.cluster_size

    @property
    def cluster_cap(self) -> int:
        return self.cfg.cluster_size - LINK_BYTES

    def seg_cap(self, seg: Segment) -> int:
        return seg.nclusters * self.cluster_size - LINK_BYTES

    def new_stream(self, group: int, tagged: bool = False) -> int:
        sid = self._next_sid
        self._next_sid += 1
        st = Stream(sid=sid, group=group % self.n_groups, tagged=tagged)
        lim = self.cfg.chain_limit
        if self.cfg.chain_limit_jitter:
            lim -= int(self._rng.randint(0, self.cfg.chain_limit_jitter + 1))
        st.chain_limit = max(2, lim)
        self.streams[sid] = st
        return sid

    def _note(self, a: str, b: str) -> None:
        self.transitions[(a, b)] = self.transitions.get((a, b), 0) + 1

    # --------------------------------------------------------------- phases --
    def begin_phase(self, group: int) -> None:
        assert self._phase_group is None, "phase already open"
        self._phase_group = group % self.n_groups
        self._resident = {}
        self._dirty = {}
        self._part_resident = set()
        self._part_dirty = set()
        # FL bulk load: the whole FL area of this group, whole clusters (5.5).
        fl_sids = self._fl_streams_by_group.get(self._phase_group, [])
        if fl_sids:
            self.device.read_sequential(len(fl_sids) * self.cluster_size)
        # SR file load: only actual block bytes, sequential (5.8).
        sr_bytes = self._sr_group_bytes.get(self._phase_group, 0)
        if sr_bytes:
            self.device.read_sequential(_blocks(sr_bytes, self.cfg.sr_block))

    def end_phase(self) -> None:
        assert self._phase_group is not None, "no open phase"
        group = self._phase_group
        # flush dirty cached clusters; contiguous runs are single ops
        for sid, ids in self._dirty.items():
            if ids:
                self.device.write_clusters(ids)
        if self._part_dirty:
            self.device.write_clusters(self._part_dirty)
        # FL bulk save: whole clusters, even half-empty ones (the FL waste)
        fl_sids = self._fl_streams_by_group.get(group, [])
        if fl_sids:
            self.device.write_sequential(len(fl_sids) * self.cluster_size)
        # SR file save: actual block bytes
        sr_bytes = self._sr_group_bytes.get(group, 0)
        if sr_bytes:
            self.device.write_sequential(_blocks(sr_bytes, self.cfg.sr_block))
        self.device.flush()  # DS buffer boundary
        self._phase_group = None
        self._resident = {}
        self._dirty = {}
        self._part_resident = set()
        self._part_dirty = set()

    # residency helpers ------------------------------------------------------
    def _res(self, sid: int) -> set:
        return self._resident.setdefault(sid, set())

    def _mark_dirty(self, sid: int, ids: Iterable[int]) -> None:
        ids = set(ids)
        self._res(sid).update(ids)
        self._dirty.setdefault(sid, set()).update(ids)
        self._enforce_quota(sid)

    def _enforce_quota(self, sid: int) -> None:
        """C1: a stream may keep at most `cache_clusters_per_stream` clusters
        resident; overflow is flushed immediately (oldest = lowest ids of
        non-tail segments first)."""
        quota = self.cfg.cache_clusters_per_stream
        res = self._res(sid)
        if len(res) <= quota:
            return
        st = self.streams[sid]
        # candidate flush order: clusters of non-tail segments, then tail
        ordered: List[int] = []
        for seg in st.segments[:-1]:
            ordered.extend(c for c in seg.ids if c in res)
        if st.segments:
            ordered.extend(c for c in st.segments[-1].ids if c in res)
        extra = [c for c in res if c not in set(ordered)]
        ordered.extend(sorted(extra))
        to_flush = ordered[: len(res) - quota]
        dirty = self._dirty.get(sid, set())
        flush_dirty = [c for c in to_flush if c in dirty]
        if flush_dirty:
            self.device.write_clusters(flush_dirty)
            dirty.difference_update(flush_dirty)
        res.difference_update(to_flush)

    def _ensure_resident(self, sid: int, ids: Iterable[int]) -> None:
        """Read the given clusters unless already resident (charges reads)."""
        ids = set(ids)
        res = self._res(sid)
        missing = ids - res
        if missing:
            self.device.read_clusters(missing)
            res.update(missing)
            self._enforce_quota(sid)

    # ------------------------------------------------------------- appends --
    def append_stream(self, sid: int, chunk: bytes) -> None:
        """Append an encoded posting chunk to a stream (within a phase)."""
        assert self._phase_group is not None, "appends happen inside a phase"
        st = self.streams[sid]
        assert st.group == self._phase_group, (
            f"stream {sid} of group {st.group} touched in phase "
            f"{self._phase_group} — C1 grouping violated"
        )
        if not chunk:
            return
        st.data += chunk
        n = len(chunk)
        cfg = self.cfg

        if st.state == EM:
            if cfg.use_em and st.total_bytes <= cfg.em_limit:
                return  # still embedded; dictionary traffic covers it
            self._leave_em(st)
        if st.state == SR0:
            self._grow_sr0(st)
            return
        if st.state == PART:
            self._grow_part(st)
            return
        if st.state in (CH, S):
            self._append_tail(st, n)
            return
        raise AssertionError(st.state)

    # --- EM exit --------------------------------------------------------------
    def _leave_em(self, st: Stream) -> None:
        cfg = self.cfg
        if cfg.use_sr and self._sr_admit(st):
            self._note(EM, SR0)
            st.state = SR0
            self._grow_sr0(st)
        elif cfg.use_part and st.total_bytes <= cfg.cluster_size // 2:
            self._note(EM, PART)
            st.state = PART
            self._part_place(st, st.total_bytes)
        else:
            self._note(EM, CH if cfg.use_ch else S)
            st.state = CH if cfg.use_ch else S
            self._tail_init(st)
            self._append_tail(st, 0)

    # --- SR -------------------------------------------------------------------
    def _sr_admit(self, st: Stream) -> bool:
        """SR RAM budget check (5.8): SR applies only to a subset of streams."""
        g = st.group
        if st.has_sr:
            return True
        used = self._sr_group_bytes.get(g, 0)
        budget = self.cfg.sr_memory_limit // self.n_groups
        if used + self.cfg.sr_block > budget:
            return False
        st.has_sr = True
        self._sr_streams_by_group.setdefault(g, []).append(st.sid)
        return True

    def _sr_account(self, st: Stream, new_bytes: int) -> None:
        g = st.group
        self._sr_group_bytes[g] = (
            self._sr_group_bytes.get(g, 0) - st.sr_bytes + new_bytes
        )
        st.sr_bytes = new_bytes

    def _grow_sr0(self, st: Stream) -> None:
        """SR0: everything lives in the SR record until it exceeds a cluster."""
        cfg = self.cfg
        if st.total_bytes <= cfg.cluster_size:
            self._sr_account(st, st.total_bytes)
            return
        # SR record overflows a cluster: move to CH/S, keep SR as tail (Fig. 8)
        nxt = CH if cfg.use_ch else S
        self._note(SR0, nxt)
        st.state = nxt
        self._tail_init(st)
        self._append_tail(st, 0)

    # --- PART -------------------------------------------------------------------
    def _part_place(self, st: Stream, need: int) -> None:
        """Place `need` bytes into the smallest sufficient part (5.3)."""
        for size in self.cfg.part_sizes():
            if need <= size - 2:  # 2 bytes of per-part metadata
                self._part_assign(st, size)
                return
        # larger than the biggest part: promote out of PART
        self._part_promote_out(st)

    def _part_assign(self, st: Stream, size: int) -> None:
        group = st.group
        key = (group, size)
        open_list = self._part_open.setdefault(key, [])
        if not open_list:
            cid = self.alloc.alloc(1)
            slots = list(range(self.cfg.cluster_size // size))
            open_list.append((cid, slots))
            # a brand-new PART cluster is resident+dirty this phase
            self._part_resident.add(cid)
        cid, slots = open_list[0]
        if cid not in self._part_resident:
            # shared cluster written in an earlier phase: read-modify-write
            self.device.read_clusters([cid])
            self._part_resident.add(cid)
        slots.pop()
        if not slots:
            open_list.pop(0)
        self._part_dirty.add(cid)
        self._part_members[cid] = self._part_members.get(cid, 0) + 1
        st.part_cluster = cid
        st.part_size = size

    def _part_release(self, st: Stream) -> None:
        cid = st.part_cluster
        if cid < 0:
            return
        self._part_members[cid] = self._part_members.get(cid, 1) - 1
        size = st.part_size
        # return the slot for reuse
        open_list = self._part_open.setdefault((st.group, size), [])
        for i, (c, slots) in enumerate(open_list):
            if c == cid:
                slots.append(0)
                break
        else:
            open_list.append((cid, [0]))
        if self._part_members.get(cid, 0) <= 0:
            self._part_members.pop(cid, None)
        st.part_cluster = -1
        st.part_size = 0

    def _grow_part(self, st: Stream) -> None:
        need = st.total_bytes
        if need <= st.part_size - 2:
            # still fits; the cluster must be in RAM to modify it
            cid = st.part_cluster
            if cid not in self._part_resident:
                self.device.read_clusters([cid])
                self._part_resident.add(cid)
            self._part_dirty.add(cid)
            return
        # outgrew the part: move to a larger part or out of PART (5.3)
        if need <= self.cfg.cluster_size // 2:
            # data must be in RAM for the move
            cid = st.part_cluster
            if cid not in self._part_resident:
                self.device.read_clusters([cid])
                self._part_resident.add(cid)
            self._part_release(st)
            self._part_place(st, need)
        else:
            self._part_promote_out(st)

    def _part_promote_out(self, st: Stream) -> None:
        """PART → CH/S: the stream gets real clusters (Fig. 8)."""
        cid = st.part_cluster
        if cid >= 0 and cid not in self._part_resident:
            self.device.read_clusters([cid])
            self._part_resident.add(cid)
        self._part_release(st)
        nxt = CH if self.cfg.use_ch else S
        self._note(PART, nxt)
        st.state = nxt
        self._tail_init(st)
        self._append_tail(st, 0)

    # --- tail buffers (FL / SR) --------------------------------------------------
    def _tail_init(self, st: Stream) -> None:
        """Give a fresh CH/S stream its tail accumulator."""
        cfg = self.cfg
        if cfg.use_sr and self._sr_admit(st):
            pass  # SR tail
        elif cfg.use_fl and not cfg.use_sr and not st.has_fl:
            if self._fl_used_clusters < self.fl_area_clusters:
                st.has_fl = True
                self._fl_used_clusters += 1
                self._fl_streams_by_group.setdefault(st.group, []).append(st.sid)

    def _tail_capacity(self, st: Stream) -> int:
        if st.has_sr:
            return self.cluster_cap  # SR record is limited by cluster size
        if st.has_fl:
            return self.cluster_cap
        return self.cluster_cap  # direct tail: partial last cluster

    def _append_tail(self, st: Stream, _n: int) -> None:
        """Drain stream bytes not yet in segments into tail + full clusters."""
        cfg = self.cfg
        while True:
            pending = st.total_bytes - st.segment_bytes()
            tail_cap = self._tail_capacity(st)
            if st.has_sr:
                if pending <= tail_cap:
                    self._sr_account(st, pending)
                    return
                # SR overflow: emit exactly one FULL cluster into the stream
                self._emit_full_cluster(st)
                self._sr_account(st, st.total_bytes - st.segment_bytes())
            elif st.has_fl:
                if pending <= tail_cap:
                    st.fl_bytes = pending
                    return
                self._emit_full_cluster(st)
                st.fl_bytes = st.total_bytes - st.segment_bytes()
            else:
                # direct append into the last cluster of the last segment
                if not self._emit_direct(st):
                    return

    def _emit_full_cluster(self, st: Stream) -> None:
        """One full cluster of data leaves the tail buffer into the chain or
        the last segment.  Under SR this is the paper's key invariant: the
        cluster is complete, so it is never read back (5.8)."""
        if st.state == CH:
            self._chain_add_cluster(st)
        else:
            self._segment_add_bytes(st, self.cluster_cap, full_only=True)

    # --- CH: bounded backward-linked chain (5.7) ----------------------------------
    def _chain_add_cluster(self, st: Stream) -> None:
        cfg = self.cfg
        res = self._res(st.sid)
        # coalesce resident tail segments with the new cluster (5.7.2):
        # collect trailing segments that are fully resident
        merged: List[Segment] = []
        for seg in reversed(st.segments):
            if all(c in res for c in seg.ids):
                merged.append(seg)
            else:
                break
        merged.reverse()
        if len(merged) >= max(1, cfg.ch_min_merge_segments - 1):
            moved_bytes = sum(s.used for s in merged)
            need = moved_bytes + self.cluster_cap
            ncl = _ceil_div(need + LINK_BYTES, self.cluster_size)
            # respect the cache quota: never build a resident segment bigger
            # than the stream's quota
            if ncl <= cfg.cache_clusters_per_stream:
                old_ids = [c for s in merged for c in s.ids]
                new = Segment(self.alloc.alloc(ncl), ncl, need)
                for s in merged:
                    st.segments.remove(s)
                st.segments.append(new)
                # free + recycle old clusters (5.7.1 step 4); drop residency
                if old_ids:
                    runs = _id_runs(sorted(old_ids))
                    for s0, l0 in runs:
                        self.alloc.free(s0, l0)
                    res.difference_update(old_ids)
                    d = self._dirty.get(st.sid, set())
                    d.difference_update(old_ids)
                self._mark_dirty(st.sid, new.ids)
                self._chain_check_limit(st)
                return
        # no coalescing possible: append a single-cluster segment
        seg = Segment(self.alloc.alloc(1), 1, self.cluster_cap)
        st.segments.append(seg)
        self._mark_dirty(st.sid, seg.ids)
        self._chain_check_limit(st)

    def _chain_check_limit(self, st: Stream) -> None:
        """5.7.3: chain length is counted in segments; convert to S at limit."""
        if len(st.segments) > st.chain_limit:
            self._convert_chain_to_segment(st)

    def _convert_chain_to_segment(self, st: Stream) -> None:
        """CH → S (5.7.1): read the chain, write one big segment, recycle."""
        res = self._res(st.sid)
        non_resident = []
        for seg in st.segments:
            missing = [c for c in seg.ids if c not in res]
            non_resident.extend(missing)
        if non_resident:
            self.device.read_clusters(non_resident)
        total = st.segment_bytes()
        ncl = _pow2_at_least(_ceil_div(total + LINK_BYTES, self.cluster_size))
        old_ids = [c for seg in st.segments for c in seg.ids]
        new = Segment(self.alloc.alloc(ncl), ncl, total)
        st.segments = [new]
        for s0, l0 in _id_runs(sorted(old_ids)):
            self.alloc.free(s0, l0)
        res.difference_update(old_ids)
        d = self._dirty.get(st.sid, set())
        d.difference_update(old_ids)
        if new.nclusters <= self.cfg.cache_clusters_per_stream:
            self._mark_dirty(st.sid, new.ids)
        else:
            self.device.write_clusters(new.ids)
        self._note(CH, S)
        st.state = S

    # --- S: power-of-two segments (5.4) -------------------------------------------
    def _segment_add_bytes(self, st: Stream, nbytes: int, full_only: bool = False) -> None:
        """Add `nbytes` of payload to the S-stream's last segment, growing by
        doubling up to seg_max, then by linking max-size segments."""
        cfg = self.cfg
        remaining = nbytes
        while remaining > 0:
            if not st.segments:
                st.segments.append(Segment(self.alloc.alloc(1), 1, 0))
                self._mark_dirty(st.sid, st.segments[-1].ids)
            last = st.segments[-1]
            room = self.seg_cap(last) - last.used
            if room > 0:
                take = min(room, remaining)
                # clusters being written must be resident (they are new or
                # bulk-covered by FL/SR; a partial tail written in an earlier
                # phase must be read back: read-modify-write)
                first_c = last.start + last.used // self.cluster_size
                last_c = last.start + (last.used + take - 1) // self.cluster_size
                partial_tail = (last.used % self.cluster_size) != 0
                if partial_tail and not (st.has_sr or st.has_fl):
                    self._ensure_resident(st.sid, [first_c])
                last.used += take
                remaining -= take
                self._mark_dirty(st.sid, range(first_c, last_c + 1))
                continue
            # last segment full: grow
            if last.nclusters < cfg.seg_max:
                self._segment_double(st)
            else:
                st.segments.append(
                    Segment(self.alloc.alloc(cfg.seg_max), cfg.seg_max, 0)
                )
                self._mark_dirty(st.sid, [])  # allocation only

    def _segment_double(self, st: Stream) -> None:
        """Allocate 2x segment, move the data into its first half (5.4)."""
        last = st.segments[-1]
        res = self._res(st.sid)
        missing = [c for c in last.ids if c not in res]
        if missing:
            self.device.read_clusters(missing)
        new_len = min(max(1, last.nclusters * 2), self.cfg.seg_max)
        if new_len <= last.nclusters:
            new_len = last.nclusters * 2  # seg_max not power-aligned; allow
        new = Segment(self.alloc.alloc(new_len), new_len, last.used)
        st.segments[-1] = new
        self.alloc.free(last.start, last.nclusters)
        res.difference_update(last.ids)
        d = self._dirty.get(st.sid, set())
        d.difference_update(last.ids)
        used_clusters = _ceil_div(new.used, self.cluster_size) or 1
        if new_len <= self.cfg.cache_clusters_per_stream:
            self._mark_dirty(st.sid, range(new.start, new.start + used_clusters))
        else:
            self.device.write_clusters(range(new.start, new.start + used_clusters))

    def _emit_direct(self, st: Stream) -> bool:
        """No tail buffer: append pending bytes straight into segments.
        Returns False when nothing is pending."""
        pending = st.total_bytes - st.segment_bytes()
        if pending <= 0:
            return False
        if st.state == CH:
            # chains without SR: fill the tail cluster of the last segment
            # (read-modify-write if it was flushed in an earlier phase)
            last = st.segments[-1] if st.segments else None
            if last is not None and last.used < self.seg_cap(last):
                tail_c = last.start + last.used // self.cluster_size
                if last.used % self.cluster_size:
                    self._ensure_resident(st.sid, [tail_c])
                take = min(self.seg_cap(last) - last.used, pending)
                end_c = last.start + (last.used + take - 1) // self.cluster_size
                last.used += take
                self._mark_dirty(st.sid, range(tail_c, end_c + 1))
            else:
                take = min(self.cluster_cap, pending)
                if take < pending:
                    self._chain_add_cluster(st)  # full cluster
                else:
                    seg = Segment(self.alloc.alloc(1), 1, take)
                    st.segments.append(seg)
                    self._mark_dirty(st.sid, seg.ids)
                    self._chain_check_limit(st)
            return st.total_bytes - st.segment_bytes() > 0
        self._segment_add_bytes(st, pending)
        return False

    # ------------------------------------------------------------- reading --
    def read_stream(self, sid: int, device: Optional[BlockDevice] = None) -> bytes:
        """Read a stream's full posting data, charging search I/O:
        one op per physically contiguous segment, one per PART cluster,
        one small read for the SR record, one for the FL cluster.

        ``device`` lets readers charge their own accounting device (the
        reader/writer split in ``repro.search.reader``); the default is
        the manager's build device."""
        dev = device if device is not None else self.device
        st = self.streams[sid]
        if st.state == EM:
            return bytes(st.data)  # dictionary-resident: no extra device op
        if st.state == SR0:
            dev.read_small(_blocks(st.sr_bytes, self.cfg.sr_block))
            return bytes(st.data)
        if st.state == PART:
            dev.read_clusters([st.part_cluster])
            return bytes(st.data)
        # CH / S
        for seg in st.segments:
            dev.read_clusters(seg.ids)
        if st.has_sr and st.sr_bytes:
            dev.read_small(_blocks(st.sr_bytes, self.cfg.sr_block))
        if st.has_fl and st.fl_bytes:
            dev.read_sequential(self.cluster_size)  # FL cluster: one op
        return bytes(st.data)

    def stream_snapshot(self, sid: int) -> bytes:
        """Open-time copy of a stream's logical payload, for
        snapshot-consistent lazy cursors.

        Charges NO device I/O: the cursor's storage units carry the
        open-time charge closures, and this copy is what those units
        decode from.  Pinning the bytes at open matters for streams whose
        payload is not append-only — TAG bucket streams are rewritten in
        place when a member is extracted (5.6), so a cursor drained after
        a mid-update extraction would otherwise decode the rewritten
        bucket (its own tag slot possibly retired) instead of the
        snapshot it was opened against.  Dedicated (OWN) streams only
        ever append, so their cursors pin layout by slicing fixed byte
        ranges and need no copy."""
        return bytes(self.streams[sid].data)

    def stream_read_units(
        self, sid: int, chunk_clusters: int = 0
    ) -> List[Tuple[int, int, "Callable[[BlockDevice], None]"]]:
        """Payload-ordered storage units of one stream, for lazy cursors.

        Returns ``[(payload_bytes, charge_bytes, charge), ...]`` covering
        the stream's byte payload in order: segments first (the stream's
        oldest bytes), then the SR record and FL cluster tails.  ``charge``
        performs exactly the device accounting a read of that unit costs
        and ``charge_bytes`` is the read bytes it will add — reading every
        unit charges the same bytes as :meth:`read_stream`, so a caller
        that stops early saves exactly the remaining units' bytes.
        ``chunk_clusters > 0`` splits contiguous segments into ranges of
        at most that many clusters so a cursor can stop mid-segment.
        """
        st = self.streams[sid]
        units: List[Tuple[int, int, "Callable[[BlockDevice], None]"]] = []
        if st.total_bytes == 0:
            return units
        if st.state == EM:
            # dictionary-resident: the entry read already covered the bytes
            units.append((st.total_bytes, 0, lambda dev: None))
            return units
        if st.state == SR0:
            nb = _blocks(st.sr_bytes, self.cfg.sr_block)
            units.append(
                (st.total_bytes, nb, lambda dev, nb=nb: dev.read_small(nb))
            )
            return units
        if st.state == PART:
            cid = st.part_cluster
            units.append((
                st.total_bytes, self.cluster_size,
                lambda dev, cid=cid: dev.read_clusters([cid]),
            ))
            return units
        # CH / S: payload = segment bytes (in list order) + SR/FL tail
        covered = (
            st.segment_bytes()
            + (st.sr_bytes if st.has_sr else 0)
            + (st.fl_bytes if st.has_fl else 0)
        )
        if covered != st.total_bytes:
            # unknown layout (defensive): one unit with read_stream charges
            def charge_all(dev, st=st):
                for seg in st.segments:
                    dev.read_clusters(seg.ids)
                if st.has_sr and st.sr_bytes:
                    dev.read_small(_blocks(st.sr_bytes, self.cfg.sr_block))
                if st.has_fl and st.fl_bytes:
                    dev.read_sequential(self.cluster_size)

            nb = sum(s.nclusters for s in st.segments) * self.cluster_size
            if st.has_sr and st.sr_bytes:
                nb += _blocks(st.sr_bytes, self.cfg.sr_block)
            if st.has_fl and st.fl_bytes:
                nb += self.cluster_size
            units.append((st.total_bytes, nb, charge_all))
            return units
        cs = self.cluster_size
        for seg in st.segments:
            if seg.used <= 0:
                continue
            if chunk_clusters and seg.nclusters > chunk_clusters:
                off = 0
                c0 = 0
                while c0 < seg.nclusters and off < seg.used:
                    c1 = min(seg.nclusters, c0 + chunk_clusters)
                    hi = min(seg.used, c1 * cs)
                    if hi >= seg.used:
                        # the payload ends inside this chunk: absorb the
                        # segment's trailing allocated clusters so a
                        # drained cursor charges exactly what a whole-
                        # segment read_clusters(seg.ids) charges
                        c1 = seg.nclusters
                    ids = range(seg.start + c0, seg.start + c1)
                    units.append((
                        hi - off, len(ids) * cs,
                        lambda dev, ids=ids: dev.read_clusters(ids),
                    ))
                    off = hi
                    c0 = c1
            else:
                units.append((
                    seg.used, seg.nclusters * cs,
                    lambda dev, ids=seg.ids: dev.read_clusters(ids),
                ))
        if st.has_sr and st.sr_bytes:
            nb = _blocks(st.sr_bytes, self.cfg.sr_block)
            units.append(
                (st.sr_bytes, nb, lambda dev, nb=nb: dev.read_small(nb))
            )
        if st.has_fl and st.fl_bytes:
            units.append((
                st.fl_bytes, cs,
                lambda dev: dev.read_sequential(self.cluster_size),
            ))
        return units

    def read_ops_estimate(self, sid: int) -> int:
        """Number of device operations a search of this stream costs."""
        st = self.streams[sid]
        if st.state == EM:
            return 0
        if st.state in (SR0, PART):
            return 1
        ops = len(st.segments)
        if st.has_sr and st.sr_bytes:
            ops += 1
        if st.has_fl and st.fl_bytes:
            ops += 1
        return ops

    # ------------------------------------------------------- compaction --
    def compact_stream(self, sid: int) -> bool:
        """Fold one CH/S stream's scattered storage into a single tight
        contiguous EM-tier segment (the background-compaction primitive:
        small update parts accumulated across many phases become one
        large external-memory run).

        Runs BETWEEN phases (maintenance, not indexing): charges a read
        of the stream's current layout — exactly what :meth:`read_stream`
        charges — plus one contiguous segment write, both on the build
        device.  The stream's logical payload is untouched, so open
        cursors keep draining their open-time snapshot (their charge
        closures price the open-time layout) and decoded posting lists
        stay valid; only the physical layout changes.  SR/FL tail
        membership is released: the folded stream is a finished run with
        no accumulator (later appends take the direct path, like any
        stream past the tail budgets).

        Returns ``False`` — charging and changing NOTHING — when the
        stream is not CH/S, is empty, already sits in one tight segment,
        or folding would make reads MORE expensive (an SR/FL tail is
        charged at sub-cluster granularity; folding a short stream whose
        bytes mostly live in its tail rounds that up to whole clusters —
        the accumulator is already the cheap layout, which is the point
        of the paper's tail constructions): a no-op compaction cycle
        must be a real no-op.
        """
        assert self._phase_group is None, "compaction runs between phases"
        st = self.streams[sid]
        if st.state not in (CH, S) or st.total_bytes <= 0:
            return False
        total = st.total_bytes
        need = _ceil_div(total + LINK_BYTES, self.cluster_size)
        allocated = sum(s.nclusters for s in st.segments)
        multi_unit = (
            len(st.segments) > 1
            or (st.has_sr and st.sr_bytes > 0)
            or (st.has_fl and st.fl_bytes > 0)
        )
        if not multi_unit and allocated <= need:
            return False
        cur_charge = allocated * self.cluster_size
        if st.has_sr and st.sr_bytes:
            cur_charge += _blocks(st.sr_bytes, self.cfg.sr_block)
        if st.has_fl and st.fl_bytes:
            cur_charge += self.cluster_size
        if need * self.cluster_size > cur_charge:
            return False
        # maintenance read of the whole current layout (segments + tails)
        self.read_stream(sid)
        # release the SR/FL tail: the compact run carries no accumulator
        if st.has_sr:
            self._sr_account(st, 0)
            group_sids = self._sr_streams_by_group.get(st.group, [])
            if sid in group_sids:
                group_sids.remove(sid)
            st.has_sr = False
        if st.has_fl:
            fl_sids = self._fl_streams_by_group.get(st.group, [])
            if sid in fl_sids:
                fl_sids.remove(sid)
                self._fl_used_clusters -= 1
            st.has_fl = False
            st.fl_bytes = 0
        old_ids = [c for seg in st.segments for c in seg.ids]
        new = Segment(self.alloc.alloc(need), need, total)
        self.device.write_clusters(new.ids)
        st.segments = [new]
        for s0, l0 in _id_runs(sorted(old_ids)):
            self.alloc.free(s0, l0)
        if st.state != S:
            self._note(st.state, S)
            st.state = S
        return True

    # ----------------------------------------------------- TAG maintenance --
    def rewrite_stream(self, sid: int, new_data: bytes, last_doc: int) -> None:
        """Replace a stream's contents (TAG extraction, 5.6).  The stream is
        rebuilt in place: old clusters freed, data re-emitted through the
        current lifecycle rules."""
        st = self.streams[sid]
        old_ids = [c for seg in st.segments for c in seg.ids]
        if old_ids:
            for s0, l0 in _id_runs(sorted(old_ids)):
                self.alloc.free(s0, l0)
            res = self._res(st.sid)
            res.difference_update(old_ids)
            d = self._dirty.get(st.sid, set())
            d.difference_update(old_ids)
        if st.state == PART:
            self._part_release(st)
        if st.has_sr:
            self._sr_account(st, 0)
        st.segments = []
        st.fl_bytes = 0
        st.data = bytearray()
        st.state = EM
        st.last_doc = last_doc
        if new_data:
            self.append_stream(sid, bytes(new_data))

    # ------------------------------------------------------------- reports --
    def state_census(self) -> Dict[str, int]:
        census = {s: 0 for s in ALL_STATES}
        for st in self.streams.values():
            census[st.state] += 1
        return census

    def storage_clusters(self) -> int:
        return self.alloc.capacity_high_water + self._fl_used_clusters


# ------------------------------------------------------------------ helpers --
def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def _blocks(nbytes: int, block: int) -> int:
    """Bytes rounded up to SR block granularity."""
    return _ceil_div(max(0, nbytes), block) * block


def _id_runs(sorted_ids: List[int]) -> List[Tuple[int, int]]:
    runs: List[Tuple[int, int]] = []
    start = prev = None
    for cid in sorted_ids:
        if start is None:
            start = prev = cid
            continue
        if cid == prev + 1:
            prev = cid
            continue
        runs.append((start, prev - start + 1))
        start = prev = cid
    if start is not None:
        runs.append((start, prev - start + 1))
    return runs
