"""Posting codec: (doc_id, position) records with varint delta encoding.

A posting is the paper's two-field record ``(ID, P)``: document identifier
and ordinal word position (section 1).  Posting lists are kept sorted by
``(doc_id, position)`` and encoded as byte streams:

  * doc_id is delta-encoded against the previous posting's doc_id,
  * position is delta-encoded within a document (and absolute when the
    doc_id changes),
  * TAG streams (section 5.6) prepend a per-posting local key tag varint.

Varints are LEB128 (7 bits per byte, high bit = continue).  The codec is
the single source of truth for *sizes*: every strategy decision in
``stream.py`` is driven by encoded byte counts, exactly as the paper's
strategies are driven by data sizes.

A vectorized (numpy) bulk encoder is provided because index construction
benchmarks push tens of millions of postings through this path.
"""

from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import numpy as np

Posting = Tuple[int, int]  # (doc_id, position)


# ----------------------------------------------------------------- varint ---
def encode_varint(value: int, out: bytearray) -> None:
    assert value >= 0
    while True:
        b = value & 0x7F
        value >>= 7
        if value:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def decode_varint(buf: bytes, offset: int) -> Tuple[int, int]:
    result = 0
    shift = 0
    while True:
        b = buf[offset]
        offset += 1
        result |= (b & 0x7F) << shift
        if not b & 0x80:
            return result, offset
        shift += 7


def varint_size(value: int) -> int:
    if value < (1 << 7):
        return 1
    size = 1
    value >>= 7
    while value:
        size += 1
        value >>= 7
    return size


# ------------------------------------------------------- bulk numpy encode ---
def _varint_sizes(values: np.ndarray) -> np.ndarray:
    """Vectorized LEB128 encoded-size computation."""
    v = values.astype(np.uint64)
    sizes = np.ones(v.shape, dtype=np.int64)
    bound = np.uint64(1 << 7)
    while True:
        bigger = v >= bound
        if not bigger.any():
            return sizes
        sizes += bigger.astype(np.int64)
        if int(bound) >= (1 << 56):
            return sizes
        bound = np.uint64(int(bound) << 7)


def _bulk_varint_encode(values: np.ndarray) -> bytes:
    """Encode a flat array of non-negative ints as concatenated varints."""
    values = values.astype(np.uint64, copy=False)
    sizes = _varint_sizes(values)
    total = int(sizes.sum())
    out = np.empty(total, dtype=np.uint8)
    offsets = np.concatenate(([0], np.cumsum(sizes)[:-1]))
    max_size = int(sizes.max()) if sizes.size else 1
    v = values.copy()
    for byte_i in range(max_size):
        active = sizes > byte_i
        if not active.any():
            break
        idx = offsets[active] + byte_i
        chunk = (v[active] & np.uint64(0x7F)).astype(np.uint8)
        more = sizes[active] > (byte_i + 1)
        chunk = chunk | (more.astype(np.uint8) << 7)
        out[idx] = chunk
        v[active] = v[active] >> np.uint64(7)
    return out.tobytes()


def _encode_small(arr, tags, prev_doc: int, zigzag: bool) -> bytes:
    """Scalar fast path: numpy per-call overhead dominates below ~32 rows."""
    out = bytearray()
    rows = arr.tolist()
    tag_list = None if tags is None else np.asarray(tags).tolist()
    pd = prev_doc
    pp = 0
    first = True
    for i, (doc, pos) in enumerate(rows):
        dd = doc - pd
        if not first and dd == 0:
            pv = pos - pp
        else:
            pv = pos
        if zigzag:
            dd = _zz(dd)
            pv = _zz(pv)
        else:
            assert dd >= 0 and pv >= 0, "postings must be sorted"
        if tag_list is not None:
            encode_varint(tag_list[i], out)
        encode_varint(dd, out)
        encode_varint(pv, out)
        pd, pp = doc, pos
        first = False
    return bytes(out)


def _zz(v: int) -> int:
    return (v << 1) if v >= 0 else ((-v) << 1) - 1


def _zigzag(v: np.ndarray) -> np.ndarray:
    return (v << 1) ^ (v >> 63)


def _unzigzag(v: int) -> int:
    return (v >> 1) ^ -(v & 1)


def encode_postings(
    postings: Sequence[Posting] | np.ndarray,
    tags: Sequence[int] | np.ndarray | None = None,
    prev_doc: int = 0,
    zigzag: bool = False,
) -> bytes:
    """Encode a posting list batch; returns the byte stream.

    ``postings`` is an (N, 2) array-like of (doc_id, position).  If ``tags``
    is given (TAG strategy), each posting is prefixed with its local key tag.
    ``prev_doc`` is the delta continuation point: the last doc_id already
    stored in the stream this batch is appended to, so that concatenated
    batches decode as one list.  ``zigzag`` encodes signed deltas — required
    for TAG streams, where batches of different keys interleave doc ranges.
    """
    arr = np.asarray(postings, dtype=np.int64)
    if arr.size == 0:
        return b""
    assert arr.ndim == 2 and arr.shape[1] == 2
    if arr.shape[0] <= 32:
        return _encode_small(arr, tags, prev_doc, zigzag)
    doc = arr[:, 0]
    pos = arr[:, 1]
    doc_delta = np.empty_like(doc)
    doc_delta[0] = doc[0] - prev_doc
    doc_delta[1:] = doc[1:] - doc[:-1]
    same_doc = np.concatenate(([False], doc_delta[1:] == 0))
    pos_delta = np.where(
        same_doc, pos - np.concatenate(([0], pos[:-1])), pos
    )
    if zigzag:
        doc_delta = _zigzag(doc_delta)
        pos_delta = _zigzag(pos_delta)
    else:
        assert (doc_delta >= 0).all(), "postings must be sorted by doc_id"
        assert (pos_delta >= 0).all(), "positions must be sorted within a doc"
    if tags is None:
        flat = np.empty(arr.shape[0] * 2, dtype=np.int64)
        flat[0::2] = doc_delta
        flat[1::2] = pos_delta
    else:
        t = np.asarray(tags, dtype=np.int64)
        assert t.shape[0] == arr.shape[0]
        flat = np.empty(arr.shape[0] * 3, dtype=np.int64)
        flat[0::3] = t
        flat[1::3] = doc_delta
        flat[2::3] = pos_delta
    return _bulk_varint_encode(flat)


def decode_postings(
    data: bytes, tagged: bool = False, zigzag: bool = False
) -> Tuple[np.ndarray, np.ndarray]:
    """Decode a byte stream back to ((N,2) postings, (N,) tags).

    Tags are all-zero when ``tagged`` is False.
    """
    docs: List[int] = []
    poss: List[int] = []
    tags: List[int] = []
    offset = 0
    prev_doc = 0
    prev_pos = 0
    n = len(data)
    while offset < n:
        if tagged:
            tag, offset = decode_varint(data, offset)
        else:
            tag = 0
        dd, offset = decode_varint(data, offset)
        pd, offset = decode_varint(data, offset)
        if zigzag:
            dd = _unzigzag(dd)
            pd = _unzigzag(pd)
        if docs and dd == 0:
            doc = prev_doc
            pos = prev_pos + pd
        else:
            doc = prev_doc + dd
            pos = pd
        docs.append(doc)
        poss.append(pos)
        tags.append(tag)
        prev_doc, prev_pos = doc, pos
    out = np.empty((len(docs), 2), dtype=np.int64)
    out[:, 0] = docs
    out[:, 1] = poss
    return out, np.asarray(tags, dtype=np.int64)


class PostingDecoder:
    """Incremental decoder over a posting byte stream fed in chunks.

    The lazy read path (``InvertedIndex.open_cursor``) fetches a stream's
    storage units one at a time; a unit boundary may split a varint or a
    whole record, so the decoder keeps the undecodable tail bytes and the
    delta-continuation state (previous doc/pos) between ``feed`` calls.
    Feeding the full stream in any chunking decodes exactly the rows
    ``decode_postings`` would return on the concatenated bytes.
    """

    def __init__(self, tagged: bool = False, zigzag: bool = False):
        self.tagged = tagged
        self.zigzag = zigzag
        self._rem = b""
        self._prev_doc = 0
        self._prev_pos = 0
        self._any = False

    @property
    def pending_bytes(self) -> int:
        """Tail bytes buffered until the next feed completes their record."""
        return len(self._rem)

    def state(self) -> Tuple[bytes, int, int, bool]:
        """The full carry: (tail bytes, prev_doc, prev_pos, any-decoded).

        With it a suspended stream resumes EXACTLY where it stopped:
        restoring the tuple into a fresh decoder (this class or the
        device-backed ``repro.kernels.posting_decode.ops.DeviceDecoder``,
        which shares the format) and feeding the remaining bytes decodes
        the same rows as an uninterrupted drain — the contract behind
        partial-prefix cache admission (``ReaderCursor.settle``)."""
        return (self._rem, self._prev_doc, self._prev_pos, self._any)

    def set_state(self, state: Tuple[bytes, int, int, bool]) -> None:
        rem, prev_doc, prev_pos, any_ = state
        self._rem = bytes(rem)
        self._prev_doc = int(prev_doc)
        self._prev_pos = int(prev_pos)
        self._any = bool(any_)

    def feed(self, data: bytes) -> Tuple[np.ndarray, np.ndarray]:
        """Decode every complete record of ``rem + data``; buffer the rest."""
        buf = self._rem + bytes(data)
        docs: List[int] = []
        poss: List[int] = []
        tags: List[int] = []
        offset = 0
        n = len(buf)
        while offset < n:
            start = offset
            try:
                if self.tagged:
                    tag, offset = decode_varint(buf, offset)
                else:
                    tag = 0
                dd, offset = decode_varint(buf, offset)
                pd, offset = decode_varint(buf, offset)
            except IndexError:  # record truncated at the chunk boundary
                offset = start
                break
            if self.zigzag:
                dd = _unzigzag(dd)
                pd = _unzigzag(pd)
            if self._any and dd == 0:
                doc = self._prev_doc
                pos = self._prev_pos + pd
            else:
                doc = self._prev_doc + dd
                pos = pd
            docs.append(doc)
            poss.append(pos)
            tags.append(tag)
            self._prev_doc, self._prev_pos = doc, pos
            self._any = True
        self._rem = buf[offset:]
        out = np.empty((len(docs), 2), dtype=np.int64)
        out[:, 0] = docs
        out[:, 1] = poss
        return out, np.asarray(tags, dtype=np.int64)


def encoded_size(postings: Sequence[Posting] | np.ndarray,
                 tags: Sequence[int] | np.ndarray | None = None) -> int:
    return len(encode_postings(postings, tags))


def merge_sorted_postings(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Merge two (N,2) posting arrays sorted by (doc, pos)."""
    if a.size == 0:
        return b
    if b.size == 0:
        return a
    both = np.concatenate([a, b], axis=0)
    order = np.lexsort((both[:, 1], both[:, 0]))
    return both[order]


def max_doc_run(posts: np.ndarray) -> int:
    """Largest per-document posting count in a doc-sorted (N, 2) array.

    This is the per-part ingredient of ``Entry.max_doc_count`` — the
    WAND-style score upper-bound metadata the ranked streaming executor
    consumes (see ``repro.search.scoring``).  Doc ids are globally
    increasing across parts, so the max over a key's lifetime is just the
    running max of this value over its per-part batches.
    """
    if posts.shape[0] == 0:
        return 0
    docs = posts[:, 0]
    change = np.flatnonzero(docs[1:] != docs[:-1])
    bounds = np.concatenate(([0], change + 1, [docs.shape[0]]))
    return int(np.diff(bounds).max())
