"""Strategy configuration for easily updatable index construction.

The paper (sections 5.1-5.9) defines nine composable strategies.  A
:class:`StrategyConfig` selects which are active and their parameters.
The three experiment sets of section 6.4 are provided as constructors.

Strategy roles (see DESIGN.md for the full table):
  C1   — always on: per-stream cluster cache + phase-wise key groups.
  EM   — posting lists below ``em_limit`` bytes live inside the dictionary.
  PART — lists below half a cluster live in 1/2^k sub-cluster "parts".
  S    — contiguous power-of-two segments, doubling up to ``seg_max``.
  FL   — first-level hot-append cluster area, bulk loaded/saved per phase.
  TAG  — many tiny keys share one tagged stream (dictionary level).
  CH   — backward-linked bounded chain of segments; converts to S at limit.
  SR   — short-record RAM accumulator (128-byte blocks), only full clusters
         enter chains; SR file streamed sequentially per phase.
  DS   — device-level small-write packing (PackedWriteDevice).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StrategyConfig:
    # cluster geometry
    cluster_size: int = 32 * 1024

    # C1 cache (always active, paper 5.1)
    cache_clusters_per_stream: int = 45
    cache_total_bytes: int = 1 << 30  # 1 GB, paper Table 1

    # EM (5.2)
    use_em: bool = True
    em_limit: int = 64  # bytes of encoded postings kept in the dictionary

    # PART (5.3)
    use_part: bool = True
    part_max_splits: int = 4  # parts of cluster/2 .. cluster/2^4

    # S (5.4)
    seg_max: int = 8  # N: maximum segment length in clusters (power of two)

    # FL (5.5)
    use_fl: bool = True

    # TAG (5.6)
    use_tag: bool = True
    tag_bucket_keys: int = 32           # keys hashed into one tagged stream
    tag_extract_bytes: int = 8 * 1024   # extract a key once it owns this much

    # CH (5.7)
    use_ch: bool = False
    chain_limit: int = 9       # max chain length, counted in segments (5.7.3)
    chain_limit_jitter: int = 0  # optional [limit-jitter, limit] per-stream limit
    ch_min_merge_segments: int = 2  # 5.7.2: merge at least the two last segments

    # SR (5.8)
    use_sr: bool = False
    sr_block: int = 128
    sr_memory_limit: int = 64 << 20  # RAM budget for SR-records per phase

    # DS (5.9) — applied at the device level
    use_ds: bool = False
    ds_small_threshold: int = 32 * 1024  # paper Table 1: <= 32 KB is "small"
    ds_buffer_size: int = 1 << 20

    def with_overrides(self, **kw) -> "StrategyConfig":
        return dataclasses.replace(self, **kw)

    # --- the paper's three experiment sets (6.4) -------------------------------
    @staticmethod
    def set1(**kw) -> "StrategyConfig":
        """C1+EM+PART+S+FL+TAG."""
        return StrategyConfig(use_ch=False, use_sr=False, use_ds=False, **kw)

    @staticmethod
    def set2(**kw) -> "StrategyConfig":
        """set1 + CH + SR."""
        return StrategyConfig(use_ch=True, use_sr=True, use_ds=False, **kw)

    @staticmethod
    def set3(**kw) -> "StrategyConfig":
        """set2 + DS."""
        return StrategyConfig(use_ch=True, use_sr=True, use_ds=True, **kw)

    @property
    def cluster_capacity(self) -> int:
        """Payload capacity of a linked cluster."""
        from repro.core.cluster_store import LINK_BYTES

        return self.cluster_size - LINK_BYTES

    def part_sizes(self) -> list:
        """Available PART sub-cluster sizes, smallest first (paper 5.3)."""
        return [self.cluster_size // (1 << k) for k in range(self.part_max_splits, 0, -1)]
