"""Synthetic three-class lexicon (paper section 6.2).

The paper divides all lemmas into stop words / frequently used / other,
backed by a Russian morphological analyser (~260k base forms).  We replace
the linguistics with a deterministic synthetic lexicon that has the same
*statistical* shape — the index strategies only ever see key statistics:

  * token word-ids are sampled Zipf(s) over a vocabulary of ``n_words``,
  * a word is *known* if the analyser dictionary contains it (we make the
    rare tail unknown: the word is its own lemma),
  * known words map to 1-2 lemmas (multi-lemma ambiguity),
  * lemmas are ranked by expected corpus frequency; the top ``n_stop``
    lemma ranks are stop lemmas, the next ``n_frequent`` are frequently
    used, the rest are "other" (6.2's three groups).

Everything is integer arrays so that posting extraction is vectorizable.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

# word classes
STOP, FREQUENT, OTHER = 0, 1, 2


@dataclasses.dataclass
class Lexicon:
    n_words: int
    n_lemmas: int
    known_cutoff: int          # word ids >= cutoff are unknown words
    lemma1: np.ndarray         # (n_words,) primary lemma of each known word
    lemma2: np.ndarray         # (n_words,) secondary lemma or -1
    lemma_class: np.ndarray    # (n_lemmas,) STOP/FREQUENT/OTHER
    zipf_s: float
    word_probs: np.ndarray     # (n_words,) sampling distribution

    @property
    def n_stop(self) -> int:
        return int((self.lemma_class == STOP).sum())

    @property
    def n_frequent(self) -> int:
        return int((self.lemma_class == FREQUENT).sum())

    def is_known(self, word_ids: np.ndarray) -> np.ndarray:
        return word_ids < self.known_cutoff

    def lemmatize(self, word_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Primary and secondary lemma per token (-1: no secondary).

        Unknown words are their own lemma, offset into a separate id space
        (lemma id = n_lemmas + word_id) so ordinary-known and
        ordinary-unknown indexes have disjoint key universes.
        """
        known = self.is_known(word_ids)
        l1 = np.where(known, self.lemma1[word_ids], self.n_lemmas + word_ids)
        l2 = np.where(known, self.lemma2[word_ids], -1)
        return l1, l2

    def classes_of(self, lemma_ids: np.ndarray) -> np.ndarray:
        """Class per lemma id; unknown lemmas are always OTHER."""
        out = np.full(lemma_ids.shape, OTHER, dtype=np.int64)
        known = (lemma_ids >= 0) & (lemma_ids < self.n_lemmas)
        out[known] = self.lemma_class[lemma_ids[known]]
        return out

    def classify_words(self, word_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(primary lemma, class) per word in one vectorized pass — the
        query planner's batch classification (no per-word round trips)."""
        word_ids = np.asarray(word_ids, dtype=np.int64)
        l1, _ = self.lemmatize(word_ids)
        return l1, self.classes_of(l1)


def make_lexicon(
    n_words: int = 60_000,
    n_lemmas: int = 26_000,
    n_stop: int = 70,
    n_frequent: int = 1_000,
    unknown_fraction: float = 0.15,
    zipf_s: float = 1.07,
    seed: int = 1234,
) -> Lexicon:
    """Build the synthetic lexicon.  Defaults are the paper's shape scaled
    ~10x down (260k lemmas → 26k) to keep CI-scale corpora fast; the
    benchmark exposes the full-size variant behind ``--scale``."""
    rng = np.random.RandomState(seed)
    known_cutoff = int(n_words * (1.0 - unknown_fraction))

    # Zipf over words (rank = word id)
    ranks = np.arange(1, n_words + 1, dtype=np.float64)
    probs = ranks ** (-zipf_s)
    probs /= probs.sum()

    # known word -> primary lemma: several inflected forms share a lemma,
    # with frequent words having more forms (rich morphology of frequent
    # verbs/nouns).  Map by rank so frequency ordering is preserved.
    forms = 1 + (rng.poisson(1.2, size=known_cutoff))
    lemma_of_known = np.repeat(
        np.arange(len(forms)), forms
    )[:known_cutoff]
    lemma_of_known = np.minimum(lemma_of_known, n_lemmas - 1)
    lemma1 = np.full(n_words, -1, dtype=np.int64)
    lemma1[:known_cutoff] = lemma_of_known

    # multi-lemma ambiguity: ~12% of known words have a second lemma
    ambiguous = rng.rand(n_words) < 0.12
    ambiguous[known_cutoff:] = False
    lemma2 = np.full(n_words, -1, dtype=np.int64)
    lemma2[ambiguous] = rng.randint(0, n_lemmas, size=int(ambiguous.sum()))

    # expected lemma frequencies -> class thresholds
    lemma_freq = np.zeros(n_lemmas, dtype=np.float64)
    np.add.at(lemma_freq, lemma1[:known_cutoff], probs[:known_cutoff])
    sec = lemma2 >= 0
    np.add.at(lemma_freq, lemma2[sec], 0.3 * probs[sec])
    order = np.argsort(-lemma_freq)
    lemma_class = np.full(n_lemmas, OTHER, dtype=np.int64)
    lemma_class[order[:n_stop]] = STOP
    lemma_class[order[n_stop : n_stop + n_frequent]] = FREQUENT

    # stop lemmas are function words: keep them morphologically unambiguous
    # (no word has a stop lemma as a secondary reading, and stop-primary
    # words have no secondary lemma) — this keeps the stop-sequence index
    # and the ordinary index exactly consistent.
    sec = lemma2 >= 0
    bad = np.zeros(n_words, dtype=bool)
    bad[sec] = lemma_class[lemma2[sec]] == STOP
    primary_stop = np.zeros(n_words, dtype=bool)
    known_mask = lemma1 >= 0
    primary_stop[known_mask] = lemma_class[lemma1[known_mask]] == STOP
    lemma2[bad | primary_stop] = -1

    return Lexicon(
        n_words=n_words,
        n_lemmas=n_lemmas,
        known_cutoff=known_cutoff,
        lemma1=lemma1,
        lemma2=lemma2,
        lemma_class=lemma_class,
        zipf_s=zipf_s,
        word_probs=probs,
    )
