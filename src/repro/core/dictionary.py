"""Key dictionary (paper section 1, component 2).

Maps a key to where its posting list lives.  Entry layouts mirror the
paper's descriptions:

  * EM entries hold the posting bytes inline ("the data of the posting list
    can be stored in the dictionary with the key", 5.2),
  * TAG entries reference a shared stream plus the key's local tag (5.6),
  * OWN entries reference a dedicated stream; the stream manager knows the
    first/last cluster numbers, FL cluster and SR record the paper lists.

Keys are arbitrary hashables canonicalised to bytes; group assignment
(C1 phases) is a stable CRC so runs are reproducible.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Hashable, List, Optional, Tuple

# entry kinds
K_EM = "em"
K_TAG = "tag"
K_OWN = "own"

ENTRY_FIXED_BYTES = 24  # key hash + location + sizes: dictionary traffic model


def key_bytes(key: Hashable) -> bytes:
    if isinstance(key, bytes):
        return key
    if isinstance(key, str):
        return key.encode("utf-8")
    if isinstance(key, int):
        return b"i" + key.to_bytes(8, "little", signed=True)
    if isinstance(key, tuple):
        return b"t" + b"|".join(key_bytes(k) for k in key)
    raise TypeError(f"unsupported key type: {type(key)}")


def stable_hash(key: Hashable) -> int:
    return zlib.crc32(key_bytes(key))


@dataclasses.dataclass
class Entry:
    kind: str = K_EM
    data: bytearray = dataclasses.field(default_factory=bytearray)  # EM only
    sid: int = -1
    tag: int = -1
    nbytes: int = 0      # this key's (untagged-equivalent) encoded bytes
    last_doc: int = 0
    npostings: int = 0
    # largest per-document posting count ever appended for this key — the
    # WAND-style score upper-bound metadata the ranked top-k executor
    # carries on cursors (doc ids only grow across parts, so the running
    # max over per-part batches is exact; see repro.search.scoring)
    max_doc_count: int = 0


class Dictionary:
    """Key → Entry map with per-group partitions (C1 phases)."""

    def __init__(self, n_groups: int):
        self.n_groups = max(1, int(n_groups))
        self.entries: Dict[Hashable, Entry] = {}
        # TAG buckets: (group, bucket) -> stream id + member keys in tag order
        self.buckets: Dict[Tuple[int, int], int] = {}
        self.bucket_members: Dict[int, List[Hashable]] = {}

    def group_of(self, key: Hashable) -> int:
        return stable_hash(key) % self.n_groups

    def get(self, key: Hashable) -> Optional[Entry]:
        return self.entries.get(key)

    def get_or_create(self, key: Hashable) -> Entry:
        e = self.entries.get(key)
        if e is None:
            e = Entry()
            self.entries[key] = e
        return e

    def group_entry_bytes(self, group: int) -> int:
        """Dictionary partition size for one phase's sequential load/save."""
        total = 0
        for key, e in self.entries.items():
            if self.group_of(key) == group:
                total += ENTRY_FIXED_BYTES + len(key_bytes(key)) + len(e.data)
        return total

    def keys_in_group(self, group: int) -> List[Hashable]:
        return [k for k in self.entries if self.group_of(k) == group]
