"""Multi-component (k-word) key index (arXiv:1812.07640 family).

The paper's additional indexes stop at two-component ``(w, v)`` keys;
the follow-up line of work shows that *multi-component* keys — one key
per tuple of k consecutive words — are what make multi-word proximity
and phrase search fast at scale.  :class:`MultiKeyIndex` indexes every
sliding ``(f1, …, fk)`` lemma tuple of the token stream (k configurable,
default 3) over the same easily updatable substrate as the single-word
case: keys live in a :class:`~repro.core.dictionary.Dictionary`, posting
data moves through :class:`~repro.core.stream.StreamManager` clusters,
and the storage tier of each key is chosen by its data size exactly like
the paper prescribes (EM for tiny lists, PART/S/CH for larger ones) —
all inherited from :class:`~repro.core.inverted_index.InvertedIndex`
via the shared :class:`~repro.core.strategies.StrategyConfig`.

Records are NSW-style ("next word") ``(doc, start_position)`` rows: a
posting at position ``p`` certifies the key's k lemmas occur at
``p, p+1, …, p+k-1`` of the document, so the executor can reconstruct
every component position of a window match from the start position
alone.  Ambiguous tokens contribute every lemma-reading combination of
the window (the same lemmatized-search convention as the extended
``(w, v)`` extraction), deduplicated per key.

Key packing is explicit and data driven: each component takes
``component_bits`` bits (enough for the lexicon's combined
known-lemma + unknown-word id universe) and the k components fold into
one int64, mirroring the stop-sequence key packing.  The packed integer
lives in its own index namespace ("multi"), and the posting cache
namespaces entries by index name, so a packed 2-word multi key can
never collide with a numerically equal extended ``(w, v)`` key.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.inverted_index import InvertedIndex
from repro.core.lexicon import Lexicon
from repro.data.corpus import group_by_key

# packed keys must stay positive int64
_MAX_PACKED_BITS = 62


def lemma_bits(lexicon: Lexicon) -> int:
    """Bits one key component needs: the lemma id universe is known
    lemmas plus offset unknown-word ids (``n_lemmas + word_id``)."""
    return int(lexicon.n_lemmas + lexicon.n_words - 1).bit_length()


def pack_components(components: Sequence[int], bits: int) -> int:
    """Fold k lemma ids into one int64 key (big end = first word)."""
    key = 0
    limit = 1 << bits
    for c in components:
        c = int(c)
        if not 0 <= c < limit:
            raise ValueError(f"component {c} out of range for {bits} bits")
        key = (key << bits) | c
    return key


def unpack_components(key: int, k: int, bits: int) -> Tuple[int, ...]:
    mask = (1 << bits) - 1
    out = [(key >> (bits * (k - 1 - j))) & mask for j in range(k)]
    return tuple(out)


def phrase_cover_keys(pack, k: int, lemmas: Sequence[int]) -> List[int]:
    """Overlapping k-word key cover of a phrase's lemma sequence — THE
    single derivation shared by :meth:`MultiKeyIndex.cover_keys` and the
    planner's :class:`~repro.search.plan.MultiKeySpec` fallback, so the
    two can never drift.  Key ``j`` is the k-gram at word offset ``j``."""
    if len(lemmas) < k:
        raise ValueError(
            f"phrase of {len(lemmas)} lemmas cannot be covered by "
            f"{k}-word keys"
        )
    return [int(pack(lemmas[off : off + k]))
            for off in range(len(lemmas) - k + 1)]


def extract_multi_postings(
    lexicon: Lexicon,
    tokens: np.ndarray,
    offsets: np.ndarray,
    doc0: int,
    k: int,
    bits: int,
) -> Dict[int, np.ndarray]:
    """Sliding k-gram posting map for one collection part (vectorized).

    Every window of k consecutive tokens inside one document yields one
    posting per lemma-reading combination: slot j may read the token's
    primary or (when present) secondary lemma, so a phrase matches no
    matter which reading the query words lemmatize to.  Duplicate
    ``(key, doc, pos)`` rows (a token whose two readings coincide) are
    dropped so the multi route's witnesses are exact window matches.
    """
    if k * bits > _MAX_PACKED_BITS:
        raise ValueError(f"k={k} at {bits} bits/component overflows int64 keys")
    T = int(tokens.shape[0])
    if T < k:
        return {}
    n_docs = offsets.shape[0] - 1
    lens = np.diff(offsets)
    doc_of = np.repeat(np.arange(n_docs, dtype=np.int64) + doc0, lens)
    pos_of = np.arange(T, dtype=np.int64) - np.repeat(offsets[:-1], lens)
    l1, l2 = lexicon.lemmatize(tokens)

    starts = np.arange(T - k + 1, dtype=np.int64)
    in_doc = doc_of[starts] == doc_of[starts + k - 1]

    keys_acc, docs_acc, poss_acc = [], [], []
    for combo in range(1 << k):
        mask = in_doc.copy()
        key = np.zeros(T - k + 1, dtype=np.int64)
        for j in range(k):
            use_secondary = (combo >> j) & 1
            lem = l2[starts + j] if use_secondary else l1[starts + j]
            if use_secondary:
                mask &= lem >= 0
            key = (key << bits) | np.where(lem >= 0, lem, 0)
        if not mask.any():
            continue
        keys_acc.append(key[mask])
        docs_acc.append(doc_of[starts[mask]])
        poss_acc.append(pos_of[starts[mask]])
    if not keys_acc:
        return {}
    rows = np.stack(
        [np.concatenate(keys_acc), np.concatenate(docs_acc), np.concatenate(poss_acc)],
        axis=1,
    )
    rows = np.unique(rows, axis=0)
    return group_by_key(rows[:, 0], rows[:, 1], rows[:, 2])


class MultiKeyIndex(InvertedIndex):
    """Easily updatable index over packed k-word lemma-tuple keys.

    A thin specialisation of :class:`InvertedIndex`: key extraction and
    packing are multi-component aware, while the update protocol, the
    storage-tier choice per key (EM/PART/S/CH by data size) and the I/O
    accounting are exactly the single-word machinery.
    """

    def __init__(self, cfg, device, k: int = 3, component_bits: int = 17, **kw):
        if k < 2:
            raise ValueError(f"multi-component keys need k >= 2, got {k}")
        if k * component_bits > _MAX_PACKED_BITS:
            raise ValueError(
                f"k={k} components of {component_bits} bits do not fit an "
                f"int64 key ({k * component_bits} > {_MAX_PACKED_BITS})"
            )
        super().__init__(cfg, device, **kw)
        self.k = int(k)
        self.component_bits = int(component_bits)

    @classmethod
    def for_lexicon(cls, cfg, device, lexicon: Lexicon, k: int = 3, **kw):
        return cls(cfg, device, k=k, component_bits=lemma_bits(lexicon), **kw)

    # ---------------------------------------------------------------- keys --
    def pack(self, lemmas: Sequence[int]) -> int:
        if len(lemmas) != self.k:
            raise ValueError(f"expected {self.k} components, got {len(lemmas)}")
        return pack_components(lemmas, self.component_bits)

    def unpack(self, key: int) -> Tuple[int, ...]:
        return unpack_components(key, self.k, self.component_bits)

    def cover_keys(self, lemmas: Sequence[int]) -> List[int]:
        """Overlapping k-word key cover of a phrase's lemma sequence.

        Key ``j`` is the k-gram at word offset ``j``; its NSW-style
        records sit at ``start + j`` of every phrase match, which is how
        the executor (batch phrase chain and streaming top-k alike)
        reconstructs the match from start positions alone.  The records
        of every cover key are (doc, start)-sorted — the invariant the
        lazy cursor's settled-doc bound relies on.
        """
        return phrase_cover_keys(self.pack, self.k, lemmas)

    # ---------------------------------------------------------- extraction --
    def extract_part(
        self,
        lexicon: Lexicon,
        tokens: np.ndarray,
        offsets: np.ndarray,
        doc0: int,
    ) -> Dict[int, np.ndarray]:
        return extract_multi_postings(
            lexicon, tokens, offsets, doc0, self.k, self.component_bits
        )

    def add_text_part(
        self,
        lexicon: Lexicon,
        tokens: np.ndarray,
        offsets: np.ndarray,
        doc0: int,
    ) -> None:
        """Extract and index one collection part in a single call."""
        self.add_part(self.extract_part(lexicon, tokens, offsets, doc0))
