"""The easily updatable associative array (paper sections 2.2-5).

``InvertedIndex`` is the user-facing structure: an associative array in
external memory mapping keys to posting lists, updatable in place (Method 2)
— no sort-and-merge pass.  It composes:

  * :class:`~repro.core.dictionary.Dictionary` — key → entry (EM/TAG/OWN),
  * :class:`~repro.core.stream.StreamManager` — stream-of-clusters lifecycle,
  * :class:`~repro.core.io_sim.BlockDevice` — exact I/O accounting
    (optionally :class:`PackedWriteDevice` for strategy DS).

Construction/update protocol (paper 2.2, 5.1): the caller hands one *part*
of the collection at a time as ``{key: (N,2) postings}``; the index runs a
C1 phase per key group, appending each key's batch into its stream.  TAG
buckets receive one merged, tag-prefixed batch per phase; a member whose
share outgrows ``tag_extract_bytes`` is extracted to a dedicated stream
(5.6).  Doc ids must be globally increasing across parts — the natural
consequence of indexing a growing collection.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Dict, Hashable, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.dictionary import (
    ENTRY_FIXED_BYTES,
    Dictionary,
    Entry,
    K_EM,
    K_OWN,
    K_TAG,
    key_bytes,
    stable_hash,
)
from repro.core.io_sim import BlockDevice, IOStats
from repro.core.postings import (
    PostingDecoder,
    decode_postings,
    encode_postings,
    max_doc_run,
)
from repro.core.strategies import StrategyConfig
from repro.core.stream import DigestLog, StreamManager

_EMPTY = np.zeros((0, 2), dtype=np.int64)

# default cursor granularity: at most this many clusters fetched per chunk,
# so a lazy reader can stop inside a large contiguous segment
CURSOR_CHUNK_CLUSTERS = 4

# parts of touched-key digest history a writer retains for readers: a
# reader within this many generations invalidates only the touched keys;
# one further behind falls back to dropping its whole cache namespace
DIGEST_HISTORY = 64

# per-part digest size cap: a part touching more keys than this records a
# sentinel instead (readers fall back to the whole-namespace drop, which
# is cheaper than a vocabulary-sized targeted scan anyway), so retained
# digests can never dwarf the posting cache they exist to protect
DIGEST_MAX_KEYS = 1 << 16


@dataclasses.dataclass(frozen=True)
class CursorResume:
    """Where a suspended K_OWN cursor stopped: enough to reopen the
    stream past its consumed storage units with the decoder carry intact.

    ``units_consumed``/``payload_consumed`` pin the open-time unit
    layout (``chunk_clusters`` included) so a resume against a stream
    whose storage moved is detected and refused — the caller falls back
    to a fresh cursor.  ``decoder_state`` is the
    ``PostingDecoder.state()`` carry tuple (tail bytes + delta
    continuation), shared with the device decoder."""

    chunk_clusters: int
    units_consumed: int
    payload_consumed: int
    decoder_state: Tuple[bytes, int, int, bool]


@dataclasses.dataclass
class _SuspendCtx:
    """Per-cursor bookkeeping that makes ``PostingCursor.suspend`` work:
    the shared decoder, the absolute stream-unit index behind each thunk
    (``None`` for a replayed cache prefix), and per-thunk payload sizes."""

    decoder: object
    chunk_clusters: int
    base_payload: int
    unit_index: List[Optional[int]]
    unit_payload: List[int]


class PostingCursor:
    """Lazy chunked reader over one key's (doc, pos)-sorted posting list.

    ``next_chunk()`` returns the next slice of the list (possibly empty
    when a storage unit ends mid-record) and charges the owning device
    only for the storage units actually fetched; ``None`` once exhausted.
    Fetching every chunk charges exactly the bytes ``lookup`` would, so
    ``bytes_total - bytes_fetched`` is the read traffic an early stop
    saved.  ``settled_bound`` is the exclusive doc-id bound below which
    the delivered rows are final: postings are stored sorted by
    (doc, pos), so every future chunk carries docs ``>= last delivered
    doc`` (the last doc itself may continue into the next chunk).
    """

    # sharing ledger slots: real on pooled cursor views
    # (repro.search.pool), zero here so the trace invariant
    # ``planned == fetched + shared + skipped`` holds for every cursor
    chunks_shared = 0
    bytes_shared = 0

    def __init__(
        self,
        thunks: List[Tuple[int, Callable[[], np.ndarray]]],
        max_doc_count: Optional[int] = None,
        suspend_ctx: Optional[_SuspendCtx] = None,
    ):
        self._thunks = thunks
        self._i = 0
        self.chunks_total = len(thunks)
        self.chunks_fetched = 0
        self.bytes_total = sum(nb for nb, _ in thunks)
        self.bytes_fetched = 0
        self.postings_delivered = 0
        self.last_doc: Optional[int] = None
        self._max_doc_count = max_doc_count
        self._src: Optional[np.ndarray] = None
        self._suspend_ctx = suspend_ctx
        # set by InvertedIndex.open_cursor when a CursorResume was applied
        self.resumed = False

    @classmethod
    def from_array(cls, arr: np.ndarray) -> "PostingCursor":
        """Single-chunk cursor over pre-decoded rows (EM/TAG/absent keys:
        their whole-list read was charged — or costs nothing — at open)."""
        if arr.shape[0] == 0:
            cur = cls([], max_doc_count=0)
        else:
            cur = cls([(0, lambda: arr)])
        cur._src = arr
        return cur

    @property
    def max_doc_count(self) -> int:
        """Largest per-doc posting count this cursor's key can deliver —
        the ranked executor's WAND-style upper-bound metadata.  Dictionary
        cursors carry the entry's lifetime max; array-backed cursors
        (cache hits, batch-shared rows) compute the exact max of their
        rows on first use (free: the rows are already decoded)."""
        if self._max_doc_count is None:
            self._max_doc_count = (
                max_doc_run(self._src) if self._src is not None else 0
            )
        return self._max_doc_count

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self._thunks)

    @property
    def settled_bound(self) -> float:
        """Docs strictly below this bound can gain no further postings."""
        if self.exhausted:
            return float("inf")
        if self.last_doc is None:
            return float("-inf")
        return float(self.last_doc)

    @property
    def prepaid(self) -> bool:
        """True while the next chunk costs zero device bytes to deliver —
        a resumed settled prefix or pre-decoded cache-hit rows.  The
        streaming executor drains prepaid chunks eagerly at open so their
        rows seed ``settled_bound`` before the first fetch round; the
        bound stays delivery-based (seeding an *undelivered* bound would
        let a region cut lose rows)."""
        return self._i < len(self._thunks) and self._thunks[self._i][0] == 0

    @property
    def chunks_skipped(self) -> int:
        return self.chunks_total - self.chunks_fetched

    @property
    def bytes_skipped(self) -> int:
        return self.bytes_total - self.bytes_fetched

    def suspend(self) -> Optional[CursorResume]:
        """Freeze a partially-drained K_OWN cursor into a resume token.

        Returns None when there is nothing worth resuming: cursors
        without a suspend context (EM/TAG/array-backed), exhausted
        cursors (the complete drain goes to the main cache tier), and
        cursors that fetched no real storage unit (a replayed cache
        prefix alone — resuming would re-record the same token).
        """
        ctx = self._suspend_ctx
        if ctx is None or self.exhausted:
            return None
        consumed = [ctx.unit_index[k] for k in range(self._i)]
        real = [u for u in consumed if u is not None]
        if not real:
            return None
        units_consumed = real[-1] + 1
        payload = ctx.base_payload + sum(
            ctx.unit_payload[k]
            for k in range(self._i)
            if ctx.unit_index[k] is not None
        )
        return CursorResume(
            chunk_clusters=ctx.chunk_clusters,
            units_consumed=units_consumed,
            payload_consumed=payload,
            decoder_state=ctx.decoder.state(),
        )

    def next_chunk(self) -> Optional[np.ndarray]:
        if self.exhausted:
            return None
        nbytes, thunk = self._thunks[self._i]
        self._i += 1
        arr = thunk()
        self.chunks_fetched += 1
        self.bytes_fetched += nbytes
        if arr.shape[0]:
            self.last_doc = int(arr[-1, 0])
            self.postings_delivered += arr.shape[0]
        if arr.flags.writeable:
            arr = arr.view()
            arr.flags.writeable = False
        return arr

    def read_all(self) -> np.ndarray:
        """Drain the cursor; the concatenation of every chunk."""
        parts = []
        while True:
            chunk = self.next_chunk()
            if chunk is None:
                break
            if chunk.shape[0]:
                parts.append(chunk)
        if not parts:
            return _EMPTY
        return parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)


class InvertedIndex:
    def __init__(
        self,
        cfg: StrategyConfig,
        device: BlockDevice,
        n_groups: int = 16,
        name: str = "index",
        fl_area_clusters: int = 8192,
        seed: int = 0,
        dict_device: Optional[BlockDevice] = None,
        digest_history: int = DIGEST_HISTORY,
        digest_max_keys: int = DIGEST_MAX_KEYS,
    ):
        self.cfg = cfg
        self.name = name
        self.mgr = StreamManager(
            cfg, device, n_groups, name=name,
            fl_area_clusters=fl_area_clusters, seed=seed,
        )
        # dictionary partition traffic is identical across strategy sets and
        # is accounted separately (the paper's tables measure the index data
        # file); defaults to the main device when not supplied.
        self.dict_dev = dict_device if dict_device is not None else device
        self.dict = Dictionary(n_groups)
        self._group_dict_bytes: Dict[int, int] = defaultdict(int)
        # TAG bucket assignment: per group, the currently-open bucket stream
        self._open_bucket: Dict[int, int] = {}
        self.n_extractions = 0
        self.n_parts = 0
        # published snapshot generation.  Decoupled from the physical part
        # counter ``n_parts``: a checkpoint reopen bulk-applies collapsed
        # state (one part standing in for many), so counting parts would
        # alias a reopened replica's position against the writer's.  The
        # published counter is monotone, advances with every part/compact
        # publication, and is *restored* (never rewound) from a durable
        # manifest via :meth:`restore_generation`.
        self.generation = 0
        # live-update observability: per-part touched-key digests, keyed by
        # the published generation the part produced.  Bounded: a reader
        # further behind than the history falls back to a full namespace
        # drop (see repro.search.reader.IndexReader.refresh).
        self._part_digests = DigestLog(digest_history)
        self._digest_max_keys = int(digest_max_keys)
        # background-compaction observability (repro.store rides on these)
        self.n_compactions = 0
        self.compacted_streams = 0

    # ------------------------------------------------------------ updating --
    def add_part(
        self, postings_by_key: Dict[Hashable, np.ndarray]
    ) -> Optional[frozenset]:
        """Index one part of the collection (build or in-place update).

        The generation counter ``n_parts`` advances ONLY when the part
        actually carried postings: an empty part changes no stored state,
        so bumping the generation would force every reader into a
        needless cache invalidation sweep.  Each applied part publishes
        its *touched-key digest* — the exact key set whose posting lists
        changed — so readers can invalidate only those keys.  Returns
        that digest, or ``None`` when the part was a no-op."""
        by_group: Dict[int, List[Tuple[Hashable, np.ndarray]]] = defaultdict(list)
        for key, posts in postings_by_key.items():
            arr = np.asarray(posts, dtype=np.int64)
            if arr.size == 0:
                continue
            by_group[self.dict.group_of(key)].append((key, arr))
        if not by_group:
            return None
        for group in sorted(by_group):
            self._run_phase(group, by_group[group])
        self.n_parts += 1
        self.generation += 1
        digest = frozenset(
            key for items in by_group.values() for key, _ in items
        )
        # oversized digests are recorded as a sentinel: readers behind
        # this part take the whole-namespace fallback instead of a
        # vocabulary-sized targeted scan, and the retained history stays
        # bounded in bytes, not just in parts
        self._part_digests.publish(
            self.generation,
            digest if len(digest) <= self._digest_max_keys else None,
        )
        return digest

    def digests_since(self, generation: int) -> Optional[List[frozenset]]:
        """Touched-key digests of every part applied after ``generation``.

        Returns one frozenset per part, oldest first — their union is the
        complete set of keys whose posting lists changed since the caller
        snapshotted :attr:`generation` — or ``None`` when the bounded
        digest history no longer reaches back that far, or some covered
        part's digest was too large to retain (the caller must then
        treat EVERY key as potentially stale)."""
        return self._part_digests.since(generation, self.generation)

    def restore_generation(self, generation: int) -> None:
        """Restore the *published* generation counter from a durable
        manifest after bulk-applying checkpointed state.

        Forward-only: the published counter is monotone, so restoring
        below the current value is a protocol violation.  Jumping
        forward clears the digest history — the bulk-applied state has
        no per-generation digests for the span the checkpoint collapsed,
        so readers behind the restore point must take the
        whole-namespace fallback rather than get a false "current"."""
        generation = int(generation)
        if generation < self.generation:
            raise ValueError(
                f"generation restore moves backwards "
                f"({self.generation} -> {generation})"
            )
        if generation > self.generation:
            self.generation = generation
            self._part_digests.clear()

    def compact(self) -> Optional[frozenset]:
        """Background compaction: fold every dedicated stream whose
        storage is scattered (chained segments, SR/FL tails, loose
        power-of-two over-allocation) into one tight EM-tier segment.

        Published as *just another generation advance*: ``n_parts``
        bumps once for the whole cycle and the touched-key digest lands
        in the same bounded history ``add_part`` feeds, so snapshot
        pins, open cursors and targeted cache invalidation all see a
        compaction exactly like an update part.  A cycle that rewrites
        nothing is a FULL no-op — no generation bump, no digest —
        mirroring the empty-part rule.  Returns the digest, or ``None``
        for a no-op cycle."""
        touched: List[Hashable] = []
        for key, e in self.dict.entries.items():
            if e.kind != K_OWN:
                continue
            if self.mgr.compact_stream(e.sid):
                touched.append(key)
        if not touched:
            return None
        self.n_compactions += 1
        self.compacted_streams += len(touched)
        self.n_parts += 1
        self.generation += 1
        digest = frozenset(touched)
        self._part_digests.publish(
            self.generation,
            digest if len(digest) <= self._digest_max_keys else None,
        )
        return digest

    def _run_phase(self, group: int, items: List[Tuple[Hashable, np.ndarray]]) -> None:
        dev = self.dict_dev
        dev.read_sequential(self._group_dict_bytes[group])
        self.mgr.begin_phase(group)
        bucket_batches: Dict[int, List[Tuple[int, Optional[np.ndarray], np.ndarray]]] = (
            defaultdict(list)
        )
        for key, posts in items:
            self._append_key(group, key, posts, bucket_batches)
        extract_candidates: List[Hashable] = []
        for sid, batch in bucket_batches.items():
            extract_candidates.extend(self._flush_bucket(group, sid, batch))
        for key in extract_candidates:
            self._extract_key(group, key)
        self.mgr.end_phase()
        dev.write_sequential(self._group_dict_bytes[group])
        dev.flush()

    def _append_key(
        self,
        group: int,
        key: Hashable,
        posts: np.ndarray,
        bucket_batches: Dict[int, List],
    ) -> None:
        cfg = self.cfg
        e = self.dict.get(key)
        if e is None:
            e = self.dict.get_or_create(key)
            self._group_dict_bytes[group] += ENTRY_FIXED_BYTES + len(key_bytes(key))

        # every posting batch for a key passes through here exactly once
        # (EM/TAG/OWN alike), and parts partition the doc-id space, so the
        # running max of per-part per-doc counts IS the key's lifetime max
        part_max = max_doc_run(posts)
        if part_max > e.max_doc_count:
            e.max_doc_count = part_max

        if e.kind == K_EM:
            chunk = encode_postings(posts, prev_doc=e.last_doc)
            if cfg.use_em and e.nbytes + len(chunk) <= cfg.em_limit:
                e.data += chunk
                self._group_dict_bytes[group] += len(chunk)
                self._bump(e, posts, len(chunk))
                return
            # leaving EM: the inline bytes move out of the dictionary
            old_em = bytes(e.data)
            old_posts = None
            if old_em:
                old_posts, _ = decode_postings(old_em)
                self._group_dict_bytes[group] -= len(old_em)
                e.data = bytearray()
            if cfg.use_tag and e.nbytes + len(chunk) <= cfg.tag_extract_bytes:
                sid, tag = self._join_bucket(group, key)
                e.kind, e.sid, e.tag = K_TAG, sid, tag
                bucket_batches[sid].append((tag, old_posts, posts))
                # nbytes re-accounted by _flush_bucket's tagged encoding
                e.nbytes = 0
                e.npostings += posts.shape[0]
                e.last_doc = int(posts[-1, 0])
                return
            # dedicated stream
            sid = self.mgr.new_stream(group)
            e.kind, e.sid = K_OWN, sid
            payload = old_em + chunk
            self.mgr.append_stream(sid, payload)
            self.mgr.streams[sid].last_doc = int(posts[-1, 0])
            self._bump(e, posts, len(chunk))
            return

        if e.kind == K_TAG:
            bucket_batches[e.sid].append((e.tag, None, posts))
            # nbytes updated in _flush_bucket (needs the merged encoding)
            e.npostings += posts.shape[0]
            e.last_doc = int(posts[-1, 0])
            return

        # K_OWN
        chunk = encode_postings(posts, prev_doc=e.last_doc)
        self.mgr.append_stream(e.sid, chunk)
        self.mgr.streams[e.sid].last_doc = int(posts[-1, 0])
        self._bump(e, posts, len(chunk))

    @staticmethod
    def _bump(e: Entry, posts: np.ndarray, nbytes: int) -> None:
        e.nbytes += nbytes
        e.npostings += posts.shape[0]
        e.last_doc = int(posts[-1, 0])

    # --------------------------------------------------------- TAG buckets --
    def _join_bucket(self, group: int, key: Hashable) -> Tuple[int, int]:
        sid = self._open_bucket.get(group, -1)
        members = self.dict.bucket_members.get(sid)
        if sid < 0 or members is None or len(members) >= self.cfg.tag_bucket_keys:
            sid = self.mgr.new_stream(group, tagged=True)
            self.dict.bucket_members[sid] = []
            self._open_bucket[group] = sid
            members = self.dict.bucket_members[sid]
        tag = len(members)
        members.append(key)
        return sid, tag

    def _flush_bucket(
        self, group: int, sid: int,
        batch: List[Tuple[int, Optional[np.ndarray], np.ndarray]],
    ) -> List[Hashable]:
        """Append one merged tag-prefixed batch; return extraction candidates."""
        stream = self.mgr.streams[sid]
        # old EM remnants of joining keys come first (older doc ranges)
        groups: List[Tuple[np.ndarray, np.ndarray]] = []
        for which in (1, 2):  # 1: old EM posts, 2: this part's posts
            posts_list, tags_list = [], []
            for tag, old_posts, new_posts in batch:
                arr = old_posts if which == 1 else new_posts
                if arr is None or arr.size == 0:
                    continue
                posts_list.append(arr)
                tags_list.append(np.full(arr.shape[0], tag, dtype=np.int64))
            if not posts_list:
                continue
            posts = np.concatenate(posts_list, axis=0)
            tags = np.concatenate(tags_list, axis=0)
            order = np.lexsort((tags, posts[:, 1], posts[:, 0]))
            groups.append((posts[order], tags[order]))
        total_chunk = bytearray()
        prev_doc = stream.last_doc
        counts: Dict[int, int] = defaultdict(int)
        for posts, tags in groups:
            chunk = encode_postings(posts, tags=tags, prev_doc=prev_doc, zigzag=True)
            total_chunk += chunk
            prev_doc = int(posts[-1, 0])
            for t in tags:
                counts[int(t)] += 1
        if not total_chunk:
            return []
        self.mgr.append_stream(sid, bytes(total_chunk))
        stream.last_doc = prev_doc
        # apportion bytes to members by posting share (untagged-equivalent)
        n_total = sum(counts.values())
        per_posting = len(total_chunk) / max(1, n_total)
        members = self.dict.bucket_members[sid]
        out: List[Hashable] = []
        for tag, cnt in counts.items():
            key = members[tag]
            if key is None:
                continue
            e = self.dict.entries[key]
            e.nbytes += int(per_posting * cnt)
            if e.nbytes > self.cfg.tag_extract_bytes:
                out.append(key)
        return out

    def _extract_key(self, group: int, key: Hashable) -> None:
        """TAG extraction (5.6): pull one key out into a dedicated stream."""
        e = self.dict.entries[key]
        assert e.kind == K_TAG
        sid, tag = e.sid, e.tag
        data = self.mgr.read_stream(sid)  # charged: extraction is build I/O
        posts, tags = decode_postings(data, tagged=True, zigzag=True)
        mine = posts[tags == tag]
        order = np.lexsort((mine[:, 1], mine[:, 0]))
        mine = mine[order]
        keep = tags != tag
        rest_posts, rest_tags = posts[keep], tags[keep]
        rest_bytes = encode_postings(
            rest_posts, tags=rest_tags, prev_doc=0, zigzag=True
        ) if rest_posts.size else b""
        rest_last = int(rest_posts[-1, 0]) if rest_posts.size else 0
        self.mgr.rewrite_stream(sid, rest_bytes, rest_last)
        members = self.dict.bucket_members[sid]
        members[tag] = None  # tag slot retired
        new_sid = self.mgr.new_stream(group)
        chunk = encode_postings(mine, prev_doc=0)
        self.mgr.append_stream(new_sid, chunk)
        self.mgr.streams[new_sid].last_doc = int(mine[-1, 0]) if mine.size else 0
        e.kind, e.sid, e.tag = K_OWN, new_sid, -1
        e.nbytes = len(chunk)
        e.last_doc = int(mine[-1, 0]) if mine.size else 0
        self.n_extractions += 1

    # ------------------------------------------------------------- queries --
    def lookup(self, key: Hashable, device: Optional[BlockDevice] = None) -> np.ndarray:
        """Return the (N, 2) posting list for a key.

        I/O is charged to ``device`` when given (how readers separate
        search accounting from the build device — see
        ``repro.search.reader``); otherwise to the build device."""
        e = self.dict.get(key)
        dev = device if device is not None else self.mgr.device
        if e is None:
            dev.read_small(ENTRY_FIXED_BYTES)
            return _EMPTY
        dev.read_small(ENTRY_FIXED_BYTES + len(key_bytes(key)) + len(e.data))
        if e.kind == K_EM:
            posts, _ = decode_postings(bytes(e.data))
            return posts
        data = self.mgr.read_stream(e.sid, device=dev)
        if e.kind == K_TAG:
            posts, tags = decode_postings(data, tagged=True, zigzag=True)
            mine = posts[tags == e.tag]
            order = np.lexsort((mine[:, 1], mine[:, 0]))
            return mine[order]
        posts, _ = decode_postings(data)
        return posts

    def open_cursor(
        self,
        key: Hashable,
        device: Optional[BlockDevice] = None,
        chunk_clusters: int = CURSOR_CHUNK_CLUSTERS,
        make_decoder: Optional[Callable[[], object]] = None,
        resume: Optional[CursorResume] = None,
        prefix: Optional[np.ndarray] = None,
    ) -> PostingCursor:
        """Lazy chunked :meth:`lookup`: the dictionary entry is read now,
        each posting storage unit only when the cursor fetches it.

        EM keys (list inline in the dictionary) and TAG keys (bucket
        streams interleave keys, so a partial read cannot isolate one
        key's sorted rows) degenerate to single-chunk cursors; dedicated
        (OWN) streams — where the large lists live — are fetched unit by
        unit in payload order, large segments split into ranges of at
        most ``chunk_clusters`` clusters.  Draining the cursor charges
        exactly the device bytes ``lookup`` charges.

        ``make_decoder`` swaps the incremental decoder on the OWN path
        (e.g. the device-backed one); ``resume`` + ``prefix`` replay a
        suspended drain: when the token still matches the stream's unit
        layout the already-decoded ``prefix`` rows become a zero-charge
        first chunk, the decoder carry is restored, and fetching starts
        at the first unconsumed unit (``cursor.resumed`` is True).  A
        stale token is ignored and the cursor opens fresh.
        """
        e = self.dict.get(key)
        dev = device if device is not None else self.mgr.device
        if e is None:
            dev.read_small(ENTRY_FIXED_BYTES)
            return PostingCursor.from_array(_EMPTY)
        dev.read_small(ENTRY_FIXED_BYTES + len(key_bytes(key)) + len(e.data))
        if e.kind == K_EM:
            posts, _ = decode_postings(bytes(e.data))
            return PostingCursor.from_array(posts)
        if e.kind == K_TAG:
            # one deferred chunk: charged only if the cursor is consumed.
            # The bucket BYTES are pinned at open time (bucket streams are
            # rewritten in place by extraction, and other members keep
            # appending): a cursor drained mid-update must deliver the
            # open-time snapshot, never the rewritten bucket — the charge
            # closures likewise price the open-time layout.
            units = self.mgr.stream_read_units(e.sid)
            charge_bytes = sum(cb for _, cb, _ in units)
            charges = [c for _, _, c in units]
            snap = self.mgr.stream_snapshot(e.sid)

            def read_tagged(snap=snap, tag=e.tag, charges=charges):
                for charge in charges:
                    charge(dev)
                posts, tags = decode_postings(snap, tagged=True, zigzag=True)
                mine = posts[tags == tag]
                order = np.lexsort((mine[:, 1], mine[:, 0]))
                return mine[order]

            return PostingCursor(
                [(charge_bytes, read_tagged)], max_doc_count=e.max_doc_count
            )
        # K_OWN: unit-by-unit fetch + incremental decode
        st = self.mgr.streams[e.sid]
        units = self.mgr.stream_read_units(e.sid, chunk_clusters=chunk_clusters)
        decoder = make_decoder() if make_decoder is not None else PostingDecoder()
        payloads = [pnb for pnb, _, _ in units]
        # resume validation: the token must describe THIS unit layout —
        # same chunking, a strict mid-stream cut, and a payload offset
        # that lands exactly on the consumed-units boundary.  Streams are
        # append-only between repacks, so a surviving prefix layout means
        # the consumed bytes are byte-identical to what was decoded.
        resumed = (
            resume is not None
            and resume.chunk_clusters == chunk_clusters
            and 0 < resume.units_consumed < len(units)
            and resume.payload_consumed == sum(payloads[: resume.units_consumed])
        )
        thunks: List[Tuple[int, Callable[[], np.ndarray]]] = []
        unit_index: List[Optional[int]] = []
        unit_payload: List[int] = []
        base_payload = 0
        start_unit = 0
        if resumed:
            decoder.set_state(resume.decoder_state)
            base_payload = resume.payload_consumed
            start_unit = resume.units_consumed
            if prefix is not None and prefix.shape[0]:
                thunks.append((0, lambda: prefix))
                unit_index.append(None)
                unit_payload.append(0)
        off = sum(payloads[:start_unit])
        for k in range(start_unit, len(units)):
            payload_nb, charge_nb, charge = units[k]
            lo, hi = off, off + payload_nb
            off = hi

            def fetch(lo=lo, hi=hi, charge=charge):
                charge(dev)
                posts, _ = decoder.feed(bytes(st.data[lo:hi]))
                return posts

            thunks.append((charge_nb, fetch))
            unit_index.append(k)
            unit_payload.append(payload_nb)
        cur = PostingCursor(
            thunks,
            max_doc_count=e.max_doc_count,
            suspend_ctx=_SuspendCtx(
                decoder=decoder,
                chunk_clusters=chunk_clusters,
                base_payload=base_payload,
                unit_index=unit_index,
                unit_payload=unit_payload,
            ),
        )
        cur.resumed = resumed
        return cur

    def lookup_ops(self, key: Hashable) -> int:
        """Device ops one search of this key costs (paper 5.7.3 criterion)."""
        e = self.dict.get(key)
        if e is None or e.kind == K_EM:
            return 1  # dictionary access
        return 1 + self.mgr.read_ops_estimate(e.sid)

    # ------------------------------------------------------------- reports --
    def stats(self) -> Dict[str, object]:
        return {
            "keys": len(self.dict.entries),
            "streams": len(self.mgr.streams),
            "extractions": self.n_extractions,
            "census": self.mgr.state_census(),
            "io": self.mgr.device.stats.as_dict(),
            "clusters": self.mgr.storage_clusters(),
        }
