"""Paged KV-cache manager: the paper's allocator, adapted to TPU serving
(DESIGN.md section 2).

Correspondence:
  cluster            <-> KV page (``page_size`` tokens)
  stream of clusters <-> one sequence's cache
  CH bounded chain   <-> bounded page-table indirection: a sequence's
                         pages may live in at most ``chain_limit``
                         physically-contiguous RUNS; the attention
                         kernel's gather depth is bounded (paper 5.7.3)
  CH->S conversion   <-> defragmentation: when a sequence exceeds the
                         run limit its pages are re-allocated as ONE
                         contiguous segment (sequential DMA reads)
  SR tail buffer     <-> write-combining: appended tokens accumulate in
                         a host-side tail buffer; only FULL pages are
                         published to the chain, so a page is never
                         re-read for modification
  free-clusters list <-> page free list with extent coalescing

The manager is pure bookkeeping (host side): it returns block tables for
``repro.kernels.paged_attention`` and measures fragmentation, compaction
traffic and gather depth — the serving-side reproduction of the paper's
I/O accounting.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster_store import ExtentAllocator


@dataclasses.dataclass
class SeqState:
    seq_id: int
    length: int = 0                 # committed tokens (in published pages)
    tail: int = 0                   # tokens in the SR write-combining buffer
    runs: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    compactions: int = 0


@dataclasses.dataclass
class PagedKVStats:
    pages_allocated: int = 0
    pages_freed: int = 0
    compactions: int = 0
    compaction_pages_moved: int = 0
    max_gather_depth: int = 0


class PagedKVManager:
    def __init__(
        self,
        n_pages: int,
        page_size: int = 128,
        chain_limit: int = 9,
        contiguous_grow: int = 2,   # S-strategy: try to grow runs in place
    ):
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.chain_limit = int(chain_limit)
        self.contiguous_grow = int(contiguous_grow)
        self.alloc = ExtentAllocator(initial_clusters=n_pages)
        self.seqs: Dict[int, SeqState] = {}
        self.stats = PagedKVStats()

    # ------------------------------------------------------------ lifecycle --
    def new_sequence(self, seq_id: int) -> SeqState:
        assert seq_id not in self.seqs
        st = SeqState(seq_id)
        self.seqs[seq_id] = st
        return st

    def free_sequence(self, seq_id: int) -> None:
        st = self.seqs.pop(seq_id)
        for start, length in st.runs:
            self.alloc.free(start, length)
            self.stats.pages_freed += length

    def append_tokens(self, seq_id: int, n: int) -> None:
        """SR semantics: tokens land in the tail buffer; full pages are
        published into the chain (never re-read, never re-written)."""
        st = self.seqs[seq_id]
        st.tail += n
        while st.tail >= self.page_size:
            self._publish_page(st)
            st.tail -= self.page_size
            st.length += self.page_size

    def _publish_page(self, st: SeqState) -> None:
        # S-strategy: extend the last run in place when the next physical
        # page is free (contiguity first)
        if st.runs:
            start, length = st.runs[-1]
            got = self._try_extend(start + length)
            if got:
                st.runs[-1] = (start, length + 1)
                self.stats.pages_allocated += 1
                self._check_chain(st)
                return
        start = self.alloc.alloc(1)
        self.stats.pages_allocated += 1
        if st.runs and st.runs[-1][0] + st.runs[-1][1] == start:
            st.runs[-1] = (st.runs[-1][0], st.runs[-1][1] + 1)
        else:
            st.runs.append((start, 1))
        self._check_chain(st)

    def _try_extend(self, page: int) -> bool:
        """Claim a specific free page id (in-place growth)."""
        for i, (s, l) in enumerate(self.alloc._free):
            if s <= page < s + l:
                if s == page:
                    if l == 1:
                        self.alloc._free.pop(i)
                    else:
                        self.alloc._free[i] = (s + 1, l - 1)
                    return True
                return False
        return False

    def _check_chain(self, st: SeqState) -> None:
        """CH limit (5.7.3): too many runs -> compact to one segment.
        The conversion happens inside the append, so a *reader* never
        observes more than ``chain_limit`` runs; the max-depth stat is
        recorded post-compaction accordingly."""
        if len(st.runs) > self.chain_limit:
            total = sum(l for _, l in st.runs)
            old = list(st.runs)
            # free first so the allocator can re-use the old extents
            for s, l in old:
                self.alloc.free(s, l)
            start = self.alloc.alloc(total)
            st.runs = [(start, total)]
            st.compactions += 1
            self.stats.compactions += 1
            self.stats.compaction_pages_moved += total
        self.stats.max_gather_depth = max(
            self.stats.max_gather_depth, len(st.runs)
        )

    # -------------------------------------------------------------- queries --
    def gather_depth(self, seq_id: int) -> int:
        """Discontiguous runs the attention gather must touch (== the
        paper's per-search I/O op count)."""
        return len(self.seqs[seq_id].runs)

    def page_ids(self, seq_id: int) -> List[int]:
        st = self.seqs[seq_id]
        out: List[int] = []
        for s, l in st.runs:
            out.extend(range(s, s + l))
        return out

    def block_table(self, seq_ids: List[int], max_pages: int) -> np.ndarray:
        """Padded (B, max_pages) table for the paged_attention kernel."""
        out = np.zeros((len(seq_ids), max_pages), np.int32)
        for i, sid in enumerate(seq_ids):
            ids = self.page_ids(sid)
            assert len(ids) <= max_pages, (sid, len(ids), max_pages)
            out[i, : len(ids)] = ids
        return out

    def lengths(self, seq_ids: List[int]) -> np.ndarray:
        return np.asarray(
            [self.seqs[s].length for s in seq_ids], np.int32
        )

    @property
    def free_pages(self) -> int:
        return self.alloc.free_clusters + (self.n_pages - self.alloc._frontier)

    def fragmentation(self) -> float:
        """Mean discontiguous runs per active sequence (1.0 = fully
        compact, the S-strategy ideal)."""
        if not self.seqs:
            return 1.0
        depths = [max(1, len(s.runs)) for s in self.seqs.values()]
        return float(np.mean(depths))
