"""The paper's three index kinds over the easily updatable substrate
(sections 6.3-6.5).

``TextIndexSet`` maintains five measured inverted indexes (the rows of
Tables 2 and 3) plus an optional ``ordinary_all`` baseline index used only
by the search-speed experiment:

  known    — ordinary index, known lemmas
  unknown  — ordinary index, unknown words
  wv_kk    — extended (w, v), both known (w FREQUENT)
  wv_ku    — extended (w, v), v unknown
  stopseq  — stop-lemma sequences

plus (unless disabled via ``multi_k=None``) the follow-up work's
multi-component key index:

  multi    — sliding k-word lemma-tuple keys (:mod:`repro.core.multi_key`),
             the planner's fourth route for phrase queries

Each index owns its own simulated block device, so construction I/O is
reported per index exactly like the paper's tables (the ``multi`` index
gets its own build/search accounting rows the same way).  Search I/O is
charged to a separate per-index device so build and search are never
conflated.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Dict, Hashable, List, Optional

import numpy as np

from repro.core.inverted_index import InvertedIndex
from repro.core.io_sim import BlockDevice, IOStats, PackedWriteDevice
from repro.core.lexicon import Lexicon
from repro.core.multi_key import MultiKeyIndex
from repro.core.strategies import StrategyConfig
from repro.data.corpus import extract_postings

INDEX_NAMES = ("known", "unknown", "wv_kk", "wv_ku", "stopseq")
MULTI_INDEX = "multi"

# paper Table 1: 243 known-lemma groups, 96 unknown groups (full scale);
# scaled defaults keep phase counts proportional at CI corpus sizes.
DEFAULT_GROUPS = {
    "known": 24,
    "unknown": 10,
    "wv_kk": 32,
    "wv_ku": 16,
    "stopseq": 8,
    "multi": 24,
    "ordinary_all": 24,
}


@dataclasses.dataclass
class IndexSetConfig:
    strategy: StrategyConfig = dataclasses.field(default_factory=StrategyConfig.set1)
    max_distance: int = 3
    groups: Dict[str, int] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_GROUPS)
    )
    fl_area_clusters: int = 2048
    build_ordinary_all: bool = False
    # multi-component (k-word) key index: tuple width, or None to disable
    multi_k: Optional[int] = 3


class IndexSetLike(abc.ABC):
    """The capability surface the read stack (``repro.search``) consumes.

    Both the single-substrate :class:`TextIndexSet` and the sharded
    :class:`~repro.core.sharded_set.ShardedTextIndexSet` implement it, so
    every consumer — readers, planner glue, ``SearchService``, benchmarks —
    is substrate-agnostic.  Implementations expose:

      * ``cfg`` / ``lexicon``     — configuration and word classification,
      * ``indexes``               — a capability view mapping index name to
        an :class:`InvertedIndex` (for a sharded set this is one shard's
        view: every shard shares the same index kinds, key packing and
        ``multi_k``, which is all the planner reads from it),
      * ``add_documents``         — index one collection part in place,
      * ``lookup``                — whole-set posting lookup (merged across
        shards for a sharded set), charging search-device I/O,
      * ``reader()``              — the read-only snapshot view feeding
        :class:`~repro.search.service.SearchService`,
      * ``build_io``/``search_io``/``census`` — the paper's I/O tables.
    """

    cfg: IndexSetConfig
    lexicon: Lexicon
    # index-name → writer view (shard-representative when sharded); an
    # attribute/property in implementations, not enforced as abstract so
    # TextIndexSet can keep it a plain instance dict
    indexes: Dict[str, InvertedIndex]

    @abc.abstractmethod
    def add_documents(
        self, tokens: np.ndarray, offsets: np.ndarray, doc0: int
    ) -> None:
        """Index one collection part (build or in-place update)."""

    @abc.abstractmethod
    def lookup(self, index_name: str, key: Hashable) -> np.ndarray:
        """Posting lookup charging I/O to search devices."""

    @abc.abstractmethod
    def reader(self, cache_bytes: int = 8 << 20, targeted: bool = True):
        """Read-only snapshot view with a posting-list LRU cache
        (``targeted=False`` reverts cache invalidation to whole-namespace
        drops — the benchmark baseline for the digest path)."""

    @abc.abstractmethod
    def build_io(self) -> Dict[str, IOStats]:
        """Construction I/O per index (aggregate when sharded)."""

    @abc.abstractmethod
    def search_io(self) -> Dict[str, IOStats]:
        """Search I/O per index (aggregate when sharded)."""

    @abc.abstractmethod
    def census(self) -> Dict[str, Dict[str, int]]:
        """Stream-state census per index (aggregate when sharded)."""


class TextIndexSet(IndexSetLike):
    def __init__(self, cfg: IndexSetConfig, lexicon: Lexicon, seed: int = 0):
        self.cfg = cfg
        self.lexicon = lexicon
        names = list(INDEX_NAMES) + (
            [MULTI_INDEX] if cfg.multi_k is not None else []
        ) + (
            ["ordinary_all"] if cfg.build_ordinary_all else []
        )
        self.indexes: Dict[str, InvertedIndex] = {}
        self.search_devices: Dict[str, BlockDevice] = {}
        self.dict_devices: Dict[str, BlockDevice] = {}
        s = cfg.strategy
        for name in names:
            if s.use_ds:
                dev = PackedWriteDevice(
                    cluster_size=s.cluster_size,
                    small_threshold=s.ds_small_threshold,
                    buffer_size=s.ds_buffer_size,
                    name=name,
                )
            else:
                dev = BlockDevice(cluster_size=s.cluster_size, name=name)
            dict_dev = BlockDevice(cluster_size=s.cluster_size, name=f"{name}-dict")
            common = dict(
                n_groups=cfg.groups.get(name, 16),
                name=name,
                fl_area_clusters=cfg.fl_area_clusters,
                seed=seed,
                dict_device=dict_dev,
            )
            if name == MULTI_INDEX:
                self.indexes[name] = MultiKeyIndex.for_lexicon(
                    s, dev, lexicon, k=cfg.multi_k, **common
                )
            else:
                self.indexes[name] = InvertedIndex(s, dev, **common)
            self.dict_devices[name] = dict_dev
            self.search_devices[name] = BlockDevice(
                cluster_size=s.cluster_size, name=f"{name}-search"
            )

    # ------------------------------------------------------------- building --
    def add_documents(
        self, tokens: np.ndarray, offsets: np.ndarray, doc0: int
    ) -> None:
        """Index one collection part (build or in-place update)."""
        maps = extract_postings(
            self.lexicon, tokens, offsets, doc0, self.cfg.max_distance
        )
        if MULTI_INDEX in self.indexes:
            maps[MULTI_INDEX] = self.indexes[MULTI_INDEX].extract_part(
                self.lexicon, tokens, offsets, doc0
            )
        self.apply_part_maps(maps)

    def apply_part_maps(
        self, maps: Dict[str, Dict[Hashable, np.ndarray]]
    ) -> Dict[str, frozenset]:
        """Apply one extracted part to every index that received rows.

        The live-update primitive beneath :meth:`add_documents` (and the
        per-shard :class:`~repro.core.sharded_set.UpdateStream`): indexes
        whose map is empty for this part are NOT touched — their
        generation (``n_parts``) stays put, so readers keep their cached
        postings for those indexes.  Returns the part's touched-key
        digest ``{index name → frozenset of changed keys}`` (empty maps
        omitted), which is also what each index published to its own
        digest history."""
        digest: Dict[str, frozenset] = {}
        for name, index in self.indexes.items():
            by_key = maps.get(name)
            if not by_key:
                continue
            touched = index.add_part(by_key)
            if touched is not None:
                digest[name] = touched
        return digest

    def compact(self) -> Dict[str, frozenset]:
        """One background-compaction cycle across every index.

        Indexes that rewrote nothing are left untouched (no generation
        bump, no digest) — the same no-op rule as an empty part.
        Returns ``{index name → touched-key digest}``, empty cycles
        omitted; the shape :meth:`apply_part_maps` returns, because to
        the read stack a compaction IS just another part."""
        digest: Dict[str, frozenset] = {}
        for name, index in self.indexes.items():
            touched = index.compact()
            if touched is not None:
                digest[name] = touched
        return digest

    def compaction_stats(self) -> Dict[str, int]:
        """Aggregate background-compaction counters across the set."""
        return {
            "compactions": sum(
                i.n_compactions for i in self.indexes.values()
            ),
            "compacted_streams": sum(
                i.compacted_streams for i in self.indexes.values()
            ),
        }

    @property
    def generation(self) -> int:
        """Monotone scalar snapshot counter: the sum of every index's
        *published* generation.  Moves exactly when some reader's view
        of this set could have changed.  Sums alias (two different
        per-index states can share one sum), so snapshot pinning and the
        replica catch-up protocol use :meth:`generation_vector`; the
        scalar survives as a cheap change signal."""
        return sum(idx.generation for idx in self.indexes.values())

    def generation_vector(self) -> List[int]:
        """Per-index published generations, in index declaration order —
        the alias-free form of :attr:`generation`.  One index advancing
        while another restores/folds can leave the *sum* unchanged; the
        vector distinguishes which index moved, so readers pin batches
        and replicas negotiate catch-up against it."""
        return [idx.generation for idx in self.indexes.values()]

    # -------------------------------------------------------------- queries --
    def lookup(self, index_name: str, key: Hashable) -> np.ndarray:
        """Posting lookup charging I/O to the per-index *search* device."""
        index = self.indexes[index_name]
        return index.lookup(key, device=self.search_devices[index_name])

    def reader(self, cache_bytes: int = 8 << 20, targeted: bool = True):
        """Read-only snapshot view with a posting-list LRU cache (the
        reader/planner/executor stack lives in :mod:`repro.search`)."""
        from repro.search.reader import IndexSetReader

        return IndexSetReader(self, cache_bytes=cache_bytes,
                              targeted=targeted)

    # -------------------------------------------------------------- reports --
    def build_io(self) -> Dict[str, IOStats]:
        return {
            name: idx.mgr.device.stats.snapshot()
            for name, idx in self.indexes.items()
        }

    def search_io(self) -> Dict[str, IOStats]:
        return {
            name: dev.stats.snapshot() for name, dev in self.search_devices.items()
        }

    def table_rows(self) -> Dict[str, Dict[str, int]]:
        """Tables 2 and 3 rows: per measured index, bytes and ops."""
        rows = {}
        for name in INDEX_NAMES:
            st = self.indexes[name].mgr.device.stats
            rows[name] = {
                "total_bytes": st.total_bytes,
                "total_ops": st.total_ops,
                "read_bytes": st.read_bytes,
                "write_bytes": st.write_bytes,
                "read_ops": st.read_ops,
                "write_ops": st.write_ops,
            }
        return rows

    def census(self) -> Dict[str, Dict[str, int]]:
        return {name: idx.mgr.state_census() for name, idx in self.indexes.items()}
