"""Fault-tolerant training loop with microbatch gradient accumulation.

Responsibilities (DESIGN.md section 4):
  * build a jit'd train step from any ``loss_fn(params, batch)`` with
    gradient accumulation over microbatches (scan) — the accumulation
    structure is also what lets XLA overlap the reduce-scatter of
    microbatch k with the compute of k+1 on a real interconnect,
  * optional int8 gradient compression before the optimizer,
  * periodic async checkpoints + resume from (step, data_cursor),
  * crash-in-the-middle restart is exercised by tests/test_train.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager, latest_step, load_checkpoint
from repro.distributed.compression import compress_tree
from repro.train.optim import OptConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainerConfig:
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)
    microbatches: int = 1
    compress_grads: bool = False
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10


def build_train_step(
    loss_fn: Callable[[Any, Dict], jnp.ndarray],
    cfg: TrainerConfig,
    donate: bool = True,
):
    """Returns jit-able ``step(params, opt_state, batch) ->
    (params, opt_state, metrics)``.  ``batch`` leaves must have a leading
    dim divisible by ``cfg.microbatches``; accumulation runs as a scan."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(params, opt_state, batch):
        mb = cfg.microbatches
        if mb > 1:
            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mb_batch):
                loss_sum, gacc = carry
                loss, g = grads_of(params, mb_batch)
                gacc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g
                )
                return (loss_sum + loss, gacc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), zeros), micro
            )
            loss = loss_sum / mb
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
        else:
            loss, grads = grads_of(params, batch)
        if cfg.compress_grads:
            grads = compress_tree(grads)
        params, opt_state, om = adamw_update(cfg.opt, grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return step


class Trainer:
    def __init__(
        self,
        loss_fn: Callable,
        params: Any,
        cfg: TrainerConfig,
        jit_kwargs: Optional[Dict] = None,
    ):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.params = params
        self.opt_state = adamw_init(params)
        self.step_num = 0
        self.data_cursor = 0
        self._step = jax.jit(
            build_train_step(loss_fn, cfg), **(jit_kwargs or {})
        )
        self.ckpt = (
            CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)
            if cfg.ckpt_dir
            else None
        )
        self.history = []

    # -- resume ----------------------------------------------------------------
    def try_resume(self, shardings=None, opt_shardings=None) -> bool:
        if not self.cfg.ckpt_dir or latest_step(self.cfg.ckpt_dir) is None:
            return False
        self.params, self.opt_state, self.step_num, self.data_cursor = (
            load_checkpoint(
                self.cfg.ckpt_dir, self.params, self.opt_state,
                shardings=shardings, opt_shardings=opt_shardings,
            )
        )
        return True

    # -- main loop ---------------------------------------------------------------
    def fit(
        self,
        batches: Callable[[int], Dict],
        n_steps: int,
        on_step: Optional[Callable[[int, Dict], None]] = None,
    ) -> Dict:
        """``batches(cursor)`` returns the batch for a given data cursor —
        deterministic data order makes restart-exactness testable."""
        last = {}
        while self.step_num < n_steps:
            batch = batches(self.data_cursor)
            self.params, self.opt_state, metrics = self._step(
                self.params, self.opt_state, batch
            )
            self.step_num += 1
            self.data_cursor += 1
            if self.step_num % self.cfg.log_every == 0 or self.step_num == n_steps:
                last = {k: float(v) for k, v in metrics.items()}
                self.history.append({"step": self.step_num, **last})
            if (
                self.ckpt
                and self.step_num % self.cfg.ckpt_every == 0
            ):
                self.ckpt.save(
                    self.step_num, self.params, self.opt_state,
                    data_cursor=self.data_cursor,
                )
        if self.ckpt:
            self.ckpt.save(
                self.step_num, self.params, self.opt_state,
                data_cursor=self.data_cursor,
            )
            self.ckpt.wait()
        return last
