"""AdamW with WSD (warmup-stable-decay) or cosine schedules, gradient
clipping and optional int8 gradient compression (no optax here).

WSD is the MiniCPM schedule (arXiv:2404.06395): linear warmup, a long
stable plateau at peak LR, then a short exponential-ish decay — included
because minicpm-2b is an assigned architecture.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    clip_norm: float = 1.0
    schedule: str = "wsd"        # wsd | cosine | const
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_fraction: float = 0.1  # WSD: final fraction of steps that decay
    min_lr_ratio: float = 0.1


def schedule_lr(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        return cfg.lr * warm
    total = float(cfg.total_steps)
    if cfg.schedule == "cosine":
        t = jnp.clip((s - cfg.warmup_steps) / jnp.maximum(total - cfg.warmup_steps, 1), 0, 1)
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return cfg.lr * warm * cos
    # WSD: stable until decay phase, then linear-in-log decay to min ratio
    decay_start = total * (1.0 - cfg.decay_fraction)
    t = jnp.clip((s - decay_start) / jnp.maximum(total - decay_start, 1), 0, 1)
    decay = cfg.min_lr_ratio ** t  # exponential decay to min ratio
    return cfg.lr * warm * jnp.where(s < decay_start, 1.0, decay)


def adamw_init(params: Any) -> Dict:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
    )
    return {"mu": zeros, "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    cfg: OptConfig, grads: Any, state: Dict, params: Any
) -> Tuple[Any, Dict, Dict]:
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        newp = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        )
        return newp.astype(p.dtype), mu, nu

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(td, [o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
