"""qwen1.5-4b [hf:Qwen/Qwen1.5 family]: 40L d_model=2560 20H (MHA kv=20)
d_ff=6912 vocab=151936, QKV bias (the Qwen1.5 signature), untied."""

from repro.configs.families import ArchBundle, lm_bundle
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen1.5-4b",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_head=128,
    d_ff=6912,
    vocab=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

REDUCED = TransformerConfig(
    name="qwen1.5-4b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=192, vocab=512, qkv_bias=True, tie_embeddings=False,
    loss_chunk=32, flash_chunk=16,
)


def bundle(reduced: bool = False) -> ArchBundle:
    if reduced:
        return lm_bundle(
            "qwen1.5-4b", REDUCED,
            shapes={"train_4k": (4, 64), "prefill_32k": (2, 64),
                    "decode_32k": (4, 64), "long_500k": (1, 128)},
        )
    return lm_bundle("qwen1.5-4b", CONFIG, microbatches=4)
