"""mace [arXiv:2206.07697]: n_layers=2 d_hidden=128 l_max=2
correlation_order=3 n_rbf=8, E(3)-equivariant ACE message passing.

Note (DESIGN.md section Arch-applicability): the paper's updatable-index
technique does not apply to the GNN compute path; the cluster arena backs
only the neighbor-list store used by the sampler."""

import dataclasses

from repro.configs.families import ArchBundle, gnn_bundle
from repro.models.mace import MACEConfig

CONFIG = MACEConfig(
    name="mace",
    n_layers=2,
    d_hidden=128,
    l_max=2,
    correlation=3,
    n_rbf=8,
    r_cut=2.5,
)

REDUCED = MACEConfig(
    name="mace-smoke",
    n_layers=2, d_hidden=16, l_max=2, correlation=3, n_rbf=4, r_cut=2.5,
)


def bundle(reduced: bool = False) -> ArchBundle:
    if reduced:
        return gnn_bundle("mace", REDUCED, reduced=True)
    return gnn_bundle("mace", CONFIG)
