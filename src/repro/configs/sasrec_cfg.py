"""sasrec [arXiv:1808.09781]: embed_dim=50, 2 blocks, 1 head, seq_len=50,
causal self-attention over the behavior sequence."""

import jax
import jax.numpy as jnp

from repro.configs.families import ArchBundle, recsys_bundle
from repro.models import recsys as RS

SDS = jax.ShapeDtypeStruct

CONFIG = RS.SASRecConfig(n_items=60_000)
REDUCED = RS.SASRecConfig(n_items=500, seq_len=16)


def _train_inputs(cfg):
    def fn(B):
        return {
            "seq": SDS((B, cfg.seq_len), jnp.int32),
            "labels": SDS((B, cfg.seq_len), jnp.int32),
        }
    return fn


def _serve_inputs(cfg, n_cand=200):
    def fn(B):
        return {
            "seq": SDS((B, cfg.seq_len), jnp.int32),
            "candidates": SDS((B, n_cand), jnp.int32),
        }
    return fn


def _retrieval_inputs(cfg, n_cand):
    def fn():
        return {
            "seq": SDS((1, cfg.seq_len), jnp.int32),
            "candidates": SDS((n_cand,), jnp.int32),
        }
    return fn


def bundle(reduced: bool = False) -> ArchBundle:
    cfg = REDUCED if reduced else CONFIG
    sizes = (
        {"train_batch": 128, "serve_p99": 32, "serve_bulk": 256}
        if reduced else None
    )
    return recsys_bundle(
        "sasrec", cfg, RS.sasrec_init,
        lambda c, p, b: RS.sasrec_loss(c, p, b),
        lambda c, p, b: RS.sasrec_score(c, p, b),
        lambda c, p, b: RS.sasrec_retrieval(c, p, b),
        _train_inputs(cfg), _serve_inputs(cfg),
        _retrieval_inputs(cfg, 500 if reduced else 1_000_000),
        batch_sizes=sizes,
    )
