"""two-tower-retrieval [RecSys'19 YouTube-style]: embed_dim=256, tower MLP
1024-512-256, dot interaction, in-batch sampled softmax."""

import jax
import jax.numpy as jnp

from repro.configs.families import ArchBundle, recsys_bundle
from repro.models import recsys as RS

SDS = jax.ShapeDtypeStruct

CONFIG = RS.TwoTowerConfig()
REDUCED = RS.TwoTowerConfig(
    n_users=2000, n_items=1000, n_context=100, embed_dim=32,
    tower_mlp=(64, 32),
)


def _train_inputs(cfg):
    def fn(B):
        return {
            "user_id": SDS((B,), jnp.int32),
            "user_ctx": SDS((B,), jnp.int32),
            "item_id": SDS((B,), jnp.int32),
            "item_cat": SDS((B,), jnp.int32),
        }
    return fn


def _retrieval_inputs(cfg, n_cand):
    def fn():
        return {
            "user_id": SDS((1,), jnp.int32),
            "user_ctx": SDS((1,), jnp.int32),
            "candidate_embs": SDS((n_cand, cfg.tower_mlp[-1]), jnp.float32),
        }
    return fn


def bundle(reduced: bool = False) -> ArchBundle:
    cfg = REDUCED if reduced else CONFIG
    sizes = (
        {"train_batch": 128, "serve_p99": 32, "serve_bulk": 256}
        if reduced else None
    )
    return recsys_bundle(
        "two-tower-retrieval", cfg, RS.twotower_init,
        lambda c, p, b: RS.twotower_loss(c, p, b),
        lambda c, p, b: RS.twotower_score(c, p, b),
        lambda c, p, b: RS.twotower_retrieval(c, p, b),
        _train_inputs(cfg), _train_inputs(cfg),
        _retrieval_inputs(cfg, 1000 if reduced else 1_000_000),
        batch_sizes=sizes,
    )
