"""dlrm-mlperf [arXiv:1906.00091, MLPerf]: 13 dense + 26 sparse features,
embed_dim=128, bottom MLP 13-512-256-128, top MLP 1024-1024-512-256-1,
dot interaction.  Table cardinalities: Criteo-1TB (MLPerf v1 setting)."""

import jax
import jax.numpy as jnp

from repro.configs.families import ArchBundle, recsys_bundle
from repro.models import recsys as RS

SDS = jax.ShapeDtypeStruct

# Criteo Terabyte per-feature cardinalities (MLPerf DLRM benchmark set)
CRITEO_1TB_ROWS = (
    45833138, 36746, 17245, 7413, 20243, 3, 7114, 1441, 62, 29275261,
    1572176, 345138, 10, 2209, 11267, 128, 4, 974, 14, 48937457,
    11316796, 40094537, 452104, 12606, 104, 35,
)

CONFIG = RS.DLRMConfig(table_rows=CRITEO_1TB_ROWS)
REDUCED = RS.DLRMConfig(
    table_rows=tuple(min(r, 1000) for r in CRITEO_1TB_ROWS),
    bot_mlp=(64, 32, 16), top_mlp=(64, 32, 1), embed_dim=16,
)


def _train_inputs(cfg):
    def fn(B):
        return {
            "dense": SDS((B, cfg.n_dense), jnp.float32),
            "sparse": SDS((B, cfg.n_sparse), jnp.int32),
            "label": SDS((B,), jnp.float32),
        }
    return fn


def _serve_inputs(cfg):
    def fn(B):
        return {
            "dense": SDS((B, cfg.n_dense), jnp.float32),
            "sparse": SDS((B, cfg.n_sparse), jnp.int32),
        }
    return fn


def _retrieval_inputs(cfg, n_cand=1_000_000):
    def fn():
        return {
            "dense": SDS((1, cfg.n_dense), jnp.float32),
            "sparse": SDS((1, cfg.n_sparse), jnp.int32),
            "candidates": SDS((n_cand,), jnp.int32),
        }
    return fn


def _score(cfg, p, batch):
    return RS.dlrm_forward(cfg, p, batch)


def bundle(reduced: bool = False) -> ArchBundle:
    cfg = REDUCED if reduced else CONFIG
    sizes = (
        {"train_batch": 256, "serve_p99": 64, "serve_bulk": 512}
        if reduced else None
    )
    return recsys_bundle(
        "dlrm-mlperf", cfg, RS.dlrm_init,
        lambda c, p, b: RS.dlrm_loss(c, p, b),
        _score,
        lambda c, p, b: RS.dlrm_retrieval(c, p, b),
        _train_inputs(cfg), _serve_inputs(cfg),
        _retrieval_inputs(cfg, 1000 if reduced else 1_000_000),
        batch_sizes=sizes,
    )
