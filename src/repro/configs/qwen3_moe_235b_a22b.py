"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B family]: 94L d_model=4096
64H GQA kv=4 MoE 128 experts top-8 expert d_ff=1536, vocab 151936,
no shared experts, untied."""

from repro.configs.families import ArchBundle, lm_bundle
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,
    vocab=151_936,
    qkv_bias=False,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    moe=MoEConfig(
        n_experts=128, top_k=8, d_ff=1536, n_shared_experts=0,
        capacity_factor=1.25, group_tokens=4096,
    ),
)

REDUCED = TransformerConfig(
    name="qwen3-moe-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=64, vocab=512, tie_embeddings=False, loss_chunk=32, flash_chunk=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=48, n_shared_experts=0,
                  capacity_factor=2.0, group_tokens=128),
)


def bundle(reduced: bool = False) -> ArchBundle:
    if reduced:
        return lm_bundle(
            "qwen3-moe-235b-a22b", REDUCED,
            shapes={"train_4k": (4, 64), "prefill_32k": (2, 64),
                    "decode_32k": (4, 64), "long_500k": (1, 128)},
        )
    return lm_bundle("qwen3-moe-235b-a22b", CONFIG, microbatches=16)
