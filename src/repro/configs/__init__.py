"""Assigned-architecture registry: ``--arch <id>`` -> ArchBundle."""

from repro.configs.registry import ARCH_IDS, get_bundle, shape_cells  # noqa: F401
