"""din [arXiv:1706.06978]: embed_dim=18, behavior seq_len=100, target
attention MLP 80-40, head MLP 200-80."""

import jax
import jax.numpy as jnp

from repro.configs.families import ArchBundle, recsys_bundle
from repro.models import recsys as RS

SDS = jax.ShapeDtypeStruct

CONFIG = RS.DINConfig(n_items=1_000_000, n_cates=10_000)
REDUCED = RS.DINConfig(n_items=1000, n_cates=50, seq_len=20)


def _train_inputs(cfg):
    def fn(B):
        return {
            "hist_items": SDS((B, cfg.seq_len), jnp.int32),
            "hist_cates": SDS((B, cfg.seq_len), jnp.int32),
            "hist_mask": SDS((B, cfg.seq_len), jnp.float32),
            "target_item": SDS((B,), jnp.int32),
            "target_cate": SDS((B,), jnp.int32),
            "label": SDS((B,), jnp.float32),
        }
    return fn


def _serve_inputs(cfg):
    def fn(B):
        d = _train_inputs(cfg)(B)
        d.pop("label")
        return d
    return fn


def _retrieval_inputs(cfg, n_cand):
    def fn():
        return {
            "hist_items": SDS((1, cfg.seq_len), jnp.int32),
            "hist_cates": SDS((1, cfg.seq_len), jnp.int32),
            "hist_mask": SDS((1, cfg.seq_len), jnp.float32),
            "candidates": SDS((n_cand,), jnp.int32),
            "candidate_cates": SDS((n_cand,), jnp.int32),
        }
    return fn


def bundle(reduced: bool = False) -> ArchBundle:
    cfg = REDUCED if reduced else CONFIG
    sizes = (
        {"train_batch": 128, "serve_p99": 32, "serve_bulk": 256}
        if reduced else None
    )
    return recsys_bundle(
        "din", cfg, RS.din_init,
        lambda c, p, b: RS.din_loss(c, p, b),
        lambda c, p, b: RS.din_forward(c, p, b),
        lambda c, p, b: RS.din_retrieval(c, p, b),
        _train_inputs(cfg), _serve_inputs(cfg),
        _retrieval_inputs(cfg, 500 if reduced else 1_000_000),
        batch_sizes=sizes,
    )
