"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base]: 40L d_model=2048
32H GQA kv=8 d_ff=8192 vocab=49155, tied embeddings."""

from repro.configs.families import ArchBundle, lm_bundle
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="granite-3-2b",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_head=64,
    d_ff=8192,
    vocab=49_155,
    qkv_bias=False,
    rope_theta=10_000.0,
    tie_embeddings=True,
)

REDUCED = TransformerConfig(
    name="granite-3-2b-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=256, vocab=512, tie_embeddings=True, loss_chunk=32, flash_chunk=16,
)


def bundle(reduced: bool = False) -> ArchBundle:
    if reduced:
        return lm_bundle(
            "granite-3-2b", REDUCED,
            shapes={"train_4k": (4, 64), "prefill_32k": (2, 64),
                    "decode_32k": (4, 64), "long_500k": (1, 128)},
        )
    return lm_bundle("granite-3-2b", CONFIG, microbatches=4)
