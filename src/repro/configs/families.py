"""Family bundle builders: LM / GNN / RecSys.

An ``ArchBundle`` carries everything the launcher needs for one --arch:

  * ``init(rng)``                 — parameter init (or eval_shape'able)
  * ``rules``                     — sharding rules for the params
  * ``cells[shape] = CellSpec``   — step fn + abstract input specs +
                                    per-input sharding spec builders

Step functions take ``(params, opt_state, batch)`` for train cells and
``(params, batch)`` for serve cells; they are pure and jit-able.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import mace as M
from repro.models import recsys as RS
from repro.models import transformer as TF
from repro.models.gnn_common import NeighborSampler
from repro.train.optim import OptConfig, adamw_init, adamw_update
from repro.train.trainer import TrainerConfig, build_train_step

F32 = jnp.float32
I32 = jnp.int32
SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class CellSpec:
    kind: str                                  # train | serve
    fn: Callable                               # the step function
    inputs: Dict[str, Any]                     # name -> ShapeDtypeStruct tree
    input_sharding: Callable[[Mesh], Dict]     # name -> sharding tree
    static_note: str = ""


@dataclasses.dataclass
class ArchBundle:
    name: str
    family: str
    config: Any
    init: Callable
    rules: list
    cells: Dict[str, CellSpec]

    def param_shardings(self, mesh: Mesh):
        shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return shd.shard_by_rules(shapes, mesh, self.rules)

    def opt_shardings(self, mesh: Mesh):
        pshapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        oshapes = jax.eval_shape(adamw_init, pshapes)
        pshard = shd.shard_by_rules(pshapes, mesh, self.rules)
        return {
            "mu": pshard,
            "nu": jax.tree_util.tree_map(lambda s: s, pshard),
            "step": NamedSharding(mesh, P()),
        }

    def abstract_params(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def abstract_opt(self):
        return jax.eval_shape(adamw_init, self.abstract_params())


def _train_fn(loss_fn, opt: OptConfig, microbatches: int = 1):
    tc = TrainerConfig(opt=opt, microbatches=microbatches)
    return build_train_step(loss_fn, tc)


# ================================================================== LM =====
MODEL_AXIS_SIZE = 16  # production mesh model-axis width


def lm_bundle(name: str, cfg: TF.TransformerConfig,
              shapes: Optional[Dict[str, Tuple[int, int]]] = None,
              opt: Optional[OptConfig] = None,
              microbatches: int = 1) -> ArchBundle:
    shapes = shapes or {
        "train_4k": (256, 4096),
        "prefill_32k": (32, 32768),
        "decode_32k": (128, 32768),
        "long_500k": (1, 524288),
    }
    opt = opt or OptConfig()
    # padded head sharding everywhere: GSPMD pads uneven head counts
    # (36 -> 3/chip, 20 -> 2/chip); see EXPERIMENTS.md Perf train iter 1
    cfg = dataclasses.replace(cfg, att_shard="heads")

    def init(rng):
        return TF.init_params(cfg, rng)

    def loss_fn(params, batch):
        return TF.lm_loss(cfg, params, batch["tokens"], batch["labels"])[0]

    train_step = _train_fn(loss_fn, opt, microbatches)

    def prefill_step(params, batch):
        logits, cache = TF.prefill(cfg, params, batch["tokens"])
        return logits, cache["len"]

    def decode_step(params, batch):
        logits, cache = TF.decode_step(cfg, params, batch["token"], batch["cache"])
        return logits, cache

    cells: Dict[str, CellSpec] = {}

    B, S = shapes["train_4k"]
    cells["train_4k"] = CellSpec(
        kind="train",
        fn=train_step,
        inputs={
            "batch": {
                "tokens": SDS((B, S), I32),
                "labels": SDS((B, S), I32),
            }
        },
        input_sharding=lambda mesh: {
            "batch": {
                k: NamedSharding(mesh, P(shd.batch_spec(mesh)[0], None))
                for k in ("tokens", "labels")
            }
        },
    )

    B, S = shapes["prefill_32k"]
    cells["prefill_32k"] = CellSpec(
        kind="serve",
        fn=prefill_step,
        inputs={"batch": {"tokens": SDS((B, S), I32)}},
        input_sharding=lambda mesh: {
            "batch": {"tokens": NamedSharding(
                mesh, P(shd.batch_spec(mesh)[0], None))}
        },
    )

    def decode_cell(B, S_max):
        L, n_kv, D = cfg.n_layers, cfg.n_kv_heads, cfg.d_head

        def shard(mesh):
            b = shd.batch_spec(mesh)[0]
            kv_spec = P(None, b, "model", None, None)  # S sharded on model
            return {
                "batch": {
                    "token": NamedSharding(mesh, P(b)),
                    "cache": {
                        "k": NamedSharding(mesh, kv_spec),
                        "v": NamedSharding(mesh, kv_spec),
                        "len": NamedSharding(mesh, P(b)),
                    },
                }
            }

        return CellSpec(
            kind="serve",
            fn=decode_step,
            inputs={
                "batch": {
                    "token": SDS((B,), I32),
                    "cache": {
                        "k": SDS((L, B, S_max, n_kv, D), cfg.dtype),
                        "v": SDS((L, B, S_max, n_kv, D), cfg.dtype),
                        "len": SDS((B,), I32),
                    },
                }
            },
            input_sharding=shard,
            static_note="decode: one token against a paged KV cache",
        )

    cells["decode_32k"] = decode_cell(*shapes["decode_32k"])
    cells["long_500k"] = decode_cell(*shapes["long_500k"])

    return ArchBundle(
        name=name, family="lm", config=cfg, init=init,
        rules=shd.LM_RULES, cells=cells,
    )


# ================================================================= GNN =====
def gnn_bundle(name: str, base: M.MACEConfig, reduced: bool = False) -> ArchBundle:
    opt = OptConfig(lr=1e-3, weight_decay=0.0, schedule="cosine",
                    warmup_steps=10, total_steps=1000)

    # one config per cell (d_feat / n_out vary per dataset shape)
    cfg_cora = dataclasses.replace(base, d_feat=1433, n_out=7)
    cfg_reddit = dataclasses.replace(base, d_feat=602, n_out=41)
    cfg_products = dataclasses.replace(base, d_feat=100, n_out=47)
    cfg_mol = dataclasses.replace(base, d_feat=0, n_species=32, n_out=1)

    if reduced:
        sizes = {
            "cora": (128, 512), "products": (256, 1024),
            "mb_seeds": (8, [3, 2]), "mol": (4, 10, 16),
        }
    else:
        sizes = {
            "cora": (2708, 10556), "products": (2_449_029, 61_859_140),
            "mb_seeds": (1024, [15, 10]), "mol": (128, 30, 64),
        }

    def make_node_cell(cfg, N, E, masked=False):
        def init(rng):
            return M.mace_init(cfg, rng)

        def loss_fn(params, batch):
            return M.mace_node_xent(cfg, params, batch)

        step = _train_fn(loss_fn, opt)
        inputs = {
            "batch": {
                "feat": SDS((N, cfg.d_feat), F32),
                "pos": SDS((N, 3), F32),
                "edges_src": SDS((E,), I32),
                "edges_dst": SDS((E,), I32),
                "labels": SDS((N,), I32),
            }
        }
        if masked:
            inputs["batch"]["edge_mask"] = SDS((E,), F32)
            inputs["batch"]["label_mask"] = SDS((N,), F32)

        def shard(mesh):
            b = shd.batch_spec(mesh)[0]
            out = {
                "feat": NamedSharding(mesh, P(b, None)),
                "pos": NamedSharding(mesh, P(b, None)),
                "edges_src": NamedSharding(mesh, P(b)),
                "edges_dst": NamedSharding(mesh, P(b)),
                "labels": NamedSharding(mesh, P(b)),
            }
            if masked:
                out["edge_mask"] = NamedSharding(mesh, P(b))
                out["label_mask"] = NamedSharding(mesh, P(b))
            return {"batch": out}

        return init, CellSpec(
            kind="train", fn=step, inputs=inputs, input_sharding=shard
        )

    init_fn, cell_cora = make_node_cell(cfg_cora, *sizes["cora"])
    n_max, e_max = NeighborSampler.padded_sizes(*sizes["mb_seeds"])
    _, cell_mb = make_node_cell(cfg_reddit, n_max, e_max, masked=True)
    _, cell_prod = make_node_cell(cfg_products, *sizes["products"])

    # molecule: batched small graphs, energy regression
    n_g, n_n, n_e = sizes["mol"]

    def init_mol(rng):
        return M.mace_init(cfg_mol, rng)

    def loss_mol(params, batch):
        return M.mace_energy_mse(cfg_mol, params, batch)

    cell_mol = CellSpec(
        kind="train",
        fn=_train_fn(loss_mol, opt),
        inputs={
            "batch": {
                "species": SDS((n_g * n_n,), I32),
                "pos": SDS((n_g * n_n, 3), F32),
                "edges_src": SDS((n_g * n_e,), I32),
                "edges_dst": SDS((n_g * n_e,), I32),
                "graph_of": SDS((n_g * n_n,), I32),
                "energy": SDS((n_g,), F32),
            }
        },
        input_sharding=lambda mesh: {
            "batch": {
                k: NamedSharding(
                    mesh, P(shd.batch_spec(mesh)[0], *([None] * (ndim - 1)))
                )
                for k, ndim in (
                    ("species", 1), ("pos", 2), ("edges_src", 1),
                    ("edges_dst", 1), ("graph_of", 1), ("energy", 1),
                )
            }
        },
    )

    # NOTE: node-cell archs share MACE weights modulo head/input dims; the
    # bundle's init is the Cora variant; each cell keeps its own init via
    # closure when lowered by the dry-run (see dryrun._cell_init).
    bundle = ArchBundle(
        name=name, family="gnn", config=base, init=init_fn,
        rules=shd.GNN_RULES,
        cells={
            "full_graph_sm": cell_cora,
            "minibatch_lg": cell_mb,
            "ogb_products": cell_prod,
            "molecule": cell_mol,
        },
    )
    bundle.cell_inits = {
        "full_graph_sm": lambda rng: M.mace_init(cfg_cora, rng),
        "minibatch_lg": lambda rng: M.mace_init(cfg_reddit, rng),
        "ogb_products": lambda rng: M.mace_init(cfg_products, rng),
        "molecule": init_mol,
    }
    bundle.cell_configs = {
        "full_graph_sm": cfg_cora,
        "minibatch_lg": cfg_reddit,
        "ogb_products": cfg_products,
        "molecule": cfg_mol,
    }
    return bundle


# ============================================================== RecSys =====
def recsys_bundle(
    name: str,
    cfg: Any,
    init_fn: Callable,
    loss_fn: Callable,
    score_fn: Callable,
    retrieval_fn: Callable,
    train_inputs: Callable[[int], Dict],
    serve_inputs: Callable[[int], Dict],
    retrieval_inputs: Callable[[], Dict],
    batch_sizes: Optional[Dict[str, int]] = None,
) -> ArchBundle:
    bs = batch_sizes or {
        "train_batch": 65_536, "serve_p99": 512, "serve_bulk": 262_144
    }
    opt = OptConfig(lr=1e-3, weight_decay=1e-5, schedule="const",
                    warmup_steps=100, total_steps=100_000)
    train_step = _train_fn(lambda p, b: loss_fn(cfg, p, b), opt)

    def serve_step(params, batch):
        return score_fn(cfg, params, batch)

    def retrieval_step(params, batch):
        return retrieval_fn(cfg, params, batch)

    def mk_shard(inputs_fn):
        def shard(mesh):
            b = shd.batch_spec(mesh)[0]

            def one(leaf):
                nd = len(leaf.shape)
                if nd == 0:
                    return NamedSharding(mesh, P())
                return NamedSharding(mesh, P(b, *([None] * (nd - 1))))

            return {"batch": jax.tree_util.tree_map(one, inputs_fn)}

        return shard

    cells = {}
    cells["train_batch"] = CellSpec(
        kind="train", fn=train_step,
        inputs={"batch": train_inputs(bs["train_batch"])},
        input_sharding=mk_shard(train_inputs(bs["train_batch"])),
    )
    cells["serve_p99"] = CellSpec(
        kind="serve", fn=serve_step,
        inputs={"batch": serve_inputs(bs["serve_p99"])},
        input_sharding=mk_shard(serve_inputs(bs["serve_p99"])),
    )
    cells["serve_bulk"] = CellSpec(
        kind="serve", fn=serve_step,
        inputs={"batch": serve_inputs(bs["serve_bulk"])},
        input_sharding=mk_shard(serve_inputs(bs["serve_bulk"])),
    )
    ret_in = retrieval_inputs()
    cells["retrieval_cand"] = CellSpec(
        kind="serve", fn=retrieval_step,
        inputs={"batch": ret_in},
        input_sharding=lambda mesh: {
            "batch": jax.tree_util.tree_map(
                lambda leaf: NamedSharding(
                    mesh,
                    P(shd.batch_spec(mesh)[0],
                      *([None] * (len(leaf.shape) - 1)))
                    if leaf.shape and leaf.shape[0] >= 1_000_000
                    else P(*([None] * len(leaf.shape))),
                ),
                ret_in,
            )
        },
    )
    return ArchBundle(
        name=name, family="recsys", config=cfg,
        init=lambda rng: init_fn(cfg, rng),
        rules=shd.RECSYS_RULES, cells=cells,
    )
