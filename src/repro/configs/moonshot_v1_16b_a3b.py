"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: 48L d_model=2048
16H kv=16 MoE 64 experts top-6 expert d_ff=1408, 2 shared experts
(DeepSeek-style), vocab 163840."""

from repro.configs.families import ArchBundle, lm_bundle
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,  # expert hidden (unused for dense path)
    vocab=163_840,
    qkv_bias=False,
    rope_theta=50_000.0,
    tie_embeddings=True,
    moe=MoEConfig(
        n_experts=64, top_k=6, d_ff=1408, n_shared_experts=2,
        capacity_factor=1.25, group_tokens=4096,
    ),
)

REDUCED = TransformerConfig(
    name="moonshot-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=64, vocab=512, tie_embeddings=True, loss_chunk=32, flash_chunk=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=64, n_shared_experts=1,
                  capacity_factor=2.0, group_tokens=128),
)


def bundle(reduced: bool = False) -> ArchBundle:
    if reduced:
        return lm_bundle(
            "moonshot-v1-16b-a3b", REDUCED,
            shapes={"train_4k": (4, 64), "prefill_32k": (2, 64),
                    "decode_32k": (4, 64), "long_500k": (1, 128)},
        )
    return lm_bundle("moonshot-v1-16b-a3b", CONFIG, microbatches=8)
