"""minicpm-2b [arXiv:2404.06395; hf]: 40L d_model=2304 36H (MHA kv=36)
d_ff=5760 vocab=122753, WSD schedule, tied embeddings (MiniCPM ties)."""

from repro.configs.families import ArchBundle, lm_bundle
from repro.models.transformer import TransformerConfig
from repro.train.optim import OptConfig

CONFIG = TransformerConfig(
    name="minicpm-2b",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_head=64,
    d_ff=5760,
    vocab=122_753,
    qkv_bias=False,
    rope_theta=10_000.0,
    tie_embeddings=True,
)

REDUCED = TransformerConfig(
    name="minicpm-2b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=160, vocab=512, tie_embeddings=True, loss_chunk=32, flash_chunk=16,
)

# the WSD (warmup-stable-decay) schedule is the arch's signature trainer
OPT = OptConfig(lr=1e-2 / 4, schedule="wsd", warmup_steps=500,
                total_steps=50_000, decay_fraction=0.1)


def bundle(reduced: bool = False) -> ArchBundle:
    if reduced:
        return lm_bundle(
            "minicpm-2b", REDUCED, opt=OPT,
            shapes={"train_4k": (4, 64), "prefill_32k": (2, 64),
                    "decode_32k": (4, 64), "long_500k": (1, 128)},
        )
    return lm_bundle("minicpm-2b", CONFIG, opt=OPT, microbatches=4)
