"""Architecture registry: maps --arch ids to bundles of
(config, init, sharding rules, per-shape step functions + input specs).

Shape cells per family (the assignment):
  LM:     train_4k, prefill_32k, decode_32k, long_500k
  GNN:    full_graph_sm, minibatch_lg, ogb_products, molecule
  RecSys: train_batch, serve_p99, serve_bulk, retrieval_cand
"""

from __future__ import annotations

import importlib
from typing import Dict, List

ARCH_IDS = [
    "minicpm-2b",
    "granite-3-2b",
    "qwen1.5-4b",
    "moonshot-v1-16b-a3b",
    "qwen3-moe-235b-a22b",
    "mace",
    "dlrm-mlperf",
    "din",
    "sasrec",
    "two-tower-retrieval",
]

_MODULES = {
    "minicpm-2b": "repro.configs.minicpm_2b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "mace": "repro.configs.mace_cfg",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
    "din": "repro.configs.din_cfg",
    "sasrec": "repro.configs.sasrec_cfg",
    "two-tower-retrieval": "repro.configs.two_tower_retrieval",
}

LM_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
GNN_SHAPES = ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"]
RECSYS_SHAPES = ["train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]


def shape_cells(arch: str) -> List[str]:
    fam = get_bundle(arch).family
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}[fam]


def get_bundle(arch: str, reduced: bool = False):
    mod = importlib.import_module(_MODULES[arch])
    return mod.bundle(reduced=reduced)


def all_cells() -> List:
    return [(a, s) for a in ARCH_IDS for s in shape_cells(a)]
