"""GNN substrate: segment-sum message passing + a real neighbor sampler.

JAX sparse is BCOO-only, so message passing is implemented as
edge-gather -> edge-compute -> ``jax.ops.segment_sum`` scatter into nodes
(this IS the system, per the brief).  The sampler produces fixed-shape
(padded) subgraphs so the sampled-training step jits once.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ host graphs ---
@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (E,)

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def n_edges(self) -> int:
        return self.indices.shape[0]


def synthetic_graph(n_nodes: int, avg_degree: int, seed: int = 0) -> CSRGraph:
    """Power-law-ish random graph in CSR (host-side, memory-bounded)."""
    rng = np.random.RandomState(seed)
    deg = np.minimum(
        rng.zipf(1.7, size=n_nodes).astype(np.int64) + avg_degree // 2,
        20 * avg_degree,
    )
    deg = (deg * (avg_degree / max(1.0, deg.mean()))).astype(np.int64)
    deg = np.maximum(deg, 1)
    indptr = np.concatenate(([0], np.cumsum(deg)))
    indices = rng.randint(0, n_nodes, size=int(indptr[-1]), dtype=np.int64)
    return CSRGraph(indptr=indptr, indices=indices)


class NeighborSampler:
    """Layered fanout sampling (GraphSAGE style) with fixed padded shapes.

    Returns a subgraph dict:
      nodes     (n_max,)   global node ids (padded with 0)
      node_mask (n_max,)   1 for real nodes
      edges_src (e_max,)   LOCAL indices into nodes
      edges_dst (e_max,)
      edge_mask (e_max,)
      seeds     (n_seeds,) local indices of the seed nodes (always 0..n_seeds-1)
    """

    def __init__(self, graph: CSRGraph, fanout: Sequence[int]):
        self.g = graph
        self.fanout = list(fanout)

    @staticmethod
    def padded_sizes(n_seeds: int, fanout: Sequence[int]) -> Tuple[int, int]:
        n_max, e_max, frontier = n_seeds, 0, n_seeds
        for f in fanout:
            e = frontier * f
            e_max += e
            n_max += e
            frontier = e
        return n_max, e_max

    def sample(self, seeds: np.ndarray, rng: np.random.RandomState) -> Dict:
        n_max, e_max = self.padded_sizes(len(seeds), self.fanout)
        nodes: List[int] = list(seeds)
        local = {int(n): i for i, n in enumerate(seeds)}
        src_l: List[int] = []
        dst_l: List[int] = []
        frontier = list(seeds)
        for f in self.fanout:
            nxt: List[int] = []
            for u in frontier:
                lo, hi = self.g.indptr[u], self.g.indptr[u + 1]
                if hi <= lo:
                    continue
                picks = self.g.indices[
                    rng.randint(lo, hi, size=min(f, hi - lo))
                ]
                for vv in picks:
                    v = int(vv)
                    if v not in local:
                        local[v] = len(nodes)
                        nodes.append(v)
                    # message flows v -> u
                    src_l.append(local[v])
                    dst_l.append(local[u])
                    nxt.append(v)
            frontier = nxt
        n, e = len(nodes), len(src_l)
        out = {
            "nodes": np.zeros(n_max, np.int64),
            "node_mask": np.zeros(n_max, np.float32),
            "edges_src": np.zeros(e_max, np.int32),
            "edges_dst": np.zeros(e_max, np.int32),
            "edge_mask": np.zeros(e_max, np.float32),
            "n_seeds": len(seeds),
        }
        out["nodes"][:n] = nodes
        out["node_mask"][:n] = 1.0
        out["edges_src"][:e] = src_l
        out["edges_dst"][:e] = dst_l
        out["edge_mask"][:e] = 1.0
        return out


def batch_small_graphs(
    n_graphs: int, n_nodes: int, n_edges: int, seed: int = 0
) -> Dict[str, np.ndarray]:
    """Batched molecule-style graphs: block-diagonal edge list + graph ids."""
    rng = np.random.RandomState(seed)
    src = rng.randint(0, n_nodes, size=(n_graphs, n_edges))
    dst = rng.randint(0, n_nodes, size=(n_graphs, n_edges))
    offs = (np.arange(n_graphs) * n_nodes)[:, None]
    return {
        "edges_src": (src + offs).reshape(-1).astype(np.int32),
        "edges_dst": (dst + offs).reshape(-1).astype(np.int32),
        "graph_of": np.repeat(np.arange(n_graphs), n_nodes).astype(np.int32),
    }
