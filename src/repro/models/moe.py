"""Mixture-of-Experts layer: GShard-style grouped top-k dispatch.

Tokens are processed in groups (sharded over the data axis); each group
computes a capacity-bounded one-hot dispatch tensor, so the whole layer is
einsums — the SPMD-friendly formulation (the token->expert scatter becomes
all-to-all under GSPMD when experts are sharded over the model axis).

This is the DS-strategy analogue at the model level (DESIGN.md): many
small scatters (token->expert sends) are packed into one dense batched
operation with an indirection structure (the dispatch tensor), exactly the
paper's pack-small-writes-into-one-large-write idea.

Capacity drops are counted in aux metrics; the router uses f32.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.hooks import constrain
from repro.nn.layers import dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    n_shared_experts: int = 0      # DeepSeek/Moonlight-style always-on experts
    capacity_factor: float = 1.25
    group_tokens: int = 4096       # tokens per dispatch group
    # 'onehot': GShard dispatch/combine einsums (SPMD-simple, but the
    #   (T,E,C,d) contractions cost ~2x the expert FFN at E=128/k=8);
    # 'sort': argsort-based scatter/gather dispatch, O(T*k*d) data
    #   movement (EXPERIMENTS.md Perf, MoE iteration)
    dispatch: str = "onehot"


def moe_init(key, d_model: int, cfg: MoEConfig) -> Dict:
    ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_ff
    scale_in = 1.0 / jnp.sqrt(d_model)
    scale_out = 1.0 / jnp.sqrt(F)
    p = {
        "router": dense_init(ks[0], d_model, E, scale=0.02),
        # SwiGLU experts: gate, up, down
        "wg": jax.random.normal(ks[1], (E, d_model, F), jnp.float32) * scale_in,
        "wu": jax.random.normal(ks[2], (E, d_model, F), jnp.float32) * scale_in,
        "wd": jax.random.normal(ks[3], (E, F, d_model), jnp.float32) * scale_out,
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": jax.random.normal(kk[0], (d_model, Fs), jnp.float32) * scale_in,
            "wu": jax.random.normal(kk[1], (d_model, Fs), jnp.float32) * scale_in,
            "wd": jax.random.normal(kk[2], (Fs, d_model), jnp.float32) * scale_out,
        }
    return p


def _top_k_dispatch(
    gates: jnp.ndarray,  # (G, T, E) f32 softmax probs
    top_k: int,
    capacity: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """GShard dispatch/combine tensors: (G, T, E, C) each."""
    G, T, E = gates.shape
    remaining = gates
    location = jnp.zeros((G, T, E), jnp.int32)  # running per-expert counter
    dispatch = None
    combine = None
    dropped = jnp.zeros((), jnp.float32)
    prev_counts = jnp.zeros((G, 1, E), jnp.int32)
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)                      # (G, T)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)        # (G, T, E)
        gate_k = (remaining * onehot).sum(-1)                     # (G, T)
        remaining = remaining * (1.0 - onehot)
        pos = jnp.cumsum(onehot, axis=1) - onehot + prev_counts   # (G, T, E)
        prev_counts = prev_counts + jnp.sum(
            onehot, axis=1, keepdims=True
        ).astype(jnp.int32)
        pos_k = (pos * onehot).sum(-1)                            # (G, T)
        keep = pos_k < capacity
        dropped = dropped + (1.0 - keep.astype(jnp.float32)).sum()
        cap_oh = jax.nn.one_hot(
            jnp.where(keep, pos_k.astype(jnp.int32), capacity), capacity,
            dtype=jnp.float32,
        )                                                          # (G, T, C)
        d_k = onehot[..., None] * cap_oh[..., None, :]             # (G, T, E, C)
        dispatch = d_k if dispatch is None else dispatch + d_k
        c_k = d_k * gate_k[..., None, None]
        combine = c_k if combine is None else combine + c_k
    aux = {"dropped_tokens": dropped}
    return dispatch, combine, aux


def _sorted_dispatch_apply(
    p: Dict, xg: jnp.ndarray, gates: jnp.ndarray, cfg: MoEConfig,
    C: int, dtype,
) -> Tuple[jnp.ndarray, Dict]:
    """Sort-based expert dispatch: argsort token->expert assignments,
    scatter tokens into (E, C, d) buffers, gather results back.  Moves
    O(T*k*d) bytes instead of contracting (T,E,C,d) one-hots — the same
    capacity/priority semantics as the GShard path (first-come within an
    expert, in token order; ties resolved identically via stable sort)."""
    G, T, E = gates.shape
    k = cfg.top_k
    # top-k experts per token (loop matches _top_k_dispatch's semantics)
    remaining = gates
    eidx, gval = [], []
    for _ in range(k):
        i = jnp.argmax(remaining, axis=-1)                  # (G, T)
        oh = jax.nn.one_hot(i, E, dtype=gates.dtype)
        eidx.append(i)
        gval.append((remaining * oh).sum(-1))
        remaining = remaining * (1.0 - oh)
    # k-major flattening: within an expert, all round-0 picks outrank
    # round-1 picks (GShard's prev_counts offset), then token order —
    # keeps drop semantics identical to the one-hot path
    e_flat = jnp.stack(eidx, 1).reshape(G, k * T)            # (G, kT)
    g_flat = jnp.stack(gval, 1).reshape(G, k * T)
    t_flat = jnp.tile(jnp.arange(T), (G, k)).astype(jnp.int32)

    order = jnp.argsort(e_flat, axis=1, stable=True)         # (G, Tk)
    e_sort = jnp.take_along_axis(e_flat, order, 1)
    t_sort = jnp.take_along_axis(t_flat, order, 1)
    g_sort = jnp.take_along_axis(g_flat, order, 1)
    # rank within expert = position - index of the expert's first entry
    first = jax.vmap(
        lambda es: jnp.searchsorted(es, jnp.arange(E), side="left")
    )(e_sort)                                                 # (G, E)
    pos = jnp.arange(T * k)[None, :] - jnp.take_along_axis(
        first, e_sort, 1
    )
    keep = pos < C
    dropped = (1.0 - keep.astype(jnp.float32)).sum()
    slot = jnp.where(keep, e_sort * C + pos, E * C)           # E*C = trash row

    xt = jnp.take_along_axis(
        xg.astype(dtype), t_sort[..., None], 1
    )                                                         # (G, Tk, d)
    xe = jnp.zeros((G, E * C + 1, xg.shape[-1]), dtype)
    xe = jax.vmap(lambda buf, s, v: buf.at[s].set(v))(xe, slot, xt)
    xe = xe[:, : E * C].reshape(G, E, C, -1)
    xe = constrain(xe, "batch", "model", None, None)
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(dtype))
    ) * jnp.einsum("gecd,edf->gecf", xe, p["wu"].astype(dtype))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"].astype(dtype))
    ye = constrain(ye, "batch", "model", None, None)
    # gather back + weighted combine into token order
    ye_flat = ye.reshape(G, E * C, -1)
    yt = jax.vmap(lambda buf, s: buf[jnp.minimum(s, E * C - 1)])(
        ye_flat, slot
    ) * (keep[..., None] * g_sort[..., None]).astype(dtype)
    y = jax.vmap(
        lambda t, v: jax.ops.segment_sum(v, t, num_segments=T)
    )(t_sort, yt)
    aux = {"dropped_tokens": dropped}
    return y.astype(dtype), aux


def moe_apply(
    p: Dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg: MoEConfig,
    dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Dict]:
    B, S, d = x.shape
    N = B * S
    Tg = min(cfg.group_tokens, N)
    while N % Tg:  # largest group size <= group_tokens that divides N
        Tg -= 1
    G = N // Tg
    xg = x.reshape(G, Tg, d)
    E = cfg.n_experts
    C = max(1, int(Tg * cfg.top_k * cfg.capacity_factor / E))

    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"]["w"]
    )
    gates = jax.nn.softmax(logits, axis=-1)

    if cfg.dispatch == "sort":
        y, aux = _sorted_dispatch_apply(p, xg, gates, cfg, C, dtype)
        me = gates.mean(axis=(0, 1))
        aux["balance_loss"] = E * jnp.sum(me * me)  # proxy (no dispatch tensor)
        if cfg.n_shared_experts:
            sh = p["shared"]
            hs = jax.nn.silu(
                jnp.einsum("gtd,df->gtf", xg.astype(dtype),
                           sh["wg"].astype(dtype))
            ) * jnp.einsum("gtd,df->gtf", xg.astype(dtype),
                           sh["wu"].astype(dtype))
            y = y + jnp.einsum("gtf,fd->gtd", hs, sh["wd"].astype(dtype))
        return y.reshape(B, S, d).astype(x.dtype), aux

    dispatch, combine, aux = _top_k_dispatch(gates, cfg.top_k, C)

    # load-balancing aux loss (Shazeer): E * sum_e f_e * p_e
    me = gates.mean(axis=(0, 1))
    ce = dispatch.sum(axis=(1, 3)).mean(axis=0) / Tg
    aux["balance_loss"] = E * jnp.sum(me * ce)

    # expert-parallel placement: groups follow the batch axes, experts the
    # model axis; the gtec/gecd einsums become the token all-to-all.
    xg = constrain(xg, "batch", None, None)
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dtype), xg.astype(dtype))
    xe = constrain(xe, "batch", "model", None, None)
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(dtype))
    ) * jnp.einsum("gecd,edf->gecf", xe, p["wu"].astype(dtype))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"].astype(dtype))
    ye = constrain(ye, "batch", "model", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(dtype), ye)

    if cfg.n_shared_experts:
        sh = p["shared"]
        hs = jax.nn.silu(
            jnp.einsum("gtd,df->gtf", xg.astype(dtype), sh["wg"].astype(dtype))
        ) * jnp.einsum("gtd,df->gtf", xg.astype(dtype), sh["wu"].astype(dtype))
        y = y + jnp.einsum("gtf,fd->gtd", hs, sh["wd"].astype(dtype))

    return y.reshape(B, S, d).astype(x.dtype), aux
