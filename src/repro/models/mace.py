"""MACE: higher-order equivariant message passing (arXiv:2206.07697),
adapted to the segment-sum substrate.

Implemented structure (l_max=2, correlation order 3, E(3)-equivariant):

  * node states h: (N, k, 9) — k channels x real-SH irreps [l0|l1(3)|l2(5)],
  * radial basis: n_rbf Bessel-type functions with a smooth cutoff,
  * A-basis: A_t = sum_{e: s->t} R_l(r_e) * (h_s (x) Y(r̂_e))  — the
    tensor product is contracted through the real-Gaunt tensor C[a,b,c]
    (computed once, numerically, by spherical quadrature — no e3nn),
  * B-basis: correlation up to nu=3 by repeated C-contraction
    (B2 = C(A, A), B3 = C(B2, A)) with per-order channel mixing,
  * readout: invariant (l=0) channels -> MLP -> node logits / energies.

Equivariance is checked in the tests by random global rotations.
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed.hooks import constrain
from repro.nn.layers import dense_init, mlp_apply, mlp_init
from repro.sparse.embedding import embedding_lookup

Params = Dict[str, Any]

N_IRREPS = 9  # l=0 (1) + l=1 (3) + l=2 (5)
L_OF = np.array([0, 1, 1, 1, 2, 2, 2, 2, 2])  # irrep -> l


def real_sph_harm(u: jnp.ndarray) -> jnp.ndarray:
    """Real spherical harmonics l<=2 for unit vectors u: (..., 3) -> (..., 9)."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    c0 = 0.28209479177387814
    c1 = 0.4886025119029199
    c2a = 1.0925484305920792
    c2b = 0.31539156525252005
    c2c = 0.5462742152960396
    return jnp.stack(
        [
            jnp.full_like(x, c0),
            c1 * y,
            c1 * z,
            c1 * x,
            c2a * x * y,
            c2a * y * z,
            c2b * (3 * z * z - 1.0),
            c2a * x * z,
            c2c * (x * x - y * y),
        ],
        axis=-1,
    )


def _np_real_sph_harm(u: np.ndarray) -> np.ndarray:
    """Pure-numpy twin of real_sph_harm (used at module-init time only —
    jnp inside a jit trace would turn the quadrature into tracers)."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    c0 = 0.28209479177387814
    c1 = 0.4886025119029199
    c2a = 1.0925484305920792
    c2b = 0.31539156525252005
    c2c = 0.5462742152960396
    return np.stack(
        [np.full_like(x, c0), c1 * y, c1 * z, c1 * x, c2a * x * y,
         c2a * y * z, c2b * (3 * z * z - 1.0), c2a * x * z,
         c2c * (x * x - y * y)],
        axis=-1,
    )


@lru_cache(maxsize=1)
def gaunt_tensor() -> np.ndarray:
    """C[a,b,c] = ∫ Y_a Y_b Y_c dΩ by Gauss-Legendre x uniform-phi quadrature
    (exact for the l<=6 band limit of triple products of l<=2)."""
    nct, nph = 64, 128
    ct, wt = np.polynomial.legendre.leggauss(nct)
    ph = (np.arange(nph) + 0.5) * (2 * np.pi / nph)
    ctg, phg = np.meshgrid(ct, ph, indexing="ij")
    st = np.sqrt(1.0 - ctg**2)
    xyz = np.stack([st * np.cos(phg), st * np.sin(phg), ctg], axis=-1)
    Y = _np_real_sph_harm(xyz)                       # (nct, nph, 9)
    w = wt[:, None] * (2 * np.pi / nph)              # (nct, 1)
    C = np.einsum("tpa,tpb,tpc,tp->abc", Y, Y, Y, np.broadcast_to(w, ctg.shape))
    C[np.abs(C) < 1e-12] = 0.0
    return C.astype(np.float32)


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128          # channels k
    l_max: int = 2               # fixed at 2 in this implementation
    correlation: int = 3
    n_rbf: int = 8
    r_cut: float = 2.5
    d_feat: int = 0              # input node feature dim (0: species embed)
    n_species: int = 32
    n_out: int = 1               # 1: energy regression; >1: node classes
    readout_mlp: Tuple[int, ...] = (64,)
    dtype: Any = jnp.float32     # equivariant algebra is f32
    # edge-chunked message passing: graphs beyond this many edges are
    # processed in lax.scan chunks of this size (bounds the (E, k, 9)
    # working set; padded edges are zero-length self loops -> masked)
    edge_chunk: int = 1 << 21


def mace_init(cfg: MACEConfig, key) -> Params:
    ks = jax.random.split(key, 8 + 4 * cfg.n_layers)
    k = cfg.d_hidden
    p: Params = {}
    if cfg.d_feat:
        p["feat_in"] = dense_init(ks[0], cfg.d_feat, k)
    else:
        p["species"] = {
            "table": jax.random.normal(ks[0], (cfg.n_species, k), jnp.float32)
            * 0.5
        }
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[1 + i], 8)
        layers.append(
            {
                # radial MLP: rbf -> per-channel, per-l weights
                "radial": mlp_init(kk[0], (cfg.n_rbf, 32, k * 3)),
                # channel mixers for B1, B2, B3 per l block: (k, k, 3)
                "w1": jax.random.normal(kk[1], (k, k, 3), jnp.float32) / math.sqrt(k),
                "w2": jax.random.normal(kk[2], (k, k, 3), jnp.float32) / math.sqrt(k),
                "w3": jax.random.normal(kk[3], (k, k, 3), jnp.float32) / math.sqrt(k),
                "self": jax.random.normal(kk[4], (k, k, 3), jnp.float32) / math.sqrt(k),
            }
        )
    p["layers"] = layers
    p["readout"] = mlp_init(
        ks[-1], (k,) + cfg.readout_mlp + (cfg.n_out,)
    )
    return p


def bessel_rbf(r: jnp.ndarray, n_rbf: int, r_cut: float) -> jnp.ndarray:
    """Bessel radial basis with smooth polynomial cutoff (MACE eq. 5)."""
    rs = jnp.maximum(r, 1e-6)[..., None]
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * math.pi * rs / r_cut) / rs
    t = jnp.clip(r / r_cut, 0.0, 1.0)[..., None]
    env = 1.0 - 10.0 * t**3 + 15.0 * t**4 - 6.0 * t**5
    return basis * env


def _mix(w: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Per-l channel mixing: w (k,k,3), h (N,k,9) -> (N,k,9)."""
    lidx = jnp.asarray(L_OF)
    wl = w[:, :, lidx]  # (k, k, 9)
    return jnp.einsum("nka,jka->nja", h, wl)


def mace_forward(
    cfg: MACEConfig,
    p: Params,
    node_feat: jnp.ndarray,   # (N, d_feat) f32 or (N,) int species
    positions: jnp.ndarray,   # (N, 3)
    edges_src: jnp.ndarray,   # (E,)
    edges_dst: jnp.ndarray,   # (E,)
    edge_mask: Optional[jnp.ndarray] = None,  # (E,)
) -> jnp.ndarray:
    """Returns node outputs (N, n_out)."""
    C = jnp.asarray(gaunt_tensor())
    N = positions.shape[0]
    k = cfg.d_hidden
    if cfg.d_feat:
        scal = jnp.einsum(
            "nd,dk->nk", node_feat.astype(jnp.float32), p["feat_in"]["w"]
        )
    else:
        scal = p["species"]["table"][node_feat]
    h = jnp.zeros((N, k, N_IRREPS), jnp.float32)
    h = h.at[:, :, 0].set(scal)

    rvec = positions[edges_dst] - positions[edges_src]       # (E, 3)
    r = jnp.sqrt(jnp.sum(rvec * rvec, axis=-1) + 1e-18)
    u = rvec / jnp.maximum(r, 1e-6)[:, None]
    Y = real_sph_harm(u)                                     # (E, 9)
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.r_cut)                # (E, n_rbf)
    # zero-length (self-loop / padded) edges carry no message: Y(0) is a
    # fixed non-scalar vector and would break equivariance if summed in
    rbf = rbf * (r > 1e-6)[:, None]
    if edge_mask is not None:
        rbf = rbf * edge_mask[:, None]
    lidx = jnp.asarray(L_OF)

    # edge tensors are sharded over the batch axes: GSPMD loses the edge
    # sharding through the h[edges_src] gather and replicates the whole
    # edge pipeline per chip — constraints pin it down (EXPERIMENTS.md
    # Perf, GNN iteration 1)
    Y = constrain(Y, "batch", None)
    rbf = constrain(rbf, "batch", None)

    E = edges_src.shape[0]
    n_chunks = max(1, -(-E // cfg.edge_chunk)) if cfg.edge_chunk else 1

    def edge_msgs(lp, h, y_c, rbf_c, src_c):
        R = mlp_apply(lp["radial"], rbf_c, dtype=jnp.float32)  # (e, k*3)
        R = constrain(R, "batch", None)
        R = R.reshape(-1, k, 3)[:, :, lidx]                    # (e, k, 9)
        hs = constrain(h[src_c], "batch", None, None)          # (e, k, 9)
        # phi_e[k, c] = R[k, c] * sum_{a,b} C[a,b,c] h_s[k,a] Y[b]
        return jnp.einsum("eka,eb,abc->ekc", hs, y_c, C) * R

    def layer(lp, h):
        if n_chunks == 1:
            msg = constrain(
                edge_msgs(lp, h, Y, rbf, edges_src), "batch", None, None
            )
            A = jax.ops.segment_sum(msg, edges_dst, num_segments=N)
        else:
            # edge-chunked accumulation: bounds the (E, k, 9) working set
            # (padded tail edges are (0,0) self-loops -> rbf masked -> 0)
            ck = cfg.edge_chunk
            pad = n_chunks * ck - E

            def padded(x, fill=0):
                return jnp.concatenate(
                    [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)]
                ).reshape((n_chunks, ck) + x.shape[1:])

            xs = (padded(Y), padded(rbf), padded(edges_src),
                  padded(edges_dst))

            def chunk_fn(A, xc):
                y_c, rbf_c, src_c, dst_c = xc
                msg = edge_msgs(lp, h, y_c, rbf_c, src_c)
                return A + jax.ops.segment_sum(
                    msg, dst_c, num_segments=N
                ), None

            A0 = jnp.zeros((N, k, N_IRREPS), jnp.float32)
            A, _ = jax.lax.scan(jax.checkpoint(chunk_fn), A0, xs)
        A = constrain(A, "batch", None, None)
        # higher-order products (correlation <= 3), channel-wise
        B2 = jnp.einsum("nka,nkb,abc->nkc", A, A, C)
        B3 = jnp.einsum("nka,nkb,abc->nkc", B2, A, C)
        m = _mix(lp["w1"], A) + _mix(lp["w2"], B2) + _mix(lp["w3"], B3)
        return constrain(_mix(lp["self"], h) + m, "batch", None, None)

    # remat per interaction layer: edge tensors (E x k x 9) dominate the
    # training footprint on full-batch graphs; recompute them in backward
    layer_ckpt = jax.checkpoint(layer)
    for lp in p["layers"]:
        h = layer_ckpt(lp, h)

    inv = h[:, :, 0]                                          # (N, k) invariants
    return mlp_apply(p["readout"], inv, dtype=jnp.float32)


# ------------------------------------------------------------- objectives ---
def mace_node_xent(cfg: MACEConfig, p: Params, batch: Dict) -> jnp.ndarray:
    out = mace_forward(
        cfg, p, batch["feat"], batch["pos"], batch["edges_src"],
        batch["edges_dst"], batch.get("edge_mask"),
    )
    logits = out.astype(jnp.float32)
    labels = batch["labels"]
    mask = batch.get("label_mask")
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[:, None], axis=-1
    )[:, 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll.mean()


def mace_energy_mse(cfg: MACEConfig, p: Params, batch: Dict) -> jnp.ndarray:
    out = mace_forward(
        cfg, p, batch["species"], batch["pos"], batch["edges_src"],
        batch["edges_dst"], batch.get("edge_mask"),
    )[:, 0]
    n_graphs = batch["energy"].shape[0]
    energies = jax.ops.segment_sum(out, batch["graph_of"], num_segments=n_graphs)
    return jnp.mean((energies - batch["energy"]) ** 2)
