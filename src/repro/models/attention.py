"""Attention in three regimes (pure JAX; the Pallas kernels in
``repro.kernels`` implement the same contracts for TPU and are validated
against these functions).

  * ``mha``                — full materialized scores (small S)
  * ``flash_ref``          — chunked online-softmax causal attention
                             (O(S) memory; the flash kernel's oracle)
  * ``decode_attention``   — one query token against a (B, S_max) KV cache
                             with a valid-length mask (paged-KV scoring:
                             softmax is permutation-invariant, so per-
                             sequence page pools need no gather — see
                             DESIGN.md on the S-segment adaptation)

GQA is handled by grouping query heads over KV heads.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.hooks import constrain

NEG_INF = -1e30


def _group_q(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B, S, H, D) -> (B, S, n_kv, H//n_kv, D)."""
    B, S, H, D = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, D)


def expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, S, n_kv, D) -> (B, S, H, D): GQA expansion to a single flat head
    dimension, so the mesh 'model' axis can shard heads (a grouped (n, g)
    pair fragments the dim and defeats GSPMD — see EXPERIMENTS.md Perf)."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


def mha(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, n_kv, D)
    v: jnp.ndarray,  # (B, Sk, n_kv, D)
    causal: bool = True,
    q_offset: int = 0,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    k = constrain(expand_kv(k, H), "batch", None, "model", None)
    v = constrain(expand_kv(v, H), "batch", None, "model", None)
    scores = jnp.einsum(
        "bshd,bthd->bhst", q, k, preferred_element_type=jnp.float32
    ) / math.sqrt(D)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", w, v)
    return out


def flash_ref(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, S, n_kv, D)
    v: jnp.ndarray,  # (B, S, n_kv, D)
    chunk: int = 1024,
    causal: bool = True,
) -> jnp.ndarray:
    """Online-softmax attention, scanning KV in chunks (flash oracle)."""
    B, S, H, D = q.shape
    k = constrain(expand_kv(k, H), "batch", None, "model", None)
    v = constrain(expand_kv(v, H), "batch", None, "model", None)
    scale = 1.0 / math.sqrt(D)
    n_chunks = S // chunk
    assert S % chunk == 0, (S, chunk)
    kc = k.reshape(B, n_chunks, chunk, H, D)
    vc = v.reshape(B, n_chunks, chunk, H, D)
    qpos = jnp.arange(S)

    def step(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        s = jnp.einsum(
            "bshd,bthd->bhst", q, kj, preferred_element_type=jnp.float32
        ) * scale
        if causal:
            kpos = j * chunk + jnp.arange(chunk)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhst,bthd->bhsd", p.astype(vj.dtype), vj)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, D), v.dtype)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc.swapaxes(0, 1), vc.swapaxes(0, 1),
                             jnp.arange(n_chunks)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 2, 1, 3)  # (B, S, H, D)


def decode_attention(
    q: jnp.ndarray,        # (B, 1, H, D) new-token queries
    k_cache: jnp.ndarray,  # (B, S_max, n_kv, D) (RoPE already applied)
    v_cache: jnp.ndarray,  # (B, S_max, n_kv, D)
    lengths: jnp.ndarray,  # (B,) valid cache lengths (including new token)
) -> jnp.ndarray:
    """Einsum orders keep the big cache operand in its stored (b,t,n,d)
    layout — only the tiny score tensor is permuted (EXPERIMENTS.md Perf,
    decode iteration 2: full-cache transposes eliminated)."""
    B, _, H, D = q.shape
    n_kv = k_cache.shape[2]
    qg = _group_q(q, n_kv)[:, 0]  # (B, n_kv, G, D)
    scores = jnp.einsum(
        "btnd,bngd->btng", k_cache, qg, preferred_element_type=jnp.float32
    ) / math.sqrt(D)
    valid = jnp.arange(k_cache.shape[1])[None] < lengths[:, None]  # (B, S)
    scores = jnp.where(valid[:, :, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=1).astype(v_cache.dtype)
    out = jnp.einsum("btng,btnd->bngd", w, v_cache)
    return out.reshape(B, 1, H, D)


def attention(q, k, v, causal: bool = True, flash_threshold: int = 4096,
              flash_chunk: int = 1024) -> jnp.ndarray:
    """Dispatch: full scores for short S, chunked online softmax beyond."""
    S = q.shape[1]
    if S > flash_threshold and S % flash_chunk == 0:
        return flash_ref(q, k, v, chunk=flash_chunk, causal=causal)
    return mha(q, k, v, causal=causal)
