"""RecSys architectures: DLRM (MLPerf), DIN, SASRec, two-tower retrieval.

All four share the same substrate: huge embedding tables (the paper's
associative arrays — see DESIGN.md), a feature-interaction op, and a small
MLP head.  Entry points per arch:

  * ``loss_fn(params, batch)``           — training objective
  * ``score_fn(params, batch)``          — pointwise serving (p99/bulk)
  * ``retrieval_fn(params, batch)``      — 1 query vs N candidates + top-k

Batches are dicts of arrays; ``input_specs`` in the configs produce the
matching ShapeDtypeStructs for the dry-run.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import mha
from repro.nn.layers import (
    dense,
    dense_init,
    embedding_init,
    mlp_apply,
    mlp_init,
    rms_norm,
    softmax_xent,
)
from repro.sparse.embedding import embedding_lookup

Params = Dict[str, Any]


def bce_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    lf = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(lf, 0) - lf * labels + jnp.log1p(jnp.exp(-jnp.abs(lf)))
    )


# ================================================================== DLRM ====
@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    table_rows: Tuple[int, ...] = ()   # 26 Criteo-1TB cardinalities
    embed_dim: int = 128
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    dtype: Any = jnp.bfloat16

    @property
    def n_sparse(self) -> int:
        return len(self.table_rows)


def dlrm_init(cfg: DLRMConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_sparse + 2)
    tables = {
        f"t{i}": embedding_init(ks[i], rows, cfg.embed_dim)
        for i, rows in enumerate(cfg.table_rows)
    }
    return {
        "tables": tables,
        "bot": mlp_init(ks[-2], (cfg.n_dense,) + cfg.bot_mlp),
        "top": mlp_init(
            ks[-1],
            (cfg.embed_dim + (cfg.n_sparse + 1) * cfg.n_sparse // 2,)
            + cfg.top_mlp,
        ),
    }


def dlrm_forward(cfg: DLRMConfig, p: Params, batch: Dict) -> jnp.ndarray:
    dense_x = batch["dense"]            # (B, 13) f32
    sparse = batch["sparse"]            # (B, 26) int32
    B = dense_x.shape[0]
    d = mlp_apply(p["bot"], dense_x.astype(cfg.dtype), dtype=cfg.dtype,
                  final_act=True)       # (B, 128)
    embs = [
        embedding_lookup(p["tables"][f"t{i}"]["table"], sparse[:, i], cfg.dtype)
        for i in range(cfg.n_sparse)
    ]
    z = jnp.stack([d] + embs, axis=1)   # (B, 27, 128)
    inter = jnp.einsum("bnd,bmd->bnm", z, z,
                       preferred_element_type=jnp.float32)  # (B, 27, 27)
    iu = jnp.triu_indices(z.shape[1], k=1)
    flat = inter[:, iu[0], iu[1]].astype(cfg.dtype)          # (B, 351)
    x = jnp.concatenate([d, flat], axis=-1)
    return mlp_apply(p["top"], x, dtype=cfg.dtype)[:, 0]     # (B,)


def dlrm_loss(cfg: DLRMConfig, p: Params, batch: Dict) -> jnp.ndarray:
    return bce_logits(dlrm_forward(cfg, p, batch), batch["label"])


def dlrm_retrieval(cfg: DLRMConfig, p: Params, batch: Dict) -> jnp.ndarray:
    """Score one user context against N candidate items (vary table 0)."""
    cand = batch["candidates"]          # (N,) ids for table 0
    N = cand.shape[0]
    dense_x = jnp.broadcast_to(batch["dense"], (N, cfg.n_dense))
    sparse = jnp.broadcast_to(batch["sparse"], (N, cfg.n_sparse))
    sparse = sparse.at[:, 0].set(cand)
    scores = dlrm_forward(cfg, p, {"dense": dense_x, "sparse": sparse})
    return jax.lax.top_k(scores, min(100, N))[1]


# =================================================================== DIN ====
@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 1_000_000
    n_cates: int = 10_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    dtype: Any = jnp.bfloat16


def din_init(cfg: DINConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    d = cfg.embed_dim * 2  # item + category embedding
    return {
        "item": embedding_init(ks[0], cfg.n_items, cfg.embed_dim),
        "cate": embedding_init(ks[1], cfg.n_cates, cfg.embed_dim),
        # attention MLP input: [e, t, e*t, e-t] -> 4d
        "attn": mlp_init(ks[2], (4 * d,) + cfg.attn_mlp + (1,)),
        "head": mlp_init(ks[3], (3 * d,) + cfg.mlp + (1,)),
    }


def _din_embed(cfg: DINConfig, p: Params, items, cates):
    e = jnp.concatenate(
        [
            embedding_lookup(p["item"]["table"], items, cfg.dtype),
            embedding_lookup(p["cate"]["table"], cates, cfg.dtype),
        ],
        axis=-1,
    )
    return e  # (..., 2*embed_dim)


def din_forward(cfg: DINConfig, p: Params, batch: Dict) -> jnp.ndarray:
    seq = _din_embed(cfg, p, batch["hist_items"], batch["hist_cates"])  # (B,S,d)
    mask = batch["hist_mask"]                                           # (B,S)
    tgt = _din_embed(cfg, p, batch["target_item"], batch["target_cate"])  # (B,d)
    t = jnp.broadcast_to(tgt[:, None, :], seq.shape)
    att_in = jnp.concatenate([seq, t, seq * t, seq - t], axis=-1)
    w = mlp_apply(p["attn"], att_in, dtype=cfg.dtype)[..., 0]           # (B,S)
    w = jnp.where(mask > 0, w.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(w, axis=-1).astype(cfg.dtype)
    user = jnp.einsum("bs,bsd->bd", w, seq)                             # (B,d)
    x = jnp.concatenate([user, tgt, user * tgt], axis=-1)
    return mlp_apply(p["head"], x, dtype=cfg.dtype)[:, 0]


def din_loss(cfg: DINConfig, p: Params, batch: Dict) -> jnp.ndarray:
    return bce_logits(din_forward(cfg, p, batch), batch["label"])


def din_retrieval(cfg: DINConfig, p: Params, batch: Dict) -> jnp.ndarray:
    cand_items = batch["candidates"]       # (N,)
    cand_cates = batch["candidate_cates"]  # (N,)
    N = cand_items.shape[0]
    b = {
        "hist_items": jnp.broadcast_to(batch["hist_items"], (N, cfg.seq_len)),
        "hist_cates": jnp.broadcast_to(batch["hist_cates"], (N, cfg.seq_len)),
        "hist_mask": jnp.broadcast_to(batch["hist_mask"], (N, cfg.seq_len)),
        "target_item": cand_items,
        "target_cate": cand_cates,
    }
    scores = din_forward(cfg, p, b)
    return jax.lax.top_k(scores, min(100, N))[1]


# ================================================================ SASRec ====
@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 60_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dropout: float = 0.0
    dtype: Any = jnp.bfloat16


def sasrec_init(cfg: SASRecConfig, key) -> Params:
    ks = jax.random.split(key, 3 + cfg.n_blocks)
    d = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        kk = jax.random.split(ks[3 + i], 6)
        blocks.append(
            {
                "ln1": jnp.ones((d,), jnp.float32),
                "wq": dense_init(kk[0], d, d),
                "wk": dense_init(kk[1], d, d),
                "wv": dense_init(kk[2], d, d),
                "wo": dense_init(kk[3], d, d),
                "ln2": jnp.ones((d,), jnp.float32),
                "fc1": dense_init(kk[4], d, d, bias=True),
                "fc2": dense_init(kk[5], d, d, bias=True),
            }
        )
    return {
        "item": embedding_init(ks[0], cfg.n_items, d),
        "pos": embedding_init(ks[1], cfg.seq_len, d),
        "ln_f": jnp.ones((d,), jnp.float32),
        "blocks": blocks,
    }


def sasrec_backbone(cfg: SASRecConfig, p: Params, seq: jnp.ndarray
                    ) -> jnp.ndarray:
    B, S = seq.shape
    d = cfg.embed_dim
    x = embedding_lookup(p["item"]["table"], seq, cfg.dtype)
    x = x + p["pos"]["table"].astype(cfg.dtype)[None, :S]
    for blk in p["blocks"]:
        h = rms_norm(blk["ln1"], x)
        q = dense(blk["wq"], h, cfg.dtype).reshape(B, S, cfg.n_heads, -1)
        k = dense(blk["wk"], h, cfg.dtype).reshape(B, S, cfg.n_heads, -1)
        v = dense(blk["wv"], h, cfg.dtype).reshape(B, S, cfg.n_heads, -1)
        o = mha(q, k, v, causal=True).reshape(B, S, d)
        x = x + dense(blk["wo"], o, cfg.dtype)
        h = rms_norm(blk["ln2"], x)
        x = x + dense(blk["fc2"], jax.nn.relu(dense(blk["fc1"], h, cfg.dtype)),
                      cfg.dtype)
    return rms_norm(p["ln_f"], x)


def sasrec_loss(cfg: SASRecConfig, p: Params, batch: Dict) -> jnp.ndarray:
    """Next-item prediction, full softmax over items, computed in
    position chunks so (B, S, n_items) logits are never materialized
    (same chunked-xent scheme as the LM loss)."""
    h = sasrec_backbone(cfg, p, batch["seq"])            # (B, S, d)
    B, S, d = h.shape
    C = 5 if S % 5 == 0 else 1
    hc = h.reshape(B, S // C, C, d).swapaxes(0, 1)
    lc = batch["labels"].reshape(B, S // C, C).swapaxes(0, 1)

    def chunk(carry, inp):
        hh, ll = inp
        logits = jnp.einsum(
            "bsd,vd->bsv", hh, p["item"]["table"].astype(hh.dtype)
        )
        n = (ll != -1).sum()
        return (carry[0] + softmax_xent(logits, ll) * n, carry[1] + n), None

    (tot, n), _ = jax.lax.scan(
        chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hc, lc),
    )
    return tot / jnp.maximum(n, 1)


def sasrec_score(cfg: SASRecConfig, p: Params, batch: Dict) -> jnp.ndarray:
    """Serving: last-position scores for given candidate items."""
    h = sasrec_backbone(cfg, p, batch["seq"])[:, -1]     # (B, d)
    cand = embedding_lookup(p["item"]["table"], batch["candidates"], cfg.dtype)
    return jnp.einsum("bd,bcd->bc", h, cand)


def sasrec_retrieval(cfg: SASRecConfig, p: Params, batch: Dict) -> jnp.ndarray:
    h = sasrec_backbone(cfg, p, batch["seq"])[:, -1]     # (1, d)
    cand = embedding_lookup(p["item"]["table"], batch["candidates"], cfg.dtype)
    scores = jnp.einsum("bd,cd->bc", h, cand)[0]
    return jax.lax.top_k(scores, min(100, scores.shape[0]))[1]


# ============================================================= Two-tower ====
@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_users: int = 10_000_000
    n_items: int = 2_000_000
    n_context: int = 100_000
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    temperature: float = 0.05
    dtype: Any = jnp.bfloat16


def twotower_init(cfg: TwoTowerConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.embed_dim
    return {
        "user": embedding_init(ks[0], cfg.n_users, d),
        "ctx": embedding_init(ks[1], cfg.n_context, d),
        "item": embedding_init(ks[2], cfg.n_items, d),
        "icat": embedding_init(ks[3], cfg.n_context, d),
        "user_tower": mlp_init(ks[4], (2 * d,) + cfg.tower_mlp),
        "item_tower": mlp_init(ks[5], (2 * d,) + cfg.tower_mlp),
    }


def user_embed(cfg: TwoTowerConfig, p: Params, batch: Dict) -> jnp.ndarray:
    e = jnp.concatenate(
        [
            embedding_lookup(p["user"]["table"], batch["user_id"], cfg.dtype),
            embedding_lookup(p["ctx"]["table"], batch["user_ctx"], cfg.dtype),
        ],
        axis=-1,
    )
    out = mlp_apply(p["user_tower"], e, dtype=cfg.dtype)
    return out / jnp.linalg.norm(out.astype(jnp.float32), axis=-1,
                                 keepdims=True).astype(cfg.dtype)


def item_embed(cfg: TwoTowerConfig, p: Params, item_id, item_cat) -> jnp.ndarray:
    e = jnp.concatenate(
        [
            embedding_lookup(p["item"]["table"], item_id, cfg.dtype),
            embedding_lookup(p["icat"]["table"], item_cat, cfg.dtype),
        ],
        axis=-1,
    )
    out = mlp_apply(p["item_tower"], e, dtype=cfg.dtype)
    return out / jnp.linalg.norm(out.astype(jnp.float32), axis=-1,
                                 keepdims=True).astype(cfg.dtype)


def twotower_loss(cfg: TwoTowerConfig, p: Params, batch: Dict) -> jnp.ndarray:
    """In-batch sampled softmax (the RecSys'19 retrieval objective)."""
    u = user_embed(cfg, p, batch)                                   # (B, d)
    i = item_embed(cfg, p, batch["item_id"], batch["item_cat"])     # (B, d)
    logits = jnp.einsum("bd,cd->bc", u, i).astype(jnp.float32)
    logits = logits / cfg.temperature
    labels = jnp.arange(u.shape[0])
    return softmax_xent(logits[:, None, :], labels[:, None])


def twotower_score(cfg: TwoTowerConfig, p: Params, batch: Dict) -> jnp.ndarray:
    u = user_embed(cfg, p, batch)
    i = item_embed(cfg, p, batch["item_id"], batch["item_cat"])
    return jnp.einsum("bd,bd->b", u, i) / cfg.temperature


def twotower_retrieval(cfg: TwoTowerConfig, p: Params, batch: Dict) -> jnp.ndarray:
    """1 query vs N precomputed candidate embeddings: blocked matmul + top-k.

    The candidate store is the paper's S-strategy in device form: one
    physically contiguous segment array scanned sequentially (DESIGN.md).
    """
    u = user_embed(cfg, p, batch)                  # (1, d)
    cands = batch["candidate_embs"].astype(cfg.dtype)  # (N, d) precomputed
    scores = jnp.einsum("bd,nd->bn", u, cands)[0].astype(jnp.float32)
    return jax.lax.top_k(scores, 100)[1]
