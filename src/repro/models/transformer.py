"""Decoder-only transformer family (dense + MoE) with train, prefill and
decode entry points.

One configuration type covers all five assigned LM architectures
(minicpm-2b, granite-3-2b, qwen1.5-4b, moonshot-v1-16b-a3b,
qwen3-moe-235b-a22b): GQA with optional QKV bias, RoPE, SwiGLU MLP or MoE
FFN, RMSNorm, tied or untied unembedding.

Implementation notes for scale (the 512-chip dry-run must compile with
compact HLO and bounded per-device memory):

  * layers are a ``lax.scan`` over stacked parameters (HLO size is O(1)
    in depth),
  * activation remat (`jax.checkpoint`) per block, policy configurable,
  * the LM loss is computed in sequence chunks (`loss_chunk`) so the
    (B, S, vocab) logits tensor is never materialized,
  * decode keeps a (B, S_max) KV cache with valid-length masking — the
    paged-KV page pool is per-sequence, so scoring needs no gather (see
    DESIGN.md: S-segment contiguity adaptation).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.hooks import constrain
from repro.models.attention import attention, decode_attention, mha
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.nn.layers import dense_init, embedding_init, rms_norm, rope, softmax_xent

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    dtype: Any = jnp.bfloat16
    remat: str = "dots"          # none | dots | full
    loss_chunk: int = 512
    flash_chunk: int = 1024
    # activation sharding (no-ops without an ambient mesh):
    #   heads — shard attention heads on the model axis (H % axis == 0)
    #   seq   — shard query positions instead (uneven head counts)
    att_shard: str = "heads"

    @property
    def params_dense(self) -> int:
        """Total parameter count (all experts included)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        att = d * (self.n_heads * self.d_head) + 2 * d * (
            self.n_kv_heads * self.d_head
        ) + (self.n_heads * self.d_head) * d
        if self.moe:
            ff = self.moe.n_experts * 3 * d * self.moe.d_ff
            ff += self.moe.n_shared_experts * 3 * d * self.moe.d_ff
            ff += d * self.moe.n_experts  # router
        else:
            ff = 3 * d * f
        emb = V * d * (1 if self.tie_embeddings else 2)
        return L * (att + ff + 2 * d) + emb + d

    @property
    def params_active(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.params_dense
        d, L = self.d_model, self.n_layers
        att = d * (self.n_heads * self.d_head) + 2 * d * (
            self.n_kv_heads * self.d_head
        ) + (self.n_heads * self.d_head) * d
        ff = (self.moe.top_k + self.moe.n_shared_experts) * 3 * d * self.moe.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return L * (att + ff + 2 * d) + emb + d


# ------------------------------------------------------------------- init ---
def init_params(cfg: TransformerConfig, key) -> Params:
    keys = list(jax.random.split(key, 16))
    L, d = cfg.n_layers, cfg.d_model
    qd = cfg.n_heads * cfg.d_head
    kvd = cfg.n_kv_heads * cfg.d_head

    def stack(initializer, *shape_args, **kw):
        ks = jax.random.split(keys.pop(), L)
        return jax.vmap(lambda k: initializer(k, *shape_args, **kw))(ks)

    block = {
        "ln1": jnp.ones((L, d), jnp.float32),
        "ln2": jnp.ones((L, d), jnp.float32),
        "wq": stack(dense_init, d, qd, bias=cfg.qkv_bias),
        "wk": stack(dense_init, d, kvd, bias=cfg.qkv_bias),
        "wv": stack(dense_init, d, kvd, bias=cfg.qkv_bias),
        "wo": stack(dense_init, qd, d),
    }
    if cfg.moe is not None:
        block["moe"] = jax.vmap(
            lambda k: moe_init(k, d, cfg.moe)
        )(jax.random.split(keys[1], L))
    else:
        block["mlp"] = {
            "wg": stack(dense_init, d, cfg.d_ff),
            "wu": stack(dense_init, d, cfg.d_ff),
            "wd": stack(dense_init, cfg.d_ff, d),
        }
    params: Params = {
        "embed": embedding_init(keys[2], cfg.vocab, d),
        "ln_f": jnp.ones((d,), jnp.float32),
        "block": block,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[3], d, cfg.vocab)
    return params


# ---------------------------------------------------------------- forward ---
def _constrain_qkv(cfg: TransformerConfig, q, k, v):
    """Attention activation sharding (EXPERIMENTS.md Perf):
    uneven GQA head counts defeat GSPMD's propagation and replicate the
    whole attention per chip.  Heads are sharded on the model axis — for
    head counts that do not divide it (minicpm 36H, qwen1.5 20H) GSPMD
    pads (<=33% attention-flop waste), which beats replicating K/V by an
    order of magnitude in collective bytes (train iteration 1: 'seq' mode
    refuted, replaced by padded head sharding).  K/V are constrained
    after GQA expansion inside the attention ops."""
    if cfg.att_shard in ("heads", "seq"):
        q = constrain(q, "batch", None, "model", None)
    return q, k, v


def _block_fwd(cfg: TransformerConfig, lp: Params, x: jnp.ndarray,
               positions: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    B, S, d = x.shape
    dtype = cfg.dtype
    h = rms_norm(lp["ln1"], x, cfg.rms_eps)
    q = jnp.einsum("bsd,dq->bsq", h.astype(dtype), lp["wq"]["w"].astype(dtype))
    k = jnp.einsum("bsd,dq->bsq", h.astype(dtype), lp["wk"]["w"].astype(dtype))
    v = jnp.einsum("bsd,dq->bsq", h.astype(dtype), lp["wv"]["w"].astype(dtype))
    if cfg.qkv_bias:
        q = q + lp["wq"]["b"].astype(dtype)
        k = k + lp["wk"]["b"].astype(dtype)
        v = v + lp["wv"]["b"].astype(dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q, k, v = _constrain_qkv(cfg, q, k, v)
    o = attention(q, k, v, causal=True, flash_chunk=cfg.flash_chunk)
    o = jnp.einsum(
        "bsq,qd->bsd",
        o.reshape(B, S, cfg.n_heads * cfg.d_head),
        lp["wo"]["w"].astype(dtype),
    )
    x = constrain(x + o.astype(x.dtype), "batch", None, None)

    h = rms_norm(lp["ln2"], x, cfg.rms_eps)
    aux: Dict = {}
    if cfg.moe is not None:
        y, aux = moe_apply(lp["moe"], h, cfg.moe, dtype=dtype)
    else:
        m = lp["mlp"]
        g = jax.nn.silu(
            jnp.einsum("bsd,df->bsf", h.astype(dtype), m["wg"]["w"].astype(dtype))
        )
        u = jnp.einsum("bsd,df->bsf", h.astype(dtype), m["wu"]["w"].astype(dtype))
        y = jnp.einsum("bsf,fd->bsd", g * u, m["wd"]["w"].astype(dtype))
    x = x + y.astype(x.dtype)
    return x, aux


def _remat(cfg: TransformerConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )


def backbone(cfg: TransformerConfig, params: Params, tokens: jnp.ndarray,
             positions: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, Dict]:
    """Embed + all blocks + final norm.  Returns (B, S, d) hidden + aux."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"]["table"].astype(cfg.dtype)[tokens]
    x = constrain(x, "batch", None, None)

    body = _remat(cfg, lambda lp, xx: _block_fwd(cfg, lp, xx, positions))

    def scan_fn(xx, lp):
        xx, aux = body(lp, xx)
        return xx, aux

    x, auxs = jax.lax.scan(scan_fn, x, params["block"])
    x = rms_norm(params["ln_f"], x, cfg.rms_eps)
    aux = {k: v.sum() for k, v in auxs.items()} if auxs else {}
    return x, aux


def _unembed_chunk(cfg: TransformerConfig, params: Params,
                   h: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum(
            "bsd,vd->bsv", h, params["embed"]["table"].astype(h.dtype)
        )
    return jnp.einsum(
        "bsd,dv->bsv", h, params["unembed"]["w"].astype(h.dtype)
    )


def lm_loss(cfg: TransformerConfig, params: Params, tokens: jnp.ndarray,
            labels: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """Chunked-softmax LM loss: never materializes (B, S, vocab)."""
    h, aux = backbone(cfg, params, tokens)
    B, S, d = h.shape
    C = min(cfg.loss_chunk, S)
    assert S % C == 0
    hc = h.reshape(B, S // C, C, d).swapaxes(0, 1)      # (n, B, C, d)
    lc = labels.reshape(B, S // C, C).swapaxes(0, 1)

    def chunk_loss(carry, inp):
        hh, ll = inp
        logits = constrain(
            _unembed_chunk(cfg, params, hh), "batch", None, "model"
        )
        nll = softmax_xent(logits, ll)
        n = (ll != -1).sum()
        return (carry[0] + nll * n, carry[1] + n), None

    (tot, n), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hc, lc),
    )
    loss = tot / jnp.maximum(n, 1)
    if "balance_loss" in aux:
        loss = loss + 0.01 * aux["balance_loss"] / cfg.n_layers
    return loss, aux


# ------------------------------------------------------------------ serve ---
def make_cache(cfg: TransformerConfig, batch: int, s_max: int,
               dtype=None) -> Dict:
    dtype = dtype or cfg.dtype
    L, n_kv, D = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((L, batch, s_max, n_kv, D), dtype),
        "v": jnp.zeros((L, batch, s_max, n_kv, D), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg: TransformerConfig, params: Params, tokens: jnp.ndarray
            ) -> Tuple[jnp.ndarray, Dict]:
    """Process a prompt; return last-position logits and a filled cache."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"]["table"].astype(cfg.dtype)[tokens]

    def scan_fn(xx, lp):
        h = rms_norm(lp["ln1"], xx, cfg.rms_eps)
        dtype = cfg.dtype
        q = jnp.einsum("bsd,dq->bsq", h.astype(dtype), lp["wq"]["w"].astype(dtype))
        k = jnp.einsum("bsd,dq->bsq", h.astype(dtype), lp["wk"]["w"].astype(dtype))
        v = jnp.einsum("bsd,dq->bsq", h.astype(dtype), lp["wv"]["w"].astype(dtype))
        if cfg.qkv_bias:
            q = q + lp["wq"]["b"].astype(dtype)
            k = k + lp["wk"]["b"].astype(dtype)
            v = v + lp["wv"]["b"].astype(dtype)
        q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
        k = k.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
        v = v.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        q, k, v = _constrain_qkv(cfg, q, k, v)
        o = attention(q, k, v, causal=True, flash_chunk=cfg.flash_chunk)
        o = jnp.einsum(
            "bsq,qd->bsd", o.reshape(B, S, cfg.n_heads * cfg.d_head),
            lp["wo"]["w"].astype(dtype),
        )
        xx = xx + o.astype(xx.dtype)
        h = rms_norm(lp["ln2"], xx, cfg.rms_eps)
        if cfg.moe is not None:
            y, _ = moe_apply(lp["moe"], h, cfg.moe, dtype=dtype)
        else:
            m = lp["mlp"]
            g = jax.nn.silu(jnp.einsum("bsd,df->bsf", h.astype(dtype),
                                       m["wg"]["w"].astype(dtype)))
            u = jnp.einsum("bsd,df->bsf", h.astype(dtype),
                           m["wu"]["w"].astype(dtype))
            y = jnp.einsum("bsf,fd->bsd", g * u, m["wd"]["w"].astype(dtype))
        xx = xx + y.astype(xx.dtype)
        return xx, (k, v)

    body = _remat(cfg, scan_fn) if cfg.remat != "none" else scan_fn
    x, (ks, vs) = jax.lax.scan(body, x, params["block"])
    x = rms_norm(params["ln_f"], x, cfg.rms_eps)
    logits = _unembed_chunk(cfg, params, x[:, -1:, :])
    cache = {
        "k": ks,  # (L, B, S, n_kv, D)
        "v": vs,
        "len": jnp.full((B,), S, jnp.int32),
    }
    return logits[:, 0], cache


def decode_step(cfg: TransformerConfig, params: Params, token: jnp.ndarray,
                cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One decode step: token (B,) int32 -> logits (B, vocab), new cache."""
    B = token.shape[0]
    lens = cache["len"]  # (B,)
    positions = lens[:, None]  # (B, 1)
    x = params["embed"]["table"].astype(cfg.dtype)[token[:, None]]
    dtype = cfg.dtype
    bidx = jnp.arange(B)

    def scan_fn(xx, per_layer):
        lp, kc, vc = per_layer
        h = rms_norm(lp["ln1"], xx, cfg.rms_eps)
        q = jnp.einsum("bsd,dq->bsq", h.astype(dtype), lp["wq"]["w"].astype(dtype))
        k = jnp.einsum("bsd,dq->bsq", h.astype(dtype), lp["wk"]["w"].astype(dtype))
        v = jnp.einsum("bsd,dq->bsq", h.astype(dtype), lp["wv"]["w"].astype(dtype))
        if cfg.qkv_bias:
            q = q + lp["wq"]["b"].astype(dtype)
            k = k + lp["wk"]["b"].astype(dtype)
            v = v + lp["wv"]["b"].astype(dtype)
        q = q.reshape(B, 1, cfg.n_heads, cfg.d_head)
        k = k.reshape(B, 1, cfg.n_kv_heads, cfg.d_head)
        v = v.reshape(B, 1, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        # write the new entry at each sequence's current length.  A one-hot
        # select instead of a scatter: scatters at mixed dtypes get promoted
        # (full-cache convert round-trips) and fragment under GSPMD, while
        # the select fuses into one slice-sized masked write
        # (EXPERIMENTS.md Perf, decode iteration 1).
        sel = (
            jnp.arange(kc.shape[1], dtype=jnp.int32)[None, :]
            == lens[:, None]
        )[..., None, None]
        kc = jnp.where(sel, k[:, 0][:, None].astype(kc.dtype), kc)
        vc = jnp.where(sel, v[:, 0][:, None].astype(vc.dtype), vc)
        o = decode_attention(q, kc, vc, lens + 1)
        o = jnp.einsum(
            "bsq,qd->bsd", o.reshape(B, 1, cfg.n_heads * cfg.d_head),
            lp["wo"]["w"].astype(dtype),
        )
        xx = xx + o.astype(xx.dtype)
        h = rms_norm(lp["ln2"], xx, cfg.rms_eps)
        if cfg.moe is not None:
            y, _ = moe_apply(lp["moe"], h, cfg.moe, dtype=dtype)
        else:
            m = lp["mlp"]
            g = jax.nn.silu(jnp.einsum("bsd,df->bsf", h.astype(dtype),
                                       m["wg"]["w"].astype(dtype)))
            u = jnp.einsum("bsd,df->bsf", h.astype(dtype),
                           m["wu"]["w"].astype(dtype))
            y = jnp.einsum("bsf,fd->bsd", g * u, m["wd"]["w"].astype(dtype))
        xx = xx + y.astype(xx.dtype)
        return xx, (kc, vc)

    x, (ks, vs) = jax.lax.scan(scan_fn, x, (params["block"], cache["k"], cache["v"]))
    x = rms_norm(params["ln_f"], x, cfg.rms_eps)
    logits = _unembed_chunk(cfg, params, x)[:, 0]
    new_cache = {"k": ks, "v": vs, "len": lens + 1}
    return logits, new_cache
