"""Segment files: the durable checkpoint format.

One segment file is a full posting snapshot of a (sharded) index set in
lexicon+barrel style — per index a *barrel* of self-contained varint
posting runs, addressed by an inline dictionary of (key, run) pairs —
laid out

    [u32 magic][u16 version][u16 n_shards]
    per shard:  [u16 n_indexes]
      per index: [u8 name_len][name][u32 n_keys]
        per key: [key codec][u32 run_len][varint posting run]
    [u32 crc32 of everything above]

The whole file is covered by the CRC trailer and published via
write-to-temp + fsync + atomic rename, so a reader either sees a
complete, verified snapshot or (on any mismatch) raises
:class:`SegmentCorruptError` and the store falls back to a full WAL
replay.  Snapshot extraction reads the in-memory substrate directly —
never through the simulated block devices — so writing a checkpoint
charges no search or build I/O.
"""

from __future__ import annotations

import os
import struct
import zlib
from pathlib import Path
from typing import Dict, Hashable, List

import numpy as np

from repro.core.dictionary import K_EM, K_TAG
from repro.core.postings import decode_postings
from repro.store.format import decode_key, decode_run, encode_key, encode_run

SEG_MAGIC = 0x53454731  # "SEG1"
SEG_VERSION = 1

_HEAD = struct.Struct("<IHH")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")

# one shard's posting state: {index name → {key → (N, 2) postings}}
ShardState = Dict[str, Dict[Hashable, np.ndarray]]


class SegmentCorruptError(Exception):
    """The segment file failed its magic/structure/CRC verification."""


# -------------------------------------------------------------- snapshot --
def index_snapshot(index) -> Dict[Hashable, np.ndarray]:
    """Every key's full posting list, decoded straight from the
    in-memory substrate (dictionary-inline EM bytes, shared TAG buckets,
    dedicated streams) with NO device charges — checkpointing must not
    perturb the I/O accounting the benches and oracles measure."""
    out: Dict[Hashable, np.ndarray] = {}
    for key, e in index.dict.entries.items():
        if e.kind == K_EM:
            posts, _ = decode_postings(bytes(e.data))
        else:
            data = bytes(index.mgr.streams[e.sid].data)
            if e.kind == K_TAG:
                posts, tags = decode_postings(data, tagged=True, zigzag=True)
                mine = posts[tags == e.tag]
                posts = mine[np.lexsort((mine[:, 1], mine[:, 0]))]
            else:
                posts, _ = decode_postings(data)
        if posts.shape[0]:
            out[key] = posts
    return out


def snapshot_state(index_set) -> List[ShardState]:
    """Per-shard posting snapshot of a sharded (or single) index set."""
    shards = getattr(index_set, "shards", None) or [index_set]
    return [
        {name: index_snapshot(idx) for name, idx in shard.indexes.items()}
        for shard in shards
    ]


# --------------------------------------------------------------- file io --
def write_segment(path, state: List[ShardState]) -> int:
    """Serialize + publish one segment file atomically; returns its size."""
    body = bytearray(_HEAD.pack(SEG_MAGIC, SEG_VERSION, len(state)))
    for shard_state in state:
        body += _U16.pack(len(shard_state))
        for name, by_key in shard_state.items():
            nb = name.encode("utf-8")
            body += struct.pack("<B", len(nb)) + nb
            body += _U32.pack(len(by_key))
            for key, posts in by_key.items():
                body += encode_key(key)
                body += encode_run(posts)
    body += _U32.pack(zlib.crc32(bytes(body)) & 0xFFFFFFFF)
    path = Path(path)
    tmp = path.with_suffix(".tmp")
    with open(tmp, "wb") as fh:
        fh.write(body)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)
    return len(body)


def read_segment(path) -> List[ShardState]:
    """Load + verify one segment file; raises :class:`SegmentCorruptError`
    on any structural or checksum mismatch (including a truncated tail)."""
    try:
        data = Path(path).read_bytes()
    except OSError as exc:
        raise SegmentCorruptError(f"unreadable segment {path}: {exc}") from exc
    if len(data) < _HEAD.size + _U32.size:
        raise SegmentCorruptError(f"segment {path} too short ({len(data)} B)")
    (crc,) = _U32.unpack_from(data, len(data) - _U32.size)
    body = data[: -_U32.size]
    if zlib.crc32(body) & 0xFFFFFFFF != crc:
        raise SegmentCorruptError(f"segment {path} failed CRC")
    magic, version, n_shards = _HEAD.unpack_from(body, 0)
    if magic != SEG_MAGIC or version != SEG_VERSION:
        raise SegmentCorruptError(
            f"segment {path} bad magic/version {magic:#x}/{version}"
        )
    off = _HEAD.size
    try:
        state: List[ShardState] = []
        for _ in range(n_shards):
            (n_indexes,) = _U16.unpack_from(body, off)
            off += _U16.size
            shard_state: ShardState = {}
            for _ in range(n_indexes):
                ln = body[off]
                off += 1
                name = bytes(body[off : off + ln]).decode("utf-8")
                off += ln
                (n_keys,) = _U32.unpack_from(body, off)
                off += _U32.size
                by_key: Dict[Hashable, np.ndarray] = {}
                for _ in range(n_keys):
                    key, off = decode_key(body, off)
                    posts, off = decode_run(body, off)
                    by_key[key] = posts
                shard_state[name] = by_key
            state.append(shard_state)
    except (struct.error, IndexError, ValueError) as exc:
        raise SegmentCorruptError(f"segment {path} malformed: {exc}") from exc
    if off != len(body):
        raise SegmentCorruptError(
            f"segment {path} trailing garbage ({len(body) - off} B)"
        )
    return state
