"""``DurableIndexStore`` — the durable on-disk backend for an index set.

Layering (and why byte-accounting parity is exact by construction):

  * *Serving* stays on the existing easily updatable substrate — a
    :class:`~repro.core.sharded_set.ShardedTextIndexSet` whose
    ``StreamManager``/``InvertedIndex`` machinery charges every search
    and build operation to the simulated block devices, exactly as
    before.  The store never routes a read or write through those
    devices, so every oracle and bench observes identical charges
    against a durable store and a plain in-memory set driven through
    the same operations.
  * *Durability* is real file I/O beside it: each mutation is appended
    to the WAL (fsynced) BEFORE it is applied, checkpoints serialize
    the full posting state into a CRC-verified segment file, and a
    MANIFEST published by atomic rename names the live (segment,
    WAL offset) pair.

Directory layout under ``path``::

    wal.log                   the write-ahead part log
    segments/ckpt-<seq>.seg   posting snapshots (latest is live)
    MANIFEST                  JSON {seq, segment, wal_offset,
                              generation_vector, n_shards}

Recovery state machine (``recovery="checkpoint"``, the default)::

    DISCOVER --------- manifest readable? segment verifies? ----+
       | yes: LOAD_CHECKPOINT (bulk-apply per-shard snapshots)  |
       | no/corrupt: FULL_REPLAY (fresh substrate, WAL offset 0)|
       v                                                        v
    REPLAY_TAIL  -- apply intact WAL records after the folded offset;
       |            first bad frame ends the scan, file truncated there
       v            (a torn part is never visible, not even partially)
    REPAIR       -- if the WAL physically lost folded bytes or the
       |            checkpoint was corrupt, publish a fresh checkpoint
       v            so the (manifest, WAL) invariant holds again
    SERVE

``recovery="replay"`` ignores the checkpoint and replays the entire WAL
— including ``REC_COMPACT`` markers, which re-run background compaction
at the same point in the part sequence — so the reopened substrate
reproduces the crashed one's physical stream layout, and therefore its
simulated I/O charges, byte for byte.  That is the mode the storage
oracle pins parity with; checkpoint recovery trades that layout identity
for O(state) + O(tail) reopen time while serving identical results.
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, Hashable, List, Optional

import numpy as np

from repro.core.io_sim import IOStats
from repro.core.lexicon import Lexicon
from repro.core.sharded_set import ShardedTextIndexSet
from repro.core.text_index import IndexSetConfig
from repro.store.format import (
    decode_part_maps,
    decode_part_tokens,
    encode_part_maps,
    encode_part_tokens,
)
from repro.store.segments import (
    SegmentCorruptError,
    read_segment,
    snapshot_state,
    write_segment,
)
from repro.store.wal import (
    REC_COMPACT,
    REC_PART_MAPS,
    REC_PART_TOKENS,
    WriteAheadLog,
)

MANIFEST_NAME = "MANIFEST"


class DurableIndexStore:
    """A WAL-fed, checkpointed, crash-recoverable index set.

    Exposes the :class:`~repro.core.text_index.IndexSetLike` capability
    surface (``add_documents`` / ``lookup`` / ``reader`` / the report
    methods), so ``SearchService``, the oracles and every bench drive it
    exactly like the substrate it wraps."""

    def __init__(
        self,
        path,
        cfg: IndexSetConfig,
        lexicon: Lexicon,
        n_shards: int = 1,
        seed: int = 0,
        fsync: bool = True,
        recovery: str = "checkpoint",
        replica: bool = False,
    ):
        if recovery not in ("checkpoint", "replay"):
            raise ValueError(f"unknown recovery mode {recovery!r}")
        self.path = Path(path)
        self.cfg = cfg
        self.lexicon = lexicon
        self.n_shards = int(n_shards)
        self.seed = int(seed)
        # replica mode: a READ-ONLY reopen of a (possibly live) primary's
        # directory — mutations raise, recovery never truncates the
        # primary's WAL, and ``poll()`` tails records the primary appends
        self.replica = bool(replica)
        (self.path / "segments").mkdir(parents=True, exist_ok=True)
        self.set = self._fresh_set()
        self.wal = WriteAheadLog(self.path / "wal.log",
                                 fsync=fsync and not self.replica)
        self.n_checkpoints = 0
        self._parts_since_ckpt = 0
        self._ckpt_seq = 0
        self._wal_pos = 0
        self.recovery_info: Dict[str, object] = {}
        self._recover(recovery)

    @classmethod
    def open_replica(cls, path, cfg, lexicon, n_shards: int = 1,
                     seed: int = 0) -> "DurableIndexStore":
        """Reopen a primary's directory as a read replica: bulk-load the
        checkpoint, restore the manifest's published generation vector
        (so the replica's snapshot coordinates align with the primary's
        — physical part counts collapse across the bulk apply and would
        alias), then tail the WAL.  ``poll()`` catches up with whatever
        the primary appended since."""
        return cls(path, cfg, lexicon, n_shards=n_shards, seed=seed,
                   fsync=False, recovery="checkpoint", replica=True)

    def _fresh_set(self) -> ShardedTextIndexSet:
        return ShardedTextIndexSet(
            self.cfg, self.lexicon, n_shards=self.n_shards, seed=self.seed
        )

    # ----------------------------------------------------------- recovery --
    def _load_manifest(self) -> Optional[dict]:
        try:
            return json.loads((self.path / MANIFEST_NAME).read_text())
        except (OSError, ValueError):
            return None

    def _recover(self, mode: str) -> None:
        info: Dict[str, object] = {
            "mode": mode,
            "from_checkpoint": False,
            "checkpoint_fallback": False,
            "wal_records": 0,
            "torn": False,
            "truncated_bytes": 0,
        }
        start = 0
        manifest = self._load_manifest() if mode == "checkpoint" else None
        if manifest is not None:
            try:
                state = read_segment(
                    self.path / "segments" / str(manifest["segment"])
                )
                for s, shard_state in enumerate(state):
                    if shard_state:
                        self.set.shards[s].apply_part_maps(shard_state)
                # the bulk apply collapsed many published parts into one
                # physical part per index — restore the manifest's
                # PUBLISHED generation vector so this store's snapshot
                # coordinates (and digest-stream positions) stay aligned
                # with the writer that produced the checkpoint
                self._restore_generations(manifest.get("generation_vector"))
                start = int(manifest["wal_offset"])
                self._ckpt_seq = int(manifest["seq"])
                info["from_checkpoint"] = True
            except (SegmentCorruptError, KeyError, IndexError, ValueError):
                # corrupt/missing checkpoint: fall back to a full replay
                self.set = self._fresh_set()
                start = 0
                info["checkpoint_fallback"] = True
        size_before = self.wal.size()
        if self.replica:
            # never truncate a live primary's log from a replica
            records, good, torn = self.wal.read_from(start)
        else:
            records, good, torn = self.wal.recover(start)
        for rec_type, payload in records:
            self._apply_record(rec_type, payload)
        self._wal_pos = good
        info["wal_records"] = len(records)
        info["torn"] = torn
        info["truncated_bytes"] = max(0, size_before - self.wal.size())
        self.recovery_info = info
        if mode == "checkpoint" and not self.replica and (
            info["checkpoint_fallback"] or start > size_before
        ):
            # the published (manifest, WAL) pair was inconsistent —
            # re-publish a checkpoint of the recovered state
            self._checkpoint()

    def _restore_generations(self, gens) -> None:
        """Forward the per-index published generation counters to the
        manifest's recorded vector (nested ``[shard][index]``)."""
        if not gens:
            return
        for shard, row in zip(self.set.shards, gens):
            if not isinstance(row, (list, tuple)):
                return  # pre-vector manifest: nothing restorable
            for idx, g in zip(shard.indexes.values(), row):
                idx.restore_generation(int(g))

    # ------------------------------------------------------- replica tail --
    def poll(self) -> int:
        """Replica catch-up: apply every WAL record the primary appended
        since this replica's position; returns how many were applied.
        The applied parts republish their touched-key digests locally,
        so the replica's own readers take the same targeted-invalidation
        path the primary's do."""
        if not self.replica:
            raise RuntimeError("poll() is the replica tailing surface; "
                               "the primary applies writes directly")
        records, good, _torn = self.wal.read_from(self._wal_pos)
        for rec_type, payload in records:
            self._apply_record(rec_type, payload)
        self._wal_pos = good
        return len(records)

    def _apply_record(self, rec_type: int, payload: bytes) -> None:
        if rec_type == REC_PART_TOKENS:
            doc0, tokens, offsets = decode_part_tokens(payload)
            self.set.add_documents(tokens, offsets, doc0)
        elif rec_type == REC_PART_MAPS:
            self.set.apply_part_maps(decode_part_maps(payload))
        elif rec_type == REC_COMPACT:
            self.set.compact()
        # unknown record types are skipped (forward compatibility)

    # ----------------------------------------------------------- updating --
    def add_documents(
        self, tokens: np.ndarray, offsets: np.ndarray, doc0: int
    ) -> None:
        """Index one collection part, durably: the raw token stream is
        in the WAL (fsynced when enabled) before any index generation
        advances."""
        self._require_primary()
        tokens = np.ascontiguousarray(tokens, dtype=np.int64)
        offsets = np.ascontiguousarray(offsets, dtype=np.int64)
        self.wal.append(REC_PART_TOKENS, encode_part_tokens(doc0, tokens, offsets))
        self._parts_since_ckpt += 1
        self.set.add_documents(tokens, offsets, doc0)

    def apply_part_maps(
        self, maps: Dict[str, Dict[Hashable, np.ndarray]]
    ) -> List[Dict[str, frozenset]]:
        """Durably apply one pre-extracted part map (the per-shard
        update-queue shape); WAL first, substrate second."""
        self._require_primary()
        self.wal.append(REC_PART_MAPS, encode_part_maps(maps))
        self._parts_since_ckpt += 1
        return self.set.apply_part_maps(maps)

    def compact(self, checkpoint: bool = True) -> List[Dict[str, frozenset]]:
        """One background-compaction cycle, logged ahead like any part
        (replay re-runs it at the same point, reproducing the layout).
        By default a cycle that changed anything — or that has parts
        pending since the last checkpoint — also publishes a fresh
        segment + manifest, folding the WAL prefix into the checkpoint."""
        self._require_primary()
        self.wal.append(REC_COMPACT, b"")
        digests = self.set.compact()
        rewrote = any(bool(d) for d in digests)
        if checkpoint and (rewrote or self._parts_since_ckpt):
            self._checkpoint()
        return digests

    def checkpoint(self) -> None:
        """Publish the current state as a segment + manifest."""
        self._require_primary()
        self._checkpoint()

    def _require_primary(self) -> None:
        if self.replica:
            raise RuntimeError("read replica: single-owner writes happen "
                               "on the primary; replicas only poll()")

    def _checkpoint(self) -> None:
        self._ckpt_seq += 1
        name = f"ckpt-{self._ckpt_seq:06d}.seg"
        write_segment(self.path / "segments" / name, snapshot_state(self.set))
        manifest = {
            "seq": self._ckpt_seq,
            "segment": name,
            "wal_offset": self.wal.tell(),
            "generation_vector": self.set.generation_vector(),
            "n_shards": self.n_shards,
        }
        tmp = self.path / (MANIFEST_NAME + ".tmp")
        with open(tmp, "w") as fh:
            fh.write(json.dumps(manifest))
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, self.path / MANIFEST_NAME)
        self._parts_since_ckpt = 0
        self.n_checkpoints += 1
        for old in (self.path / "segments").glob("ckpt-*.seg"):
            if old.name != name:
                try:
                    old.unlink()
                except OSError:
                    pass

    # --------------------------------------------- the IndexSetLike surface --
    @property
    def indexes(self):
        return self.set.indexes

    @property
    def shards(self):
        return self.set.shards

    @property
    def update_streams(self):
        return self.set.update_streams

    def lookup(self, index_name: str, key: Hashable) -> np.ndarray:
        return self.set.lookup(index_name, key)

    def reader(self, cache_bytes: int = 8 << 20, targeted: bool = True):
        return self.set.reader(cache_bytes=cache_bytes, targeted=targeted)

    def generation_vector(self) -> List[List[int]]:
        return self.set.generation_vector()

    def build_io(self) -> Dict[str, IOStats]:
        return self.set.build_io()

    def search_io(self) -> Dict[str, IOStats]:
        return self.set.search_io()

    def census(self) -> Dict[str, Dict[str, int]]:
        return self.set.census()

    def compaction_stats(self) -> Dict[str, int]:
        return self.set.compaction_stats()

    # -------------------------------------------------------------- admin --
    def stats(self) -> Dict[str, object]:
        return {
            "wal_bytes": self.wal.tell(),
            "wal_appends": self.wal.appends,
            "wal_syncs": self.wal.synced,
            "n_checkpoints": self.n_checkpoints,
            "parts_since_checkpoint": self._parts_since_ckpt,
            "recovery": dict(self.recovery_info),
            "compaction": self.compaction_stats(),
        }

    def close(self) -> None:
        self.wal.close()

    def __enter__(self) -> "DurableIndexStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
