"""Durable on-disk storage backend for the easily updatable index set.

The paper's substrate organizes posting streams for cheap in-place
update; this package makes that substrate *durable*: a write-ahead part
log (:mod:`repro.store.wal`) feeds the existing ``add_part`` path, CRC-
verified segment files (:mod:`repro.store.segments`) checkpoint full
posting snapshots in lexicon+barrel style, and
:class:`~repro.store.store.DurableIndexStore` ties them together with
crash recovery (torn WAL tails truncated, never a partially visible
part) and background compaction published as just another generation
advance.  Serving I/O stays on the simulated block devices, untouched —
see the :mod:`repro.store.store` module docstring for why accounting
parity with the in-memory substrate is exact by construction.
"""

from repro.store.segments import (
    SegmentCorruptError,
    read_segment,
    snapshot_state,
    write_segment,
)
from repro.store.store import DurableIndexStore
from repro.store.wal import (
    REC_COMPACT,
    REC_PART_MAPS,
    REC_PART_TOKENS,
    WriteAheadLog,
)

__all__ = [
    "DurableIndexStore",
    "WriteAheadLog",
    "SegmentCorruptError",
    "read_segment",
    "write_segment",
    "snapshot_state",
    "REC_PART_TOKENS",
    "REC_PART_MAPS",
    "REC_COMPACT",
]
