"""The write-ahead part log.

Every mutation of a durable index set lands here BEFORE it is applied to
the serving substrate, so each applied part is on disk before its
generation advances (the publish point IS the WAL append).  Records are
framed

    [u32 magic][u8 type][u32 payload_len][u32 crc32(payload)][payload]

and recovery scans the file front to back: the first frame whose magic,
length or CRC fails — a torn tail from a crash mid-append — ends the
scan, and the file is truncated there so a partially written part is
never visible, not even partially.  Everything before the tear replays
byte-identically.

Record types:

  * ``REC_PART_TOKENS`` — one collection part as the raw token stream
    (re-extracted on replay, so replay takes the exact ``add_documents``
    path the live write took);
  * ``REC_PART_MAPS``   — one pre-extracted part map (the per-shard
    queue shape of PR 5's update streams);
  * ``REC_COMPACT``     — a background-compaction cycle marker: replay
    re-runs the cycle at the same point in the part sequence, so a
    replayed substrate reproduces the live one's physical layout (and
    therefore its I/O charges) exactly.
"""

from __future__ import annotations

import os
import struct
import zlib
from pathlib import Path
from typing import List, Tuple

WAL_MAGIC = 0x57414C31  # "WAL1"

REC_PART_TOKENS = 1
REC_PART_MAPS = 2
REC_COMPACT = 3

_HEADER = struct.Struct("<IBII")
HEADER_BYTES = _HEADER.size


class WriteAheadLog:
    def __init__(self, path, fsync: bool = True):
        self.path = Path(path)
        self.fsync = bool(fsync)
        self._f = open(self.path, "ab")
        self._end = self.path.stat().st_size
        self.appends = 0
        self.synced = 0

    # ------------------------------------------------------------ writing --
    def append(self, rec_type: int, payload: bytes) -> int:
        """Durably append one record; returns the file offset after it.
        The record is on disk (fsynced when enabled) when this returns —
        callers apply the mutation to the serving substrate only after."""
        frame = _HEADER.pack(
            WAL_MAGIC, rec_type, len(payload), zlib.crc32(payload) & 0xFFFFFFFF
        )
        self._f.write(frame + payload)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
            self.synced += 1
        self.appends += 1
        self._end += HEADER_BYTES + len(payload)
        return self._end

    def tell(self) -> int:
        return self._end

    def size(self) -> int:
        try:
            return self.path.stat().st_size
        except OSError:
            return 0

    # ----------------------------------------------------------- recovery --
    def recover(self, start: int = 0) -> Tuple[List[Tuple[int, bytes]], int, bool]:
        """Scan records from ``start``; truncate any torn tail.

        Returns ``(records, good_offset, torn)``: the intact records in
        order, the offset the file was left at, and whether anything had
        to be discarded.  ``start`` beyond the physical end (the file
        lost bytes a checkpoint already folded — e.g. an external
        truncation) yields no records and reports ``torn`` so the owner
        can re-publish a consistent checkpoint."""
        records, off, torn = self.read_from(start)
        if torn and off < self.size():
            # drop the tear: O_APPEND writes land at the new end, so the
            # already-open append handle stays valid
            with open(self.path, "rb+") as fh:
                fh.truncate(off)
        self._end = off
        return records, off, torn

    def read_from(self, start: int = 0) -> Tuple[List[Tuple[int, bytes]], int, bool]:
        """Non-destructive scan: the intact records from ``start`` and the
        offset after the last one, WITHOUT truncating a torn tail.

        This is the replica polling surface — a read replica tails a
        LIVE primary's log, where an apparent tear may simply be a frame
        the primary is mid-append on; truncating would corrupt the
        owner.  The owner's :meth:`recover` is the destructive variant."""
        try:
            data = self.path.read_bytes()
        except OSError:
            data = b""
        size = len(data)
        if start > size:
            return [], size, True
        records: List[Tuple[int, bytes]] = []
        off = start
        while off < size:
            if off + HEADER_BYTES > size:
                break
            magic, rtype, ln, crc = _HEADER.unpack_from(data, off)
            if magic != WAL_MAGIC or off + HEADER_BYTES + ln > size:
                break
            payload = data[off + HEADER_BYTES : off + HEADER_BYTES + ln]
            if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                break
            records.append((rtype, payload))
            off += HEADER_BYTES + ln
        return records, off, off < size

    def close(self) -> None:
        self._f.close()
