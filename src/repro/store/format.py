"""Binary primitives shared by the WAL and segment files.

Everything durable in :mod:`repro.store` is built from four little
codecs, all little-endian, all length-prefixed so a reader can skip what
it does not understand:

  * the *key codec* — posting-map keys as stored by the extraction
    layer: almost always packed int64 (plain lemma ids, ``(w<<32)|v``
    word pairs, bit-packed stop sequences, multi-component k-gram
    packs), with str/bytes/tuple kept for generality;
  * the *array codec* — raw int64 numpy columns (token streams, offset
    tables);
  * the *run codec* — one key's posting list as a varint delta run
    (:func:`repro.core.postings.encode_postings` with ``prev_doc=0``,
    i.e. self-contained);
  * the *maps codec* — one extracted part, ``{index name → {key →
    (N, 2) postings}}``, the exact shape ``apply_part_maps`` consumes.

Integrity is the caller's business: the WAL frames records with a CRC
header (:mod:`repro.store.wal`) and segment files carry a whole-file CRC
trailer (:mod:`repro.store.segments`); the codecs here assume their
input passed those checks.
"""

from __future__ import annotations

import struct
from typing import Dict, Hashable, Tuple

import numpy as np

KT_INT = 0
KT_STR = 1
KT_BYTES = 2
KT_TUPLE = 3

_KEY_INT = struct.Struct("<Bq")
_KEY_VAR = struct.Struct("<BH")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_I64 = struct.Struct("<q")


# ------------------------------------------------------------- key codec --
def encode_key(key: Hashable) -> bytes:
    if isinstance(key, (int, np.integer)):
        return _KEY_INT.pack(KT_INT, int(key))
    if isinstance(key, str):
        b = key.encode("utf-8")
        return _KEY_VAR.pack(KT_STR, len(b)) + b
    if isinstance(key, bytes):
        return _KEY_VAR.pack(KT_BYTES, len(key)) + key
    if isinstance(key, tuple):
        out = bytearray(_KEY_VAR.pack(KT_TUPLE, len(key)))
        for item in key:
            out += encode_key(item)
        return bytes(out)
    raise TypeError(f"unencodable key type {type(key).__name__}: {key!r}")


def decode_key(buf: bytes, off: int) -> Tuple[Hashable, int]:
    kt = buf[off]
    if kt == KT_INT:
        (_, v) = _KEY_INT.unpack_from(buf, off)
        return v, off + _KEY_INT.size
    (_, n) = _KEY_VAR.unpack_from(buf, off)
    off += _KEY_VAR.size
    if kt == KT_STR:
        return buf[off : off + n].decode("utf-8"), off + n
    if kt == KT_BYTES:
        return bytes(buf[off : off + n]), off + n
    if kt == KT_TUPLE:
        items = []
        for _ in range(n):
            item, off = decode_key(buf, off)
            items.append(item)
        return tuple(items), off
    raise ValueError(f"unknown key type tag {kt}")


# ----------------------------------------------------------- array codec --
def encode_array(arr: np.ndarray) -> bytes:
    a = np.ascontiguousarray(arr, dtype="<i8")
    return _U32.pack(a.shape[0]) + a.tobytes()


def decode_array(buf: bytes, off: int) -> Tuple[np.ndarray, int]:
    (n,) = _U32.unpack_from(buf, off)
    off += _U32.size
    end = off + 8 * n
    a = np.frombuffer(buf, dtype="<i8", count=n, offset=off).astype(np.int64)
    return a, end


# ------------------------------------------------------------- run codec --
def encode_run(postings: np.ndarray) -> bytes:
    """One key's posting list as a self-contained varint delta run."""
    from repro.core.postings import encode_postings

    run = encode_postings(postings, prev_doc=0)
    return _U32.pack(len(run)) + run


def decode_run(buf: bytes, off: int) -> Tuple[np.ndarray, int]:
    from repro.core.postings import decode_postings

    (n,) = _U32.unpack_from(buf, off)
    off += _U32.size
    posts, _ = decode_postings(bytes(buf[off : off + n]))
    return posts, off + n


# ------------------------------------------------------------ maps codec --
def encode_part_maps(maps: Dict[str, Dict[Hashable, np.ndarray]]) -> bytes:
    out = bytearray(_U16.pack(len(maps)))
    for name, by_key in maps.items():
        nb = name.encode("utf-8")
        out += struct.pack("<B", len(nb)) + nb
        out += _U32.pack(len(by_key))
        for key, arr in by_key.items():
            out += encode_key(key)
            out += encode_run(np.asarray(arr, dtype=np.int64))
    return bytes(out)


def decode_part_maps(buf: bytes) -> Dict[str, Dict[Hashable, np.ndarray]]:
    (n_indexes,) = _U16.unpack_from(buf, 0)
    off = _U16.size
    maps: Dict[str, Dict[Hashable, np.ndarray]] = {}
    for _ in range(n_indexes):
        ln = buf[off]
        off += 1
        name = bytes(buf[off : off + ln]).decode("utf-8")
        off += ln
        (n_keys,) = _U32.unpack_from(buf, off)
        off += _U32.size
        by_key: Dict[Hashable, np.ndarray] = {}
        for _ in range(n_keys):
            key, off = decode_key(buf, off)
            posts, off = decode_run(buf, off)
            by_key[key] = posts
        maps[name] = by_key
    return maps


# ----------------------------------------------------- part-tokens codec --
def encode_part_tokens(
    doc0: int, tokens: np.ndarray, offsets: np.ndarray
) -> bytes:
    return _I64.pack(int(doc0)) + encode_array(tokens) + encode_array(offsets)


def decode_part_tokens(buf: bytes) -> Tuple[int, np.ndarray, np.ndarray]:
    (doc0,) = _I64.unpack_from(buf, 0)
    tokens, off = decode_array(buf, _I64.size)
    offsets, _ = decode_array(buf, off)
    return doc0, tokens, offsets
