"""Gradient compression for cross-pod reduction.

On a multi-pod mesh the ``pod`` axis crosses the slow DCI links; the
standard trick is to reduce-scatter in full precision inside a pod (fast
ICI) and compress the cross-pod all-reduce.  Two pieces:

  * ``quantize_int8`` / ``dequantize_int8`` — per-tensor symmetric int8
    with an f32 scale (4x on-the-wire reduction),
  * ``compressed_psum`` — a shard_map-compatible psum that quantizes
    before and dequantizes after the collective on a named axis,
  * ``compress_tree`` — applied to a full gradient pytree inside the
    train step (simulates the wire format end to end and exposes the
    quantization error to tests).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: Any) -> Any:
    """Quantize+dequantize every leaf (wire-format simulation)."""

    def one(g):
        q, s = quantize_int8(g)
        return dequantize_int8(q, s, g.dtype)

    return jax.tree_util.tree_map(one, grads)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-compressed all-reduce over a named axis (use under shard_map).

    Quantizes the local shard, all-reduces the int32-widened payload, and
    rescales by the max participating scale — the classic compressed
    ring-reduce approximation.
    """
    q, scale = quantize_int8(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # re-quantize against the common scale so the sum is well-defined
    q_common = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale_max), -127, 127
    ).astype(jnp.int32)
    total = jax.lax.psum(q_common, axis_name)
    return (total.astype(jnp.float32) * scale_max).astype(x.dtype)
