"""Sharding policy engine: param-path rules -> PartitionSpecs.

Design (DESIGN.md section 4): the mesh has a tensor axis (``model``) and
batch axes (``data``, plus ``pod`` in the multi-pod mesh).  Rules map
parameter path regexes to *logical* specs written in axis names; the
engine drops axis names that the target mesh does not have (so the same
rules drive the (16,16) single-pod and (2,16,16) multi-pod meshes) and
falls back to replication for dimensions that would not divide.

Weights are sharded both ways (tensor axis on the contraction-output dim,
batch axes on the other dim) — the GSPMD rendering of Megatron-TP x FSDP.
"""

from __future__ import annotations

import re
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = List[Tuple[str, Tuple]]

BATCH = ("pod", "data")  # logical batch axes, in mesh order


def _mesh_axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        if n in mesh.axis_names:
            size *= mesh.shape[n]
    return size


def _fit_axes(mesh: Mesh, names: Tuple[str, ...], dim: int):
    """Largest usable subset of axis names whose product divides dim:
    try the full tuple, then prefixes, then each single axis."""
    names = tuple(n for n in names if n in mesh.axis_names)
    candidates = [names[:k] for k in range(len(names), 0, -1)]
    candidates += [(n,) for n in names]
    for cand in candidates:
        if not cand:
            continue
        if dim % _mesh_axis_size(mesh, cand) == 0:
            return cand[0] if len(cand) == 1 else cand
    return None


def resolve_spec(mesh: Mesh, spec: Sequence, shape: Tuple[int, ...]) -> P:
    """Filter a logical spec against a mesh: drop unknown axes; pjit input
    shardings require exact divisibility, so degrade tuple -> prefix ->
    single axis -> replicated per dimension."""
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        out.append(_fit_axes(mesh, names, dim))
    return P(*out)


def sanitize_shardings(shard_tree, abstract_tree, mesh: Mesh):
    """Re-validate a NamedSharding pytree against abstract shapes: any
    dimension whose assigned axes do not divide it exactly is degraded
    (prefix / single axis / replicated).  Keeps every launcher sharding
    legal for pjit regardless of batch size or mesh."""

    def one(shard, leaf):
        if not isinstance(shard, NamedSharding):
            return shard
        shape = np.shape(leaf) if not hasattr(leaf, "shape") else leaf.shape
        spec = tuple(shard.spec) + (None,) * (len(shape) - len(tuple(shard.spec)))
        return NamedSharding(mesh, resolve_spec(mesh, spec, shape))

    return jax.tree_util.tree_map(
        one, shard_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, NamedSharding),
    )


def shard_by_rules(
    params: Any, mesh: Mesh, rules: Rules, default: Tuple = ()
) -> Any:
    """Build a NamedSharding pytree matching ``params`` from path rules."""

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        shape = np.shape(leaf)
        chosen: Optional[P] = None
        for pattern, spec in rules:
            if re.search(pattern, name):
                spec = tuple(spec)
                if len(spec) < len(shape):  # right-align (leading stack dims)
                    spec = (None,) * (len(shape) - len(spec)) + spec
                chosen = resolve_spec(mesh, spec[: len(shape)], shape)
                break
        if chosen is None:
            chosen = resolve_spec(
                mesh, tuple(default)[: len(shape)] + (None,) * len(shape),
                shape,
            )
        specs.append(NamedSharding(mesh, chosen))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ------------------------------------------------------- family rule sets ---
# Transformer (dense + MoE).  Stacked layer params have a leading L dim,
# handled by right-alignment in shard_by_rules.
LM_RULES: Rules = [
    (r"embed/table", ("model", "data")),
    (r"unembed/w", ("data", "model")),
    (r"block/(wq|wk|wv)/w", ("data", "model")),
    (r"block/(wq|wk|wv)/b", ("model",)),
    (r"block/wo/w", ("model", "data")),
    (r"block/mlp/(wg|wu)/w", ("data", "model")),
    (r"block/mlp/wd/w", ("model", "data")),
    (r"block/moe/router", ("data", None)),
    (r"block/moe/(wg|wu)$", ("model", "data", None)),
    (r"block/moe/wd$", ("model", None, "data")),
    (r"block/moe/shared/(wg|wu)", ("data", "model")),
    (r"block/moe/shared/wd", ("model", "data")),
    (r"ln", (None,)),
]

# RecSys: embedding tables row-sharded over every axis (MLPerf-DLRM style
# table-wise+row-wise parallelism); MLPs tensor-sharded on their wide dim.
RECSYS_RULES: Rules = [
    (r"tables/t\d+/table", (BATCH + ("model",), None)),
    (r"(item|cate|user|ctx|icat)/table", (BATCH + ("model",), None)),
    (r"(bot|top|head|attn|user_tower|item_tower)/fc\d+/w", (None, "model")),
    (r"pos/table", (None, None)),
    (r"blocks/.*", (None, None)),
]

# GNN: parameters are tiny (channel mixers) -> replicate everything.
GNN_RULES: Rules = [
    (r".*", ()),
]


def batch_spec(mesh: Mesh, *, extra: Tuple = ()) -> P:
    names = tuple(n for n in BATCH if n in mesh.axis_names)
    lead = names[0] if len(names) == 1 else names
    return P(lead, *extra)


def shard_batch(batch: Any, mesh: Mesh, leading_specs: Dict[str, P] = None
                ) -> Any:
    """NamedSharding pytree for a batch dict: shard dim 0 over batch axes."""
    leading_specs = leading_specs or {}

    def one(path, leaf):
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        if name in leading_specs:
            return NamedSharding(mesh, leading_specs[name])
        shape = np.shape(leaf)
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        spec = batch_spec(mesh)
        bsz = _mesh_axis_size(mesh, tuple(n for n in BATCH if n in mesh.axis_names))
        if shape[0] % bsz != 0:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, P(*spec, *([None] * (len(shape) - 1)))
        )

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat]
    )


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree
    )
