"""Activation sharding constraints that adapt to the ambient mesh.

Model code calls ``constrain(x, "batch", None, "model", ...)`` with logical
entries; the hook resolves them against the mesh active at trace time:

  * "batch" -> the tuple of batch axes present (("pod","data") / ("data",))
  * an axis name -> itself if the mesh has it, else replicated
  * None -> replicated

Outside any mesh (CPU smoke tests) the hook is a no-op, so the same model
code runs everywhere.  Dimensions that an axis does not divide are LEFT
constrained — GSPMD pads intermediates, which is exactly what we want to
force (e.g. shard 36 heads over 16 as 3-per-shard with padding rather
than replicate the whole attention).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

BATCH_AXES = ("pod", "data")


def _ambient_axis_names() -> Optional[Tuple[str, ...]]:
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if not mesh.empty:
            return tuple(mesh.axis_names)
    except Exception:
        pass
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            return tuple(am.axis_names)
    except Exception:
        pass
    return None


def constrain(x, *entries):
    names = _ambient_axis_names()
    if names is None:
        return x
    spec = []
    for e in entries:
        if e == "batch":
            batch = tuple(n for n in BATCH_AXES if n in names)
            spec.append(
                None if not batch else (batch[0] if len(batch) == 1 else batch)
            )
        elif e is None:
            spec.append(None)
        elif isinstance(e, str) and e in names:
            spec.append(e)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
