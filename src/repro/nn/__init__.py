from repro.nn.layers import (  # noqa: F401
    dense,
    dense_init,
    embedding_init,
    mlp_init,
    mlp_apply,
    rms_norm,
    rope,
    softmax_xent,
)
