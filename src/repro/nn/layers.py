"""Minimal functional NN substrate (no flax/optax in this environment).

Parameters are nested dicts of jnp arrays; every layer is an explicit
``init`` + ``apply`` pair.  Compute dtype is configurable (bf16 matmuls,
f32 softmax/norms — the TPU-native mixed precision recipe); parameters are
kept in f32 master copies.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ----------------------------------------------------------------- inits ----
def dense_init(key, d_in: int, d_out: int, bias: bool = False,
               scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def embedding_init(key, vocab: int, dim: int, scale: float = 0.02) -> Params:
    return {"table": jax.random.normal(key, (vocab, dim), jnp.float32) * scale}


def mlp_init(key, dims: Sequence[int], bias: bool = True) -> Params:
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"fc{i}": dense_init(keys[i], dims[i], dims[i + 1], bias=bias)
        for i in range(len(dims) - 1)
    }


# ---------------------------------------------------------------- applies ----
def dense(p: Params, x: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    y = jnp.einsum("...d,df->...f", x.astype(dtype), p["w"].astype(dtype))
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def mlp_apply(p: Params, x: jnp.ndarray, act=jax.nn.relu,
              dtype=jnp.bfloat16, final_act: bool = False) -> jnp.ndarray:
    n = len(p)
    for i in range(n):
        x = dense(p[f"fc{i}"], x, dtype=dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def rms_norm(g: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * scale) * g.astype(jnp.float32)).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding.  x: (..., S, n_heads, d_head); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 ignore_id: int = -1) -> jnp.ndarray:
    """Mean token cross-entropy in f32; labels == ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
