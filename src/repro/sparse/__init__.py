from repro.sparse.embedding import (  # noqa: F401
    embedding_bag,
    embedding_lookup,
    segment_softmax,
)
