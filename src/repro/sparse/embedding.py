"""Sparse embedding ops built from ``jnp.take`` + ``jax.ops.segment_sum``.

JAX has no native EmbeddingBag or CSR sparse support (BCOO only) — these
ARE the system's lookup substrate, as the brief requires.  The same
gather+segment-reduce pattern backs the recsys models and the GNN message
passing; the Pallas kernel in ``repro.kernels.embedding_bag`` implements
the fused TPU version and is validated against these functions.

The paper mapping (DESIGN.md): an embedding table is the associative
array; the *rows are keys*.  Batched lookups are the read path; gradient
scatter-adds are the posting appends, and packing many of them into one
dense segment_sum is the DS strategy's small-write elision on device.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                     dtype=jnp.bfloat16) -> jnp.ndarray:
    """Plain row gather: (..., ) ids -> (..., dim)."""
    return jnp.take(table, ids, axis=0).astype(dtype)


def embedding_bag(
    table: jnp.ndarray,        # (vocab, dim)
    ids: jnp.ndarray,          # (n_ids,) flat indices
    segment_ids: jnp.ndarray,  # (n_ids,) output row per id
    num_segments: int,
    weights: Optional[jnp.ndarray] = None,
    mode: str = "sum",
    dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """EmbeddingBag: gather rows, segment-reduce into bags.

    Equivalent to torch.nn.EmbeddingBag(mode='sum'|'mean') with explicit
    segment ids (padding-free ragged bags).
    """
    rows = jnp.take(table, ids, axis=0).astype(dtype)
    if weights is not None:
        rows = rows * weights[:, None].astype(dtype)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, dtype), segment_ids,
            num_segments=num_segments,
        )
        out = out / jnp.maximum(cnt, 1)[:, None]
    elif mode != "sum":
        raise ValueError(mode)
    return out


def segment_softmax(
    logits: jnp.ndarray,       # (n,) or (n, h)
    segment_ids: jnp.ndarray,  # (n,)
    num_segments: int,
) -> jnp.ndarray:
    """Softmax within segments (GAT-style attention over ragged neighbors)."""
    mx = jax.ops.segment_max(logits, segment_ids, num_segments=num_segments)
    z = jnp.exp(logits - mx[segment_ids])
    denom = jax.ops.segment_sum(z, segment_ids, num_segments=num_segments)
    return z / jnp.maximum(denom[segment_ids], 1e-20)
