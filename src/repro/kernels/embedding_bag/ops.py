"""Dispatch wrapper for the fused EmbeddingBag kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def embedding_bag_fixed(
    table: jnp.ndarray,    # (V, D)
    ids: jnp.ndarray,      # (B, K)
    weights: jnp.ndarray,  # (B, K)
) -> jnp.ndarray:
    return embedding_bag_kernel(
        table, ids.astype(jnp.int32), weights.astype(jnp.float32),
        interpret=not _on_tpu(),
    )
