"""EmbeddingBag Pallas kernel (TPU): fused gather + weighted reduce.

The paper mapping (DESIGN.md): the table is the associative array, rows
are keys; a batched lookup is the read path.  On TPU the win over
take+segment_sum is fusing the row gather with the accumulate so gathered
rows never round-trip through HBM.

Tiling: grid = (B_blocks, K) — ids ride in scalar-prefetch SMEM and pick
the table row block (1, D) per (bag, slot); a VMEM f32 accumulator
carries the bag sum across the K innermost steps.  D is lane-aligned
(multiple of 128 for real tables).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, w_ref, row_ref, o_ref, acc_scr, *, K: int):
    b = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    w = w_ref[b, k]
    acc_scr[...] += row_ref[0].astype(jnp.float32) * w

    @pl.when(k == K - 1)
    def _final():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def embedding_bag_kernel(
    table: jnp.ndarray,    # (V, D)
    ids: jnp.ndarray,      # (B, K) int32
    weights: jnp.ndarray,  # (B, K) f32
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    V, D = table.shape
    B, K = ids.shape
    kern = functools.partial(_kernel, K=K)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # ids, weights
        grid=(B, K),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, k, ids_s, w_s: (ids_s[b, k], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, k, ids_s, w_s: (b, 0)),
        scratch_shapes=[pltpu.VMEM((D,), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(ids, weights, table)
