from repro.kernels.embedding_bag.ops import embedding_bag_fixed  # noqa: F401
from repro.kernels.embedding_bag.ref import embedding_bag_fixed_ref  # noqa: F401
