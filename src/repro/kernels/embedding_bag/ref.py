"""Oracle: fixed-size multi-hot EmbeddingBag (pure jnp)."""

from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_fixed_ref(
    table: jnp.ndarray,    # (V, D)
    ids: jnp.ndarray,      # (B, K)
    weights: jnp.ndarray,  # (B, K)
    mode: str = "sum",
) -> jnp.ndarray:
    rows = table[ids]                        # (B, K, D)
    out = (rows * weights[..., None].astype(rows.dtype)).sum(axis=1)
    if mode == "mean":
        out = out / jnp.maximum(
            weights.sum(axis=1), 1e-9
        )[:, None].astype(out.dtype)
    return out
