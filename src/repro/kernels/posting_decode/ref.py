"""Oracle: byte-parallel LEB128 posting decode (vectorized numpy).

The scalar decoder (``repro.core.postings.PostingDecoder``) walks the
byte stream varint by varint.  The data-parallel formulation below is
what the device kernels implement, and doubles as their exact oracle:

  1. terminator flags — a byte with the high bit CLEAR ends a varint,
     so a cumulative sum of the flags assigns every byte its value id;
  2. per-byte contributions — byte ``b`` at rank ``r`` inside its value
     contributes ``(b & 0x7f) << (7 * r)``;
  3. segmented sum — summing contributions by value id yields the
     decoded varints (contributions occupy disjoint bit ranges, so an
     add-reduction IS the bitwise assembly);
  4. delta expansion — untagged posting records are (doc_delta,
     pos_value) pairs: docs are a prefix sum of the deltas, positions a
     per-same-doc-run prefix sum (a segmented cumsum over the runs
     where the doc delta is zero).

Everything here is exact int64 host arithmetic; the device paths in
``ops.py`` reuse steps 1-3 with an int32 width gate and always run
step 4 on the host (bit-for-bit parity with the scalar decoder).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

_EMPTY = np.zeros((0, 2), dtype=np.int64)

# a decoded (doc_delta, pos_value) record is two varints
_VALS_PER_RECORD = 2


def as_byte_array(data) -> np.ndarray:
    """Bytes-like → (n,) uint8 array without copying when possible."""
    if isinstance(data, np.ndarray) and data.dtype == np.uint8:
        return data
    return np.frombuffer(bytes(data), dtype=np.uint8)


def complete_prefix(buf: np.ndarray) -> int:
    """Byte length of the longest prefix holding only WHOLE records.

    A record is ``_VALS_PER_RECORD`` varints; the prefix ends after the
    last terminator that completes a record, so the remainder (a split
    varint or a dangling doc delta) is the tail the incremental decoder
    must buffer — the same boundary ``PostingDecoder.feed`` finds by
    catching the truncated-record IndexError.
    """
    buf = as_byte_array(buf)
    if buf.size == 0:
        return 0
    term_idx = np.flatnonzero((buf & 0x80) == 0)
    n_records = term_idx.size // _VALS_PER_RECORD
    if n_records == 0:
        return 0
    return int(term_idx[n_records * _VALS_PER_RECORD - 1]) + 1


def byte_prep(buf: np.ndarray) -> Tuple[np.ndarray, np.ndarray, int]:
    """Steps 1-2 of the byte-parallel decode (shared by every backend).

    ``buf`` must end on a varint terminator (a ``complete_prefix``
    slice).  Returns ``(contrib, vid, n_vals)``: per-byte shifted
    payloads (int64), per-byte value ids (sorted, int64), and the
    number of varints.
    """
    buf = as_byte_array(buf)
    n = buf.size
    if n == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64), 0)
    term = (buf & 0x80) == 0
    assert term[-1], "buffer must end on a varint terminator"
    # a value starts at byte 0 and right after every terminator
    new_val = np.empty(n, dtype=bool)
    new_val[0] = True
    new_val[1:] = term[:-1]
    vid = np.cumsum(new_val) - 1
    starts = np.flatnonzero(new_val)
    rank = np.arange(n, dtype=np.int64) - starts[vid]
    contrib = (buf & 0x7F).astype(np.int64) << (7 * rank)
    return contrib, vid.astype(np.int64), int(starts.size)


def unpack_varints_np(buf: np.ndarray) -> np.ndarray:
    """Step 3 on the host: decode a terminator-aligned buffer's varints."""
    contrib, vid, n_vals = byte_prep(buf)
    if n_vals == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.flatnonzero(np.diff(vid, prepend=-1))
    return np.add.reduceat(contrib, starts)


def expand_deltas(
    values: np.ndarray, prev_doc: int, prev_pos: int, started: bool
) -> Tuple[np.ndarray, Tuple[int, int, bool]]:
    """Step 4: (doc_delta, pos_value) varint pairs → (N,2) postings.

    Continuation-aware: ``(prev_doc, prev_pos, started)`` is the scalar
    decoder's carry state, so feeding a stream block by block through
    this expansion decodes exactly what one-shot decoding would.
    Returns the rows and the updated carry.
    """
    assert values.size % _VALS_PER_RECORD == 0
    n = values.size // _VALS_PER_RECORD
    if n == 0:
        return _EMPTY, (prev_doc, prev_pos, started)
    dd = values[0::2]
    pv = values[1::2]
    docs = prev_doc + np.cumsum(dd)
    # a record CONTINUES its doc's position run iff its doc delta is 0
    # and some record precedes it (the very first record of a stream is
    # absolute even when its delta is 0 — doc id 0's first posting)
    same = dd == 0
    if not started:
        same[0] = False
    # positions: absolute at each run head, cumulative within a run.
    # With cs = cumsum(pv), pos[i] = cs[i] - C[i] where C is constant
    # per run: cs[h] - pv[h] at a head h, and -prev_pos for the leading
    # continuation run (no head in this block).  Head values cs[h]-pv[h]
    # = cs[h-1] are nondecreasing and >= 0 >= -prev_pos, so a running
    # max forward-fills C exactly.
    cs = np.cumsum(pv)
    carry = np.where(~same, cs - pv, -np.int64(prev_pos))
    c = np.maximum.accumulate(carry)
    pos = cs - c
    out = np.empty((n, 2), dtype=np.int64)
    out[:, 0] = docs
    out[:, 1] = pos
    return out, (int(docs[-1]), int(pos[-1]), True)


def decode_block_ref(
    block: np.ndarray,
    prev_doc: int = 0,
    prev_pos: int = 0,
    started: bool = False,
) -> Tuple[np.ndarray, Tuple[int, int, bool]]:
    """Whole-record block → (N,2) postings + updated carry (numpy path)."""
    values = unpack_varints_np(block)
    return expand_deltas(values, prev_doc, prev_pos, started)
