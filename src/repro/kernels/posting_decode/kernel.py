"""Varint unpack as a Pallas segmented-sum kernel (TPU adaptation).

The byte-parallel decode (see ``ref.py``) reduces LEB128 unpacking to a
segmented sum: byte ``k`` carries a shifted payload ``contrib[k]`` and a
SORTED segment id ``vid[k]`` (which varint it belongs to), and
``values[v] = sum(contrib[k] for vid[k] == v)``.  A scalar gather-scan
is pointer chasing; the TPU-native formulation is the same dense-tile
broadcast-compare as the intersect kernel: for each (value-block,
byte-block) pair, compare the block's value ids against the tile's
output slots and sum the masked contributions.  Sortedness of ``vid``
bounds useful work exactly like sorted doc ids do for intersect — tiles
whose id ranges don't overlap are skipped via the block-corner test.

Grid = (N/bn, M/bm), byte blocks innermost; the output value block
accumulates across byte blocks in place.  All int32: the dispatch layer
(``ops.py``) gates on varint width so no contribution or value can
overflow the device integer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(v_ref, c_ref, o_ref, *, bn: int, bm: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vid = v_ref[...]      # (bm,) sorted per-byte value ids
    contrib = c_ref[...]  # (bm,) shifted payloads
    lo = pl.program_id(0) * bn
    # block-corner range test: sorted ids => disjoint ranges, no hits
    overlap = jnp.logical_and(vid[0] <= lo + bn - 1, vid[bm - 1] >= lo)

    @pl.when(overlap)
    def _tile():
        # (bn, bm) VPU tile: output slot ids vs byte segment ids
        slots = lo + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 0)
        hit = slots == vid[None, :]
        o_ref[...] = o_ref[...] + jnp.where(
            hit, contrib[None, :], 0
        ).sum(axis=1).astype(o_ref.dtype)


def varint_unpack_kernel(
    vid: jnp.ndarray,      # (M,) sorted int32 segment ids
    contrib: jnp.ndarray,  # (M,) int32 shifted payloads
    n_values: int,         # N, a multiple of bn
    *,
    bn: int = 256,
    bm: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    M = vid.shape[0]
    assert n_values % bn == 0 and M % bm == 0, (n_values, M, bn, bm)
    kern = functools.partial(_kernel, bn=bn, bm=bm)
    return pl.pallas_call(
        kern,
        grid=(n_values // bn, M // bm),
        in_specs=[
            pl.BlockSpec((bm,), lambda i, j: (j,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_values,), jnp.int32),
        interpret=interpret,
    )(vid, contrib)
