"""Device-resident varint posting decode (+ fused decode→intersect).

``repro.core.postings.PostingDecoder`` is the host-side incremental
decoder the lazy cursors feed chunk by chunk.  This package is its
device-resident counterpart, mirroring ``repro.kernels.intersect``:

  ref.py    — vectorized numpy oracle: the byte-parallel formulation of
              the LEB128 record decode (terminator cumsum → per-byte
              value ids/ranks → segmented payload sum → delta expansion)
  kernel.py — the Pallas segmented-sum kernel over the byte-parallel
              form (dense VPU tiles, block-corner range skip)
  ops.py    — backend dispatch (numpy | jax segment_sum | pallas),
              the cursor-compatible :class:`DeviceDecoder`, the fused
              :func:`decode_member_prefilter` entry point, and the
              int32 device-width gates
"""
