"""Dispatch wrappers: backend-selected varint posting decode.

``unpack_varints`` runs step 3 of the byte-parallel decode (see
``ref.py``) on the chosen backend; ``DeviceDecoder`` wraps it behind
the exact ``feed``/state surface of the host
:class:`~repro.core.postings.PostingDecoder`, so the lazy cursor path
can swap decoders without changing semantics; ``decode_member_prefilter``
is the fused decode→intersect entry point (decode a chunk AND mask its
rows against another list's doc ids in one call).

Device-width gate: jax runs with 64-bit disabled, so the jax/pallas
paths are taken only when every varint in the block fits 4 bytes (28
payload bits < int32).  Wider varints fall back to the exact int64 host
path — callers never see a difference (the parity suite in
``tests/test_kernels.py`` pins this bit-for-bit).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.intersect.ops import doc_member_mask
from repro.kernels.posting_decode.kernel import varint_unpack_kernel
from repro.kernels.posting_decode.ref import (
    as_byte_array,
    byte_prep,
    complete_prefix,
    expand_deltas,
    unpack_varints_np,
)

DECODE_BACKENDS = ("numpy", "jax", "pallas")

# widest varint the device integer can hold: 4 bytes = 28 payload bits
_MAX_DEVICE_VARINT_BYTES = 4

# blocks below this take the segment_sum path even under the pallas
# backend: kernel dispatch (and interpret-mode tracing on CPU) dominates
# tiny launches; the dense-tile kernel earns its keep on big blocks
_PALLAS_MIN_BYTES = 1 << 14


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pow2(n: int, floor: int = 8) -> int:
    return max(floor, 1 << int(np.ceil(np.log2(max(n, 1)))))


@functools.partial(jax.jit, static_argnums=2)
def _segment_sum_jit(contrib, vid, num_segments: int):
    return jax.ops.segment_sum(contrib, vid, num_segments=num_segments)


def unpack_varints(buf, backend: str = "numpy") -> np.ndarray:
    """Decode a terminator-aligned byte buffer's varints as (N,) int64.

    ``backend`` picks where the segmented sum runs; the byte prep (flag
    scan, ranks, shifts) is host work either way.  Blocks containing a
    varint wider than the int32 gate run the host path regardless — the
    result is always exact int64.
    """
    if backend not in DECODE_BACKENDS:
        raise ValueError(
            f"unknown decode backend {backend!r}; expected one of "
            f"{DECODE_BACKENDS}"
        )
    buf = as_byte_array(buf)
    if backend == "numpy" or buf.size == 0:
        return unpack_varints_np(buf)
    contrib, vid, n_vals = byte_prep(buf)
    widths = np.bincount(vid, minlength=n_vals)
    if widths.max(initial=0) > _MAX_DEVICE_VARINT_BYTES:
        return unpack_varints_np(buf)
    if backend == "jax":
        # pad bytes AND segments to power-of-two buckets: chunk payloads
        # vary byte by byte, and an unpadded call would retrace the jit
        # per distinct (M, n_vals) pair — pow2 bucketing caps the number
        # of compiled shapes at a handful per stream
        M2 = _pow2(contrib.size)
        n2 = _pow2(n_vals + 1)  # sentinel id n_vals stays in range
        vid_p = np.concatenate(
            [vid, np.full(M2 - contrib.size, n_vals, dtype=np.int64)]
        )
        contrib_p = np.concatenate(
            [contrib, np.zeros(M2 - contrib.size, dtype=np.int64)]
        )
        values = _segment_sum_jit(
            jnp.asarray(contrib_p, jnp.int32),
            jnp.asarray(vid_p, jnp.int32),
            n2,
        )
        return np.asarray(values[:n_vals]).astype(np.int64)
    # pallas: pad bytes with a sentinel id beyond every output slot and
    # values to the block grid; sentinel bytes can never hit a slot
    M = int(contrib.size)
    bn = min(256, _pow2(n_vals))
    bm = min(1024, _pow2(M))
    n_pad = (-n_vals) % bn
    m_pad = (-M) % bm
    vid_p = np.concatenate(
        [vid, np.full(m_pad, n_vals + n_pad, dtype=np.int64)]
    )
    contrib_p = np.concatenate([contrib, np.zeros(m_pad, dtype=np.int64)])
    values = varint_unpack_kernel(
        jnp.asarray(vid_p, jnp.int32),
        jnp.asarray(contrib_p, jnp.int32),
        n_vals + n_pad,
        bn=bn,
        bm=bm,
        interpret=not _on_tpu(),
    )
    return np.asarray(values[:n_vals]).astype(np.int64)


class DeviceDecoder:
    """Incremental posting decoder with a device-resident varint unpack.

    Drop-in for :class:`repro.core.postings.PostingDecoder` on the
    untagged streams the lazy (K_OWN) cursor path feeds: same ``feed``
    contract (decode every complete record of ``rem + data``, buffer the
    tail), same ``state()``/``set_state()`` carry tuple — a stream may
    be suspended under one decoder and resumed under the other.  The
    delta expansion stays exact host int64; only the byte-crunching
    varint unpack is dispatched to the device.
    """

    def __init__(self, backend: str = "jax"):
        if backend not in DECODE_BACKENDS:
            raise ValueError(
                f"unknown decode backend {backend!r}; expected one of "
                f"{DECODE_BACKENDS}"
            )
        self.backend = backend
        self._rem = b""
        self._prev_doc = 0
        self._prev_pos = 0
        self._any = False

    @property
    def pending_bytes(self) -> int:
        return len(self._rem)

    def feed(self, data) -> Tuple[np.ndarray, np.ndarray]:
        buf = self._rem + bytes(data)
        cut = complete_prefix(np.frombuffer(buf, dtype=np.uint8))
        backend = self.backend
        if backend == "pallas" and cut < _PALLAS_MIN_BYTES:
            backend = "jax"
        values = unpack_varints(buf[:cut], backend=backend)
        posts, (pd, pp, st) = expand_deltas(
            values, self._prev_doc, self._prev_pos, self._any
        )
        self._rem = buf[cut:]
        self._prev_doc, self._prev_pos, self._any = pd, pp, st
        return posts, np.zeros(posts.shape[0], dtype=np.int64)

    # carry tuple shared with PostingDecoder (see its state/set_state)
    def state(self) -> Tuple[bytes, int, int, bool]:
        return (self._rem, self._prev_doc, self._prev_pos, self._any)

    def set_state(self, state: Tuple[bytes, int, int, bool]) -> None:
        rem, prev_doc, prev_pos, any_ = state
        self._rem = bytes(rem)
        self._prev_doc = int(prev_doc)
        self._prev_pos = int(prev_pos)
        self._any = bool(any_)


def decode_member_prefilter(
    data,
    other_docs: np.ndarray,
    backend: str = "pallas",
    state: Tuple[bytes, int, int, bool] = (b"", 0, 0, False),
) -> Tuple[np.ndarray, np.ndarray, Tuple[bytes, int, int, bool]]:
    """Fused decode→intersect: decode a posting chunk and mask its rows
    whose doc id occurs in ``other_docs`` — one entry point instead of a
    host decode followed by a separate membership pass, so a hot chunk's
    bytes go straight from storage to the intersect prefilter.

    ``state`` is the decoder carry (``DeviceDecoder.state()`` tuple) so
    chunked streams fuse too.  Returns ``(posts, member_mask,
    new_state)``; the mask is exact (the pallas path falls back to the
    searchsorted host test when doc ids exceed the kernel's int32 key
    width).
    """
    dec = DeviceDecoder(
        backend=backend if backend in DECODE_BACKENDS else "numpy"
    )
    dec.set_state(state)
    posts, _ = dec.feed(data)
    docs = posts[:, 0]
    other = np.unique(np.asarray(other_docs, dtype=np.int64))
    mask = None
    if backend == "pallas":
        mask = doc_member_mask(docs, other)
    if mask is None:
        if other.size == 0 or docs.size == 0:
            mask = np.zeros(docs.shape, dtype=bool)
        else:
            idx = np.clip(np.searchsorted(other, docs), 0, other.size - 1)
            mask = other[idx] == docs
    return posts, np.asarray(mask, dtype=bool), dec.state()


# ------------------------------------------------- device-resident rows ---
def to_device_rows(posts: np.ndarray) -> Optional[jnp.ndarray]:
    """(N,2) int64 postings → int32 device buffer, or None when any
    value exceeds the device integer width (jax runs without 64-bit, so
    an int64 upload would silently truncate — the gate keeps the device
    tier exact-or-absent)."""
    if posts.size and int(posts.max()) >= np.iinfo(np.int32).max:
        return None
    return jnp.asarray(posts, jnp.int32)


def from_device_rows(buf: jnp.ndarray) -> np.ndarray:
    """Device buffer → immutable (N,2) int64 host rows (the cursor ABI)."""
    rows = np.asarray(buf).astype(np.int64)
    rows.flags.writeable = False
    return rows
