"""Paged decode attention Pallas kernel (TPU).

The page table is the device rendering of the paper's stream-of-clusters:
a sequence's KV lives in pages scattered through a global pool, located
through a bounded indirection structure (the CH chain-length limit bounds
``max_pages`` indirections per read — paper 5.7.3).

Mechanics: ``block_table`` and ``lengths`` ride in scalar-prefetch SMEM
(PrefetchScalarGridSpec) so the k/v BlockSpec index maps can pick the
page: block (1, page, D) of the pool at row ``table[b, p]``.  The grid is
(B, max_pages) with the online-softmax state in VMEM scratch, exactly the
flash pattern but with gathered pages.  Invalid tail pages are masked via
``lengths``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, page: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)
    n_p = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    page_start = p * page

    @pl.when(page_start < length)
    def _block():
        q = q_ref[0].astype(jnp.float32)              # (H, D)
        k = k_ref[0].astype(jnp.float32)              # (page, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                      # (H, page)
        pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1
        )
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + pexp.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(p == n_p - 1)
    def _final():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


def paged_attention_kernel(
    q: jnp.ndarray,            # (B, H, D)
    k_pool: jnp.ndarray,       # (n_pages, page, D)
    v_pool: jnp.ndarray,       # (n_pages, page, D)
    block_table: jnp.ndarray,  # (B, max_pages)
    lengths: jnp.ndarray,      # (B,)
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, D = q.shape
    n_pages, page, _ = k_pool.shape
    max_pages = block_table.shape[1]
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(_kernel, page=page, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_table, lengths
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, p, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, D), lambda b, p, tbl, ln: (tbl[b, p], 0, 0)),
            pl.BlockSpec((1, page, D), lambda b, p, tbl, ln: (tbl[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, p, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(block_table, lengths, q, k_pool, v_pool)
