"""Oracle: decode attention through a page table (pure jnp)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(
    q: jnp.ndarray,            # (B, H, D) one query token per sequence
    k_pool: jnp.ndarray,       # (n_pages, page, D) global page pool
    v_pool: jnp.ndarray,       # (n_pages, page, D)
    block_table: jnp.ndarray,  # (B, max_pages) int32 page ids
    lengths: jnp.ndarray,      # (B,) valid tokens per sequence
) -> jnp.ndarray:
    B, H, D = q.shape
    n_pages, page, _ = k_pool.shape
    max_pages = block_table.shape[1]
    k = k_pool[block_table]        # (B, max_pages, page, D)
    v = v_pool[block_table]
    k = k.reshape(B, max_pages * page, D)
    v = v.reshape(B, max_pages * page, D)
    scores = jnp.einsum(
        "bhd,btd->bht", q, k, preferred_element_type=jnp.float32
    ) / math.sqrt(D)
    valid = jnp.arange(max_pages * page)[None] < lengths[:, None]
    scores = jnp.where(valid[:, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bht,btd->bhd", w.astype(v.dtype), v)
