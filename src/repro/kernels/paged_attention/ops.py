"""Dispatch wrapper for paged decode attention."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def paged_attention(
    q: jnp.ndarray,            # (B, H, D)
    k_pool: jnp.ndarray,       # (n_pages, page, D)
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,  # (B, max_pages)
    lengths: jnp.ndarray,      # (B,)
) -> jnp.ndarray:
    return paged_attention_kernel(
        q, k_pool, v_pool, block_table, lengths,
        interpret=not _on_tpu(),
    )
