"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel directory has:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd dispatch wrapper (interpret=True on CPU)
  ref.py    — pure-jnp oracle, used by the models and the tests

Kernels:
  flash_attention — causal online-softmax attention (train/prefill)
  paged_attention — decode attention through a block table whose depth is
                    bounded by the paper's chain-length limit (CH strategy)
  embedding_bag   — fused gather + segment-reduce (recsys hot path)
  intersect       — sorted posting-list intersection as dense VPU tiles
                    (TPU adaptation of merge-intersection: no pointer
                    chasing, block-parallel compares)
  posting_decode  — byte-parallel LEB128 varint posting decode (terminator
                    scan → segmented sum → host delta expansion); wraps a
                    DeviceDecoder drop-in for the scalar PostingDecoder
                    plus the fused decode→intersect prefilter entry point
"""
