"""Dispatch wrapper: pad to block multiples, run the intersect kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.intersect.kernel import intersect_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def intersect_sorted(a, b, bn: int = 1024, bm: int = 1024):
    """mask[i] = a[i] in b for sorted int32 arrays (host-callable; pads to
    block multiples with sentinels that can never match)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    N, M = a.shape[0], b.shape[0]
    bn = min(bn, max(8, 1 << int(np.ceil(np.log2(max(N, 1))))))
    bm = min(bm, max(8, 1 << int(np.ceil(np.log2(max(M, 1))))))
    pn = (-N) % bn
    pm = (-M) % bm
    big = jnp.iinfo(jnp.int32).max
    ap = jnp.concatenate([a, jnp.full((pn,), big - 1, a.dtype)])
    bp = jnp.concatenate([b, jnp.full((pm,), big, b.dtype)])
    mask = intersect_kernel(
        ap, bp, bn=bn, bm=bm, interpret=not _on_tpu()
    )
    return mask[:N]
