"""Dispatch wrappers: pad to block multiples, run the intersect kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.intersect.kernel import intersect_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def intersect_sorted(a, b, bn: int = 1024, bm: int = 1024):
    """mask[i] = a[i] in b for sorted int32 arrays (host-callable; pads to
    block multiples with sentinels that can never match)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    N, M = a.shape[0], b.shape[0]
    bn = min(bn, max(8, 1 << int(np.ceil(np.log2(max(N, 1))))))
    bm = min(bm, max(8, 1 << int(np.ceil(np.log2(max(M, 1))))))
    pn = (-N) % bn
    pm = (-M) % bm
    big = jnp.iinfo(jnp.int32).max
    ap = jnp.concatenate([a, jnp.full((pn,), big - 1, a.dtype)])
    bp = jnp.concatenate([b, jnp.full((pm,), big, b.dtype)])
    mask = intersect_kernel(
        ap, bp, bn=bn, bm=bm, interpret=not _on_tpu()
    )
    return mask[:N]


def doc_member_mask(a_docs: np.ndarray, b_docs: np.ndarray) -> Optional[np.ndarray]:
    """Host mask[i] = a_docs[i] occurs in b_docs, via the Pallas kernel.

    The doc-level prefilter of the proximity search pallas backend
    (``repro.search.join.pallas_window_join``).  ``a_docs`` must be sorted;
    ``b_docs`` is deduplicated here.  Returns None when the doc ids do not
    fit the kernel's int32 key width — callers fall back to a host join.
    """
    if a_docs.size == 0 or b_docs.size == 0:
        return np.zeros(a_docs.shape, dtype=bool)
    b_docs = np.unique(b_docs)
    if int(a_docs[-1]) >= np.iinfo(np.int32).max or (
        int(b_docs[-1]) >= np.iinfo(np.int32).max
    ):
        return None
    mask = intersect_sorted(a_docs.astype(np.int32), b_docs.astype(np.int32))
    return np.asarray(mask).astype(bool)
