from repro.kernels.intersect.ops import intersect_sorted  # noqa: F401
from repro.kernels.intersect.ref import intersect_sorted_ref  # noqa: F401
