"""Sorted-list intersection Pallas kernel (TPU adaptation).

This is the query-side hot spot of the paper: intersecting posting lists
(doc-id keys) during proximity search.  A CPU merge-intersection is
pointer chasing — hostile to the TPU's vector unit.  The TPU-native
formulation is dense tile comparison: for each (a-block, b-block) pair,
broadcast-compare the 2D tile and OR-reduce.  O(N*M/(bn*bm)) tiles of
pure VPU compares beats a data-dependent merge on this hardware, and the
sortedness still bounds useful work: tiles whose ranges don't overlap
contribute nothing and are skipped via a cheap range test on block
corners (the block-level analogue of galloping).

Grid = (N/bn, M/bm), b innermost; the output mask block accumulates
across b-blocks in place.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref, *, bn: int, bm: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # (bn,)
    b = b_ref[...]  # (bm,)
    # block-corner range test: sorted inputs => disjoint ranges, no hits
    overlap = jnp.logical_and(a[0] <= b[bm - 1], b[0] <= a[bn - 1])

    @pl.when(overlap)
    def _tile():
        eq = a[:, None] == b[None, :]           # (bn, bm) VPU compare tile
        o_ref[...] = jnp.logical_or(
            o_ref[...], eq.any(axis=1)
        ).astype(o_ref.dtype)


def intersect_kernel(
    a: jnp.ndarray,  # (N,) sorted int32
    b: jnp.ndarray,  # (M,) sorted int32
    *,
    bn: int = 1024,
    bm: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    N, M = a.shape[0], b.shape[0]
    assert N % bn == 0 and M % bm == 0, (N, M, bn, bm)
    kern = functools.partial(_kernel, bn=bn, bm=bm)
    return pl.pallas_call(
        kern,
        grid=(N // bn, M // bm),
        in_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.bool_),
        interpret=interpret,
    )(a, b)
