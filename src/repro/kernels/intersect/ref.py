"""Oracle: sorted posting intersection membership (pure jnp)."""

from __future__ import annotations

import jax.numpy as jnp


def intersect_sorted_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """mask[i] = a[i] in b, for sorted int arrays (searchsorted oracle)."""
    idx = jnp.clip(jnp.searchsorted(b, a), 0, b.shape[0] - 1)
    return b[idx] == a
