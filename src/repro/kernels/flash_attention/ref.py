"""Oracle: causal attention with exact softmax (pure jnp)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, H, S, D)
    v: jnp.ndarray,  # (B, H, S, D)
    causal: bool = True,
) -> jnp.ndarray:
    S = q.shape[2]
    scores = jnp.einsum(
        "bhsd,bhtd->bhst", q, k, preferred_element_type=jnp.float32
    ) / math.sqrt(q.shape[-1])
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w.astype(v.dtype), v)
