"""Dispatch wrapper: (B, H, S, D) attention through the Pallas kernel.

On CPU (this container) the kernel body runs in interpret mode; on TPU the
same call compiles to Mosaic.  ``flash_attention`` folds (B, H) into the
grid's batch dimension and picks MXU-aligned block sizes.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    out = flash_attention_kernel(
        q.reshape(B * H, S, D),
        k.reshape(B * H, S, D),
        v.reshape(B * H, S, D),
        bq=bq,
        bk=bk,
        causal=causal,
        interpret=not _on_tpu(),
    )
    return out.reshape(B, H, S, D)
