"""Flash attention Pallas kernel (TPU): causal online-softmax.

Tiling: grid = (B*H, Sq/bq, Sk/bk), kv innermost so the VMEM scratch
(m, l, acc) carries the online-softmax state across kv blocks for one
q block.  Block shapes are MXU-aligned (multiples of 128 on the lane
dim); the q/k/v tiles live in VMEM via BlockSpec index maps.

Causality is exploited at block granularity: kv blocks strictly above
the diagonal are skipped via @pl.when (their compute contributes
nothing), which is the 2x triangular saving.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # skip blocks entirely above the diagonal
        run = (kj * bk) <= (qi * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0].astype(jnp.float32)          # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                  # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jnp.ndarray,  # (BH, S, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    bq: int = 128,
    bk: int = 128,
    causal: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, S, D = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = 1.0 / math.sqrt(D)
    grid = (BH, S // bq, S // bk)
    kern = functools.partial(
        _kernel, bq=bq, bk=bk, causal=causal, scale=scale
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
