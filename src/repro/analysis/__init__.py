"""``repro.analysis`` — AST-based invariant linter for the repo's own
contracts: charge accounting, trace schema, generation discipline,
cache-tier encapsulation, kernel purity.

Run as ``python -m repro.analysis [paths...]`` (or ``scripts/lint.sh``);
exits non-zero when any finding survives pragma suppression.  See
DESIGN_SEARCH.md §12 for what each pass guards and why.
"""

from __future__ import annotations

from repro.analysis.engine import (
    LintPass,
    lint_file,
    lint_paths,
    lint_source,
)
from repro.analysis.passes import all_passes
from repro.analysis.schema import Finding, render_json, render_text

__all__ = [
    "Finding",
    "LintPass",
    "all_passes",
    "lint_file",
    "lint_paths",
    "lint_source",
    "render_json",
    "render_text",
]
