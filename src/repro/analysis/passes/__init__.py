"""Pass registry for ``repro.analysis``.

Order is stable (it is the order findings tie-break in) and additive:
new invariant passes register here and nowhere else.
"""

from __future__ import annotations

from typing import List

from repro.analysis.engine import LintPass
from repro.analysis.passes.cache import CacheTierPass
from repro.analysis.passes.charge import ChargeAccountingPass
from repro.analysis.passes.generation import GenerationDisciplinePass
from repro.analysis.passes.kernel import KernelPurityPass
from repro.analysis.passes.trace import TraceSchemaPass

__all__ = [
    "CacheTierPass",
    "ChargeAccountingPass",
    "GenerationDisciplinePass",
    "KernelPurityPass",
    "TraceSchemaPass",
    "all_passes",
]


def all_passes() -> List[LintPass]:
    return [
        ChargeAccountingPass(),
        TraceSchemaPass(),
        GenerationDisciplinePass(),
        CacheTierPass(),
        KernelPurityPass(),
    ]
