"""generation-discipline: ``n_parts`` is not a snapshot coordinate.

PR 9's aliasing bug: readers tracked the physical part counter
``n_parts`` as if it were the published generation, and a checkpoint
reopen that collapses many parts into one left ``n_parts`` equal while
every posting list had been rewritten — caches served stale bytes with
no invalidation.  ``InvertedIndex.generation`` is the only publication
coordinate; only the index itself (and ``restore_generation``, replaying
a manifest) may advance it.
"""

from __future__ import annotations

import ast
from typing import List

from repro.analysis.allowlists import (
    GENERATION_WRITER_MODULES,
    in_allowlist,
)
from repro.analysis.engine import LintPass
from repro.analysis.schema import Finding

_SNAPSHOTTY = ("generation", "snapshot")


def _mentions_n_parts(node: ast.AST) -> bool:
    return any(
        isinstance(n, ast.Attribute) and n.attr == "n_parts"
        for n in ast.walk(node)
    )


def _snapshotty_name(node: ast.AST) -> bool:
    """Whether an expression's identifiers suggest a generation/snapshot
    coordinate (``gen``, ``generation``, ``snapshot``, ...)."""
    for n in ast.walk(node):
        text = ""
        if isinstance(n, ast.Name):
            text = n.id
        elif isinstance(n, ast.Attribute):
            text = n.attr
        low = text.lower()
        if (
            low.startswith("gen")
            or "_gen" in low
            or "snap" in low
            or any(s in low for s in _SNAPSHOTTY)
        ):
            return True
    return False


class GenerationDisciplinePass(LintPass):
    id = "generation-discipline"

    def run(self, tree: ast.AST, path: str, src: str) -> List[Finding]:
        out: List[Finding] = []
        gen_writer = in_allowlist(path, GENERATION_WRITER_MODULES)
        for node in ast.walk(tree):
            targets = ()
            value = None
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AugAssign):
                targets, value = (node.target,), node.value
            for t in targets:
                if (
                    isinstance(t, ast.Attribute)
                    and t.attr == "generation"
                    and not gen_writer
                ):
                    out.append(self.finding(
                        path, t,
                        "write to `.generation` outside InvertedIndex / "
                        "restore_generation; the published generation is "
                        "the index's to advance",
                    ))
                # snapshot-named target fed from n_parts
                if (
                    _snapshotty_name(t)
                    and value is not None
                    and _mentions_n_parts(value)
                ):
                    out.append(self.finding(
                        path, t,
                        "generation/snapshot coordinate derived from "
                        "`.n_parts`; use the published `.generation` "
                        "(checkpoint reopens collapse parts — the PR 9 "
                        "aliasing class)",
                    ))
            if isinstance(node, ast.Compare):
                operands = [node.left] + list(node.comparators)
                if any(_mentions_n_parts(o) for o in operands) and any(
                    _snapshotty_name(o) for o in operands
                ):
                    out.append(self.finding(
                        path, node,
                        "`.n_parts` compared against a generation/snapshot "
                        "coordinate; part counts alias across checkpoint "
                        "reopens (PR 9)",
                    ))
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "restore_generation"
                and any(_mentions_n_parts(a) for a in node.args)
            ):
                out.append(self.finding(
                    path, node,
                    "restore_generation() fed from `.n_parts`; persist and "
                    "replay the published generation vector instead",
                ))
            # dict-literal persistence: {"generation...": <n_parts expr>}
            if isinstance(node, ast.Dict):
                for k, v in zip(node.keys, node.values):
                    if (
                        isinstance(k, ast.Constant)
                        and isinstance(k.value, str)
                        and any(s in k.value.lower() for s in _SNAPSHOTTY)
                        and v is not None
                        and _mentions_n_parts(v)
                    ):
                        out.append(self.finding(
                            path, k,
                            f"persisting `.n_parts` under key {k.value!r}; "
                            f"a part count is not a snapshot coordinate "
                            f"(PR 9)",
                        ))
        return out
