"""kernel-purity: kernels and jitted functions stay deterministic.

The cross-backend identity tests (numpy == jax == pallas) are the
repo's ground truth; they only hold if kernel code has no Python-level
nondeterminism (wall clock, ``random``, dict-ordering iteration) and no
data-dependent Python branching on traced values — a branch on a traced
operand either crashes under ``jit`` or, worse, bakes one trace-time
path into the compiled function.  Static arguments (declared via
``static_argnames``/``static_argnums``) are concrete at trace time and
exempt, as are shape/dtype attributes.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.allowlists import in_kernel_scope
from repro.analysis.engine import LintPass
from repro.analysis.schema import Finding

_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
_NONDET_MODULES = {"time", "random"}


def _ends_with_jit(node: ast.AST) -> bool:
    return (isinstance(node, ast.Name) and node.id == "jit") or (
        isinstance(node, ast.Attribute) and node.attr == "jit"
    )


def _const_strs(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [
            e.value for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, str)
        ]
    return []


def _const_ints(node: ast.AST) -> List[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [
            e.value for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, int)
        ]
    return []


def _statics_from_jit_kwargs(
    kwargs: List[ast.keyword], params: List[str]
) -> Set[str]:
    statics: Set[str] = set()
    for kw in kwargs:
        if kw.arg == "static_argnames":
            statics.update(_const_strs(kw.value))
        elif kw.arg == "static_argnums":
            for i in _const_ints(kw.value):
                if 0 <= i < len(params):
                    statics.add(params[i])
    return statics


class KernelPurityPass(LintPass):
    id = "kernel-purity"

    def run(self, tree: ast.AST, path: str, src: str) -> List[Finding]:
        out: List[Finding] = []
        kernel_mod = in_kernel_scope(path)
        if kernel_mod:
            out.extend(self._check_imports(tree, path))
        # names wrapped with jax.jit(f) as an expression (not a decorator)
        wrapped: Dict[str, List[ast.keyword]] = {}
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Call)
                and _ends_with_jit(node.func)
                and node.args
                and isinstance(node.args[0], ast.Name)
            ):
                wrapped[node.args[0].id] = node.keywords
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            statics = self._jit_statics(node, wrapped)
            jitted = statics is not None
            if not (jitted or kernel_mod):
                continue
            out.extend(self._check_dict_iteration(node, path))
            if not kernel_mod:
                out.extend(self._check_nondet_calls(node, path))
            if jitted:
                out.extend(self._check_branches(node, path, statics))
        return out

    # -------------------------------------------------------------------
    def _check_imports(self, tree: ast.AST, path: str) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(tree):
            names: List[str] = []
            if isinstance(node, ast.Import):
                names = [a.name.split(".")[0] for a in node.names]
            elif isinstance(node, ast.ImportFrom):
                names = [(node.module or "").split(".")[0]]
            for n in names:
                if n in _NONDET_MODULES:
                    out.append(self.finding(
                        path, node,
                        f"kernel module imports `{n}`; kernels must be "
                        f"deterministic (cross-backend identity depends "
                        f"on it)",
                    ))
            if (
                isinstance(node, ast.Attribute)
                and node.attr == "random"
                and isinstance(node.value, ast.Name)
                and node.value.id in ("np", "numpy", "jnp")
            ):
                out.append(self.finding(
                    path, node,
                    "numpy/jax `random` used in a kernel module; seed-free "
                    "randomness breaks cross-backend identity",
                ))
        return out

    def _check_nondet_calls(
        self, fn: ast.AST, path: str
    ) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(fn):
            if (
                isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and (
                    node.value.id in _NONDET_MODULES
                    or (
                        node.attr == "random"
                        and node.value.id in ("np", "numpy", "jnp")
                    )
                )
            ):
                out.append(self.finding(
                    path, node,
                    f"nondeterministic `{node.value.id}.{node.attr}` inside "
                    f"a jitted function",
                ))
        return out

    def _check_dict_iteration(
        self, fn: ast.AST, path: str
    ) -> List[Finding]:
        out: List[Finding] = []
        iters: List[ast.AST] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.For):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters.extend(gen.iter for gen in node.generators)
        for it in iters:
            if (
                isinstance(it, ast.Call)
                and isinstance(it.func, ast.Attribute)
                and it.func.attr in ("keys", "items", "values")
            ):
                out.append(self.finding(
                    path, it,
                    f"iteration over dict `.{it.func.attr}()` without "
                    f"`sorted(...)`; dict order is insertion order, not a "
                    f"deterministic function of the contents",
                ))
        return out

    def _jit_statics(
        self,
        fn: ast.AST,
        wrapped: Dict[str, List[ast.keyword]],
    ) -> Optional[Set[str]]:
        """The set of static parameter names if ``fn`` is jitted (via a
        decorator or a ``jax.jit(fn)`` wrap in the same module), else
        ``None``."""
        params = [
            a.arg for a in fn.args.posonlyargs + fn.args.args
        ]
        for dec in fn.decorator_list:
            if _ends_with_jit(dec):
                return set()
            if isinstance(dec, ast.Call):
                if _ends_with_jit(dec.func):
                    return _statics_from_jit_kwargs(dec.keywords, params)
                # functools.partial(jax.jit, static_argnames=...)
                if (
                    (
                        isinstance(dec.func, ast.Name)
                        and dec.func.id == "partial"
                    )
                    or (
                        isinstance(dec.func, ast.Attribute)
                        and dec.func.attr == "partial"
                    )
                ) and dec.args and _ends_with_jit(dec.args[0]):
                    return _statics_from_jit_kwargs(dec.keywords, params)
        if fn.name in wrapped:
            return _statics_from_jit_kwargs(wrapped[fn.name], params)
        return None

    def _check_branches(
        self, fn: ast.AST, path: str, statics: Set[str]
    ) -> List[Finding]:
        params = {
            a.arg for a in fn.args.posonlyargs + fn.args.args
            + fn.args.kwonlyargs
        }
        params.discard("self")
        traced = params - statics
        out: List[Finding] = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                name = self._traced_ref(node.test, traced)
                if name:
                    out.append(self.finding(
                        path, node,
                        f"Python branch on traced value `{name}` inside a "
                        f"jitted function; use jnp.where/lax.cond or "
                        f"declare the argument static",
                    ))
        return out

    @classmethod
    def _traced_ref(
        cls, node: ast.AST, traced: Set[str]
    ) -> Optional[str]:
        """First traced parameter referenced by ``node`` outside a
        shape/dtype attribute or ``len(...)`` (both concrete at trace
        time)."""
        if isinstance(node, ast.Attribute) and node.attr in _SHAPE_ATTRS:
            return None  # q.shape[0] etc: static under jit
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "len"
        ):
            return None
        if isinstance(node, ast.Name) and node.id in traced:
            return node.id
        for child in ast.iter_child_nodes(node):
            hit = cls._traced_ref(child, traced)
            if hit:
                return hit
        return None
