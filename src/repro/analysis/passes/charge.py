"""charge-accounting: every device byte is charged at a chokepoint.

The paper's cost model only means anything because every read/write
against the simulated :class:`BlockDevice` flows through StreamManager /
InvertedIndex / the store, where ``IOStats`` charges it.  A module that
calls a device method directly (or pokes an ``IOStats`` field) creates
I/O the benchmarks never see — the silent-uncharged-read bug class.
"""

from __future__ import annotations

import ast
from typing import List

from repro.analysis.allowlists import (
    CHARGE_CHOKEPOINT_MODULES,
    DEVICE_METHODS,
    IOSTATS_FIELDS,
    in_allowlist,
)
from repro.analysis.engine import LintPass
from repro.analysis.schema import Finding


class ChargeAccountingPass(LintPass):
    id = "charge-accounting"

    def run(self, tree: ast.AST, path: str, src: str) -> List[Finding]:
        if in_allowlist(path, CHARGE_CHOKEPOINT_MODULES):
            return []
        out: List[Finding] = []
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in DEVICE_METHODS
            ):
                out.append(self.finding(
                    path, node,
                    f"direct device I/O `{node.func.attr}(...)` outside the "
                    f"charge chokepoints "
                    f"({', '.join(sorted(CHARGE_CHOKEPOINT_MODULES))}); "
                    f"route the read through StreamManager/IndexReader so "
                    f"it is charged",
                ))
            targets = ()
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AugAssign):
                targets = (node.target,)
            for t in targets:
                if isinstance(t, ast.Attribute) and t.attr in IOSTATS_FIELDS:
                    out.append(self.finding(
                        path, t,
                        f"write to IOStats field `.{t.attr}` outside the "
                        f"charge chokepoints bypasses the I/O ledger",
                    ))
        return out
