"""trace-schema: every ``last_trace`` key written is declared centrally.

``check_trace_complete`` can only prove a batch's trace complete if the
runtime checker and the code writing the trace agree on the key set, so
every key written into ``SearchService.last_trace`` (directly, through a
local later stored into it, or through a dict parameter named ``trace``)
must appear in ``repro.search.schema.TRACE_SCHEMA``.  Counters that are
members of a completeness partition must additionally be written with
integer expressions — PR 7 accumulated ``any(...)`` bools into
``early_terminated``, which saturated the count at 1 per batch while
every partition still balanced.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.engine import LintPass
from repro.analysis.schema import Finding
from repro.search.schema import TRACE_COUNTERS, TRACE_SCHEMA

ALL_TRACE_KEYS = frozenset().union(*TRACE_SCHEMA.values())

_BOOLISH_CALLS = {"any", "all", "bool"}


def _is_boolish(node: ast.AST) -> bool:
    """Whether an expression is bool-valued on its face: comparisons,
    and/or chains, True/False literals, and any()/all()/bool() calls."""
    if isinstance(node, (ast.Compare, ast.BoolOp)):
        return True
    if isinstance(node, ast.Constant) and isinstance(node.value, bool):
        return True
    if (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in _BOOLISH_CALLS
    ):
        return True
    return False


def _is_last_trace(node: ast.AST) -> bool:
    return isinstance(node, ast.Attribute) and node.attr == "last_trace"


def _const_keys(sub: ast.Subscript) -> List[str]:
    """String key(s) a subscript writes: a constant, or both arms of a
    conditional key like ``t["a" if ranked else "b"]``."""
    sl = sub.slice
    if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
        return [sl.value]
    if isinstance(sl, ast.IfExp):
        keys = []
        for arm in (sl.body, sl.orelse):
            if isinstance(arm, ast.Constant) and isinstance(arm.value, str):
                keys.append(arm.value)
        return keys
    return []


def _scopes(tree: ast.AST) -> Iterator[ast.AST]:
    yield tree  # module level
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _scope_walk(scope: ast.AST) -> Iterator[ast.AST]:
    """Walk a scope WITHOUT descending into nested function scopes (a
    name's binding to a trace block is per-function; the module-level
    sweep must not see a method's locals)."""
    stack = list(
        ast.iter_child_nodes(scope)
        if isinstance(scope, (ast.Module, ast.FunctionDef,
                              ast.AsyncFunctionDef))
        else [scope]
    )
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


class TraceSchemaPass(LintPass):
    id = "trace-schema"

    def run(self, tree: ast.AST, path: str, src: str) -> List[Finding]:
        out: List[Finding] = []
        for scope in _scopes(tree):
            out.extend(self._check_scope(scope, path))
        return out

    # -------------------------------------------------------------------
    def _check_scope(self, scope: ast.AST, path: str) -> List[Finding]:
        out: List[Finding] = []
        # block ("" = top level) each local name is bound to, discovered
        # from `X.last_trace = name` / `X.last_trace[key] = name` sinks
        bound: Dict[str, str] = {}
        if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for arg in (
                scope.args.posonlyargs + scope.args.args
                + scope.args.kwonlyargs
            ):
                if arg.arg == "trace":
                    bound["trace"] = "*"  # block unknown: union of all
        for node in _scope_walk(scope):
            if not isinstance(node, ast.Assign):
                continue
            for t in node.targets:
                if _is_last_trace(t) and isinstance(node.value, ast.Name):
                    bound[node.value.id] = ""
                elif (
                    isinstance(t, ast.Subscript)
                    and _is_last_trace(t.value)
                    and isinstance(node.value, ast.Name)
                ):
                    for key in _const_keys(t):
                        bound[node.value.id] = key
                elif _is_last_trace(node.value) and isinstance(t, ast.Name):
                    bound[t.id] = ""  # tr = self.last_trace

        def keyset(block: str):
            if block == "*":
                return ALL_TRACE_KEYS
            return TRACE_SCHEMA.get(block)

        def check_key(node: ast.AST, key: str, block: str) -> None:
            ks = keyset(block)
            if ks is not None and key not in ks:
                where = f"block {block!r}" if block not in ("", "*") else \
                    "the top level"
            else:
                return
            out.append(self.finding(
                path, node,
                f"trace key {key!r} written to {where} is not declared "
                f"in repro.search.schema.TRACE_SCHEMA",
            ))

        def check_counter(node: ast.AST, key: str, value: ast.AST) -> None:
            if key in TRACE_COUNTERS and _is_boolish(value):
                out.append(self.finding(
                    path, node,
                    f"partition counter {key!r} written with a bool-valued "
                    f"expression; use an integer count (the PR 7 "
                    f"`any(...)` accumulation bug class)",
                ))

        def check_dict_literal(d: ast.AST, block: str) -> None:
            if not isinstance(d, ast.Dict):
                return
            for k, v in zip(d.keys, d.values):
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    check_key(k, k.value, block)
                    check_counter(k, k.value, v)

        for node in _scope_walk(scope):
            value: Optional[ast.AST] = None
            targets: Tuple[ast.AST, ...] = ()
            if isinstance(node, ast.Assign):
                targets, value = tuple(node.targets), node.value
            elif isinstance(node, ast.AugAssign):
                targets, value = (node.target,), node.value
            for t in targets:
                # X.last_trace = {...} / name  (dict literal checked here,
                # name bindings were resolved in the first sweep)
                if _is_last_trace(t):
                    check_dict_literal(value, "")
                    continue
                if not isinstance(t, ast.Subscript):
                    continue
                if _is_last_trace(t.value):
                    for key in _const_keys(t):
                        check_key(t, key, "")
                        check_counter(t, key, value)
                        check_dict_literal(value, key)
                elif isinstance(t.value, ast.Name) and t.value.id in bound:
                    block = bound[t.value.id]
                    for key in _const_keys(t):
                        check_key(t, key, block)
                        check_counter(t, key, value)
            # name = {...} for a name later stored into last_trace
            if isinstance(node, ast.Assign) and isinstance(
                node.value, ast.Dict
            ):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id in bound:
                        check_dict_literal(node.value, bound[t.id])
        return out
