"""cache-tier: PostingCache tier dicts are touched only by their owner.

The three tiers (``_map`` host entries, ``_partials`` prefix+resume,
``_device`` decoded rows) share one byte budget, one eviction clock and
one invalidation path; an outside writer that pokes a tier dict skips
the charge/evict/freeze bookkeeping, and an admit of a still-writeable
array lets the caller mutate bytes other queries will later be served
(the stale-cache-admit bug class of PR 5/8).  Admission goes through
``put``/``put_partial``/``put_device`` inside the cache modules, and
every host-tier value is detached via ``_frozen``.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.allowlists import (
    CACHE_TIER_ATTRS,
    CACHE_TIER_MODULES,
    in_allowlist,
)
from repro.analysis.engine import LintPass
from repro.analysis.schema import Finding

_HOST_TIERS = ("_map", "_partials")


def _contains_frozen_call(node: ast.AST) -> bool:
    return any(
        isinstance(n, ast.Call)
        and (
            (isinstance(n.func, ast.Name) and n.func.id == "_frozen")
            or (isinstance(n.func, ast.Attribute) and n.func.attr == "_frozen")
        )
        for n in ast.walk(node)
    )


def _receiver_text(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return ""


class CacheTierPass(LintPass):
    id = "cache-tier"

    def run(self, tree: ast.AST, path: str, src: str) -> List[Finding]:
        inside = in_allowlist(path, CACHE_TIER_MODULES)
        out: List[Finding] = []
        if not inside:
            out.extend(self._check_outside(tree, path))
        out.extend(self._check_admits(tree, path, inside))
        return out

    # ------------------------------------------------- encapsulation ------
    def _check_outside(self, tree: ast.AST, path: str) -> List[Finding]:
        """Outside the cache modules, any access to a tier dict on a
        non-self base is a breach (self is exempt so unrelated classes
        may use the same private names for their own state)."""
        out: List[Finding] = []
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Attribute)
                and node.attr in CACHE_TIER_ATTRS
                and not (
                    isinstance(node.value, ast.Name)
                    and node.value.id in ("self", "cls")
                )
            ):
                out.append(self.finding(
                    path, node,
                    f"access to PostingCache tier `.{node.attr}` outside "
                    f"{', '.join(sorted(CACHE_TIER_MODULES))}; tiers share "
                    f"one budget/eviction/freeze path — go through "
                    f"get/put/drop",
                ))
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("put_partial", "put_device")
            ):
                out.append(self.finding(
                    path, node,
                    f"`{node.func.attr}(...)` called outside the cache "
                    f"modules; partial/device admission is the reader's "
                    f"settle/refresh path, not a public API",
                ))
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "put"
                and "cache" in _receiver_text(node.func.value).lower()
            ):
                out.append(self.finding(
                    path, node,
                    "cache `.put(...)` outside the cache modules; only the "
                    "reader admits (admit-time generation re-checks live "
                    "there)",
                ))
        return out

    # ------------------------------------------------ frozen admission ----
    def _check_admits(
        self, tree: ast.AST, path: str, inside: bool
    ) -> List[Finding]:
        """Host-tier assignments must store ``_frozen(...)`` values — the
        value expression contains the call, or the assigned name's most
        recent binding does."""
        if not inside:
            return []
        out: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Assign):
                continue
            for t in node.targets:
                if not (
                    isinstance(t, ast.Subscript)
                    and isinstance(t.value, ast.Attribute)
                    and t.value.attr in _HOST_TIERS
                ):
                    continue
                if _contains_frozen_call(node.value):
                    continue
                name = (
                    node.value.id
                    if isinstance(node.value, ast.Name)
                    else None
                )
                if name and self._name_frozen_before(tree, name, node.lineno):
                    continue
                out.append(self.finding(
                    path, t,
                    f"tier `.{t.value.attr}` stores a value not detached "
                    f"via `_frozen(...)`; a writeable admit lets the "
                    f"caller mutate cached bytes",
                ))
        return out

    @staticmethod
    def _name_frozen_before(
        tree: ast.AST, name: str, line: int
    ) -> bool:
        best: Optional[ast.Assign] = None
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Assign)
                and node.lineno < line
                and any(
                    isinstance(t, ast.Name) and t.id == name
                    for t in node.targets
                )
            ):
                if best is None or node.lineno > best.lineno:
                    best = node
        return best is not None and _contains_frozen_call(best.value)
