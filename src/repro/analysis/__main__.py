"""CLI: ``python -m repro.analysis [paths...] [--json]``.

Exit status 0 = clean, 1 = findings, 2 = usage error.  Text output is
one finding per line in the stable ``file:line pass-id message`` form.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.analysis.engine import lint_paths
from repro.analysis.passes import all_passes
from repro.analysis.schema import render_json, render_text


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="invariant linter (charge / trace / generation / "
        "cache / kernel passes)",
    )
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as a JSON array")
    ap.add_argument("--list-passes", action="store_true",
                    help="print registered pass ids and exit")
    ns = ap.parse_args(argv)
    if ns.list_passes:
        for p in all_passes():
            print(p.id)
        return 0
    findings = lint_paths(ns.paths or ["src"])
    if findings:
        print(render_json(findings) if ns.json else render_text(findings))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
