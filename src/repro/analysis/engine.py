"""Shared visitor engine for the ``repro.analysis`` invariant passes.

Each pass is a small class with a stable ``id`` and a ``run(tree, path,
src)`` method returning :class:`~repro.analysis.schema.Finding` records.
The engine parses every file once, hands the same AST to every pass,
and applies pragma suppression afterwards so passes never need to know
about escape hatches.

Pragma form, on (or immediately above) the offending line::

    dev.read_small(n)  # repro-lint: allow(charge-accounting) why it's ok

``allow(*)`` suppresses every pass on that line.  Pragmas are *scoped*:
an ``allow(charge-accounting)`` does not silence a generation finding on
the same line, so escape hatches stay auditable per invariant.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.schema import Finding

_PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*allow\(([^)]*)\)")


class LintPass:
    """Base class: subclasses set ``id`` and implement :meth:`run`."""

    id: str = ""

    def run(self, tree: ast.AST, path: str, src: str) -> List[Finding]:
        raise NotImplementedError

    # -- shared helpers -------------------------------------------------
    def finding(self, path: str, node: ast.AST, message: str) -> Finding:
        return Finding(
            file=path,
            line=getattr(node, "lineno", 0),
            pass_id=self.id,
            message=message,
        )


def parse_pragmas(src: str) -> Dict[int, Set[str]]:
    """Map 1-based line number -> set of allowed pass ids ("*" = all)."""
    pragmas: Dict[int, Set[str]] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m:
            ids = {p.strip() for p in m.group(1).split(",") if p.strip()}
            pragmas[i] = ids
    return pragmas


def _suppressed(f: Finding, pragmas: Dict[int, Set[str]]) -> bool:
    # a pragma covers its own line and the line below it, so long calls
    # can carry the pragma on the opening line while the finding anchors
    # to a continuation (and vice versa)
    for line in (f.line, f.line - 1):
        ids = pragmas.get(line)
        if ids and ("*" in ids or f.pass_id in ids):
            return True
    return False


def lint_source(
    src: str, path: str, passes: Sequence[LintPass]
) -> List[Finding]:
    """Lint one already-read source string (testing seam: fixtures lint
    without touching the filesystem)."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as exc:
        return [Finding(path, exc.lineno or 0, "parse-error", str(exc.msg))]
    pragmas = parse_pragmas(src)
    out: List[Finding] = []
    for p in passes:
        for f in p.run(tree, path, src):
            if not _suppressed(f, pragmas):
                out.append(f)
    return out


def lint_file(path: str, passes: Sequence[LintPass]) -> List[Finding]:
    src = Path(path).read_text(encoding="utf-8")
    return lint_source(src, path, passes)


def iter_python_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        pp = Path(p)
        if pp.is_dir():
            out.extend(str(f) for f in sorted(pp.rglob("*.py")))
        elif pp.suffix == ".py" and pp.exists():
            out.append(str(pp))
    return out


def lint_paths(
    paths: Iterable[str], passes: Optional[Sequence[LintPass]] = None
) -> List[Finding]:
    if passes is None:
        from repro.analysis.passes import all_passes

        passes = all_passes()
    findings: List[Finding] = []
    for f in iter_python_files(paths):
        findings.extend(lint_file(f, passes))
    return sorted(findings)
