"""Module allowlists the invariant passes key off — data, not code.

A new chokepoint (say, a PR 11 multi-process fetch worker that charges
its own device reads) opts in by adding its module path HERE, in review,
rather than by editing pass logic.  Paths are repo-relative with forward
slashes; membership is tested by suffix so the linter works from any
checkout root.
"""

from __future__ import annotations

from typing import FrozenSet

# Modules allowed to call BlockDevice read/write methods or mutate
# IOStats directly.  Everything else must go through StreamManager /
# IndexReader / the store so every byte lands in one charge ledger.
CHARGE_CHOKEPOINT_MODULES: FrozenSet[str] = frozenset({
    "repro/core/io_sim.py",          # the device simulator itself
    "repro/core/stream.py",          # StreamManager: cluster/packed I/O
    "repro/core/inverted_index.py",  # dictionary-group + entry charges
})

# Method names on the simulated devices whose call sites are charged
# I/O.  Kept with the allowlist (same review surface) because adding a
# device method and adding a chokepoint tend to happen together.
DEVICE_METHODS: FrozenSet[str] = frozenset({
    "read_clusters", "write_clusters",
    "read_small", "write_small",
    "read_sequential", "write_sequential",
})

# Fields of IOStats; assignment/augassign to these on a non-self base
# outside the chokepoints is a charge bypass.
IOSTATS_FIELDS: FrozenSet[str] = frozenset({
    "read_ops", "write_ops", "read_bytes", "write_bytes",
})

# Modules allowed to touch PostingCache internal tier dicts and to
# admit entries (put/put_partial/put_device).  reader.py owns the cache;
# pool.py settles pooled cursors into the partial tier.
CACHE_TIER_MODULES: FrozenSet[str] = frozenset({
    "repro/search/reader.py",
    "repro/search/pool.py",
})

# PostingCache internal tier attributes (host map, partial-prefix tier,
# device-resident tier).
CACHE_TIER_ATTRS: FrozenSet[str] = frozenset({
    "_map", "_partials", "_device",
})

# Modules allowed to write ``.generation`` — InvertedIndex publishes it,
# restore_generation replays it from the manifest.
GENERATION_WRITER_MODULES: FrozenSet[str] = frozenset({
    "repro/core/inverted_index.py",
})

# Module prefixes whose every function is held to kernel purity even
# without a jit decorator (trailing slash = package).
KERNEL_MODULE_PREFIXES: FrozenSet[str] = frozenset({
    "repro/kernels/",
})


def module_path(path: str) -> str:
    """Normalise ``path`` to the repo-relative form the allowlists use
    (forward slashes, ``src/``-relative when under ``src/``)."""
    p = path.replace("\\", "/")
    if "/src/" in p:
        p = p.split("/src/", 1)[1]
    elif p.startswith("src/"):
        p = p[len("src/"):]
    return p


def in_allowlist(path: str, allowlist: FrozenSet[str]) -> bool:
    p = module_path(path)
    return any(p == m or p.endswith("/" + m) for m in allowlist)


def in_kernel_scope(path: str) -> bool:
    p = module_path(path)
    return any(
        p.startswith(pref) or ("/" + pref) in p
        for pref in KERNEL_MODULE_PREFIXES
    )
