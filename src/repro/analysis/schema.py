"""Finding model and output formats for ``repro.analysis``.

A finding is one violation at one source line.  The text format is the
stable machine interface (``file:line pass-id message``, one per line);
``--json`` emits the same records as a JSON array for tooling that wants
structure without parsing.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One lint violation, ordered (file, line, pass) for stable output."""

    file: str
    line: int
    pass_id: str
    message: str

    def render(self) -> str:
        return f"{self.file}:{self.line} {self.pass_id} {self.message}"

    def to_dict(self) -> Dict[str, object]:
        return {
            "file": self.file,
            "line": self.line,
            "pass": self.pass_id,
            "message": self.message,
        }


def render_text(findings: List[Finding]) -> str:
    return "\n".join(f.render() for f in sorted(findings))


def render_json(findings: List[Finding]) -> str:
    return json.dumps(
        [f.to_dict() for f in sorted(findings)], indent=2, sort_keys=True
    )
