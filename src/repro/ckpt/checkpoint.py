"""Distributed checkpoint/restore with elastic resume.

Layout (one directory per step):
    step_000123/
      manifest.json   — leaf paths, shapes, dtypes, content hashes, step,
                        data-cursor, mesh shape at save time
      <leaf>.npy      — one array per pytree leaf (host-gathered)

Properties required at 1000-node scale and tested here:
  * atomic publish (write to tmp dir, rename) — a crashed save never
    corrupts the latest checkpoint,
  * content hashes verified on load (bit-rot / truncation detection),
  * elastic restore: arrays are loaded on host and re-sharded through
    ``jax.device_put`` against the *current* mesh, which may have a
    different shape than the mesh at save time (N->M reshard),
  * resume cursor: (step, data_cursor) travel with the checkpoint so a
    restarted job continues from the exact batch.

In a real multi-host deployment each host writes only its owned shards;
here host-gather is exact (single process) and the manifest format is the
same.  Async saving runs the host-gather + write on a worker thread.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out[name] = np.asarray(leaf)
    return out


def save_checkpoint(
    directory: str,
    step: int,
    params: Any,
    opt_state: Any = None,
    data_cursor: int = 0,
    extra: Optional[Dict] = None,
) -> str:
    """Atomic checkpoint write; returns the published path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory)
    manifest = {
        "step": int(step),
        "data_cursor": int(data_cursor),
        "extra": extra or {},
        "leaves": {},
    }
    for prefix, tree in (("params", params), ("opt", opt_state)):
        if tree is None:
            continue
        for name, arr in _flatten(tree).items():
            fname = f"{prefix}__{name.replace('/', '__')}.npy"
            np.save(os.path.join(tmp, fname), arr)
            with open(os.path.join(tmp, fname), "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()[:16]
            manifest["leaves"][f"{prefix}/{name}"] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha": digest,
            }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_")
    ]
    return max(steps) if steps else None


def load_checkpoint(
    directory: str,
    params_template: Any,
    opt_template: Any = None,
    step: Optional[int] = None,
    shardings: Any = None,
    opt_shardings: Any = None,
) -> Tuple[Any, Any, int, int]:
    """Restore (params, opt_state, step, data_cursor).

    ``shardings`` (pytree of NamedSharding matching params) enables elastic
    restore onto any current mesh.
    """
    step = step if step is not None else latest_step(directory)
    assert step is not None, f"no checkpoint found in {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    def restore(prefix, template, shard_tree):
        if template is None:
            return None
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shards_flat = (
            jax.tree_util.tree_leaves(shard_tree) if shard_tree is not None
            else [None] * len(flat)
        )
        leaves = []
        for (pth, leaf), shard in zip(flat, shards_flat):
            name = "/".join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in pth
            )
            meta = manifest["leaves"][f"{prefix}/{name}"]
            fpath = os.path.join(path, meta["file"])
            with open(fpath, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()[:16]
            assert digest == meta["sha"], f"hash mismatch for {name}"
            arr = np.load(fpath)
            assert list(arr.shape) == meta["shape"]
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = restore("params", params_template, shardings)
    opt = restore("opt", opt_template, opt_shardings)
    return params, opt, manifest["step"], manifest["data_cursor"]


class CheckpointManager:
    """Keeps the last N checkpoints; optional async (threaded) saves."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, params: Any, opt_state: Any = None,
             data_cursor: int = 0) -> None:
        # snapshot to host before handing to the writer thread
        host_params = jax.tree_util.tree_map(np.asarray, params)
        host_opt = (
            jax.tree_util.tree_map(np.asarray, opt_state)
            if opt_state is not None else None
        )

        def work():
            try:
                save_checkpoint(
                    self.directory, step, host_params, host_opt, data_cursor
                )
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error:
                err, self._error = self._error, None
                raise err

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )
