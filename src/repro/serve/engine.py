"""Batched serving engine over the paged KV substrate.

Continuous batching: requests join a fixed-slot batch as slots free up;
each engine step decodes one token for every active slot.  The
:class:`~repro.core.paged_kv.PagedKVManager` tracks page placement with
the paper's CH/S/SR semantics — its gather-depth bound is what keeps the
per-step read pattern bounded (the serving twin of bounded search I/O).

The device cache uses per-sequence slot layout (S-segment contiguity,
DESIGN.md section 2); the manager's block tables drive the Pallas
paged_attention kernel on TPU deployments.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.paged_kv import PagedKVManager
from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    make_cache,
    prefill,
)


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray          # (S,) token ids
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        cfg: TransformerConfig,
        params,
        batch_slots: int = 4,
        s_max: int = 256,
        page_size: int = 16,
        chain_limit: int = 9,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.s_max = s_max
        self.cache = make_cache(cfg, batch_slots, s_max)
        self.kv_mgr = PagedKVManager(
            n_pages=batch_slots * (s_max // page_size) * 2,
            page_size=page_size,
            chain_limit=chain_limit,
        )
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.steps = 0
        self._decode = jax.jit(
            lambda p, t, c: decode_step(cfg, p, t, c)
        )

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            logits, cache1 = prefill(
                self.cfg, self.params, jnp.asarray(req.prompt[None, :])
            )
            S = req.prompt.shape[0]
            self.cache["k"] = self.cache["k"].at[:, slot, :S].set(
                cache1["k"][:, 0]
            )
            self.cache["v"] = self.cache["v"].at[:, slot, :S].set(
                cache1["v"][:, 0]
            )
            self.cache["len"] = self.cache["len"].at[slot].set(S)
            first = int(jnp.argmax(logits[0]))
            req.out_tokens.append(first)
            self.slot_req[slot] = req
            self.kv_mgr.new_sequence(req.req_id)
            self.kv_mgr.append_tokens(req.req_id, S)

    # --------------------------------------------------------------- step --
    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.slots,), np.int32)
        for i in active:
            tokens[i] = self.slot_req[i].out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            req = self.slot_req[i]
            req.out_tokens.append(int(nxt[i]))
            self.kv_mgr.append_tokens(req.req_id, 1)
            hit_limit = len(req.out_tokens) >= req.max_new_tokens
            full = int(self.cache["len"][i]) + 1 >= self.s_max
            if hit_limit or full:
                req.done = True
                self.kv_mgr.free_sequence(req.req_id)
                self.slot_req[i] = None
                self.cache["len"] = self.cache["len"].at[i].set(0)
        self.steps += 1
        return len(active)

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        done: List[Request] = []
        while (self.queue or any(self.slot_req)) and self.steps < max_steps:
            before = [r for r in self.slot_req]
            self.step()
            for r in before:
                if r is not None and r.done:
                    done.append(r)
        return done

    def stats(self) -> Dict:
        return {
            "steps": self.steps,
            "kv": dataclasses.asdict(self.kv_mgr.stats),
            "fragmentation": self.kv_mgr.fragmentation(),
        }
