"""Batched proximity-search execution: plan → scatter-fetch → join → gather.

``SearchService`` is the read-side query processor, restructured as four
explicit stages so the same code path serves an unsharded
:class:`~repro.core.text_index.TextIndexSet` (the 1-shard degenerate
case) and a :class:`~repro.core.sharded_set.ShardedTextIndexSet`:

  1. **plan** — the batch is planned ONCE (:mod:`repro.search.plan`);
     the lexicon/planner layer is shard-agnostic because document-hash
     sharding never changes which (index, key) lookups a query needs.
  2. **scatter-fetch** — the plan's unique lookups are walked in
     (index, dictionary-group) waves so group-mates amortize dictionary
     visits; every lookup is scattered to all shards of the reader.  A
     single-worker *prefetch pipeline* overlaps the NEXT wave's device
     fetches with the CURRENT wave's host-side join work: as soon as a
     query's last lookup lands, its phrase-chain / single-lookup result
     is finalized on the main thread while the worker is already reading
     the next (index, group) wave.  (One worker means exactly one thread
     ever touches the readers and the shared posting cache.)
  3. **join** — ordinary-route window joins from ALL (query, shard) jobs
     are executed together: with the ``jax`` backend they land in the
     same power-of-two ``(B, N, M)`` buckets, so sharding *increases*
     bucket occupancy (bigger launches) instead of multiplying kernel
     dispatches.  ``pallas`` routes each join through the TPU intersect
     kernel's doc-level prefilter; ``numpy`` is the exact host oracle.
  4. **gather** — per-shard results concatenate losslessly: shard doc
     sets are disjoint and per-shard arrays are (doc, pos)-ordered
     subsequences, so a stable merge on the doc column reconstructs the
     unsharded result element-wise.

All backends and all shard counts return results element-wise identical
to the unsharded numpy oracle.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.core.sharded_set import merge_shard_chunks, merge_shard_postings
from repro.search.join import (
    JOIN_BACKENDS,
    _jax_dtype_for,
    _pow2,
    batched_window_mask,
    numpy_phrase_join,
    numpy_window_join,
    pack_keys,
)
from repro.search.plan import (
    ROUTE_MULTI,
    ROUTE_ORDINARY,
    ROUTE_STOPSEQ,
    ROUTE_WV,
    KeyLookup,
    MultiKeySpec,
    Query,
    QueryPlan,
    QueryResult,
    plan_batch,
)
from repro.core.inverted_index import PostingCursor
from repro.kernels.posting_decode.ops import DeviceDecoder
from repro.search.pool import ChunkPool
from repro.search.reader import IndexSetReader, ShardedIndexSetReader
from repro.search.schema import validate_trace
from repro.search.replica import ReplicaSetReader
from repro.search.scoring import (
    doc_counts,
    head_order,
    max_doc_run,
    score_docs,
    score_docs_jax,
)

_EMPTY = np.zeros((0, 2), dtype=np.int64)
_INF = float("inf")


class TraceIncompleteError(RuntimeError):
    """The executor's trace failed the completeness invariant: a planned
    fetch wave / lookup / cursor chunk is neither recorded as executed nor
    as explicitly skipped.  Raised by
    :meth:`SearchService.check_trace_complete` — the guard that keeps the
    route-census/trace observability honest (an optimization that silently
    drops accounting would otherwise look like saved I/O)."""


class SnapshotViolationError(RuntimeError):
    """A writer advanced some shard's generation while a batch was
    executing against its pinned snapshot.  Every batch runs against the
    per-shard generation vector recorded at plan time
    (``last_trace['snapshot']``); a mid-batch update would mix posting
    lists from two collection states inside one result set, so the
    executor re-reads the vector after the gather stage and refuses to
    return torn results."""

QueryLike = Union[Query, Sequence[int]]

# per-shard posting lists of one fetched (index, key), in shard order
ShardPosts = List[np.ndarray]


def _as_query(q: QueryLike) -> Query:
    if isinstance(q, Query):
        return q
    return Query(tuple(int(w) for w in q))


class SearchService:
    """Planned, batched query execution over a (possibly sharded) index set.

    ``source`` is a ``TextIndexSet``/``ShardedTextIndexSet`` (a reader is
    built over it) or an existing ``IndexSetReader``/
    ``ShardedIndexSetReader``.  ``backend`` is ``"numpy"`` | ``"jax"`` |
    ``"pallas"`` or any callable ``join(a, b, window) -> rows of a``
    (executed per (query, shard) pair).  ``prefetch=False`` disables the
    pipelined fetch worker (pure in-order fetching — same results, used
    by the equivalence tests as the sequential oracle).

    ``share_chunks`` pools the streaming stage's physical posting drains
    across the queries of one batch: N queries over the same hot
    (shard, index, key) read each chunk once and replay it N-1 times
    (``last_trace['topk']`` ledgers replays as ``chunks_shared``).
    ``device_decode`` swaps the OWN-stream varint decoder for the
    device-backed one and pins fully-drained hot lists as device
    buffers in the posting cache; defaults to on for the jax/pallas
    backends, off for numpy/callable.  Both knobs change I/O and
    residency only — results stay element-wise identical.
    """

    def __init__(
        self,
        source,
        window: int = 3,
        backend: Union[str, Callable] = "numpy",
        cache_bytes: int = 8 << 20,
        use_multi: bool = True,
        prefetch: bool = True,
        share_chunks: bool = True,
        device_decode: Optional[bool] = None,
    ):
        if isinstance(
            source, (IndexSetReader, ShardedIndexSetReader, ReplicaSetReader)
        ):
            self.reader = source
        else:
            self.reader = source.reader(cache_bytes=cache_bytes)
        # replica-fabric failover counter at the last trace cut, so
        # last_trace['replicas'] can report the PER-BATCH delta
        self._failovers_seen = 0
        self.index_set = self.reader.index_set
        self.lexicon = self.reader.lexicon
        self.window = min(window, self.index_set.cfg.max_distance)
        self.prefetch = prefetch
        # observability for the pipeline stage: wave/overlap counters and
        # per-shard fetch seconds of the LAST search_batch call
        self.last_trace: Dict[str, object] = {}
        # multi-component route: available when the set built the multi
        # index and the caller did not opt out (use_multi=False forces
        # phrase queries down the ordinary path — the benchmark baseline)
        self.multi: Optional[MultiKeySpec] = None
        if use_multi and "multi" in self.index_set.indexes:
            mi = self.index_set.indexes["multi"]
            self.multi = MultiKeySpec(k=mi.k, pack=mi.pack,
                                      cover=mi.cover_keys)
        if callable(backend):
            self.backend: Union[str, Callable] = backend
        elif backend in JOIN_BACKENDS:
            self.backend = backend
        else:
            raise ValueError(
                f"unknown backend {backend!r}; expected one of "
                f"{sorted(JOIN_BACKENDS)} or a callable"
            )
        self.share_chunks = bool(share_chunks)
        if device_decode is None:
            device_decode = self.backend in ("jax", "pallas")
        self.device_decode = bool(device_decode)
        if self.device_decode:
            dec_backend = (
                self.backend if self.backend in ("jax", "pallas") else "jax"
            )
            self._make_decoder: Optional[Callable[[], DeviceDecoder]] = (
                lambda: DeviceDecoder(backend=dec_backend)
            )
        else:
            self._make_decoder = None

    @property
    def n_shards(self) -> int:
        return self.reader.n_shards

    # ------------------------------------------------------------ planning --
    def plan(self, queries: Sequence[QueryLike]) -> QueryPlan:
        # per-query windows obey the same max_distance clamp as the default:
        # the stopseq/wv indexes are precomputed at max_distance, so a wider
        # ordinary-route join would give route-dependent semantics
        md = self.index_set.cfg.max_distance
        qs = [
            dataclasses.replace(q, window=min(q.window, md))
            if q.window is not None and q.window > md else q
            for q in map(_as_query, queries)
        ]
        return plan_batch(qs, self.lexicon, self.reader.group_of, self.window,
                          multi=self.multi, max_distance=md)

    # ----------------------------------------------------------- execution --
    def search(
        self,
        words: Sequence[int],
        window: Optional[int] = None,
        phrase: bool = False,
        top_k: Optional[int] = None,
        rank: Optional[str] = None,
    ) -> QueryResult:
        q = Query(tuple(int(w) for w in words), window, phrase=phrase,
                  top_k=top_k, rank=rank)
        return self.search_batch([q])[0]

    def search_batch(self, queries: Sequence[QueryLike]) -> List[QueryResult]:
        # pin the serving snapshot: apply any pending (targeted) cache
        # invalidations NOW, then record the per-shard generation vector
        # the whole batch executes against — a lookup mid-batch can never
        # observe a different collection state than the plan did
        self.reader.refresh()
        snapshot = list(self.reader.generation_vector())
        plan = self.plan(queries)                               # stage 1
        results: List[Optional[QueryResult]] = [None] * len(plan.queries)
        ordinary: List[Tuple[int, List[ShardPosts]]] = []
        posts: Dict[Tuple[str, int], ShardPosts] = {}

        # best-k queries take the streaming (lazy cursor) stage; their
        # lookups are deferred out of the batch scatter-fetch waves unless
        # a batch query also needs the same (index, key)
        streaming = [i for i, pq in enumerate(plan.queries)
                     if pq.top_k is not None]
        batch_idents = {
            (lk.index, lk.key)
            for pq in plan.queries if pq.top_k is None
            for lk in pq.lookups
        }

        # countdown of unlanded lookups per batch query, so each query
        # finalizes the moment its last wave lands (overlapping the next
        # fetch wave); streaming queries never enter the countdown
        pending = [
            len({(lk.index, lk.key) for lk in pq.lookups})
            if pq.top_k is None else -1
            for pq in plan.queries
        ]
        waiting: Dict[Tuple[str, int], List[int]] = {}
        for i, pq in enumerate(plan.queries):
            if pq.top_k is not None:
                continue
            for lk in pq.lookups:
                waiting.setdefault((lk.index, lk.key), [])
                if i not in waiting[(lk.index, lk.key)]:
                    waiting[(lk.index, lk.key)].append(i)

        def on_landed(idents: List[Tuple[str, int]]) -> int:
            done = 0
            for ident in idents:
                for qi in waiting.get(ident, ()):
                    pending[qi] -= 1
                    if pending[qi] == 0:
                        self._finalize(plan, qi, posts, results, ordinary)
                        done += 1
            return done

        self._scatter_fetch(plan, posts, on_landed, batch_idents)  # stage 2
        self.last_trace["snapshot"] = snapshot
        self._execute_ordinary(plan, ordinary, results)         # stages 3+4
        self._execute_streaming(plan, streaming, results, posts)  # top-k stage
        now = list(self.reader.generation_vector())
        if now != snapshot:
            raise SnapshotViolationError(
                f"shard generations moved {snapshot} -> {now} while the "
                f"batch executed against its pinned snapshot"
            )
        if getattr(self.reader, "is_replica_fabric", False):
            rt = self.reader.route_trace()
            rt["failovers_batch"] = rt["failovers"] - self._failovers_seen
            self._failovers_seen = rt["failovers"]
            self.last_trace["replicas"] = rt
        self.check_trace_complete(plan)
        # serving-health counters: cumulative posting-cache stats (the
        # full_drops count is THE regression signal for targeted
        # invalidation — it moves only when a reader fell back to a
        # whole-namespace sweep) and the substrate's background-compaction
        # totals, so traces tie a batch to the maintenance that preceded it
        cs = self.reader.cache_stats
        if cs is not None:
            self.last_trace["cache"] = {
                "hits": cs.hits,
                "misses": cs.misses,
                "evictions": cs.evictions,
                "invalidations": cs.invalidations,
                "full_drops": cs.full_drops,
                "bytes_used": cs.bytes_used,
                "pool_hits": cs.pool_hits,
                "device_hits": cs.device_hits,
                "partial_admits": cs.partial_admits,
            }
        comp = getattr(self.index_set, "compaction_stats", None)
        if comp is not None:
            self.last_trace["compactions"] = comp()
        return results

    # --------------------------------------------- stage 2: scatter-fetch --
    def _scatter_fetch(
        self,
        plan: QueryPlan,
        posts: Dict[Tuple[str, int], ShardPosts],
        on_landed: Callable[[List[Tuple[str, int]]], int],
        batch_idents: Optional[set] = None,
    ) -> None:
        """Fetch each unique (index, key) once from every shard, walking
        (index, group) waves in order so lookups of the same dictionary
        group run back to back.  With ``prefetch`` on, wave ``i+1``'s
        device reads run on a worker thread while wave ``i``'s completed
        queries finalize (host joins) on this thread.

        Lookups needed ONLY by best-k queries are *deferred* to the
        streaming stage (recorded, never silently dropped): a wave whose
        lookups all defer is an explicitly ``skipped_wave``.  The trace
        invariant ``waves == executed_waves + skipped_waves`` and
        ``lookups_planned == lookups_fetched + lookups_deferred`` is
        enforced by :meth:`check_trace_complete` after every batch."""
        S = self.n_shards
        shard_s = [0.0] * S
        trace = {"waves": 0, "executed_waves": 0, "skipped_waves": 0,
                 "lookups_planned": plan.n_unique_lookups,
                 "lookups_fetched": 0, "lookups_deferred": 0,
                 "prefetched_waves": 0,
                 "overlapped_finalizes": 0, "shard_fetch_s": shard_s}
        waves = []
        for gkey in sorted(plan.grouped):
            wave = plan.grouped[gkey]
            if batch_idents is not None:
                keep = [lk for lk in wave
                        if (lk.index, lk.key) in batch_idents]
            else:
                keep = wave
            trace["waves"] += 1
            trace["lookups_deferred"] += len(wave) - len(keep)
            if not keep:
                trace["skipped_waves"] += 1
                continue
            trace["executed_waves"] += 1
            trace["lookups_fetched"] += len(keep)
            waves.append(keep)

        # replica fabrics pin one replica per shard per fetch wave: the
        # in-flight-wave counter is the load signal routing balances on
        begin_wave = getattr(self.reader, "begin_wave", None)
        end_wave = getattr(self.reader, "end_wave", None)

        def fetch_wave(wave: List[KeyLookup]) -> List[Tuple[Tuple[str, int], ShardPosts]]:
            out = []
            if begin_wave is not None:
                begin_wave()
            try:
                for lk in wave:
                    per_shard: ShardPosts = []
                    for s in range(S):
                        t0 = time.perf_counter()
                        per_shard.append(
                            self.reader.lookup_shard(s, lk.index, lk.key)
                        )
                        shard_s[s] += time.perf_counter() - t0
                    out.append(((lk.index, lk.key), per_shard))
            finally:
                if end_wave is not None:
                    end_wave()
            return out

        def land(fetched, overlapping: bool) -> None:
            for ident, per_shard in fetched:
                posts[ident] = per_shard
            n = on_landed([ident for ident, _ in fetched])
            if overlapping:
                trace["overlapped_finalizes"] += n

        if not self.prefetch or len(waves) <= 1:
            for wave in waves:
                land(fetch_wave(wave), overlapping=False)
        else:
            # exactly ONE worker: the readers and the shared posting cache
            # are only ever touched from the worker thread during the
            # pipeline, while this thread runs the finalize joins
            with ThreadPoolExecutor(max_workers=1) as pool:
                fut = pool.submit(fetch_wave, waves[0])
                for i in range(len(waves)):
                    fetched = fut.result()
                    overlapping = i + 1 < len(waves)
                    if overlapping:
                        fut = pool.submit(fetch_wave, waves[i + 1])
                        trace["prefetched_waves"] += 1
                    land(fetched, overlapping)
        self.last_trace = trace

    # --------------------------------------- per-query assembly + gather --
    def _finalize(
        self,
        plan: QueryPlan,
        qi: int,
        posts: Dict[Tuple[str, int], ShardPosts],
        results: List[Optional[QueryResult]],
        ordinary: List[Tuple[int, List[ShardPosts]]],
    ) -> None:
        """All lookups of query ``qi`` have landed: finalize every route
        except the ordinary window join, which is deferred so all
        (query, shard) jobs share the stage-3 buckets."""
        pq = plan.queries[qi]
        fetched = [posts[(lk.index, lk.key)] for lk in pq.lookups]
        log = [(lk.index, lk.key) for lk in pq.lookups]
        scanned = sum(a.shape[0] for per_shard in fetched for a in per_shard)
        if pq.route == ROUTE_ORDINARY and not pq.query.phrase:
            ordinary.append((qi, fetched))
            results[qi] = QueryResult(_EMPTY[:, 0], _EMPTY, log, scanned,
                                      pq.route)
        elif pq.route == ROUTE_MULTI or pq.route == ROUTE_ORDINARY:
            # phrase reconstruction: lookup j's records must sit at
            # start+j (multi: k-gram at word offset j; ordinary phrase:
            # word j itself) — staged exact host joins, chained per shard
            # (disjoint doc sets) and gathered by stable doc merge
            acc = merge_shard_postings([
                self._phrase_chain([f[s] for f in fetched])
                for s in range(self.n_shards)
            ])
            docs, counts = np.unique(acc[:, 0], return_counts=True)
            results[qi] = QueryResult(docs, acc, log, scanned, pq.route,
                                      counts)
        else:
            p = merge_shard_postings(fetched[0])
            docs, counts = np.unique(p[:, 0], return_counts=True)
            results[qi] = QueryResult(docs, p, log, scanned, pq.route,
                                      counts)

    @staticmethod
    def _phrase_chain(fetched: List[np.ndarray]) -> np.ndarray:
        acc = fetched[0]
        for dist, nxt in enumerate(fetched[1:], start=1):
            acc = numpy_phrase_join(acc, nxt, dist)
        return acc

    # ---------------------- stage 3: bucketed window joins, stage 4: gather --
    def _execute_ordinary(
        self,
        plan: QueryPlan,
        jobs: List[Tuple[int, List[ShardPosts]]],
        results: List[Optional[QueryResult]],
    ) -> None:
        # state per (query, shard) job: accumulator + lists still to join.
        # Every shard of every query joins in the same rounds, so one jax
        # bucket holds jobs from the whole batch AND all shards.
        S = self.n_shards
        accs: Dict[Tuple[int, int], np.ndarray] = {}
        rest: Dict[Tuple[int, int], List[np.ndarray]] = {}
        for qi, fetched in jobs:
            for s in range(S):
                accs[(qi, s)] = fetched[0][s]
                rest[(qi, s)] = [f[s] for f in fetched[1:]]
        while any(rest.values()):
            round_ids = [k for k in accs if rest[k]]
            pairs = [
                (accs[k], rest[k].pop(0), plan.queries[k[0]].window)
                for k in round_ids
            ]
            for k, joined in zip(round_ids, self._join_many(pairs)):
                accs[k] = joined
        for qi, _ in jobs:
            acc = merge_shard_postings([accs[(qi, s)] for s in range(S)])
            r = results[qi]
            docs, counts = np.unique(acc[:, 0], return_counts=True)
            results[qi] = QueryResult(
                docs, acc, r.lookups, r.postings_scanned, r.route, counts,
            )

    def _join_many(
        self, pairs: List[Tuple[np.ndarray, np.ndarray, int]]
    ) -> List[np.ndarray]:
        if self.backend == "jax":
            return self._join_many_jax(pairs)
        join = self.backend if callable(self.backend) else JOIN_BACKENDS[self.backend]
        return [join(a, b, w) for a, b, w in pairs]

    def _join_many_jax(
        self, pairs: List[Tuple[np.ndarray, np.ndarray, int]]
    ) -> List[np.ndarray]:
        """Bucket join jobs by padded power-of-two shape; one vmapped
        kernel launch per bucket."""
        out: List[Optional[np.ndarray]] = [None] * len(pairs)
        buckets: Dict[Tuple[int, int, str], List] = {}
        for idx, (a, b, w) in enumerate(pairs):
            if a.size == 0 or b.size == 0:
                out[idx] = _EMPTY
                continue
            akey, bkey, _ = pack_keys(a, b, w)
            dtype = _jax_dtype_for(int(max(akey[-1], bkey[-1])), w)
            if dtype is None:
                # packed keys exceed the device integer width: exact host join
                out[idx] = numpy_window_join(a, b, w)
                continue
            shape = (_pow2(akey.shape[0]), _pow2(bkey.shape[0]),
                     np.dtype(dtype).name)
            buckets.setdefault(shape, []).append((idx, a, akey, bkey, w))
        for (n, m, dtname), jobs in buckets.items():
            dtype = np.dtype(dtname)
            big = np.iinfo(dtype).max
            nb = _pow2(len(jobs))
            ak = np.full((nb, n), big - 1, dtype)
            bk = np.full((nb, m), big, dtype)
            ws = np.zeros((nb,), dtype)
            for r, (idx, a, akey, bkey, w) in enumerate(jobs):
                # pad a below the overflow line for this row's window; pad b
                # above every real key so padding can never witness a hit
                ak[r, : akey.shape[0]] = akey
                ak[r, akey.shape[0]:] = big - w - 1
                bk[r, : bkey.shape[0]] = bkey
                ws[r] = w
            mask = np.asarray(
                batched_window_mask(jnp.asarray(ak), jnp.asarray(bk),
                                    jnp.asarray(ws))
            )
            for r, (idx, a, _akey, _bkey, _w) in enumerate(jobs):
                out[idx] = a[mask[r, : a.shape[0]]]
        return out

    # ------------------------------- streaming top-k stage (lazy cursors) --
    def _execute_streaming(
        self,
        plan: QueryPlan,
        streaming: List[int],
        results: List[Optional[QueryResult]],
        posts: Optional[Dict[Tuple[str, int], ShardPosts]] = None,
    ) -> None:
        """Serve every best-k query through lazy cursors, aggregating the
        chunks-fetched/skipped and bytes-saved observability into
        ``last_trace['topk']``.  ``posts`` carries the batch stage's
        already-fetched lookups: a key shared with a batch query streams
        from those rows at zero extra device I/O instead of re-reading.

        With ``share_chunks`` a batch-lifetime :class:`ChunkPool`
        deduplicates the physical drains: queries hitting the same
        (shard, index, key) replay pooled chunks (``chunks_shared``)
        instead of re-fetching.  After the whole batch, every physical
        cursor that early-terminated is *settled* — its decoded prefix
        and resume token go to the cache's partial tier, so the NEXT
        batch of the same hot keys replays the prefix at zero I/O."""
        if not streaming:
            return
        t = {"queries": len(streaming), "ranked_queries": 0,
             # per-query stop classification: every streaming query ends
             # exactly one way — ranked threshold stop, doc-id bound stop,
             # or full drain (check_trace_complete enforces the partition)
             "early_terminated": 0, "threshold_stops": 0, "bound_stops": 0,
             "fully_drained": 0, "threshold_checks": 0,
             "chunks_planned": 0, "chunks_fetched": 0, "chunks_skipped": 0,
             "chunks_shared": 0,
             "bytes_planned": 0, "bytes_fetched": 0, "bytes_skipped": 0,
             "bytes_shared": 0,
             "query_s": []}
        pool = (
            ChunkPool(stats=self.reader.cache_stats)
            if self.share_chunks else None
        )
        # physical ReaderCursors opened by this stage (pooled: one per
        # distinct identity), settled once after the batch
        settle: List[object] = []
        for qi in streaming:
            t0 = time.perf_counter()
            results[qi] = self._search_topk(plan.queries[qi], t,
                                            posts or {}, pool, settle)
            t["query_s"].append(time.perf_counter() - t0)
        t["pool_streams"] = len(pool) if pool is not None else 0
        for rc in settle:
            settler = getattr(rc, "settle", None)
            if settler is not None:
                settler()
        self.last_trace["topk"] = t

    def _search_topk(
        self,
        pq,
        trace: Dict[str, int],
        posts: Dict[Tuple[str, int], ShardPosts],
        pool: Optional[ChunkPool] = None,
        settle: Optional[List[object]] = None,
    ) -> QueryResult:
        """Best-k execution of one query over per-(lookup, shard) cursors.

        Every cursor delivers its key's postings in (doc, pos) order, so a
        cursor's *settled bound* — the doc id of its last delivered row
        (``+inf`` once exhausted) — is a lower bound on everything it has
        not delivered yet: no future chunk of any cursor can produce a
        match in a doc strictly below the minimum bound over all cursors.
        The loop joins the settled prefix region by region, and stops
        fetching by the mode's rule:

        * **doc-id mode** (``rank=None``): stop the moment ``k`` matching
          docs lie below the global bound — the lowest-id best-k set is
          provably final, remaining chunks are skipped.
        * **ranked mode** (``rank="prox"``): the WAND-style threshold
          test.  Every settled doc's score is exact (its region held ALL
          slot postings); every *unsettled* doc's score is bounded by the
          sum over slots of ``w_slot * tf_sat(max_doc_count)``, where an
          exhausted slot's bound is refined to the actual max over its
          still-pending rows (in particular: an exhausted slot with no
          pending rows kills every future match — conjunctive death).
          Stop once the k-th best settled score >= that remaining upper
          bound: a candidate can at best TIE the k-th score, and every
          candidate's doc id exceeds the bound (hence every settled id),
          so under the (score desc, doc id asc) tie rule it cannot enter
          the head.  See DESIGN_SEARCH.md §9 for the full argument.

        Either way, exhaustion of every cursor degenerates to the
        exhaustive answer, and per-shard cursors merge by the same global
        bound, so scatter/gather and the 1-shard case share one code path.
        """
        k = pq.top_k
        ranked = pq.rank is not None
        spec = pq.score_spec
        S = self.n_shards
        # one cursor per unique (index, key) — a repeated lookup inside
        # one query (e.g. a periodic phrase's cover) shares the stream
        idents: List[KeyLookup] = []
        slot: Dict[Tuple[str, int], int] = {}
        for lk in pq.lookups:
            ident = (lk.index, lk.key)
            if ident not in slot:
                slot[ident] = len(idents)
                idents.append(lk)
        lookup_slots = [slot[(lk.index, lk.key)] for lk in pq.lookups]

        def open_physical(s: int, lk: KeyLookup):
            fetched = posts.get((lk.index, lk.key))
            if fetched is not None:
                # the batch waves already read this key: stream its rows
                # as one zero-I/O chunk (same shape as a cache hit)
                return PostingCursor.from_array(fetched[s])
            c = self.reader.open_cursor_shard(
                s, lk.index, lk.key,
                make_decoder=self._make_decoder,
                device_tier=self.device_decode,
            )
            if settle is not None:
                settle.append(c)
            return c

        def open_cursor(s: int, lk: KeyLookup):
            if pool is None:
                return open_physical(s, lk)
            # pool identity is the full (shard, index, key): shards hold
            # disjoint doc sets and must never share a drain
            return pool.cursor(
                (s, lk.index, lk.key),
                lambda s=s, lk=lk: open_physical(s, lk),
            )

        cursors = [
            [open_cursor(s, lk) for s in range(S)]
            for lk in idents
        ]
        flat = [c for row in cursors for c in row]

        key_max: List[int] = []
        if ranked:
            trace["ranked_queries"] += 1
            # static per-key score bound ingredient: the key's largest
            # per-doc posting count, carried as cursor metadata from the
            # dictionary entry (array-backed cursors compute it from
            # their rows).  A doc lives in exactly one shard, so the max
            # over the shard row bounds every doc the key can deliver.
            key_max = [max(c.max_doc_count for c in row) for row in cursors]

        # incremental settled-region execution: matches are per-doc (no
        # join crosses a doc boundary), so joining ONLY the newly settled
        # [prev_bound, bound) rows each round and appending reproduces the
        # full-prefix join — every delivered row is merged and joined once
        pending: List[np.ndarray] = [_EMPTY] * len(idents)
        fresh: List[List[List[np.ndarray]]] = [
            [[] for _ in range(S)] for _ in idents
        ]
        # deliver every PREPAID chunk up front — resumed settled
        # prefixes, cache-hit rows, pooled prefix replays: they cost
        # zero device bytes, and delivering them now seeds each cursor's
        # settled bound before the first fetch round instead of leaving
        # a warm cursor at -inf.  The bound itself stays delivery-based:
        # seeding a bound whose rows were NOT delivered would let a
        # region cut below it lose matches.
        for i, row in enumerate(cursors):
            for s, c in enumerate(row):
                while not c.exhausted and getattr(c, "prepaid", False):
                    chunk = c.next_chunk()
                    if chunk is not None and chunk.shape[0]:
                        fresh[i][s].append(chunk)
        acc_parts: List[np.ndarray] = []
        doc_parts: List[np.ndarray] = []
        score_parts: List[np.ndarray] = []
        n_docs = 0
        prev_bound = -_INF
        while True:
            bound = min(c.settled_bound for c in flat)
            if bound > prev_bound:
                region = []
                for i in range(len(idents)):
                    merged = merge_shard_chunks([[pending[i]]] + fresh[i])
                    fresh[i] = [[] for _ in range(S)]
                    if bound < _INF:
                        cut = int(np.searchsorted(merged[:, 0], bound))
                        region.append(merged[:cut])
                        pending[i] = merged[cut:]
                    else:
                        region.append(merged)
                        pending[i] = _EMPTY
                part = self._streaming_join(
                    pq, [region[i] for i in lookup_slots]
                )
                if part.shape[0]:
                    acc_parts.append(part)
                    rdocs = np.unique(part[:, 0])
                    n_docs += int(rdocs.shape[0])
                    if ranked:
                        # score the region's docs NOW: the region holds
                        # every slot posting of every settled doc, so the
                        # per-slot counts — hence the scores — are exact
                        doc_parts.append(rdocs)
                        counts = [doc_counts(rdocs, region[i])
                                  for i in lookup_slots]
                        score_parts.append(self._score(counts, spec))
                prev_bound = bound
                if bound == _INF:
                    break
                if ranked:
                    if self._ranked_stop(trace, cursors, pending, key_max,
                                         lookup_slots, spec, score_parts,
                                         n_docs, k):
                        break
                elif n_docs >= k:
                    break
            elif bound == _INF:  # nothing newly settled and all drained
                break
            # advance the laggards: every cursor sitting at the bound is
            # fetched until it clears it (every such chunk is required
            # before the global bound can rise), so the bound strictly
            # increases per round
            for i, row in enumerate(cursors):
                for s, c in enumerate(row):
                    while not c.exhausted and c.settled_bound <= bound:
                        chunk = c.next_chunk()
                        if chunk is not None and chunk.shape[0]:
                            fresh[i][s].append(chunk)

        acc = (
            acc_parts[0] if len(acc_parts) == 1
            else np.concatenate(acc_parts, axis=0) if acc_parts
            else _EMPTY
        )

        # stop-reason ledger: every streaming query lands in exactly one
        # bucket (check_trace_complete enforces the partition per batch)
        if any(not c.exhausted for c in flat):
            trace["early_terminated"] += 1
            trace["threshold_stops" if ranked else "bound_stops"] += 1
        else:
            trace["fully_drained"] += 1
        for c in flat:
            trace["chunks_planned"] += c.chunks_total
            trace["chunks_fetched"] += c.chunks_fetched
            trace["chunks_skipped"] += c.chunks_skipped
            trace["chunks_shared"] += c.chunks_shared
            trace["bytes_planned"] += c.bytes_total
            trace["bytes_fetched"] += c.bytes_fetched
            trace["bytes_skipped"] += c.bytes_skipped
            trace["bytes_shared"] += c.bytes_shared

        log = [(lk.index, lk.key) for lk in pq.lookups]
        # count delivered postings per LOOKUP OCCURRENCE (a duplicated
        # cover key streams once but is scanned by both positions), so a
        # full drain reports exactly the batch stage's postings_scanned
        per_ident = [sum(c.postings_delivered for c in row)
                     for row in cursors]
        scanned = sum(per_ident[i] for i in lookup_slots)

        if ranked:
            zero = np.zeros(0, dtype=np.int64)
            docs_all = np.concatenate(doc_parts) if doc_parts else zero
            scores_all = np.concatenate(score_parts) if score_parts else zero
            order = head_order(docs_all, scores_all, k, ranked=True)
            top_docs = docs_all[order]
            witnesses = (acc[np.isin(acc[:, 0], top_docs)]
                         if acc.shape[0] else acc)
            return QueryResult(top_docs, witnesses, log, scanned, pq.route,
                               scores_all[order])

        docs, counts = np.unique(acc[:, 0], return_counts=True)
        order = head_order(docs, counts, k, ranked=False)
        top_docs = docs[order]
        witnesses = acc[np.isin(acc[:, 0], top_docs)] if acc.shape[0] else acc
        return QueryResult(top_docs, witnesses, log, scanned, pq.route,
                           counts[order])

    def _score(self, slot_counts, spec) -> np.ndarray:
        """Backend dispatch for region scoring: jax/pallas take the
        bucketable device form, everything else the numpy reference —
        all-integer arithmetic, so the outputs are bit-identical."""
        if self.backend in ("jax", "pallas"):
            return score_docs_jax(slot_counts, spec)
        return score_docs(slot_counts, spec)

    def _ranked_stop(
        self,
        trace: Dict[str, int],
        cursors,
        pending: List[np.ndarray],
        key_max: List[int],
        lookup_slots: List[int],
        spec,
        score_parts: List[np.ndarray],
        n_docs: int,
        k: int,
    ) -> bool:
        """The WAND threshold test at the current global bound.

        Upper-bounds the score of every not-yet-settled doc: slot by
        slot, a candidate's posting count is at most the key's lifetime
        ``max_doc_count`` — refined, once a key's cursors are all
        exhausted, to the exact max over its still-pending rows (all of
        which sit at or above the bound).  An exhausted key with an empty
        pending region can never witness another match (the joins are
        conjunctive): stop immediately regardless of how many docs have
        settled.  Otherwise stop iff k docs have settled and the k-th
        best settled score already meets the bound (a candidate tie
        loses on doc id — candidates sit above every settled doc).
        """
        trace["threshold_checks"] += 1
        per_ident: List[int] = []
        for i, row in enumerate(cursors):
            if all(c.exhausted for c in row):
                cnt = max_doc_run(pending[i])
                if cnt == 0:
                    return True  # conjunctive death: no future match
            else:
                cnt = key_max[i]
            per_ident.append(cnt)
        ub = sum(
            spec.weights[s] * min(per_ident[ident], spec.tf_cap)
            for s, ident in enumerate(lookup_slots)
        )
        if n_docs < k:
            return False
        scores = (score_parts[0] if len(score_parts) == 1
                  else np.concatenate(score_parts))
        theta = int(np.partition(scores, scores.shape[0] - k)
                    [scores.shape[0] - k])
        return theta >= ub

    def _streaming_join(
        self, pq, prefix: List[np.ndarray]
    ) -> np.ndarray:
        """Join the settled prefix of every lookup — the same staged exact
        joins as the batch stage, on the numpy oracle path (prefixes are
        small by construction: the loop stops at ~k matching docs)."""
        if pq.route in (ROUTE_STOPSEQ, ROUTE_WV):
            return prefix[0]
        acc = prefix[0]
        if pq.route == ROUTE_MULTI or pq.query.phrase:
            for dist, nxt in enumerate(prefix[1:], start=1):
                acc = numpy_phrase_join(acc, nxt, dist)
        else:
            for nxt in prefix[1:]:
                acc = numpy_window_join(acc, nxt, pq.window)
        return acc

    # ------------------------------------------- trace completeness guard --
    def check_trace_complete(self, plan: Optional[QueryPlan] = None) -> None:
        """Assert every planned fetch was either executed or explicitly
        skipped/deferred in ``last_trace`` (and, for the streaming stage,
        every cursor chunk either fetched or skipped).  Runs after every
        ``search_batch``; raises :class:`TraceIncompleteError` so a future
        edit that drops a wave without accounting for it fails loudly
        instead of masquerading as saved I/O."""
        tr = self.last_trace
        # schema gate first: the runtime trace and the static registry in
        # repro.search.schema must agree on the key set, so an undeclared
        # key fails here even when no completeness partition involves it
        msg = validate_trace(tr)
        if msg:
            raise TraceIncompleteError(msg)
        if "snapshot" not in tr:
            raise TraceIncompleteError(
                "trace carries no pinned snapshot generation vector"
            )
        if tr.get("waves", 0) != (
            tr.get("executed_waves", 0) + tr.get("skipped_waves", 0)
        ):
            raise TraceIncompleteError(
                f"waves {tr.get('waves')} != executed "
                f"{tr.get('executed_waves')} + skipped "
                f"{tr.get('skipped_waves')}"
            )
        if tr.get("lookups_planned", 0) != (
            tr.get("lookups_fetched", 0) + tr.get("lookups_deferred", 0)
        ):
            raise TraceIncompleteError(
                f"lookups planned {tr.get('lookups_planned')} != fetched "
                f"{tr.get('lookups_fetched')} + deferred "
                f"{tr.get('lookups_deferred')}"
            )
        if plan is not None and tr.get("lookups_planned") != plan.n_unique_lookups:
            raise TraceIncompleteError(
                f"trace covers {tr.get('lookups_planned')} lookups, plan "
                f"has {plan.n_unique_lookups}"
            )
        rb = tr.get("replicas")
        if rb is not None:
            # per-replica staleness bound against the batch's pinned
            # snapshot: no replica may have consumed the digest stream
            # PAST the snapshot (it would have served a newer collection
            # state into this batch), and every LIVE replica must sit
            # exactly AT it (refresh() catches live replicas up before
            # the snapshot is pinned; dead replicas may lag — they serve
            # nothing until revived)
            snap = tr["snapshot"]
            for s, row in enumerate(rb["snapshot"]):
                for r, gv in enumerate(row):
                    if any(g > w for g, w in zip(gv, snap[s])):
                        raise TraceIncompleteError(
                            f"replica s{s}r{r} generation vector {gv} runs "
                            f"AHEAD of the pinned snapshot {list(snap[s])}"
                        )
                    if rb["live"][s][r] and list(gv) != list(snap[s]):
                        raise TraceIncompleteError(
                            f"live replica s{s}r{r} at {gv} is stale "
                            f"against the pinned snapshot {list(snap[s])}"
                        )
        tk = tr.get("topk")
        if tk is not None:
            # per-query stop partition: every streaming query ended
            # exactly one way, and "early_terminated" is a true per-query
            # COUNT (it used to accumulate a bool per batch, conflating
            # "how many stopped early" with "did any stop early")
            if tk["queries"] != tk["early_terminated"] + tk["fully_drained"]:
                raise TraceIncompleteError(
                    f"streaming queries {tk['queries']} != early_terminated "
                    f"{tk['early_terminated']} + fully_drained "
                    f"{tk['fully_drained']}"
                )
            if tk["early_terminated"] != (
                tk["threshold_stops"] + tk["bound_stops"]
            ):
                raise TraceIncompleteError(
                    f"early_terminated {tk['early_terminated']} != "
                    f"threshold_stops {tk['threshold_stops']} + bound_stops "
                    f"{tk['bound_stops']}"
                )
            if not 0 <= tk["ranked_queries"] <= tk["queries"]:
                raise TraceIncompleteError(
                    f"ranked_queries {tk['ranked_queries']} outside "
                    f"[0, {tk['queries']}]"
                )
            # shared chunks are replays of a chunk some OTHER view of the
            # same pooled stream physically fetched: per cursor view,
            # planned partitions into fetched (this view paid the I/O),
            # shared (replayed from the pool at zero I/O) and skipped —
            # so summed over a batch, chunks_fetched counts every
            # physical chunk EXACTLY once however many queries read it
            shared = tk.get("chunks_shared", 0)
            if tk["chunks_planned"] != (
                tk["chunks_fetched"] + tk["chunks_skipped"] + shared
            ):
                raise TraceIncompleteError(
                    f"cursor chunks planned {tk['chunks_planned']} != "
                    f"fetched {tk['chunks_fetched']} + skipped "
                    f"{tk['chunks_skipped']} + shared {shared}"
                )
            bshared = tk.get("bytes_shared", 0)
            if tk["bytes_planned"] != (
                tk["bytes_fetched"] + tk["bytes_skipped"] + bshared
            ):
                raise TraceIncompleteError(
                    f"cursor bytes planned {tk['bytes_planned']} != "
                    f"fetched {tk['bytes_fetched']} + skipped "
                    f"{tk['bytes_skipped']} + shared {bshared}"
                )
