"""Batched proximity-search execution over planned queries.

``SearchService`` is the read-side query processor: it plans a batch of
queries (:mod:`repro.search.plan`), fetches every unique posting list
once through the reader layer (:mod:`repro.search.reader`) in
(index, dictionary-group) order so group-mates amortize dictionary
visits, and then runs the ordinary-route window joins through one of
the join backends (:mod:`repro.search.join`).

The ``jax`` backend is the batched fast path: join jobs from the whole
batch are padded into power-of-two ``(B, N, M)`` buckets and each bucket
runs as ONE jit-compiled vmapped kernel launch — a batch of 64 queries
costs a handful of launches instead of 64+ per-query dispatches.
``pallas`` routes each join through the TPU intersect kernel's doc-level
prefilter.  All backends return results element-wise identical to the
numpy oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.search.join import (
    JOIN_BACKENDS,
    _jax_dtype_for,
    _pow2,
    batched_window_mask,
    numpy_phrase_join,
    numpy_window_join,
    pack_keys,
)
from repro.search.plan import (
    ROUTE_MULTI,
    ROUTE_ORDINARY,
    MultiKeySpec,
    Query,
    QueryPlan,
    QueryResult,
    plan_batch,
)
from repro.search.reader import IndexSetReader

_EMPTY = np.zeros((0, 2), dtype=np.int64)

QueryLike = Union[Query, Sequence[int]]


def _as_query(q: QueryLike) -> Query:
    if isinstance(q, Query):
        return q
    return Query(tuple(int(w) for w in q))


class SearchService:
    """Planned, batched query execution over a :class:`TextIndexSet`.

    ``backend`` is ``"numpy"`` | ``"jax"`` | ``"pallas"`` or any callable
    ``join(a, b, window) -> rows of a`` (executed per pair).
    """

    def __init__(
        self,
        source,
        window: int = 3,
        backend: Union[str, Callable] = "numpy",
        cache_bytes: int = 8 << 20,
        use_multi: bool = True,
    ):
        if isinstance(source, IndexSetReader):
            self.reader = source
        else:
            self.reader = IndexSetReader(source, cache_bytes=cache_bytes)
        self.index_set = self.reader.index_set
        self.lexicon = self.reader.lexicon
        self.window = min(window, self.index_set.cfg.max_distance)
        # multi-component route: available when the set built the multi
        # index and the caller did not opt out (use_multi=False forces
        # phrase queries down the ordinary path — the benchmark baseline)
        self.multi: Optional[MultiKeySpec] = None
        if use_multi and "multi" in self.index_set.indexes:
            mi = self.index_set.indexes["multi"]
            self.multi = MultiKeySpec(k=mi.k, pack=mi.pack)
        if callable(backend):
            self.backend: Union[str, Callable] = backend
        elif backend in JOIN_BACKENDS:
            self.backend = backend
        else:
            raise ValueError(
                f"unknown backend {backend!r}; expected one of "
                f"{sorted(JOIN_BACKENDS)} or a callable"
            )

    # ------------------------------------------------------------ planning --
    def plan(self, queries: Sequence[QueryLike]) -> QueryPlan:
        # per-query windows obey the same max_distance clamp as the default:
        # the stopseq/wv indexes are precomputed at max_distance, so a wider
        # ordinary-route join would give route-dependent semantics
        md = self.index_set.cfg.max_distance
        qs = [
            dataclasses.replace(q, window=min(q.window, md))
            if q.window is not None and q.window > md else q
            for q in map(_as_query, queries)
        ]
        return plan_batch(qs, self.lexicon, self.reader.group_of, self.window,
                          multi=self.multi, max_distance=md)

    # ----------------------------------------------------------- execution --
    def search(
        self,
        words: Sequence[int],
        window: Optional[int] = None,
        phrase: bool = False,
    ) -> QueryResult:
        q = Query(tuple(int(w) for w in words), window, phrase=phrase)
        return self.search_batch([q])[0]

    def search_batch(self, queries: Sequence[QueryLike]) -> List[QueryResult]:
        plan = self.plan(queries)
        posts = self._fetch(plan)
        results: List[Optional[QueryResult]] = [None] * len(plan.queries)
        ordinary: List[Tuple[int, List[np.ndarray]]] = []
        for i, pq in enumerate(plan.queries):
            fetched = [posts[(lk.index, lk.key)] for lk in pq.lookups]
            log = [(lk.index, lk.key) for lk in pq.lookups]
            scanned = sum(f.shape[0] for f in fetched)
            if pq.route == ROUTE_ORDINARY and not pq.query.phrase:
                ordinary.append((i, fetched))
                results[i] = QueryResult(_EMPTY[:, 0], _EMPTY, log, scanned,
                                         pq.route)
            elif pq.route == ROUTE_MULTI or pq.route == ROUTE_ORDINARY:
                # phrase reconstruction: lookup j's records must sit at
                # start+j (multi: k-gram at word offset j; ordinary
                # phrase: word j itself) — staged exact host joins
                acc = self._phrase_chain(fetched)
                results[i] = QueryResult(np.unique(acc[:, 0]), acc, log,
                                         scanned, pq.route)
            else:
                p = fetched[0]
                results[i] = QueryResult(np.unique(p[:, 0]), p, log, scanned,
                                         pq.route)
        self._execute_ordinary(plan, ordinary, results)
        return results

    @staticmethod
    def _phrase_chain(fetched: List[np.ndarray]) -> np.ndarray:
        acc = fetched[0]
        for dist, nxt in enumerate(fetched[1:], start=1):
            acc = numpy_phrase_join(acc, nxt, dist)
        return acc

    def _fetch(self, plan: QueryPlan) -> Dict[Tuple[str, int], np.ndarray]:
        """Fetch each unique (index, key) once, walking (index, group) in
        order so lookups of the same dictionary group run back to back."""
        out: Dict[Tuple[str, int], np.ndarray] = {}
        for index, _group in sorted(plan.grouped):
            for lk in plan.grouped[(index, _group)]:
                out[(lk.index, lk.key)] = self.reader.lookup(lk.index, lk.key)
        return out

    # ordinary route: staged window joins -----------------------------------
    def _execute_ordinary(self, plan, jobs, results) -> None:
        # state per job: accumulator + posting lists still to join
        accs: Dict[int, np.ndarray] = {}
        rest: Dict[int, List[np.ndarray]] = {}
        for i, fetched in jobs:
            accs[i] = fetched[0]
            rest[i] = fetched[1:]
        while any(rest.values()):
            round_ids = [i for i in accs if rest[i]]
            pairs = [
                (accs[i], rest[i].pop(0), plan.queries[i].window)
                for i in round_ids
            ]
            for i, joined in zip(round_ids, self._join_many(pairs)):
                accs[i] = joined
        for i, _ in jobs:
            acc = accs[i]
            r = results[i]
            results[i] = QueryResult(
                np.unique(acc[:, 0]), acc, r.lookups, r.postings_scanned,
                r.route,
            )

    def _join_many(
        self, pairs: List[Tuple[np.ndarray, np.ndarray, int]]
    ) -> List[np.ndarray]:
        if self.backend == "jax":
            return self._join_many_jax(pairs)
        join = self.backend if callable(self.backend) else JOIN_BACKENDS[self.backend]
        return [join(a, b, w) for a, b, w in pairs]

    def _join_many_jax(
        self, pairs: List[Tuple[np.ndarray, np.ndarray, int]]
    ) -> List[np.ndarray]:
        """Bucket join jobs by padded power-of-two shape; one vmapped
        kernel launch per bucket."""
        out: List[Optional[np.ndarray]] = [None] * len(pairs)
        buckets: Dict[Tuple[int, int, str], List] = {}
        for idx, (a, b, w) in enumerate(pairs):
            if a.size == 0 or b.size == 0:
                out[idx] = _EMPTY
                continue
            akey, bkey, _ = pack_keys(a, b, w)
            dtype = _jax_dtype_for(int(max(akey[-1], bkey[-1])), w)
            if dtype is None:
                # packed keys exceed the device integer width: exact host join
                out[idx] = numpy_window_join(a, b, w)
                continue
            shape = (_pow2(akey.shape[0]), _pow2(bkey.shape[0]),
                     np.dtype(dtype).name)
            buckets.setdefault(shape, []).append((idx, a, akey, bkey, w))
        for (n, m, dtname), jobs in buckets.items():
            dtype = np.dtype(dtname)
            big = np.iinfo(dtype).max
            nb = _pow2(len(jobs))
            ak = np.full((nb, n), big - 1, dtype)
            bk = np.full((nb, m), big, dtype)
            ws = np.zeros((nb,), dtype)
            for r, (idx, a, akey, bkey, w) in enumerate(jobs):
                # pad a below the overflow line for this row's window; pad b
                # above every real key so padding can never witness a hit
                ak[r, : akey.shape[0]] = akey
                ak[r, akey.shape[0]:] = big - w - 1
                bk[r, : bkey.shape[0]] = bkey
                ws[r] = w
            mask = np.asarray(
                batched_window_mask(jnp.asarray(ak), jnp.asarray(bk),
                                    jnp.asarray(ws))
            )
            for r, (idx, a, _akey, _bkey, _w) in enumerate(jobs):
                out[idx] = a[mask[r, : a.shape[0]]]
        return out
