"""Read-side query stack: Reader → Planner → Executor.

Layering (DESIGN_SEARCH.md):

  * :mod:`repro.search.reader`  — read-only index snapshots with their own
    search-I/O accounting and a byte-budgeted posting-list LRU,
  * :mod:`repro.search.plan`    — typed ``Query → QueryPlan`` routing over
    the four lookup paths (the paper's three + the multi-component
    k-word route), batched and vectorized,
  * :mod:`repro.search.service` — ``SearchService.search_batch``: the
    plan → scatter-fetch → join → gather pipeline (pipelined reader
    prefetch, bucketed JAX/Pallas window joins, lossless per-shard
    gather over a sharded substrate),
  * :mod:`repro.search.replica` — the replica read fabric: N replica
    readers per shard subscribing to the writer's touched-key digest
    stream, with least-loaded wave routing and mid-batch failover,
  * :mod:`repro.search.join`    — the interchangeable join backends,
  * :mod:`repro.search.scoring` — the ranked-retrieval score (proximity
    weights × saturating tf) shared by the streaming executor's
    WAND-style pruning and the exhaustive test oracles.
"""

from repro.search.join import (
    JOIN_BACKENDS,
    batched_window_mask,
    jax_window_join,
    numpy_phrase_join,
    numpy_window_join,
    pack_keys,
    pallas_window_join,
    pos_scale,
)
from repro.search.plan import (
    ROUTE_MULTI,
    ROUTE_ORDINARY,
    ROUTE_STOPSEQ,
    ROUTE_WV,
    ROUTES,
    KeyLookup,
    MultiKeySpec,
    PlannedQuery,
    Query,
    QueryPlan,
    QueryResult,
    plan_batch,
)
from repro.search.scoring import (
    PROX_SCALE,
    TF_CAP,
    ScoreSpec,
    head_order,
    score_docs,
    score_docs_jax,
    spec_for,
)
from repro.search.reader import (
    CacheStats,
    IndexReader,
    IndexSetReader,
    PostingCache,
    ReaderCursor,
    ShardedIndexSetReader,
)
from repro.search.replica import (
    AllReplicasDeadError,
    ReplicaDeadError,
    ReplicaReader,
    ReplicaSetReader,
)
from repro.search.service import (
    SearchService,
    SnapshotViolationError,
    TraceIncompleteError,
)

__all__ = [
    "JOIN_BACKENDS",
    "batched_window_mask",
    "jax_window_join",
    "numpy_phrase_join",
    "numpy_window_join",
    "pack_keys",
    "pallas_window_join",
    "pos_scale",
    "ROUTE_MULTI",
    "ROUTE_ORDINARY",
    "ROUTE_STOPSEQ",
    "ROUTE_WV",
    "ROUTES",
    "KeyLookup",
    "MultiKeySpec",
    "PlannedQuery",
    "Query",
    "QueryPlan",
    "QueryResult",
    "plan_batch",
    "PROX_SCALE",
    "TF_CAP",
    "ScoreSpec",
    "head_order",
    "score_docs",
    "score_docs_jax",
    "spec_for",
    "CacheStats",
    "IndexReader",
    "IndexSetReader",
    "PostingCache",
    "ReaderCursor",
    "ShardedIndexSetReader",
    "AllReplicasDeadError",
    "ReplicaDeadError",
    "ReplicaReader",
    "ReplicaSetReader",
    "SearchService",
    "SnapshotViolationError",
    "TraceIncompleteError",
]
