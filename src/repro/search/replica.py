"""Replica read fabric: N read replicas per shard behind one scatter surface.

The paper's updatability claim (arXiv:2007.09377) keeps WRITE cost flat
while parts stream in; read qps is scaled the other way — by fanning
each shard's digest stream out to N replica readers (the serve side of
the build/serve split in arXiv:2006.07954).  Writers stay single-owner:
a replica never mutates index state, it *subscribes*.

Topology (one fabric = the whole serving tier)::

    shard 0 writer ──digests──► ReplicaReader(s0,r0) ─┐
                   └──────────► ReplicaReader(s0,r1) ─┤
    shard 1 writer ──digests──► ReplicaReader(s1,r0) ─┼─► ReplicaSetReader
                   └──────────► ReplicaReader(s1,r1) ─┘   (routing+failover)

Each :class:`ReplicaReader` is one (shard, replica): per-index
:class:`~repro.search.reader.IndexReader` snapshots over the shard's
published storage with the replica's OWN posting cache and OWN search
devices (``s{shard}r{replica}/{index}-read``), so read I/O is charged —
and capacity measured — per replica.  Catch-up consumes the shard
writer's touched-key digest stream (``digests_since``): a replica
within the bounded digest history invalidates exactly the touched keys;
one behind it falls back to the existing whole-namespace drop.  Both
modes are ledgered per replica.

Routing: ``SearchService`` pins one replica per shard per *fetch wave*
(:meth:`ReplicaSetReader.begin_wave` — least-loaded live replica by the
in-flight-wave counter, ties by waves served).  A replica that dies
mid-wave (the injectable ``fault`` hook, or an explicit :meth:`kill`)
raises :class:`ReplicaDeadError`; the fabric marks it dead, counts a
failover, and re-pins a live sibling — results stay element-wise
identical to the single-reader path because every replica serves the
same published snapshot.

Staleness bound: ``last_trace['replicas']`` carries every replica's
generation vector next to the batch's pinned snapshot;
``check_trace_complete`` asserts no replica runs AHEAD of the snapshot
and every live replica is exactly AT it (dead replicas may lag — they
catch up on revive, targeted or full-drop).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Hashable, List, Optional

import numpy as np

from repro.core.io_sim import BlockDevice, IOStats
from repro.search.reader import (
    IndexReader,
    PostingCache,
    ReaderCursor,
)


class ReplicaDeadError(RuntimeError):
    """Raised when a serve hits a dead (or fault-injected) replica; the
    fabric catches it and fails over to a live sibling."""


class AllReplicasDeadError(RuntimeError):
    """No live replica is left for a shard — nothing to fail over to."""


class ReplicaReader:
    """One (shard, replica): per-index readers over the shard's published
    storage, with this replica's own cache, devices and catch-up ledger."""

    def __init__(
        self,
        shard_id: int,
        replica_id: int,
        shard_set,
        cache_bytes: int = 8 << 20,
        targeted: bool = True,
    ):
        self.shard_id = int(shard_id)
        self.replica_id = int(replica_id)
        self.shard_set = shard_set
        self.cache = PostingCache(cache_bytes) if cache_bytes > 0 else None
        ns = f"s{self.shard_id}r{self.replica_id}"
        # per-replica search devices: replica capacity and read traffic
        # are measured per replica, never pooled into the writer's devices
        self.devices: Dict[str, BlockDevice] = {
            name: BlockDevice(
                cluster_size=idx.cfg.cluster_size,
                name=f"{ns}/{name}-read",
            )
            for name, idx in shard_set.indexes.items()
        }
        self.readers: Dict[str, IndexReader] = {
            name: IndexReader(
                idx,
                device=self.devices[name],
                cache=self.cache,
                cache_ns=f"{ns}:{name}",
                targeted=targeted,
            )
            for name, idx in shard_set.indexes.items()
        }
        self.live = True
        # routing load signals: waves currently in flight on this replica
        # plus waves served overall (the tiebreak that round-robins)
        self.inflight = 0
        self.waves_served = 0
        self.lookups_served = 0
        self.cursors_served = 0
        # accumulated real serve seconds — the capacity denominator the
        # --replicas bench scales by
        self.busy_s = 0.0
        # injectable fault hook: called before every serve as
        # ``fault(replica, op)``; raise ReplicaDeadError to simulate a
        # crash mid-batch (the fabric then marks this replica dead and
        # fails the wave over to a sibling)
        self.fault: Optional[Callable[["ReplicaReader", str], None]] = None
        self.failures = 0
        # digest-stream consumption ledger, by catch-up mode
        self.catch_ups = {"current": 0, "targeted": 0, "full_drop": 0}

    # ------------------------------------------------------------- serving --
    def _check(self, op: str) -> None:
        if self.fault is not None:
            self.fault(self, op)
        if not self.live:
            raise ReplicaDeadError(
                f"replica s{self.shard_id}r{self.replica_id} is down"
            )

    def lookup(self, index_name: str, key: Hashable) -> np.ndarray:
        self._check("lookup")
        t0 = time.perf_counter()
        try:
            return self.readers[index_name].lookup(key)
        finally:
            self.busy_s += time.perf_counter() - t0
            self.lookups_served += 1

    def open_cursor(
        self, index_name: str, key: Hashable,
        make_decoder=None, device_tier: bool = False,
    ) -> ReaderCursor:
        self._check("cursor")
        t0 = time.perf_counter()
        try:
            return self.readers[index_name].open_cursor(
                key, make_decoder=make_decoder, device_tier=device_tier
            )
        finally:
            self.busy_s += time.perf_counter() - t0
            self.cursors_served += 1

    # ---------------------------------------------------------- subscribing --
    def catch_up(self) -> List[str]:
        """Consume the shard writer's digest stream: every index reader
        refreshes from its pinned published generation — targeted drops
        within the bounded digest history, the whole-namespace fallback
        behind it.  Returns the per-index modes taken."""
        modes = [r.refresh() for r in self.readers.values()]
        for m in modes:
            self.catch_ups[m] += 1
        return modes

    def generation_vector(self) -> List[int]:
        """This replica's pinned per-index published generations — its
        position on the digest stream (lags the writer while dead)."""
        return [r._generation for r in self.readers.values()]

    def lag(self) -> int:
        """Generations behind the writer (max over indexes)."""
        return max(
            r.index.generation - r._generation
            for r in self.readers.values()
        )

    # -------------------------------------------------------------- faults --
    def kill(self) -> None:
        self.live = False

    def revive(self, catch_up: bool = True) -> List[str]:
        """Bring the replica back; by default it catches up on the digest
        stream immediately (behind the bounded history this is the
        namespace-drop path — the ledger records which)."""
        self.live = True
        self.fault = None
        return self.catch_up() if catch_up else []

    def io_stats(self) -> Dict[str, IOStats]:
        return {name: d.stats.snapshot() for name, d in self.devices.items()}

    def read_bytes(self) -> int:
        return sum(s.read_bytes for s in self.io_stats().values())


class _FabricCacheStats:
    """Aggregate cache-stats view over every replica's private cache.

    Quacks like :class:`~repro.search.reader.CacheStats` for the
    service's trace block; ``pool_hits`` is a REAL attribute (the batch
    ``ChunkPool`` increments it in place) layered over the replicas'
    own counters."""

    def __init__(self, caches: List[PostingCache]):
        self._caches = caches
        self.pool_hits_extra = 0

    def _sum(self, field: str) -> int:
        return sum(getattr(c.stats, field) for c in self._caches)

    @property
    def hits(self) -> int:
        return self._sum("hits")

    @property
    def misses(self) -> int:
        return self._sum("misses")

    @property
    def evictions(self) -> int:
        return self._sum("evictions")

    @property
    def invalidations(self) -> int:
        return self._sum("invalidations")

    @property
    def full_drops(self) -> int:
        return self._sum("full_drops")

    @property
    def bytes_used(self) -> int:
        return self._sum("bytes_used")

    @property
    def device_hits(self) -> int:
        return self._sum("device_hits")

    @property
    def partial_admits(self) -> int:
        return self._sum("partial_admits")

    @property
    def pool_hits(self) -> int:
        return self._sum("pool_hits") + self.pool_hits_extra

    @pool_hits.setter
    def pool_hits(self, value: int) -> None:
        self.pool_hits_extra = value - self._sum("pool_hits")

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class ReplicaSetReader:
    """N replicas per shard behind the standard reader scatter surface.

    Drop-in for :class:`~repro.search.reader.ShardedIndexSetReader`
    (``n_shards`` / ``lookup_shard`` / ``open_cursor_shard`` /
    ``group_of`` / ``refresh`` / ``generation_vector`` /
    ``cache_stats``), plus the wave-routing surface ``SearchService``
    pins fetch waves with (:meth:`begin_wave` / :meth:`end_wave`) and
    the failover loop.  ``generation_vector()`` reports the WRITERS'
    published truth (that is what a batch pins); per-replica positions
    are a separate observable (:meth:`replica_generations`).
    """

    # duck-type marker SearchService keys the routing/trace extras on
    is_replica_fabric = True

    def __init__(
        self,
        source,
        n_replicas: int = 2,
        cache_bytes: int = 8 << 20,
        targeted: bool = True,
    ):
        if n_replicas < 1:
            raise ValueError(f"need at least one replica, got {n_replicas}")
        # source: ShardedTextIndexSet / DurableIndexStore (.shards) or a
        # bare TextIndexSet (the 1-shard degenerate case)
        shards = getattr(source, "shards", None)
        self._shards = list(shards) if shards is not None else [source]
        self.index_set = source
        self.lexicon = source.lexicon
        self.replicas: List[List[ReplicaReader]] = [
            [
                ReplicaReader(s, r, shard, cache_bytes=cache_bytes,
                              targeted=targeted)
                for r in range(n_replicas)
            ]
            for s, shard in enumerate(self._shards)
        ]
        self.failovers = 0
        self._wave_pin: List[Optional[ReplicaReader]] = [None] * len(
            self._shards
        )
        self.cache_stats = _FabricCacheStats(
            [rep.cache for row in self.replicas for rep in row
             if rep.cache is not None]
        )

    # ---------------------------------------------------------------- shape --
    @property
    def n_shards(self) -> int:
        return len(self.replicas)

    @property
    def n_replicas(self) -> int:
        return len(self.replicas[0])

    # -------------------------------------------------------------- routing --
    def _route(self, shard: int) -> ReplicaReader:
        """Least-loaded LIVE replica: fewest waves in flight, then least
        cumulative read I/O (waves have very unequal costs — counting
        them would park one hot wave's replica at the same priority as
        its idle siblings; simulated bytes are a deterministic cost
        proxy, where wall time would make routing — and every failover
        test — timing-dependent), then waves served (round-robin when
        costs tie), then replica id."""
        live = [rep for rep in self.replicas[shard] if rep.live]
        if not live:
            raise AllReplicasDeadError(
                f"shard {shard}: all {self.n_replicas} replicas are down"
            )
        return min(
            live,
            key=lambda rep: (rep.inflight, rep.read_bytes(),
                             rep.waves_served, rep.replica_id),
        )

    def begin_wave(self) -> None:
        """Pin one replica per shard for the next fetch wave and count it
        in flight — the load signal :meth:`_route` balances on."""
        for s in range(self.n_shards):
            rep = self._route(s)
            rep.inflight += 1
            self._wave_pin[s] = rep

    def end_wave(self) -> None:
        for s, rep in enumerate(self._wave_pin):
            if rep is not None:
                rep.inflight -= 1
                rep.waves_served += 1
                self._wave_pin[s] = None

    def _serve(self, shard: int, op: Callable[[ReplicaReader], object]):
        """Serve through the wave-pinned (or freshly routed) replica,
        failing over to a live sibling when it dies mid-serve."""
        rep = self._wave_pin[shard]
        pinned = rep is not None
        if rep is None:
            rep = self._route(shard)
        while True:
            try:
                return op(rep)
            except ReplicaDeadError:
                rep.live = False
                rep.failures += 1
                if pinned and rep.inflight > 0:
                    rep.inflight -= 1
                self.failovers += 1
                rep = self._route(shard)  # AllReplicasDeadError if none
                if pinned:
                    rep.inflight += 1
                    self._wave_pin[shard] = rep

    # ----------------------------------------------------- reader surface --
    def lookup_shard(
        self, shard: int, index_name: str, key: Hashable
    ) -> np.ndarray:
        return self._serve(shard, lambda rep: rep.lookup(index_name, key))

    def open_cursor_shard(
        self, shard: int, index_name: str, key: Hashable,
        make_decoder=None, device_tier: bool = False,
    ) -> ReaderCursor:
        return self._serve(
            shard,
            lambda rep: rep.open_cursor(
                index_name, key,
                make_decoder=make_decoder, device_tier=device_tier,
            ),
        )

    def lookup(self, index_name: str, key: Hashable) -> np.ndarray:
        from repro.core.sharded_set import merge_shard_postings

        return merge_shard_postings(
            [self.lookup_shard(s, index_name, key)
             for s in range(self.n_shards)]
        )

    def group_of(self, index_name: str, key: Hashable) -> int:
        # dictionary grouping is shard- and replica-invariant
        return self.replicas[0][0].readers[index_name].group_of(key)

    def refresh(self) -> None:
        """Catch every LIVE replica up on its shard's digest stream (dead
        replicas stay where they are; they catch up on revive)."""
        for row in self.replicas:
            for rep in row:
                if rep.live:
                    rep.catch_up()

    def generation_vector(self) -> List[List[int]]:
        """The WRITERS' published per-shard per-index generations — the
        source of truth a snapshot-consistent batch pins.  Replica
        positions live in :meth:`replica_generations`."""
        return [shard.generation_vector() for shard in self._shards]

    # -------------------------------------------------------- observability --
    def replica_generations(self) -> List[List[List[int]]]:
        """``[shard][replica] -> per-index generation vector``: each
        replica's position on its shard's digest stream."""
        return [[rep.generation_vector() for rep in row]
                for row in self.replicas]

    def replica_liveness(self) -> List[List[bool]]:
        return [[rep.live for rep in row] for row in self.replicas]

    def route_trace(self) -> Dict[str, object]:
        """The per-batch trace block ``SearchService`` embeds as
        ``last_trace['replicas']`` (and ``check_trace_complete`` bounds
        staleness with)."""
        return {
            "n_replicas": self.n_replicas,
            "snapshot": self.replica_generations(),
            "live": self.replica_liveness(),
            "failovers": self.failovers,
            "waves": [[rep.waves_served for rep in row]
                      for row in self.replicas],
            "lookups": [[rep.lookups_served for rep in row]
                        for row in self.replicas],
            "cursors": [[rep.cursors_served for rep in row]
                        for row in self.replicas],
            "busy_s": [[rep.busy_s for rep in row]
                       for row in self.replicas],
            "catch_ups": [[dict(rep.catch_ups) for rep in row]
                          for row in self.replicas],
        }

    def io_stats_per_replica(self) -> List[List[Dict[str, IOStats]]]:
        return [[rep.io_stats() for rep in row] for row in self.replicas]

    def read_bytes_per_replica(self) -> List[List[int]]:
        return [[rep.read_bytes() for rep in row] for row in self.replicas]

    def io_stats(self) -> Dict[str, IOStats]:
        from repro.core.sharded_set import merge_io_reports

        return merge_io_reports(
            [rep.io_stats() for row in self.replicas for rep in row]
        )
