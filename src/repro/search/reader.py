"""Read-side snapshot views over the easily updatable indexes.

The writer (:class:`~repro.core.inverted_index.InvertedIndex`) owns the
build device and the update protocol; readers own everything about
serving lookups:

  * each :class:`IndexReader` charges its I/O to a dedicated *search*
    device, so build and search traffic are never conflated (previously
    done by temporarily swapping the stream manager's device — a
    writer-side hack that could not be made concurrent-safe);
  * posting lists are cached in a byte-budgeted LRU shared across the
    readers of a :class:`IndexSetReader` — a cache hit costs ZERO device
    I/O, which is what makes repeated keys in a query batch (and hot stop
    pairs across batches) nearly free;
  * readers snapshot the writer's part counter; when the writer indexes
    another collection part, the next lookup invalidates exactly the
    keys the writer's touched-key digest names (falling back to a
    whole-namespace drop only when the bounded digest history no longer
    covers the reader's snapshot) — single-writer, read-your-writes
    semantics with the cache kept warm for untouched keys;
  * cursors pin their open-time generation: an open cursor keeps serving
    its snapshot across writer updates, and the cache-admit path
    re-checks the generation so a mid-update drain can never publish a
    stale list.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.core.inverted_index import (
    CURSOR_CHUNK_CLUSTERS,
    InvertedIndex,
    PostingCursor,
)
from repro.core.io_sim import BlockDevice, IOStats


def _frozen(arr: np.ndarray) -> np.ndarray:
    """An immutable alias of ``arr``: frozen in place when it owns its
    buffer, a frozen copy when the buffer stays writeable through a base
    (freezing only the view would let a holder of the base — or anyone
    flipping the flag back on, which numpy permits while the base is
    writeable — mutate it anyway)."""
    owner = arr if arr.base is None else arr.base
    if isinstance(owner, np.ndarray) and not owner.flags.writeable:
        return arr
    if arr.base is not None:
        arr = arr.copy()
    arr.flags.writeable = False
    return arr


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0      # capacity pressure: LRU victims only
    invalidations: int = 0  # correctness drops: writer-generation changes
    full_drops: int = 0     # whole-namespace sweeps (no digest coverage)
    bytes_used: int = 0
    pool_hits: int = 0       # chunk replays served by a batch ChunkPool
    device_hits: int = 0     # cursors served from the device-buffer tier
    partial_admits: int = 0  # settled prefixes admitted by early stops

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class PostingCache:
    """Byte-budgeted LRU over decoded posting arrays.

    Values are (N,2) int64 arrays, charged at ``arr.nbytes`` with a small
    per-entry floor (so negative-cache entries for absent keys stay
    bounded by the budget too).  Entries are namespaced by index name AT
    THE API level — ``get``/``put`` take ``(index_name, key)`` as two
    separate arguments — so different indexes whose packed integer keys
    happen to coincide numerically (e.g. an extended ``(w, v)`` key and
    a 2-word multi-component key) can never share a cache slot, and no
    caller can accidentally pass an un-namespaced key.  Cached arrays
    are marked read-only: every consumer of a posting list treats it as
    immutable, and the flag turns an accidental in-place mutation into a
    loud error instead of silent cross-query corruption.
    """

    # accounting floor per entry: map/key overhead, and the reason a
    # stream of distinct absent keys cannot grow the cache unboundedly
    MIN_CHARGE = 64

    def __init__(self, budget_bytes: int = 8 << 20):
        self.budget = int(budget_bytes)
        self._map: "OrderedDict[Tuple[str, Hashable], np.ndarray]" = OrderedDict()
        # partial tier: (prefix rows, CursorResume) per slot — settled
        # prefixes admitted by early-terminated cursors (ReaderCursor.settle)
        self._partials: "OrderedDict[Tuple[str, Hashable], Tuple[np.ndarray, object]]" = (
            OrderedDict()
        )
        # device tier: decoded rows pinned as device buffers (int32),
        # admitted beside the host tier when a device-decode reader drains
        self._device: "OrderedDict[Tuple[str, Hashable], object]" = OrderedDict()
        self.stats = CacheStats()

    def get(self, index_name: str, key: Hashable) -> Optional[np.ndarray]:
        slot = (index_name, key)
        arr = self._map.get(slot)
        if arr is None:
            self.stats.misses += 1
            return None
        self._map.move_to_end(slot)
        self.stats.hits += 1
        return arr

    def _charge(self, arr) -> int:
        return max(int(arr.nbytes), self.MIN_CHARGE)

    def _partial_charge(self, prefix: np.ndarray, resume) -> int:
        return max(
            int(prefix.nbytes) + len(resume.decoder_state[0]), self.MIN_CHARGE
        )

    def _evict(self) -> None:
        # one byte budget across ALL tiers; reclaim order mirrors value
        # density: full host lists first (cheapest to rebuild via the
        # partial), then partials, then device buffers
        while self.stats.bytes_used > self.budget:
            if self._map:
                _, victim = self._map.popitem(last=False)
                self.stats.bytes_used -= self._charge(victim)
            elif self._partials:
                _, (pfx, res) = self._partials.popitem(last=False)
                self.stats.bytes_used -= self._partial_charge(pfx, res)
            elif self._device:
                _, victim = self._device.popitem(last=False)
                self.stats.bytes_used -= self._charge(victim)
            else:
                return
            self.stats.evictions += 1

    def put(self, index_name: str, key: Hashable, arr: np.ndarray) -> None:
        if self._charge(arr) > self.budget:
            return  # bigger than the whole budget: not cacheable
        slot = (index_name, key)
        old = self._map.pop(slot, None)
        if old is not None:
            self.stats.bytes_used -= self._charge(old)
        # a full list supersedes any cached partial of the same slot
        part = self._partials.pop(slot, None)
        if part is not None:
            self.stats.bytes_used -= self._partial_charge(*part)
        # detach through a view so _frozen can never flip the CALLER's
        # handle read-only: put() borrows the array, it does not take
        # ownership (a writeable owner forces _frozen to copy instead)
        arr = _frozen(arr.view())
        self._map[slot] = arr
        self.stats.bytes_used += self._charge(arr)
        self._evict()

    # ------------------------------------------------------ partial tier --
    def get_partial(
        self, index_name: str, key: Hashable
    ) -> Optional[Tuple[np.ndarray, object]]:
        """(prefix rows, resume token) for a slot, or None.  NOT counted
        as a hit/miss — the partial tier shortens a miss, it does not
        replace one."""
        slot = (index_name, key)
        entry = self._partials.get(slot)
        if entry is None:
            return None
        self._partials.move_to_end(slot)
        return entry

    def put_partial(
        self, index_name: str, key: Hashable, prefix: np.ndarray, resume
    ) -> None:
        """Admit an early-terminated cursor's settled prefix + resume
        token.  Skipped when a FULL list for the slot is already cached
        (strictly better)."""
        slot = (index_name, key)
        if slot in self._map:
            return
        charge = self._partial_charge(prefix, resume)
        if charge > self.budget:
            return
        old = self._partials.pop(slot, None)
        if old is not None:
            self.stats.bytes_used -= self._partial_charge(*old)
        self._partials[slot] = (_frozen(prefix), resume)
        self.stats.bytes_used += charge
        self.stats.partial_admits += 1
        self._evict()

    def drop_partial(self, index_name: str, key: Hashable) -> None:
        """Discard one slot's partial (its resume token went stale)."""
        entry = self._partials.pop((index_name, key), None)
        if entry is not None:
            self.stats.bytes_used -= self._partial_charge(*entry)

    # ------------------------------------------------------- device tier --
    def get_device(self, index_name: str, key: Hashable) -> Optional[object]:
        """Device-resident decoded rows for a slot, or None."""
        slot = (index_name, key)
        buf = self._device.get(slot)
        if buf is None:
            return None
        self._device.move_to_end(slot)
        self.stats.device_hits += 1
        return buf

    def put_device(self, index_name: str, key: Hashable, buf) -> None:
        """Pin a decoded list as a device buffer beside the host entry.
        The buffer shares the byte budget (charged at its nbytes)."""
        if buf is None:
            return
        if self._charge(buf) > self.budget:
            return
        slot = (index_name, key)
        old = self._device.pop(slot, None)
        if old is not None:
            self.stats.bytes_used -= self._charge(old)
        self._device[slot] = buf
        self.stats.bytes_used += self._charge(buf)
        self._evict()

    # ----------------------------------------------------- invalidation --
    def drop_index(self, index_name: str) -> None:
        """Invalidate every entry of one index namespace (writer advanced).

        Counted as ``invalidations`` — NOT ``evictions``, which stay a pure
        capacity-pressure signal — and each entry reclaims the same
        ``_charge`` (nbytes with the ``MIN_CHARGE`` floor) it was admitted
        at, so ``bytes_used`` returns exactly to its pre-admission level
        even for floor-charged (e.g. negative-cache) entries.  Sweeps ALL
        tiers: a stale device buffer or resume token is as poisonous as a
        stale host list."""
        stale = [k for k in self._map if k[0] == index_name]
        for k in stale:
            self.stats.bytes_used -= self._charge(self._map.pop(k))
            self.stats.invalidations += 1
        stale_p = [k for k in self._partials if k[0] == index_name]
        for k in stale_p:
            self.stats.bytes_used -= self._partial_charge(*self._partials.pop(k))
            self.stats.invalidations += 1
        stale_d = [k for k in self._device if k[0] == index_name]
        for k in stale_d:
            self.stats.bytes_used -= self._charge(self._device.pop(k))
            self.stats.invalidations += 1
        self.stats.full_drops += 1

    def drop_touched(self, index_name: str, digests) -> int:
        """Targeted invalidation: drop the namespace entries whose key
        appears in any of the writer's touched-key ``digests`` (one set
        per applied part), leaving every other entry warm.

        Iterates the CACHED entries — bounded by the byte budget — not
        the digests: a part can touch most of the vocabulary, and a
        refresh that walked the digest union would cost update-sized
        work per reader even when almost none of it is cached.  Each
        dropped entry counts as an ``invalidation`` and reclaims its
        admission ``_charge``.  Applies to every tier (host, partial,
        device) under the same digest test.  Returns the number of
        entries dropped."""

        def touched(slot) -> bool:
            return slot[0] == index_name and any(slot[1] in d for d in digests)

        stale = [slot for slot in self._map if touched(slot)]
        for slot in stale:
            self.stats.bytes_used -= self._charge(self._map.pop(slot))
            self.stats.invalidations += 1
        stale_p = [slot for slot in self._partials if touched(slot)]
        for slot in stale_p:
            self.stats.bytes_used -= self._partial_charge(
                *self._partials.pop(slot)
            )
            self.stats.invalidations += 1
        stale_d = [slot for slot in self._device if touched(slot)]
        for slot in stale_d:
            self.stats.bytes_used -= self._charge(self._device.pop(slot))
            self.stats.invalidations += 1
        return len(stale) + len(stale_p) + len(stale_d)

    def __len__(self) -> int:
        return len(self._map)


class ReaderCursor:
    """Cache-aware lazy cursor over one (index, key) posting list.

    A cache hit serves the whole cached list as ONE zero-I/O chunk; a
    miss wraps the index's chunked :class:`PostingCursor` and — only if
    the cursor drains completely — assembles the full list and admits it
    to the cache, so the next reader of the key pays nothing.  An
    early-terminated cursor never caches a partial list AS a full list
    (serving a truncated list would be silent corruption) — but via
    :meth:`settle` it CAN admit its settled prefix plus a resume token
    to the cache's partial tier, so the next reader of the key replays
    the decoded prefix for free and pays I/O only past the stop point.

    ``generation`` pins the reader's writer-snapshot at open time: the
    cursor keeps serving that snapshot however long it stays open, and
    the admit path re-checks the generation so a drain that outlived an
    update can never publish its (now stale) list to the cache.
    """

    def __init__(
        self,
        inner: PostingCursor,
        on_complete: Optional[Callable[[np.ndarray], None]] = None,
        generation: Optional[int] = None,
        on_partial: Optional[Callable[[np.ndarray, object], None]] = None,
    ):
        self._inner = inner
        self._on_complete = on_complete
        self._on_partial = on_partial
        self._parts: List[np.ndarray] = []
        self._completed = False
        # open-time snapshot pin, read-only record — not an advance
        self.generation = generation  # repro-lint: allow(generation-discipline)

    def next_chunk(self) -> Optional[np.ndarray]:
        chunk = self._inner.next_chunk()
        if chunk is None:
            self._complete()
            return None
        if chunk.shape[0] and (
            self._on_complete is not None or self._on_partial is not None
        ):
            self._parts.append(chunk)
        if self._inner.exhausted:
            # the consumer has every chunk: admit the full list NOW — a
            # caller that stops polling at `exhausted` (the streaming
            # executor does) must still warm the cache
            self._complete()
        return chunk

    def _complete(self) -> None:
        if self._completed:
            return
        self._completed = True
        if self._on_complete is not None:
            if not self._parts:
                full = np.zeros((0, 2), dtype=np.int64)
            elif len(self._parts) == 1:
                full = self._parts[0]
            else:
                full = np.concatenate(self._parts, axis=0)
            # admitted lists are frozen exactly like IndexReader.lookup
            # results: a single-chunk drain would otherwise hand the
            # cache a view over a buffer the consumer can still reach
            full = _frozen(full)
            self._on_complete(full)

    def settle(self) -> bool:
        """Admit this cursor's settled prefix to the partial cache tier.

        Called by the executor when a query early-terminates: the chunks
        delivered so far plus the inner cursor's resume token (decoder
        carry included) let the NEXT reader of the key replay the prefix
        at zero I/O and fetch only past the stop point.  A no-op (False)
        when the drain completed (the full list was already admitted),
        no partial sink is wired, or the inner cursor has nothing worth
        resuming (e.g. it never fetched a real storage unit)."""
        if self._completed or self._on_partial is None:
            return False
        suspend = getattr(self._inner, "suspend", None)
        if suspend is None:
            return False
        resume = suspend()
        if resume is None:
            return False
        if not self._parts:
            prefix = np.zeros((0, 2), dtype=np.int64)
        elif len(self._parts) == 1:
            prefix = self._parts[0]
        else:
            prefix = np.concatenate(self._parts, axis=0)
        self._on_partial(_frozen(prefix), resume)
        return True

    def read_all(self) -> np.ndarray:
        """Drain the remaining chunks through :meth:`next_chunk` (NEVER
        the inner cursor's ``read_all``, which would bypass the
        accumulation above and let a later completion admit a truncated
        list to the cache).  The result is immutable, like every other
        posting list a reader hands out."""
        parts: List[np.ndarray] = []
        while True:
            chunk = self.next_chunk()
            if chunk is None:
                break
            if chunk.shape[0]:
                parts.append(chunk)
        if not parts:
            return np.zeros((0, 2), dtype=np.int64)
        full = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        return _frozen(full)

    def __getattr__(self, name):
        # the counter/bound/metadata surface (exhausted, settled_bound,
        # chunks_*, bytes_*, postings_delivered, max_doc_count — the ranked
        # executor's score upper bound) delegates to the underlying cursor
        return getattr(self._inner, name)


class IndexReader:
    """Read-only access to one :class:`InvertedIndex` snapshot.

    All lookup I/O is charged to ``self.device`` (never the writer's
    build device); decoded posting lists go through the shared LRU.
    """

    def __init__(
        self,
        index: InvertedIndex,
        device: Optional[BlockDevice] = None,
        cache: Optional[PostingCache] = None,
        cache_ns: Optional[str] = None,
        targeted: bool = True,
    ):
        self.index = index
        self.device = device if device is not None else BlockDevice(
            cluster_size=index.cfg.cluster_size, name=f"{index.name}-read"
        )
        self.cache = cache
        # cache namespace: defaults to the index name; a sharded reader
        # passes "s{shard}:{name}" so the shared cache is keyed by
        # (shard, index, key) and shards can never answer for each other
        self.cache_ns = cache_ns if cache_ns is not None else index.name
        # targeted invalidation: refresh drops only the keys the writer's
        # touched-key digests name; False forces the whole-namespace drop
        # (the pre-digest behaviour, kept as the benchmark baseline)
        self.targeted = targeted
        # the writer's PUBLISHED generation counter — NOT the physical
        # part counter ``n_parts``: checkpoint reopens bulk-apply
        # collapsed state (one part standing in for many), so a reader
        # tracking parts could believe itself current across a fold that
        # rewrote every list and skip both the targeted drop and the
        # behind-history namespace-drop fallback
        self._generation = index.generation

    # ------------------------------------------------------------ lookups --
    def lookup(self, key: Hashable) -> np.ndarray:
        if self.index.generation != self._generation:
            self.refresh()
        if self.cache is not None:
            hit = self.cache.get(self.cache_ns, key)
            if hit is not None:
                return hit
        posts = self.index.lookup(key, device=self.device)
        # readers hand out immutable postings: the same buffer is shared
        # with every later cache hit, so a mutation by the first caller
        # must fail loudly instead of corrupting other queries' results
        posts.flags.writeable = False
        if self.cache is not None:
            self.cache.put(self.cache_ns, key, posts)
        return posts

    def open_cursor(
        self,
        key: Hashable,
        chunk_clusters: int = CURSOR_CHUNK_CLUSTERS,
        make_decoder: Optional[Callable[[], object]] = None,
        device_tier: bool = False,
    ) -> ReaderCursor:
        """Lazy chunked :meth:`lookup` — the streaming executor's fetch
        primitive.  Cache hits serve one zero-I/O chunk; misses read the
        key's storage units on demand and cache the full list only if the
        cursor drains completely.

        Hit order: host tier, then device tier (``device_tier=True``:
        decoded rows pinned as device buffers are rematerialized without
        touching storage), then the partial tier (a settled prefix +
        resume token replays for free and fetches only past the stop
        point), then a fresh storage read.  ``make_decoder`` swaps the
        OWN-stream decoder (e.g. the device-backed one); a full drain
        additionally pins the rows on device when ``device_tier`` is set
        and the values fit the device integer."""
        if self.index.generation != self._generation:
            self.refresh()
        gen = self._generation
        if self.cache is not None:
            hit = self.cache.get(self.cache_ns, key)
            if hit is not None:
                return ReaderCursor(PostingCursor.from_array(hit),
                                    generation=gen)
            if device_tier:
                dev_buf = self.cache.get_device(self.cache_ns, key)
                if dev_buf is not None:
                    from repro.kernels.posting_decode.ops import from_device_rows

                    return ReaderCursor(
                        PostingCursor.from_array(from_device_rows(dev_buf)),
                        generation=gen,
                    )
        resume_entry = (
            self.cache.get_partial(self.cache_ns, key)
            if self.cache is not None else None
        )
        prefix, resume = resume_entry if resume_entry is not None else (None, None)
        inner = self.index.open_cursor(
            key,
            device=self.device,
            chunk_clusters=chunk_clusters,
            make_decoder=make_decoder,
            resume=resume,
            prefix=prefix,
        )
        if resume is not None and not inner.resumed:
            # the token no longer matches the stream's unit layout (the
            # key was repacked without a digest naming it — e.g. its
            # strategy changed): drop it so it is not retried forever
            self.cache.drop_partial(self.cache_ns, key)
        on_complete = None
        on_partial = None
        if self.cache is not None:
            def on_complete(full, key=key, gen=gen):
                # admit-time generation re-check: a cursor that stayed
                # open across a writer update still DELIVERS its open-time
                # snapshot (correct for the batch it serves), but its list
                # is stale the moment the writer advanced — admitting it
                # would poison every later lookup of the key.  The check
                # at open time alone cannot see an update that landed
                # mid-drain.
                if self.index.generation != gen:
                    return
                self.cache.put(self.cache_ns, key, full)
                if device_tier:
                    from repro.kernels.posting_decode.ops import to_device_rows

                    self.cache.put_device(
                        self.cache_ns, key, to_device_rows(full)
                    )

            def on_partial(prefix, resume, key=key, gen=gen):
                # same mid-drain staleness rule as full admission
                if self.index.generation != gen:
                    return
                self.cache.put_partial(self.cache_ns, key, prefix, resume)
        return ReaderCursor(inner, on_complete, generation=gen,
                            on_partial=on_partial)

    def lookup_ops(self, key: Hashable) -> int:
        return self.index.lookup_ops(key)

    def group_of(self, key: Hashable) -> int:
        """Dictionary group of a key — the planner's amortization unit."""
        return self.index.dict.group_of(key)

    # ------------------------------------------------------------- state --
    def refresh(self) -> str:
        """Re-snapshot after the writer published more generations.

        A no-op when the writer's *published* generation is unchanged:
        cached postings are still valid, and dropping them would turn
        every periodic refresh sweep into a full cold restart of the
        posting cache.  (Published generation, not ``n_parts``: physical
        part counts alias across checkpoint reopens and folds.)

        When the writer DID advance, the writer's per-part touched-key
        digests (``InvertedIndex.digests_since``) name exactly the keys
        whose lists changed, so only those ``(shard, index, key)`` cache
        entries are invalidated — every untouched hot key stays warm.
        The whole-namespace drop survives as the fallback for a reader so
        far behind that the bounded digest history no longer covers its
        snapshot (and as the explicit ``targeted=False`` baseline).

        Returns the catch-up mode taken — ``"current"``, ``"targeted"``
        or ``"full_drop"`` — which the replica fabric ledgers per
        replica."""
        if self.index.generation == self._generation:
            return "current"
        mode = "targeted"
        if self.cache is not None:
            digests = (
                self.index.digests_since(self._generation)
                if self.targeted else None
            )
            if digests is None:
                self.cache.drop_index(self.cache_ns)
                mode = "full_drop"
            else:
                self.cache.drop_touched(self.cache_ns, digests)
        self._generation = self.index.generation
        return mode

    def io_stats(self) -> IOStats:
        return self.device.stats.snapshot()


class IndexSetReader:
    """Readers for every index of a :class:`TextIndexSet`, one shared cache.

    Reuses the set's per-index search devices so the existing
    ``TextIndexSet.search_io()`` reporting keeps aggregating reader
    traffic.
    """

    # the executor's scatter surface: an unsharded reader is the 1-shard
    # degenerate case, so SearchService has exactly one fetch/gather path
    n_shards = 1

    def __init__(self, index_set, cache_bytes: int = 8 << 20,
                 targeted: bool = True):
        self.index_set = index_set
        self.cache = PostingCache(cache_bytes) if cache_bytes > 0 else None
        self.readers: Dict[str, IndexReader] = {
            name: IndexReader(
                idx, device=index_set.search_devices[name], cache=self.cache,
                targeted=targeted,
            )
            for name, idx in index_set.indexes.items()
        }
        self.lexicon = index_set.lexicon

    def lookup(self, index_name: str, key: Hashable) -> np.ndarray:
        return self.readers[index_name].lookup(key)

    def lookup_shard(self, shard: int, index_name: str, key: Hashable) -> np.ndarray:
        if shard != 0:
            raise IndexError(f"unsharded reader has one shard, got {shard}")
        return self.readers[index_name].lookup(key)

    def open_cursor_shard(
        self, shard: int, index_name: str, key: Hashable,
        make_decoder=None, device_tier: bool = False,
    ) -> ReaderCursor:
        """Lazy cursor over one shard's posting subset (the streaming
        executor's scatter primitive; shard 0 is the whole set here)."""
        if shard != 0:
            raise IndexError(f"unsharded reader has one shard, got {shard}")
        return self.readers[index_name].open_cursor(
            key, make_decoder=make_decoder, device_tier=device_tier
        )

    def group_of(self, index_name: str, key: Hashable) -> int:
        return self.readers[index_name].group_of(key)

    def refresh(self) -> None:
        for r in self.readers.values():
            r.refresh()

    def generation_vector(self) -> List[List[int]]:
        """Per-shard, per-index published generations (one shard entry:
        the unsharded set is the 1-shard degenerate case).  Per-index
        vectors, never a sum: summed counters alias — one index
        advancing while another folds/restores can leave the sum
        unchanged, letting a mid-batch write dodge
        ``SnapshotViolationError`` and a refresh no-op on a changed
        set.  Derived from the writers' published counters, so a direct
        ``add_part`` is never missed."""
        return [[r.index.generation for r in self.readers.values()]]

    def io_stats(self) -> Dict[str, IOStats]:
        return {name: r.io_stats() for name, r in self.readers.items()}

    @property
    def cache_stats(self) -> Optional[CacheStats]:
        return self.cache.stats if self.cache is not None else None


class ShardedIndexSetReader:
    """Per-shard :class:`IndexReader` fabric over a
    :class:`~repro.core.sharded_set.ShardedTextIndexSet`.

    One byte-budgeted :class:`PostingCache` is shared by ALL shards'
    readers, namespaced ``s{shard}:{index}`` so entries are keyed by
    (shard, index, key): hot keys of a hot shard may claim most of the
    budget (global LRU), but shards can never answer for each other, and
    a single shard's writer advancing invalidates ONLY that shard's
    entries.  Each per-shard reader charges the owning shard's search
    devices, so ``ShardedTextIndexSet.search_io_per_shard()`` keeps
    reporting true per-shard read traffic.
    """

    def __init__(self, sharded_set, cache_bytes: int = 8 << 20,
                 targeted: bool = True):
        self.index_set = sharded_set
        self.cache = PostingCache(cache_bytes) if cache_bytes > 0 else None
        self.shard_readers: List[Dict[str, IndexReader]] = [
            {
                name: IndexReader(
                    idx,
                    device=shard.search_devices[name],
                    cache=self.cache,
                    cache_ns=f"s{s}:{name}",
                    targeted=targeted,
                )
                for name, idx in shard.indexes.items()
            }
            for s, shard in enumerate(sharded_set.shards)
        ]
        self.lexicon = sharded_set.lexicon

    @property
    def n_shards(self) -> int:
        return len(self.shard_readers)

    # ------------------------------------------------------------ lookups --
    def lookup_shard(self, shard: int, index_name: str, key: Hashable) -> np.ndarray:
        """One shard's posting subset for a key (the scatter primitive)."""
        return self.shard_readers[shard][index_name].lookup(key)

    def open_cursor_shard(
        self, shard: int, index_name: str, key: Hashable,
        make_decoder=None, device_tier: bool = False,
    ) -> ReaderCursor:
        """Lazy cursor over one shard's posting subset.  Per-shard cursors
        share the set-wide posting cache under the shard's namespace, so a
        fully drained cursor warms exactly the slot ``lookup_shard`` uses."""
        return self.shard_readers[shard][index_name].open_cursor(
            key, make_decoder=make_decoder, device_tier=device_tier
        )

    def lookup(self, index_name: str, key: Hashable) -> np.ndarray:
        """Whole-set lookup: scatter to every shard, gather by merge."""
        from repro.core.sharded_set import merge_shard_postings

        return merge_shard_postings(
            [
                readers[index_name].lookup(key)
                for readers in self.shard_readers
            ]
        )

    def group_of(self, index_name: str, key: Hashable) -> int:
        # dictionary grouping is shard-invariant (identical seeds): the
        # planner stays shard-agnostic by asking shard 0
        return self.shard_readers[0][index_name].group_of(key)

    # ------------------------------------------------------------- state --
    def refresh(self) -> None:
        for readers in self.shard_readers:
            for r in readers.values():
                r.refresh()

    def generation_vector(self) -> List[List[int]]:
        """Per-shard, per-index published generations: row ``s`` moves
        exactly when shard ``s``'s update stream applied a part that
        touched it — what a snapshot-consistent batch pins in
        ``last_trace``.  Per-index vectors, never per-shard sums, for
        the aliasing reason documented on
        :meth:`IndexSetReader.generation_vector`."""
        return [
            [r.index.generation for r in readers.values()]
            for readers in self.shard_readers
        ]

    def io_stats_per_shard(self) -> List[Dict[str, IOStats]]:
        return [
            {name: r.io_stats() for name, r in readers.items()}
            for readers in self.shard_readers
        ]

    def io_stats(self) -> Dict[str, IOStats]:
        from repro.core.sharded_set import merge_io_reports

        return merge_io_reports(self.io_stats_per_shard())

    @property
    def cache_stats(self) -> Optional[CacheStats]:
        return self.cache.stats if self.cache is not None else None
