"""Central registry of every key ``SearchService.last_trace`` may carry.

The trace is the audit trail the paper's charge-accounting story hangs
off: ``check_trace_complete`` proves, after every ``search_batch``, that
each planned fetch was executed, skipped, deferred, or shared — never
silently dropped.  That proof only holds if the runtime checker and the
code writing the trace agree on the key set.  PR 7's bug class was
exactly a drift of this kind (a partition counter accumulated ``any(...)``
bools, so the "count" saturated at 1 and the partition still summed).

``TRACE_SCHEMA`` is the single source of truth, consumed from two sides:

* ``SearchService.check_trace_complete`` validates the *runtime* trace
  against it — an undeclared key, wherever it was written, raises
  ``TraceIncompleteError``;
* the static ``trace-schema`` lint pass (``repro.analysis``) validates
  every ``last_trace[...]`` write in the *source tree* against it, so a
  new key fails CI before any test drives the code path.

Adding a trace field is a two-line change: declare it here, write it in
the service.  Forgetting either half fails loudly on the other.
"""

from __future__ import annotations

from typing import Dict, FrozenSet

# Block name -> allowed keys.  "" is the top level of ``last_trace``;
# the other blocks are the nested dicts stored under the same-named
# top-level key ("topk", "cache", "replicas", "compactions").
TRACE_SCHEMA: Dict[str, FrozenSet[str]] = {
    "": frozenset({
        # scatter-fetch wave accounting (stage 2)
        "waves", "executed_waves", "skipped_waves",
        "lookups_planned", "lookups_fetched", "lookups_deferred",
        "prefetched_waves", "overlapped_finalizes", "shard_fetch_s",
        # batch-level pins and nested blocks
        "snapshot", "topk", "cache", "compactions", "replicas",
    }),
    "topk": frozenset({
        "queries", "ranked_queries",
        "early_terminated", "threshold_stops", "bound_stops",
        "fully_drained", "threshold_checks",
        "chunks_planned", "chunks_fetched", "chunks_skipped",
        "chunks_shared",
        "bytes_planned", "bytes_fetched", "bytes_skipped", "bytes_shared",
        "query_s", "pool_streams",
    }),
    "cache": frozenset({
        "hits", "misses", "evictions", "invalidations", "full_drops",
        "bytes_used", "pool_hits", "device_hits", "partial_admits",
    }),
    "replicas": frozenset({
        "n_replicas", "snapshot", "live", "failovers", "failovers_batch",
        "waves", "lookups", "cursors", "busy_s", "catch_ups",
    }),
    "compactions": frozenset({
        "compactions", "compacted_streams",
    }),
}

# Counters that participate in a completeness partition (LHS == sum of
# RHS members).  These MUST be incremented with integer expressions —
# a bool lands in the sum as 0/1 and the partition can still balance
# while the count is wrong (the PR 7 ``any(...)`` accumulation bug).
# The static trace-schema pass rejects bool-valued writes to these keys.
TRACE_COUNTERS: FrozenSet[str] = frozenset({
    "waves", "executed_waves", "skipped_waves",
    "lookups_planned", "lookups_fetched", "lookups_deferred",
    "queries", "early_terminated", "threshold_stops", "bound_stops",
    "fully_drained",
    "chunks_planned", "chunks_fetched", "chunks_skipped", "chunks_shared",
    "bytes_planned", "bytes_fetched", "bytes_skipped", "bytes_shared",
})


def validate_trace(trace: Dict[str, object]) -> str:
    """Return "" if every key in ``trace`` (top level and nested blocks)
    is declared in :data:`TRACE_SCHEMA`, else a human-readable message
    naming the first undeclared key.  Pure check — never raises — so the
    caller decides the failure type."""
    for key in trace:
        if key not in TRACE_SCHEMA[""]:
            return f"undeclared top-level trace key {key!r}"
        block = TRACE_SCHEMA.get(key)
        if block is None:
            continue
        sub = trace.get(key)
        if isinstance(sub, dict):
            for k in sub:
                if k not in block:
                    return f"undeclared trace key {k!r} in block {key!r}"
    return ""
