"""Typed query planning over the paper family's four lookup routes.

``plan_batch`` turns a batch of word-id queries into a
:class:`QueryPlan`: every query is classified (vectorized — ONE
lemmatize/classes pass over all words of the batch, replacing the old
per-word round trips) and routed down one of four paths:

  * ``ROUTE_STOPSEQ``  — all words are stop lemmas: the whole
    co-occurrence is precomputed under one stop-sequence key,
  * ``ROUTE_MULTI``    — a phrase query whose words are covered by one
    (or a small overlapping cover of) multi-component k-word keys
    (arXiv:1812.07640); the executor reconstructs the window matches
    from the NSW-style (doc, start-position) records alone,
  * ``ROUTE_WV``       — a FREQUENT lemma pairs with the other word
    through one extended (w, v) key,
  * ``ROUTE_ORDINARY`` — ordinary-index lookups + position join
    (window join, or staged phrase joins for phrase queries the
    multi index cannot cover).

The plan also carries the batch's key lookups grouped by
``(index, dictionary group)`` so the executor can fetch group-mates
together (one dictionary partition visit serves every query that needs
it) and deduplicate identical keys across the batch.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.lexicon import FREQUENT, Lexicon, STOP
from repro.data.corpus import PAIR_SHIFT, SEQ2_FLAG, SEQ_SHIFT
from repro.search.scoring import ScoreSpec, spec_for

ROUTE_STOPSEQ = "stopseq"
ROUTE_MULTI = "multi"
ROUTE_WV = "wv"
ROUTE_ORDINARY = "ordinary"

ROUTES = (ROUTE_STOPSEQ, ROUTE_MULTI, ROUTE_WV, ROUTE_ORDINARY)

# proximity queries stay at the paper's 2-3 words; phrase queries may be
# longer — the multi route covers them with overlapping k-word keys
MAX_PHRASE_WORDS = 8


@dataclasses.dataclass(frozen=True)
class MultiKeySpec:
    """Planner view of the multi-component key index: tuple width ``k``,
    the key packing, and the phrase cover — all owned by the index itself
    (:meth:`~repro.core.multi_key.MultiKeyIndex.cover_keys`)."""

    k: int
    pack: Callable[[Sequence[int]], int]
    name: str = "multi"
    cover: Optional[Callable[[Sequence[int]], List[int]]] = None

    def cover_keys(self, lemmas: Sequence[int]) -> List[int]:
        if self.cover is not None:
            if len(lemmas) < self.k:
                # the index's cover validates too; fail here so a bad
                # call can never surface later as a zero-lookup plan
                raise ValueError(
                    f"phrase of {len(lemmas)} lemmas cannot be covered "
                    f"by {self.k}-word keys"
                )
            return list(self.cover(lemmas))
        # fallback for specs built without a cover: the shared derivation
        from repro.core.multi_key import phrase_cover_keys

        return phrase_cover_keys(self.pack, self.k, lemmas)


@dataclasses.dataclass(frozen=True)
class Query:
    """One query: word ids + an optional per-query window.

    ``phrase=True`` asks for ordered-contiguous semantics (word j at
    start+j) — the stop-sequence index's semantics extended to arbitrary
    words; ``window`` is ignored for phrase queries.  Proximity queries
    are 2-3 words; phrase queries may be up to ``MAX_PHRASE_WORDS``.

    ``top_k=N`` asks for the *best-k result mode*: only the N best
    matching documents with their witness postings and per-doc scores.
    The executor serves it through the streaming stage: per-key posting
    records are consumed in sorted (doc, start) order via lazy cursors
    and fetching stops once the top-k set is provably settled.  What
    "best" means is chosen by ``rank``:

      * ``rank=None`` (default) — doc-id order: the N lowest matching doc
        ids (the collection is indexed in arrival order, so the lowest
        ids are the canonical head); scores are match-occurrence counts.
        Element-wise identical to the exhaustive path's first N docs.
      * ``rank="prox"`` — score order: the N best documents under the
        proximity × saturating-tf score of ``repro.search.scoring``,
        ties broken by ascending doc id, pruned WAND-style via per-key
        upper bounds.  Element-wise identical (docs, scores, tie order)
        to exhaustively scoring every match and stable-sorting.

    ``rank`` requires ``top_k`` — a ranked exhaustive result would just
    be a permutation the caller can apply themselves.
    """

    words: Tuple[int, ...]
    window: Optional[int] = None
    phrase: bool = False
    top_k: Optional[int] = None
    rank: Optional[str] = None

    def __post_init__(self):
        if self.phrase:
            if not 2 <= len(self.words) <= MAX_PHRASE_WORDS:
                raise ValueError(
                    f"phrase queries are 2-{MAX_PHRASE_WORDS} words, "
                    f"got {len(self.words)}"
                )
        elif not 2 <= len(self.words) <= 3:
            raise ValueError(f"queries are 2-3 words, got {len(self.words)}")
        if self.top_k is not None and self.top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {self.top_k}")
        if self.rank is not None:
            if self.rank != "prox":
                raise ValueError(
                    f"rank must be None or 'prox', got {self.rank!r}"
                )
            if self.top_k is None:
                raise ValueError("rank= requires top_k= (best-k mode)")


@dataclasses.dataclass(frozen=True)
class KeyLookup:
    """One (index, key) posting fetch; ``group`` is the dictionary group."""

    index: str
    key: int
    group: int


@dataclasses.dataclass
class PlannedQuery:
    query: Query
    route: str
    lookups: List[KeyLookup]
    window: int
    # best-k result mode: set when the query asked for top_k — the
    # executor routes these lookups down the streaming (lazy cursor)
    # stage instead of the batch scatter-fetch waves
    top_k: Optional[int] = None
    # score-ordered best-k: rank mode + the frozen per-slot score recipe
    # (set iff the query asked for rank=; see repro.search.scoring)
    rank: Optional[str] = None
    score_spec: Optional[ScoreSpec] = None


@dataclasses.dataclass
class QueryPlan:
    """Executable plan for a batch of queries."""

    queries: List[PlannedQuery]
    # all *unique* lookups of the batch, grouped by (index, dict group)
    grouped: Dict[Tuple[str, int], List[KeyLookup]]

    @property
    def n_unique_lookups(self) -> int:
        return sum(len(v) for v in self.grouped.values())

    def route_census(self) -> Dict[str, int]:
        census = {r: 0 for r in ROUTES}
        for pq in self.queries:
            census[pq.route] += 1
        return census


@dataclasses.dataclass
class QueryResult:
    docs: np.ndarray                 # matched doc ids (unique, sorted)
    witnesses: np.ndarray            # (N,2) witness postings
    lookups: List[Tuple[str, int]]   # (index, key) lookups performed
    postings_scanned: int            # total postings decoded
    route: Optional[str] = None      # which planner route produced this
    # per-doc score, aligned with ``docs``.  Exhaustive and doc-id top-k
    # results carry match-occurrence counts; ranked (rank="prox") results
    # carry the proximity × saturating-tf scores of the returned head,
    # with ``docs`` in (score desc, doc id asc) order.  Mandatory on
    # every executor path — a missing-scores side never compares equal
    # to a scored one.
    scores: Optional[np.ndarray] = None

    def __eq__(self, other) -> bool:  # element-wise identity for tests
        return (
            isinstance(other, QueryResult)
            and np.array_equal(self.docs, other.docs)
            and np.array_equal(self.witnesses, other.witnesses)
            and self.lookups == other.lookups
            and self.postings_scanned == other.postings_scanned
            # scores are part of the identity: both sides must agree on
            # HAVING them, then on every element.  (The old "either side
            # may omit" escape hatch let an executor that silently
            # dropped scores pass every oracle.)
            and (self.scores is None) == (other.scores is None)
            and (
                self.scores is None
                or np.array_equal(self.scores, other.scores)
            )
        )


def classify_batch(
    lexicon: Lexicon, queries: Sequence[Query]
) -> Tuple[np.ndarray, np.ndarray, List[slice]]:
    """One vectorized lemmatize+classify pass over all words of the batch.

    Returns (lemmas, classes) flat over the concatenated query words plus
    the per-query slice into them.
    """
    spans: List[slice] = []
    flat: List[int] = []
    for q in queries:
        spans.append(slice(len(flat), len(flat) + len(q.words)))
        flat.extend(q.words)
    words = np.asarray(flat, dtype=np.int64)
    if words.size == 0:
        return words, words, spans
    lemmas, classes = lexicon.classify_words(words)
    return lemmas, classes, spans


def _planned(
    query: Query,
    route: str,
    lookups: List[KeyLookup],
    window: int,
    max_distance: Optional[int],
) -> PlannedQuery:
    """Construct the planned query, attaching the frozen score spec when
    the query asked for ranked best-k (one weight per lookup slot)."""
    spec = None
    if query.rank is not None:
        spec = spec_for(
            route,
            len(lookups),
            window,
            max_distance if max_distance is not None else window,
            phrase=query.phrase,
        )
    return PlannedQuery(
        query, route, lookups, window,
        top_k=query.top_k, rank=query.rank, score_spec=spec,
    )


def plan_query(
    lemmas: np.ndarray,
    classes: np.ndarray,
    query: Query,
    lexicon: Lexicon,
    group_of,
    window: int,
    multi: Optional[MultiKeySpec] = None,
    max_distance: Optional[int] = None,
) -> PlannedQuery:
    """Route one classified query (mirrors the paper's decision order,
    with the multi-component route slotted between stopseq and (w, v))."""
    lem = [int(x) for x in lemmas]
    cls = [int(x) for x in classes]

    if all(c == STOP for c in cls) and len(lem) <= 3:
        if len(lem) == 2:
            key = int(SEQ2_FLAG | (lem[0] << SEQ_SHIFT) | lem[1])
        else:
            key = int(
                (lem[0] << (2 * SEQ_SHIFT)) | (lem[1] << SEQ_SHIFT) | lem[2]
            )
        lk = KeyLookup("stopseq", key, group_of("stopseq", key))
        return _planned(query, ROUTE_STOPSEQ, [lk], window, max_distance)

    if query.phrase and multi is not None and len(lem) >= multi.k:
        # cover the phrase with L-k+1 overlapping k-word keys (the cover
        # is owned by the index: key j's records sit at start+j); the
        # executor intersects them at their fixed start-position offsets
        lookups = [
            KeyLookup(multi.name, key, group_of(multi.name, key))
            for key in multi.cover_keys(lem)
        ]
        return _planned(query, ROUTE_MULTI, lookups, window, max_distance)

    freq_i = next((i for i, c in enumerate(cls) if c == FREQUENT), None)
    if (
        freq_i is not None
        and len(query.words) == 2
        and not query.phrase
        # (w, v) records are precomputed at max_distance and carry only
        # w's position, so a NARROWER window cannot be applied to them —
        # those queries take the ordinary route, which honors the window
        and (max_distance is None or window >= max_distance)
    ):
        # (w, v) records carry only w's position — enough for window
        # proximity, not for reconstructing a phrase match
        w = lem[freq_i]
        v = lem[1 - freq_i]
        key = int((w << PAIR_SHIFT) | v)
        name = "wv_kk" if v < lexicon.n_lemmas else "wv_ku"
        lk = KeyLookup(name, key, group_of(name, key))
        return _planned(query, ROUTE_WV, [lk], window, max_distance)

    lookups = []
    for lemma in lem:
        name = "unknown" if lemma >= lexicon.n_lemmas else "known"
        lookups.append(KeyLookup(name, lemma, group_of(name, lemma)))
    return _planned(query, ROUTE_ORDINARY, lookups, window, max_distance)


def plan_batch(
    queries: Sequence[Query],
    lexicon: Lexicon,
    group_of,
    default_window: int,
    multi: Optional[MultiKeySpec] = None,
    max_distance: Optional[int] = None,
) -> QueryPlan:
    """Plan a batch: classify all words at once, route each query, group
    the batch's unique lookups by (index, dictionary group)."""
    lemmas, classes, spans = classify_batch(lexicon, queries)
    planned = [
        plan_query(
            lemmas[span], classes[span], q, lexicon, group_of,
            q.window if q.window is not None else default_window,
            multi=multi, max_distance=max_distance,
        )
        for q, span in zip(queries, spans)
    ]
    grouped: Dict[Tuple[str, int], List[KeyLookup]] = {}
    seen = set()
    for pq in planned:
        for lk in pq.lookups:
            ident = (lk.index, lk.key)
            if ident in seen:
                continue
            seen.add(ident)
            grouped.setdefault((lk.index, lk.group), []).append(lk)
    return QueryPlan(planned, grouped)
