"""Ranked-retrieval scoring for the streaming top-k executor.

The sequel paper (arXiv:2108.00410, "Relevance ranking for proximity
full-text search based on additional indexes with multi-component keys")
ranks documents by combining a *proximity* contribution — how tightly the
query words co-occur, which is exactly what the (w, v) and multi-component
key records encode — with a tf-style *occurrence* weight.  This module is
the single source of truth for that score on both executor paths:

  * ``score_docs``      — the numpy int64 reference,
  * ``score_docs_jax``  — the same arithmetic in a power-of-two-padded
    (bucketable) form for the jax / pallas backends, int32 on device.

**Model.**  Each planned lookup occurrence (a *slot*) contributes
``w_slot * tf_sat(c_slot(doc))`` where ``c_slot(doc)`` is the number of
postings of the slot's key in that document and ``tf_sat`` saturates at
``TF_CAP``.  ``w_slot`` is the proximity weight of the route's record
distance ``d``: phrase / multi / stop-sequence records witness adjacent
words (``d = 1``), (w, v) records are precomputed at ``max_distance``,
ordinary-route slots get the query window.  All-integer arithmetic —
``PROX_SCALE // (1 + d)`` weights, integer counts, integer cap — makes
the score *exact*, so numpy / jax / pallas and every shard count produce
element-wise identical ranked heads (no float tolerance anywhere).

**Why counts are per-slot key postings** (not join-witness rows): the
streaming executor settles doc-id regions that contain *every* posting of
every slot for the settled docs, and the exhaustive oracle can recount
the same quantity from whole-list lookups — the two paths compute the
identical integer without sharing any code path.

**Why tf saturates.**  The saturation is what makes WAND-style pruning
possible at all: a slot's score contribution is bounded by
``w_slot * min(max_doc_count, TF_CAP)`` where ``max_doc_count`` (carried
on the dictionary entry and its cursors) is the key's largest per-doc
posting count.  Without the cap the upper bound would grow with the
largest document and the threshold test would almost never fire.

``head_order`` pins the deterministic result order shared by the
executor and the test oracles: ranked mode sorts (score desc, doc id
asc); doc-id mode keeps ascending doc ids.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.postings import max_doc_run

__all__ = [
    "PROX_SCALE",
    "TF_CAP",
    "ScoreSpec",
    "doc_counts",
    "head_order",
    "max_doc_run",
    "prox_weight",
    "score_docs",
    "score_docs_jax",
    "slot_upper_bound",
    "spec_for",
    "tf_sat",
]

# integer proximity scale: weight of distance d is PROX_SCALE // (1 + d),
# i.e. 12 / 8 / 6 / ... for d = 1, 2, 3, ...  (never below 1)
PROX_SCALE = 24

# tf saturation: per-slot occurrence counts beyond this add nothing.
# Kept small on purpose — it is the lever that lets the k-th settled
# score actually reach a cursor's upper bound (see module docstring).
TF_CAP = 4


def prox_weight(distance: int) -> int:
    """Integer proximity weight of a record distance (>= 1 always)."""
    return max(1, PROX_SCALE // (1 + max(1, int(distance))))


@dataclasses.dataclass(frozen=True)
class ScoreSpec:
    """Frozen per-query scoring recipe: one integer weight per lookup
    occurrence (slot), plus the shared tf saturation cap.  Attached to
    ``PlannedQuery`` by the planner when ``Query.rank`` is set."""

    weights: Tuple[int, ...]
    tf_cap: int = TF_CAP

    @property
    def max_score(self) -> int:
        """Largest score any document can reach under this spec."""
        return sum(w * self.tf_cap for w in self.weights)


def spec_for(
    route: str,
    n_slots: int,
    window: int,
    max_distance: int,
    phrase: bool = False,
) -> ScoreSpec:
    """Build the score spec for one planned query.

    Route strings are compared literally to avoid a circular import with
    the planner (which imports this module for the spec type).
    """
    if phrase or route in ("stopseq", "multi"):
        d = 1  # the records witness adjacent words
    elif route == "wv":
        d = int(max_distance)  # (w, v) records precomputed at max_distance
    else:
        d = int(window)
    return ScoreSpec(weights=(prox_weight(d),) * int(n_slots))


def tf_sat(counts: np.ndarray, cap: int = TF_CAP) -> np.ndarray:
    """Saturating term frequency: ``min(count, cap)``."""
    return np.minimum(counts, cap)


def slot_upper_bound(weight: int, max_doc_count: int, cap: int = TF_CAP) -> int:
    """Largest score contribution one slot can make to any document."""
    return int(weight) * min(int(max_doc_count), int(cap))


def doc_counts(docs: np.ndarray, posts: np.ndarray) -> np.ndarray:
    """Postings-per-doc of a doc-sorted (N, 2) array for each of ``docs``
    (ascending doc ids), via two binary searches — no join required."""
    if docs.shape[0] == 0:
        return np.zeros(0, dtype=np.int64)
    col = posts[:, 0] if posts.shape[0] else np.zeros(0, dtype=np.int64)
    lo = np.searchsorted(col, docs, side="left")
    hi = np.searchsorted(col, docs, side="right")
    return (hi - lo).astype(np.int64)


def score_docs(slot_counts: Sequence[np.ndarray], spec: ScoreSpec) -> np.ndarray:
    """Numpy reference: sum of per-slot weighted saturated counts."""
    if not slot_counts:
        return np.zeros(0, dtype=np.int64)
    total = np.zeros(slot_counts[0].shape[0], dtype=np.int64)
    for w, c in zip(spec.weights, slot_counts):
        total += int(w) * tf_sat(np.asarray(c, dtype=np.int64), spec.tf_cap)
    return total


def _pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


@functools.lru_cache(maxsize=None)
def _jitted_score(n_slots: int, n_docs: int, cap: int):
    import jax
    import jax.numpy as jnp

    def f(counts, weights):
        return jnp.sum(
            weights[:, None] * jnp.minimum(counts, jnp.int32(cap)), axis=0
        )

    return jax.jit(f)


def score_docs_jax(
    slot_counts: Sequence[np.ndarray], spec: ScoreSpec
) -> np.ndarray:
    """Device form of :func:`score_docs` for the jax / pallas backends.

    Counts are packed into an (S, N) int32 matrix with N padded to the
    next power of two, so concurrent queries of similar size share one
    compiled bucket (the same bucketing discipline as the window joins).
    Weights, counts and the cap all fit int32 by construction
    (``spec.max_score <= PROX_SCALE * TF_CAP * n_slots``), so the result
    is bit-identical to the int64 numpy reference.
    """
    if not slot_counts:
        return np.zeros(0, dtype=np.int64)
    n = slot_counts[0].shape[0]
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    import jax.numpy as jnp

    nb = _pow2(n)
    mat = np.zeros((len(slot_counts), nb), dtype=np.int32)
    for s, c in enumerate(slot_counts):
        # counts above the cap score identically: clip before the int32
        # narrowing so a pathological count cannot overflow the device form
        mat[s, :n] = np.minimum(np.asarray(c, dtype=np.int64), spec.tf_cap)
    w = np.asarray(spec.weights, dtype=np.int32)
    fn = _jitted_score(len(slot_counts), nb, int(spec.tf_cap))
    out = np.asarray(fn(jnp.asarray(mat), jnp.asarray(w)))
    return out[:n].astype(np.int64)


def head_order(
    docs: np.ndarray, scores: np.ndarray, k: int, ranked: bool
) -> np.ndarray:
    """Indices of the deterministic best-k head — THE shared tie rule.

    Ranked mode: score descending, doc id ascending within a tie (stable
    and total, so the head is unique and a k-prefix of the k+1 head).
    Doc-id mode: ascending doc ids (``docs`` comes from ``np.unique``).
    Both the streaming executor head and the exhaustive oracle head go
    through this one function, so they cannot disagree on tie order.
    """
    n = int(docs.shape[0])
    k = min(int(k), n)
    if not ranked:
        return np.arange(k)
    order = np.lexsort((docs, -np.asarray(scores, dtype=np.int64)))
    return order[:k]
