"""Cross-query shared chunk pool: one physical drain per hot key.

A hot-vocabulary batch opens the SAME ``(shard, index, key)`` posting
stream once per query; without sharing, every cursor re-fetches (or at
best re-serves from cache) the same chunks, so read traffic scales with
the query count.  The :class:`ChunkPool` deduplicates at the chunk
level WITHIN a batch: the first cursor opened for an identity owns the
physical :class:`~repro.search.reader.ReaderCursor`; the pool records
every chunk it yields, and every other cursor for the identity replays
the recorded chunks at zero I/O, fetching a NEW physical chunk only
when it advances past the recorded frontier.  Physical bytes are
charged exactly once — to whichever view triggered the fetch — and
replays are ledgered as ``chunks_shared``/``bytes_shared``, so the
per-view trace invariant becomes

    chunks_planned == chunks_fetched + chunks_shared + chunks_skipped

(bytes likewise) and summing ``chunks_fetched`` over a batch counts
every physical chunk exactly once (``check_trace_complete`` pins this).

Snapshot safety: a pool lives for ONE batch, and every view serves the
open-time snapshot the shared inner cursor pinned — the same guarantee
a private cursor gives.  The pool never outlives the batch precisely so
a writer update between batches cannot leak a stale drain across the
generation check.
"""

from __future__ import annotations

from typing import Callable, Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.search.reader import CacheStats, ReaderCursor


class _SharedStream:
    """One identity's physical cursor plus the replay log of its chunks.

    ``chunks`` holds ``(rows, nbytes)`` per yielded chunk, where
    ``nbytes`` is the physical charge measured as the inner cursor's
    ``bytes_fetched`` delta — so replaying views account the exact bytes
    the original fetch paid (zero for a cache-hit chunk)."""

    def __init__(self, inner: ReaderCursor):
        self.inner = inner
        self.chunks: List[Tuple[np.ndarray, int]] = []

    def extend(self) -> bool:
        """Fetch one more physical chunk into the log; False at EOF."""
        before = self.inner.bytes_fetched
        chunk = self.inner.next_chunk()
        if chunk is None:
            return False
        self.chunks.append((chunk, self.inner.bytes_fetched - before))
        return True


class PooledCursor:
    """One query's view over a shared stream.

    Quacks like a :class:`~repro.core.inverted_index.PostingCursor`:
    ``next_chunk``/``exhausted``/``settled_bound`` plus the full counter
    surface, all PER VIEW — two views of one stream each see the whole
    chunk sequence and keep independent positions, but only the view
    that advances the shared frontier is charged the fetch; the others
    ledger a replay (``chunks_shared``/``bytes_shared``).
    """

    def __init__(self, stream: _SharedStream, first: bool,
                 stats: Optional[CacheStats] = None):
        self._stream = stream
        self._first = first  # the view that opened the physical cursor
        self._pos = 0
        self._stats = stats
        self.chunks_fetched = 0
        self.bytes_fetched = 0
        self.chunks_shared = 0
        self.bytes_shared = 0
        self.postings_delivered = 0
        self.last_doc: Optional[int] = None

    # totals and metadata delegate to the one physical cursor
    @property
    def chunks_total(self) -> int:
        return self._stream.inner.chunks_total

    @property
    def bytes_total(self) -> int:
        return self._stream.inner.bytes_total

    @property
    def max_doc_count(self) -> int:
        return self._stream.inner.max_doc_count

    @property
    def exhausted(self) -> bool:
        return (
            self._pos >= len(self._stream.chunks)
            and self._stream.inner.exhausted
        )

    @property
    def settled_bound(self) -> float:
        if self.exhausted:
            return float("inf")
        if self.last_doc is None:
            return float("-inf")
        return float(self.last_doc)

    @property
    def resumed(self) -> bool:
        """Whether the shared physical cursor resumed a settled prefix
        (``CursorResume`` from the cache's partial tier)."""
        return bool(getattr(self._stream.inner, "resumed", False))

    @property
    def prepaid(self) -> bool:
        """True while this view's next chunk costs zero device bytes:
        a replay of an already-logged chunk (the fetching view paid), or
        the inner cursor's own next chunk is prepaid (a resumed settled
        prefix / cache-hit rows).  Without this, a view over a warm
        resumed stream reports ``settled_bound == -inf`` until the
        executor happens to poll it — the executor instead drains
        prepaid chunks at open, seeding ``last_doc`` from the resumed
        prefix exactly like a private ``ReaderCursor`` gets seeded.
        Replays of chunks another view PAID for stay lazy (zero marginal
        cost, but they are real fetch-frontier data — the executor's
        bound loop decides if they are needed at all)."""
        if self._pos < len(self._stream.chunks):
            return self._stream.chunks[self._pos][1] == 0
        inner = self._stream.inner
        return not inner.exhausted and bool(getattr(inner, "prepaid", False))

    @property
    def chunks_skipped(self) -> int:
        return self.chunks_total - self.chunks_fetched - self.chunks_shared

    @property
    def bytes_skipped(self) -> int:
        return self.bytes_total - self.bytes_fetched - self.bytes_shared

    def next_chunk(self) -> Optional[np.ndarray]:
        if self._pos < len(self._stream.chunks):
            chunk, nbytes = self._stream.chunks[self._pos]
            self.chunks_shared += 1
            self.bytes_shared += nbytes
            if self._stats is not None:
                self._stats.pool_hits += 1
        else:
            if not self._stream.extend():
                return None
            chunk, nbytes = self._stream.chunks[self._pos]
            self.chunks_fetched += 1
            self.bytes_fetched += nbytes
        self._pos += 1
        if chunk.shape[0]:
            self.last_doc = int(chunk[-1, 0])
            self.postings_delivered += chunk.shape[0]
        return chunk

    def read_all(self) -> np.ndarray:
        parts: List[np.ndarray] = []
        while True:
            chunk = self.next_chunk()
            if chunk is None:
                break
            if chunk.shape[0]:
                parts.append(chunk)
        if not parts:
            return np.zeros((0, 2), dtype=np.int64)
        return parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)


class ChunkPool:
    """Per-batch registry of shared streams, keyed by cursor identity.

    ``cursor(ident, opener)`` returns a :class:`PooledCursor` view; the
    first call for an identity invokes ``opener`` to open the physical
    cursor, later calls share it.  ``streams()`` exposes the physical
    cursors so the batch teardown can :meth:`ReaderCursor.settle` each
    one exactly once (per-view settling would admit duplicate partials).
    """

    def __init__(self, stats: Optional[CacheStats] = None):
        self._streams: Dict[Hashable, _SharedStream] = {}
        self._stats = stats

    def cursor(
        self, ident: Hashable, opener: Callable[[], ReaderCursor]
    ) -> PooledCursor:
        stream = self._streams.get(ident)
        first = stream is None
        if first:
            stream = _SharedStream(opener())
            self._streams[ident] = stream
        return PooledCursor(stream, first, stats=self._stats)

    def streams(self) -> List[ReaderCursor]:
        """The physical cursors, one per distinct identity."""
        return [s.inner for s in self._streams.values()]

    def __len__(self) -> int:
        return len(self._streams)
