"""Position-join backends for proximity search.

The window join is the query-side hot spot of the ordinary+join route:
given two posting lists sorted by (doc, pos), keep the rows of ``a``
that have a row of ``b`` in the same doc within ``window`` positions.

Three interchangeable backends:

  * ``numpy_window_join``   — host oracle (searchsorted over packed keys),
  * ``jax_window_join``     — jit-compiled, padded to powers of two; the
    batched variant ``batched_window_mask`` joins many (a, b) pairs of the
    same padded shape in ONE kernel launch (vmapped searchsorted),
  * ``pallas_window_join``  — doc-level prefilter through the Pallas
    ``intersect`` kernel (dense tile compare on TPU), then an exact host
    window join over the surviving rows.

Key packing is explicit everywhere: ``pos_scale`` picks the smallest
power of two that can hold ``max_pos + window + 1``, so ``doc * scale +
pos ± window`` never crosses a doc boundary, and the int32-vs-int64
decision is made from the *packed key range* — never from whatever dtype
``jnp.asarray`` happens to produce (without x64, JAX silently truncates
int64 inputs to int32, which used to flip the scale choice and corrupt
joins for doc ids beyond the 24-bit packing range).
"""

from __future__ import annotations

from functools import partial
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

_INT32_SAFE = np.int64(np.iinfo(np.int32).max)


# ----------------------------------------------------------- key packing --
def pos_scale(max_pos: int, window: int) -> int:
    """Smallest power of two > max_pos + window (explicit, data-driven)."""
    need = int(max_pos) + int(window) + 1
    scale = 1
    while scale < need:
        scale <<= 1
    return scale


def pack_keys(
    a: np.ndarray, b: np.ndarray, window: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pack (doc, pos) rows into sortable int64 scalar keys.

    Returns ``(akey, bkey, scale)`` with ``key = doc * scale + pos``;
    ``scale`` leaves headroom so ``key ± window`` stays inside the doc.
    """
    max_pos = int(max(a[:, 1].max(), b[:, 1].max())) if a.size and b.size else 0
    scale = pos_scale(max_pos, window)
    akey = a[:, 0] * np.int64(scale) + a[:, 1]
    bkey = b[:, 0] * np.int64(scale) + b[:, 1]
    return akey, bkey, scale


# ------------------------------------------------------------ numpy oracle --
def numpy_window_join(a: np.ndarray, b: np.ndarray, window: int) -> np.ndarray:
    """Rows of ``a`` having a row of ``b`` with the same doc and
    |pos_a - pos_b| <= window.  Both (N,2), sorted by (doc, pos)."""
    if a.size == 0 or b.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    akey, bkey, _ = pack_keys(a, b, window)
    lo = np.searchsorted(bkey, akey - window)
    hi = np.searchsorted(bkey, akey + window, side="right")
    return a[hi > lo]


def numpy_phrase_join(a: np.ndarray, b: np.ndarray, dist: int) -> np.ndarray:
    """Rows of ``a`` where ``b`` has the same doc at exactly pos_a + dist
    (ordered adjacency — the stop-sequence index semantics)."""
    if a.size == 0 or b.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    akey, bkey, _ = pack_keys(a, b, dist)
    want = akey + dist
    i = np.searchsorted(bkey, want)
    i = np.minimum(i, bkey.shape[0] - 1)
    return a[bkey[i] == want]


# ---------------------------------------------------------------- jax path --
@jax.jit
def _window_mask(akey: jnp.ndarray, bkey: jnp.ndarray, window: jnp.ndarray):
    lo = jnp.searchsorted(bkey, akey - window)
    hi = jnp.searchsorted(bkey, akey + window, side="right")
    return hi > lo


@jax.jit
def batched_window_mask(
    akeys: jnp.ndarray, bkeys: jnp.ndarray, windows: jnp.ndarray
) -> jnp.ndarray:
    """Join B pairs at once: (B,N) x (B,M) packed keys -> (B,N) bool mask.

    One compiled kernel per (B, N, M) shape; the executor buckets jobs into
    power-of-two shapes so the variant count stays tiny.
    """

    def one(ak, bk, w):
        lo = jnp.searchsorted(bk, ak - w)
        hi = jnp.searchsorted(bk, ak + w, side="right")
        return hi > lo

    return jax.vmap(one)(akeys, bkeys, windows)


def _jax_dtype_for(max_key: int, window: int) -> Optional[np.dtype]:
    """Pick the device dtype the packed keys survive in, or None."""
    if max_key + window < int(_INT32_SAFE):
        return np.int32
    if jax.config.jax_enable_x64:
        return np.int64
    return None  # keys do not fit the device integer width


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def jax_window_join(a: np.ndarray, b: np.ndarray, window: int) -> np.ndarray:
    """JAX path: pack keys host-side, pad to the next power of two, join.

    Falls back to the numpy oracle when the packed keys cannot be
    represented on the device (x64 disabled and keys beyond int32) — a
    silent wrong answer is never an option.
    """
    if a.size == 0 or b.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    akey, bkey, _ = pack_keys(a, b, window)
    dtype = _jax_dtype_for(int(max(akey[-1], bkey[-1])), window)
    if dtype is None:
        return numpy_window_join(a, b, window)

    def pad(key: np.ndarray, fill: int) -> np.ndarray:
        n = _pow2(key.shape[0])
        return np.concatenate(
            [key.astype(dtype), np.full((n - key.shape[0],), fill, dtype)]
        )

    big = np.iinfo(dtype).max
    # b pads ABOVE every real a-key + window (the dtype gate guarantees
    # real keys stay below big - window), so padding can never witness a
    # hit; a pads stay clear of +window overflow — their mask rows are
    # sliced away below
    pa = pad(akey, big - window - 1)
    pb = pad(bkey, big)
    mask = np.asarray(_window_mask(jnp.asarray(pa), jnp.asarray(pb),
                                   jnp.asarray(window, dtype)))
    return a[mask[: a.shape[0]]]


# --------------------------------------------------------- pallas backend --
def pallas_window_join(a: np.ndarray, b: np.ndarray, window: int) -> np.ndarray:
    """Doc-level prefilter with the Pallas intersect kernel, exact finish.

    The kernel computes membership of ``a``'s doc ids in ``b``'s doc ids
    (dense tile compare — the TPU-native formulation); only rows in common
    docs reach the exact host window join, which on real queries is a tiny
    fraction of the input.
    """
    if a.size == 0 or b.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    from repro.kernels.intersect.ops import doc_member_mask

    mask = doc_member_mask(a[:, 0], b[:, 0])
    if mask is None:  # doc ids beyond the kernel's int32 keys
        return numpy_window_join(a, b, window)
    a_hit = a[mask]
    if a_hit.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    b_hit = b[np.isin(b[:, 0], np.unique(a_hit[:, 0]))]
    return numpy_window_join(a_hit, b_hit, window)


JOIN_BACKENDS = {
    "numpy": numpy_window_join,
    "jax": jax_window_join,
    "pallas": pallas_window_join,
}
