"""Production meshes.

Single pod:  (16, 16)      axes (data, model)  = 256 chips (one v5e pod)
Multi pod:   (2, 16, 16)   axes (pod, data, model) = 512 chips

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for smoke tests on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware model for the roofline (single chip)
HW = {
    "name": "tpu-v5e",
    "peak_bf16_flops": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link (~4 links usable per chip)
    "dci_bw": 6.25e9,            # B/s per chip cross-pod (data-center links)
    "hbm_bytes": 16 * 2**30,
}
