"""Training launcher: --arch <id> with the full substrate.

On this CPU container use --reduced (default) for a runnable
demonstration; on a TPU slice drop --reduced and pass --mesh single to
shard the full config over the production mesh (params/opt/batch
shardings come from the same policy engine the dry-run validates).

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --steps 200 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_bundle
from repro.train.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def synth_lm_batches(vocab: int, batch: int, seq: int):
    def fn(cursor: int):
        rng = np.random.RandomState(cursor)
        toks = np.sort(rng.zipf(1.5, size=(batch, seq)) % vocab, axis=1)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1
        return {
            "tokens": jnp.asarray(toks, jnp.int32),
            "labels": jnp.asarray(labels, jnp.int32),
        }

    return fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full (assigned) config — needs a real TPU slice")
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    bundle = get_bundle(args.arch, reduced=not args.full)
    if bundle.family != "lm":
        raise SystemExit(
            f"{args.arch} is a {bundle.family} arch; this launcher drives "
            "the LM family (see examples/ for the others)"
        )
    cfg = bundle.config
    params = bundle.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={args.arch} params={n/1e6:.1f}M mesh={args.mesh}")

    jit_kwargs = {}
    mesh = None
    if args.mesh != "host":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        pshard = bundle.param_shardings(mesh)
        params = jax.device_put(params, pshard)

    from repro.models.transformer import lm_loss

    trainer = Trainer(
        lambda p, b: lm_loss(cfg, p, b["tokens"], b["labels"])[0],
        params,
        TrainerConfig(
            opt=OptConfig(lr=3e-3, schedule="wsd", warmup_steps=20,
                          total_steps=args.steps),
            microbatches=args.microbatches,
            compress_grads=args.compress_grads,
            ckpt_dir=args.ckpt_dir or None,
            ckpt_every=100,
            log_every=20,
        ),
        jit_kwargs=jit_kwargs,
    )
    if args.ckpt_dir and trainer.try_resume():
        print(f"resumed at step {trainer.step_num}")

    batches = synth_lm_batches(cfg.vocab, args.batch, args.seq)
    t0 = time.time()
    if mesh is not None:
        with mesh:
            last = trainer.fit(batches, args.steps)
    else:
        last = trainer.fit(batches, args.steps)
    dt = time.time() - t0
    print(f"done: {trainer.step_num} steps in {dt:.1f}s, metrics={last}")


if __name__ == "__main__":
    main()
