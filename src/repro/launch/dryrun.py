import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any other import (jax locks the
# device count at first initialization).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=...).lower(*abstract_args)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective parse

Results are cached as JSON under experiments/dryrun/ so the roofline
report (launch/roofline.py) and EXPERIMENTS.md tables read from disk.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch minicpm-2b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs.registry import ARCH_IDS, get_bundle, shape_cells
from repro.launch import hlo_stats
from repro.launch.mesh import HW, make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")


def cell_path(arch: str, shape: str, mesh_name: str) -> str:
    return os.path.abspath(
        os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_name}.json")
    )


def run_cell(arch: str, shape: str, multi_pod: bool,
             save: bool = True) -> Dict:
    mesh_name = "multi" if multi_pod else "single"
    bundle = get_bundle(arch)
    cell = bundle.cells[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    t0 = time.time()
    with mesh:
        pshard = bundle.param_shardings(mesh)
        in_shardings = [pshard]
        abstract = [bundle.abstract_params()]
        if hasattr(bundle, "cell_inits"):  # per-cell param variants (GNN)
            abstract = [jax.eval_shape(bundle.cell_inits[shape],
                                       jax.random.PRNGKey(0))]
            from repro.distributed.sharding import shard_by_rules

            in_shardings = [shard_by_rules(abstract[0], mesh, bundle.rules)]
        if cell.kind == "train":
            oshard = jax.tree_util.tree_map(
                lambda s: s, in_shardings[0]
            )
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.train.optim import adamw_init

            opt_abstract = jax.eval_shape(adamw_init, abstract[0])
            opt_shard = {
                "mu": in_shardings[0],
                "nu": jax.tree_util.tree_map(lambda s: s, in_shardings[0]),
                "step": NamedSharding(mesh, P()),
            }
            abstract.append(opt_abstract)
            in_shardings.append(opt_shard)
        ishard = cell.input_sharding(mesh)
        abstract.append(cell.inputs["batch"])
        in_shardings.append(ishard["batch"])

        from repro.distributed.sharding import sanitize_shardings

        in_shardings = [
            sanitize_shardings(s, a, mesh)
            for s, a in zip(in_shardings, abstract)
        ]
        jitted = jax.jit(cell.fn, in_shardings=tuple(in_shardings))
        lowered = jitted.lower(*abstract)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    from repro.launch import hlo_graph

    xla_flops = float((cost or {}).get("flops", 0.0))
    xla_bytes = float((cost or {}).get("bytes accessed", 0.0))
    n_per_pod = n_chips // 2 if multi_pod else n_chips
    graph = hlo_graph.analyze(hlo, n_chips, n_per_pod=n_per_pod)
    # per-pod DCI provision: dci_bw per chip x chips per pod; cross-pod
    # exchange moves ~2(P-1)/P of the payload across the pod boundary
    cross_pod_chip_bytes = (
        graph["cross_pod_bytes"] * 1.0 / n_per_pod if multi_pod else 0.0
    )
    terms = hlo_stats.roofline_terms(
        graph["dot_flops"], graph["hbm_bytes"],
        graph["collectives"]["total_wire_bytes"], n_chips, HW,
        cross_pod_bytes=cross_pod_chip_bytes,
    )

    def _mem(name):
        try:
            return int(getattr(mem, name))
        except Exception:
            return None

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "n_chips": int(n_chips),
        "kind": cell.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": graph["dot_flops"],
        "bytes_accessed": graph["hbm_bytes"],
        "xla_cost_flops": xla_flops,
        "xla_cost_bytes": xla_bytes,
        "cross_pod_bytes": graph["cross_pod_bytes"],
        "collectives": graph["collectives"],
        "memory": {
            "argument_size": _mem("argument_size_in_bytes"),
            "output_size": _mem("output_size_in_bytes"),
            "temp_size": _mem("temp_size_in_bytes"),
            "generated_code_size": _mem("generated_code_size_in_bytes"),
        },
        "roofline": terms,
        "ok": True,
    }
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        with open(cell_path(arch, shape, mesh_name), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="")
    ap.add_argument("--shape", type=str, default="")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-cached", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    for a in archs:
        shapes = shape_cells(a) if (args.all or not args.shape) else [args.shape]
        for s in shapes:
            cells.append((a, s))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for a, s in cells:
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            path = cell_path(a, s, mesh_name)
            if args.skip_cached and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        print(f"[cached] {a} x {s} x {mesh_name}")
                        continue
            try:
                r = run_cell(a, s, mp)
                tm = r["roofline"]
                print(
                    f"[ok] {a} x {s} x {mesh_name}: "
                    f"compile={r['compile_s']}s flops={r['flops']:.3e} "
                    f"bytes={r['bytes_accessed']:.3e} "
                    f"wire={r['collectives']['total_wire_bytes']:.3e} "
                    f"dominant={tm['dominant']}"
                )
            except Exception as e:
                failures.append((a, s, mesh_name, repr(e)))
                traceback.print_exc()
                os.makedirs(OUT_DIR, exist_ok=True)
                with open(cell_path(a, s, mesh_name), "w") as f:
                    json.dump(
                        {"arch": a, "shape": s, "mesh": mesh_name,
                         "ok": False, "error": repr(e)}, f, indent=1,
                    )
    print(f"\n{len(cells) * len(meshes) - len(failures)} ok, "
          f"{len(failures)} failed")
    for f_ in failures:
        print("FAIL:", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
