"""HLO call-graph cost analysis with loop trip-count multiplication.

XLA's built-in ``compiled.cost_analysis()`` counts each ``while`` body
ONCE — a transformer lowered as ``lax.scan`` over 40 layers under-reports
FLOPs, bytes and collectives by ~40x (and gradient-accumulation scans
compound it).  This analyzer parses the optimized HLO text into a call
graph and evaluates:

  * dot_flops          — 2 * prod(result dims) * prod(contracted dims),
  * hbm_bytes          — per top-level instruction: result + operand
                         bytes (fusions are one kernel: internals skipped),
  * collectives        — result bytes and ring wire bytes per op kind,
                         with replica-group sizes,

with fusion/call/while/conditional edges resolved and while bodies
multiplied by their trip count (parsed from the loop condition's constant
bound).  Validated in tests against hand-computed matmul/scan programs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ops that move no real data / are control only
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _parse_shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append(
                (dtype, [int(d) for d in dims.split(",")] if dims else [])
            )
    return out


def type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _parse_shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


_COMP_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
# type (lazy) followed by an op name directly attached to '('
_INSTR = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$"
)
_OPERAND = re.compile(r"%([\w.\-]+)")


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for line in hlo.splitlines():
        s = line.strip()
        if not s:
            continue
        if s.endswith("{") and " = " not in s:
            m = _COMP_NAME.match(s)
            if m and not s.startswith("{"):
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    entry = cur.name
                continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(s)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        # operand names: only those before any attribute list (calls=,
        # body=, condition= reference computations — captured separately)
        args_part = rest.split("), ")[0] if "), " in rest else rest
        operands = _OPERAND.findall(args_part.split(")")[0])
        inst = Instr(name, type_str, op, operands, s)
        cur.instrs.append(inst)
        cur.by_name[name] = inst
    return comps, entry


def _attr_comp(raw: str, attr: str) -> Optional[str]:
    m = re.search(attr + r"=%?([\w.\-]+)", raw)
    return m.group(1) if m else None


def _trip_count(while_raw: str, cond: Optional[Computation]) -> int:
    """Loop bound: prefer XLA's known_trip_count backend_config on the
    while op; fall back to the largest positive constant in the loop
    condition (the bound the induction variable is compared against)."""
    m = re.search(r'known_trip_count["\s:{]+n["\s:]+"?(\d+)', while_raw)
    if m:
        return int(m.group(1))
    if cond is None:
        return 1
    best = 1
    for i in cond.instrs:
        if i.op == "constant":
            mm = re.search(r"constant\((\d+)\)", i.raw)
            if mm:
                best = max(best, int(mm.group(1)))
    return best


def _group_size(raw: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", raw)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", raw)
    if m:
        return int(m.group(2))
    return default


@dataclasses.dataclass
class Costs:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_result_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_wire_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    cross_pod_bytes: float = 0.0  # collective result bytes spanning pods

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.dot_flops += other.dot_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.cross_pod_bytes += other.cross_pod_bytes * mult
        for d_self, d_other in (
            (self.coll_counts, other.coll_counts),
            (self.coll_result_bytes, other.coll_result_bytes),
            (self.coll_wire_bytes, other.coll_wire_bytes),
        ):
            for k, v in d_other.items():
                d_self[k] = d_self.get(k, 0.0) + v * mult


def _spans_pods(raw: str, n_per_pod: int) -> bool:
    """True if any replica group mixes device ids from different pods."""
    m = re.search(r"replica_groups=\{(.+?)\}\}", raw)
    if not m:
        # iota form [groups,size]<...> — conservatively assume spanning
        return "replica_groups=[" in raw
    for grp in re.findall(r"\{([0-9,]+)", "{" + m.group(1) + "}"):
        ids = [int(x) for x in grp.split(",") if x]
        pods = {i // n_per_pod for i in ids}
        if len(pods) > 1:
            return True
    return False


class HloAnalyzer:
    def __init__(self, hlo_text: str, n_devices: int,
                 n_per_pod: Optional[int] = None):
        self.comps, self.entry = parse_module(hlo_text)
        self.n_devices = n_devices
        self.n_per_pod = n_per_pod or n_devices
        self._memo: Dict[Tuple[str, bool], Costs] = {}

    # -- per-instruction costs -------------------------------------------------
    def _dot_flops(self, comp: Computation, inst: Instr) -> float:
        result_elems = 0
        for _, dims in _parse_shape_dims(inst.type_str):
            n = 1
            for d in dims:
                n *= d
            result_elems += n
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.raw)
        contract = 1
        if m and inst.operands:
            lhs = comp.by_name.get(inst.operands[0])
            if lhs is not None:
                shapes = _parse_shape_dims(lhs.type_str)
                if shapes:
                    dims = shapes[0][1]
                    for ci in m.group(1).split(","):
                        if ci != "" and int(ci) < len(dims):
                            contract *= dims[int(ci)]
        return 2.0 * result_elems * contract

    def _operand_bytes(self, comp: Computation, inst: Instr) -> int:
        total = 0
        for o in inst.operands:
            src = comp.by_name.get(o)
            if src is not None:
                total += type_bytes(src.type_str)
        return total

    # slicing ops read only their result-sized window, not the operand
    _SLICING = {"dynamic-slice", "slice", "gather"}

    def _inst_hbm_bytes(self, comp: Computation, inst: Instr) -> float:
        """HBM traffic of one top-level (unfused) instruction."""
        op = inst.op
        res = type_bytes(inst.type_str)
        if op in self._SLICING or op in ("broadcast", "iota", "reshape",
                                         "transpose", "copy", "reverse"):
            return 2.0 * res  # read window + write result
        if op in ("dynamic-update-slice", "scatter"):
            # read+write the updated window (operand 1 is the update)
            upd = 0
            if len(inst.operands) > 1:
                src = comp.by_name.get(inst.operands[1])
                if src is not None:
                    upd = type_bytes(src.type_str)
            return res * 0.0 + 2.0 * max(upd, 1)
        if op == "fusion":
            dus = self._dus_root_update_bytes(inst)
            if dus is not None:
                # scan-output / in-place update fusion: on TPU the carried
                # buffer is aliased and only the update window moves.  (The
                # CPU backend wraps these in full-buffer bf16<->f32 convert
                # sandwiches — a backend artifact we must not count.)
                return 2.0 * dus
            return res + self._fusion_read_bytes(comp, inst)
        return res + self._operand_bytes(comp, inst)

    def _dus_root_update_bytes(self, inst: Instr) -> Optional[float]:
        """If a fusion's root is dynamic-update-slice (possibly behind
        converts), return the update-window byte count, else None."""
        callee_name = _attr_comp(inst.raw, "calls")
        callee = self.comps.get(callee_name) if callee_name else None
        if callee is None or not callee.instrs:
            return None
        root = callee.instrs[-1]
        for i in callee.instrs:
            if i.raw.startswith("ROOT"):
                root = i
                break
        seen = set()
        while root.op == "convert" and root.operands:
            if root.name in seen:
                return None
            seen.add(root.name)
            nxt = callee.by_name.get(root.operands[0])
            if nxt is None:
                return None
            root = nxt
        if root.op != "dynamic-update-slice" or len(root.operands) < 2:
            return None
        upd = callee.by_name.get(root.operands[1])
        return float(type_bytes(upd.type_str)) if upd is not None else None

    def _fusion_read_bytes(self, comp: Computation, inst: Instr) -> float:
        """Bytes read by a fusion: parameters that are only sliced inside
        the fused computation contribute their slice windows, not their
        full extent (the scan-over-stacked-weights pattern)."""
        callee_name = _attr_comp(inst.raw, "calls")
        callee = self.comps.get(callee_name) if callee_name else None
        total = 0.0
        for pos, o in enumerate(inst.operands):
            src = comp.by_name.get(o)
            if src is None:
                continue
            full = type_bytes(src.type_str)
            if callee is None:
                total += full
                continue
            # find the callee's parameter(pos) and its consumers
            pname = None
            for ci in callee.instrs:
                if ci.op == "parameter" and re.search(
                    rf"parameter\({pos}\)", ci.raw
                ):
                    pname = ci.name
                    break
            if pname is None:
                total += full
                continue
            consumers = [
                ci for ci in callee.instrs if pname in ci.operands
            ]
            if consumers and all(
                c.op in self._SLICING for c in consumers
            ):
                total += sum(type_bytes(c.type_str) for c in consumers)
            else:
                total += full
        return total

    # -- computation evaluation ---------------------------------------------------
    def costs_of(self, comp_name: str, fused: bool = False) -> Costs:
        key = (comp_name, fused)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        out = Costs()
        self._memo[key] = out
        if comp is None:
            return out
        for inst in comp.instrs:
            op = inst.op
            if op == "fusion":
                callee = _attr_comp(inst.raw, "calls")
                if callee:
                    out.add(self.costs_of(callee, fused=True))
                out.hbm_bytes += self._inst_hbm_bytes(comp, inst)
                continue
            if op in ("call", "custom-call"):
                callee = _attr_comp(inst.raw, "calls") or _attr_comp(
                    inst.raw, "to_apply"
                )
                if callee:
                    out.add(self.costs_of(callee, fused=fused))
                if not fused:
                    out.hbm_bytes += type_bytes(inst.type_str)
                continue
            if op == "while":
                body = _attr_comp(inst.raw, "body")
                cond = _attr_comp(inst.raw, "condition")
                trips = _trip_count(inst.raw, self.comps.get(cond))
                if body:
                    out.add(self.costs_of(body, fused=False), mult=max(1, trips))
                continue
            if op == "conditional":
                for m_ in re.finditer(r"(?:branch_computations=\{|true_computation=|false_computation=)%?([\w.\-]+)", inst.raw):
                    out.add(self.costs_of(m_.group(1), fused=False))
                continue
            if op == "dot" or op == "convolution":
                out.dot_flops += self._dot_flops(comp, inst)
                if not fused:
                    out.hbm_bytes += type_bytes(inst.type_str) + \
                        self._operand_bytes(comp, inst)
                continue
            if op == "dynamic-slice" and fused:
                continue
            base = None
            for c in COLLECTIVE_KINDS:
                if op == c or op.startswith(c + "-start"):
                    base = c
                    break
            if base is not None:
                nbytes = type_bytes(inst.type_str)
                n = max(2, _group_size(inst.raw, self.n_devices))
                if base == "all-reduce":
                    wire = 2.0 * (n - 1) / n * nbytes
                elif base == "all-gather":
                    wire = (n - 1) / n * nbytes
                elif base == "reduce-scatter":
                    wire = (n - 1.0) * nbytes
                elif base == "all-to-all":
                    wire = (n - 1) / n * nbytes
                else:
                    wire = float(nbytes)
                out.coll_counts[base] = out.coll_counts.get(base, 0) + 1
                out.coll_result_bytes[base] = (
                    out.coll_result_bytes.get(base, 0) + nbytes
                )
                out.coll_wire_bytes[base] = (
                    out.coll_wire_bytes.get(base, 0) + wire
                )
                out.hbm_bytes += nbytes
                if self.n_per_pod < self.n_devices and _spans_pods(
                    inst.raw, self.n_per_pod
                ):
                    out.cross_pod_bytes += nbytes
                continue
            if op in _FREE_OPS or op.endswith("-done"):
                continue
            if not fused:
                # top-level unfused op: one kernel reading operands,
                # writing result
                out.hbm_bytes += self._inst_hbm_bytes(comp, inst)
        return out

    def entry_costs(self) -> Costs:
        return self.costs_of(self.entry, fused=False)


def analyze(hlo_text: str, n_devices: int, n_per_pod: Optional[int] = None
            ) -> Dict:
    a = HloAnalyzer(hlo_text, n_devices, n_per_pod)
    c = a.entry_costs()
    return {
        "dot_flops": c.dot_flops,
        "hbm_bytes": c.hbm_bytes,
        "cross_pod_bytes": c.cross_pod_bytes,
        "collectives": {
            "counts": {k: int(v) for k, v in c.coll_counts.items()},
            "result_bytes": {k: int(v) for k, v in c.coll_result_bytes.items()},
            "wire_bytes": {k: int(v) for k, v in c.coll_wire_bytes.items()},
            "total_wire_bytes": int(sum(c.coll_wire_bytes.values())),
        },
    }
