"""HLO analysis: collective byte counting + roofline terms.

``cost_analysis()`` gives HLO FLOPs and bytes accessed but NOT collective
traffic; we parse the optimized HLO text and sum the result-shape bytes of
every collective op, converting to on-the-wire bytes with the standard
ring-algorithm factors:

  op                  wire bytes per participating shard (ring, n shards)
  all-reduce          2 (n-1)/n x result
  all-gather          (n-1)/n x result          (result = gathered size)
  reduce-scatter      (n-1)/n x operand ~ result x (n-1)
  all-to-all          (n-1)/n x result
  collective-permute  1 x result

n is read from the op's replica_groups when present (else the mesh size).
Terms are reported per-chip per the brief's formulas.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes in an HLO type string (handles
    tuples by summing every embedded shape)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # [groups, group_size] form
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]
    wire_bytes: Dict[str, float]

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def as_dict(self) -> Dict:
        return {
            "counts": self.counts,
            "result_bytes": self.result_bytes,
            "wire_bytes": {k: int(v) for k, v in self.wire_bytes.items()},
            "total_wire_bytes": int(self.total_wire_bytes),
        }


def collective_stats(hlo_text: str, n_devices: int) -> CollectiveStats:
    counts: Dict[str, int] = {}
    result_bytes: Dict[str, int] = {}
    wire: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        # opname appears as `= <type> opname(` — match the instruction
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([a-z0-9\-]+)\(", s)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                base = c
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        nbytes = shape_bytes(type_str)
        n = max(2, _group_size(s, n_devices))
        if base == "all-reduce":
            w = 2.0 * (n - 1) / n * nbytes
        elif base == "all-gather":
            w = (n - 1) / n * nbytes
        elif base == "reduce-scatter":
            w = (n - 1.0) * nbytes  # result is the scattered shard
        elif base == "all-to-all":
            w = (n - 1) / n * nbytes
        else:  # collective-permute
            w = float(nbytes)
        counts[base] = counts.get(base, 0) + 1
        result_bytes[base] = result_bytes.get(base, 0) + nbytes
        wire[base] = wire.get(base, 0.0) + w
    return CollectiveStats(counts, result_bytes, wire)


def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    wire_bytes: float,
    n_chips: int,
    hw: Dict,
    cross_pod_bytes: float = 0.0,
) -> Dict[str, float]:
    """The three roofline terms in seconds (per the brief's formulas).

    FLOPs/bytes from cost_analysis are already per-partition (SPMD lowers
    one program per device), so terms are per-chip directly.
    """
    compute_s = hlo_flops / hw["peak_bf16_flops"]
    memory_s = hlo_bytes / hw["hbm_bw"]
    # ICI: each chip drives ~4 usable links on a 2D torus
    ici_s = wire_bytes / (4 * hw["ici_bw"])
    dci_s = cross_pod_bytes / hw["dci_bw"]
    collective_s = ici_s + dci_s
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": total,
        "compute_fraction": compute_s / total if total else 0.0,
    }
