"""Roofline report: reads experiments/dryrun/*.json, adds analytic
MODEL_FLOPS, emits the EXPERIMENTS.md tables.

Per (arch x shape x mesh):
  compute_s    = HLO dot FLOPs / peak bf16
  memory_s     = HLO bytes / HBM bw
  collective_s = wire bytes / (4 links x ICI bw) + DCI term (multi-pod)
  MODEL_FLOPS  = analytic useful compute (6*N*D train / 2*N*D serve for
                 LM; op-count models for GNN/recsys)
  ratio        = HLO FLOPs / MODEL_FLOPS  (remat + padding + dispatch waste)

Usage: PYTHONPATH=src python -m repro.launch.roofline [--write-md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

from repro.launch.mesh import HW

DRYRUN_DIR = os.path.join(
    os.path.dirname(__file__), "../../../experiments/dryrun"
)


def _lm_model_flops(arch: str, shape: str, n_chips: int) -> float:
    from repro.configs.registry import get_bundle

    cfg = get_bundle(arch).config
    n_active = cfg.params_active
    B, S = {
        "train_4k": (256, 4096),
        "prefill_32k": (32, 32768),
        "decode_32k": (128, 32768),
        "long_500k": (1, 524288),
    }[shape]
    if shape == "train_4k":
        flops = 6.0 * n_active * B * S
    elif shape == "prefill_32k":
        # fwd only + causal attention term
        att = 2.0 * cfg.n_layers * B * S * S * cfg.n_heads * cfg.d_head
        flops = 2.0 * n_active * B * S + att
    else:
        # decode: one token per sequence reads the whole KV cache
        att = 4.0 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.d_head
        flops = 2.0 * n_active * B + att
    return flops / n_chips


def _gnn_model_flops(shape: str, n_chips: int) -> float:
    k = 128
    cells = {
        "full_graph_sm": (2708, 10556, 1433),
        "minibatch_lg": (169_984, 168_960, 602),
        "ogb_products": (2_449_029, 61_859_140, 100),
        "molecule": (128 * 30, 128 * 64, 0),
    }
    N, E, dfeat = cells[shape]
    L = 2
    msg = 2.0 * E * k * 9 * 9 * 9          # Gaunt contraction per edge
    bbasis = 2.0 * N * k * 9 * 9 * 9 * 2   # B2 + B3
    mix = 2.0 * N * k * k * 9 * 4          # w1,w2,w3,self
    radial = 2.0 * E * (8 * 32 + 32 * 3 * k)
    feat = 2.0 * N * dfeat * k
    fwd = L * (msg + bbasis + mix + radial) + feat
    return 3.0 * fwd / n_chips  # train step ~ 3x fwd


def _recsys_model_flops(arch: str, shape: str, n_chips: int) -> float:
    B = {"train_batch": 65_536, "serve_p99": 512, "serve_bulk": 262_144,
         "retrieval_cand": 1_000_000}[shape]
    per_ex = {
        # fwd flops per example (dominant MLP/interaction terms)
        "dlrm-mlperf": 2.0 * (13 * 512 + 512 * 256 + 256 * 128
                              + 479 * 1024 + 1024 * 1024 + 1024 * 512
                              + 512 * 256 + 256),
        "din": 2.0 * (100 * (4 * 36 * 80 + 80 * 40 + 40)
                      + 3 * 36 * 200 + 200 * 80 + 80),
        "sasrec": 2.0 * (2 * (4 * 50 * 50 + 2 * 50 * 50 + 2 * 50 * 50) * 50
                         + 50 * 50 * 60_000),
        "two-tower-retrieval": 2.0 * 2 * (512 * 1024 + 1024 * 512 + 512 * 256),
    }[arch]
    if arch == "two-tower-retrieval" and shape == "retrieval_cand":
        return (per_ex / 2 + 2.0 * 1_000_000 * 256) / n_chips
    if arch == "sasrec" and shape != "train_batch":
        per_ex = per_ex - 2.0 * 50 * 50 * 60_000 + 2.0 * 50 * 200  # no full softmax
    mult = 3.0 if shape == "train_batch" else 1.0
    return mult * per_ex * B / n_chips


def model_flops(arch: str, shape: str, n_chips: int) -> Optional[float]:
    try:
        if shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            return _lm_model_flops(arch, shape, n_chips)
        if shape in ("full_graph_sm", "minibatch_lg", "ogb_products",
                     "molecule"):
            return _gnn_model_flops(shape, n_chips)
        return _recsys_model_flops(arch, shape, n_chips)
    except Exception:
        return None


def load_cells(mesh: str = "single") -> Dict:
    out = {}
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r.get("ok"):
            out[(r["arch"], r["shape"])] = r
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def build_table(mesh: str = "single") -> str:
    cells = load_cells(mesh)
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_TF/chip | HLO/MODEL | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(cells.items()):
        t = r["roofline"]
        mf = model_flops(arch, shape, r["n_chips"])
        ratio = (r["flops"] / mf) if (mf and mf > 0) else float("nan")
        note = {
            "compute": "at compute roofline; fuse/quantize to go further",
            "memory": "cut HBM: fp8/bf16 staging, fusion, smaller remat",
            "collective": "reshard or overlap collectives with compute",
        }[t["dominant"]]
        lines.append(
            f"| {arch} | {shape} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['dominant']}** | {(mf or 0)/1e12:.3f} | {ratio:.2f} | {note} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--write-md", action="store_true")
    args = ap.parse_args()
    table = build_table(args.mesh)
    print(table)
    if args.write_md:
        path = os.path.join(DRYRUN_DIR, f"roofline_{args.mesh}.md")
        with open(path, "w") as f:
            f.write(table + "\n")
        print(f"\nwritten: {path}")


if __name__ == "__main__":
    main()
