"""Serving launcher: continuous batching over the paged-KV substrate.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --requests 16 --slots 4
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs.registry import ARCH_IDS, get_bundle
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--chain-limit", type=int, default=9)
    args = ap.parse_args()

    bundle = get_bundle(args.arch, reduced=True)
    if bundle.family != "lm":
        raise SystemExit(f"{args.arch} is not an LM arch")
    cfg = bundle.config
    params = bundle.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        cfg, params, batch_slots=args.slots, s_max=256,
        page_size=16, chain_limit=args.chain_limit,
    )
    rng = np.random.RandomState(0)
    for i in range(args.requests):
        engine.submit(Request(
            req_id=i,
            prompt=rng.randint(0, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    t0 = time.time()
    done = engine.run_until_done(max_steps=2000)
    dt = time.time() - t0
    s = engine.stats()
    tokens = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {tokens} tokens in {s['steps']} steps "
          f"({dt:.1f}s, {tokens/max(dt,1e-9):.1f} tok/s host-side)")
    print(f"paged-KV: gather depth <= {s['kv']['max_gather_depth']} "
          f"(limit {args.chain_limit}), {s['kv']['compactions']} compactions, "
          f"fragmentation {s['fragmentation']:.2f}")


if __name__ == "__main__":
    main()
