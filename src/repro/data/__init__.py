from repro.data.corpus import (  # noqa: F401
    generate_part,
    extract_postings,
    group_by_key,
)
