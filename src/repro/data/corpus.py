"""Synthetic corpus + vectorized posting extraction (paper sections 1, 6).

The paper indexes a 71.5 GB plain-text collection split into parts of
10-20 GB (section 2.2: "the size of each part is dependent on the amount
of available RAM").  We generate deterministic Zipf documents and extract
postings for the paper's five index types:

  1. ordinary index over known lemmas   (key: lemma id)
  2. ordinary index over unknown words  (key: n_lemmas + word id)
  3. extended (w, v), w and v known     (key: w * 2^32 + v; w is FREQUENT)
  4. extended (w, v), v unknown         (same packing)
  5. stop-lemma sequences               (key: l0*2^42 + l1*2^21 + l2 + FLAG)

Packing keys into int64 keeps extraction fully vectorized; the inverted
index treats keys as opaque hashables.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.lexicon import FREQUENT, Lexicon, OTHER, STOP

PAIR_SHIFT = 32          # (w, v) key packing
SEQ_SHIFT = 21           # stop-sequence key packing: 3 x 21 bits
SEQ2_FLAG = 1 << 62      # disambiguate 2-sequences from 3-sequences


def generate_part(
    lexicon: Lexicon,
    n_docs: int,
    avg_doc_len: int,
    doc0: int,
    seed: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """One collection part: (tokens, doc_offsets).  Doc ids are
    ``doc0 .. doc0+n_docs-1``; offsets have length n_docs+1."""
    rng = np.random.RandomState(seed)
    lens = np.maximum(8, rng.poisson(avg_doc_len, size=n_docs))
    total = int(lens.sum())
    tokens = rng.choice(lexicon.n_words, size=total, p=lexicon.word_probs)
    offsets = np.concatenate(([0], np.cumsum(lens)))
    return tokens.astype(np.int64), offsets.astype(np.int64)


def group_by_key(
    keys: np.ndarray, docs: np.ndarray, poss: np.ndarray
) -> Dict[int, np.ndarray]:
    """Group (key, doc, pos) rows into {key: (N,2) sorted postings}."""
    if keys.size == 0:
        return {}
    order = np.lexsort((poss, docs, keys))
    k = keys[order]
    dp = np.stack([docs[order], poss[order]], axis=1)
    uniq, starts = np.unique(k, return_index=True)
    chunks = np.split(dp, starts[1:])
    return {int(u): c for u, c in zip(uniq.tolist(), chunks)}


def extract_postings(
    lexicon: Lexicon,
    tokens: np.ndarray,
    offsets: np.ndarray,
    doc0: int,
    max_distance: int = 3,
) -> Dict[str, Dict[int, np.ndarray]]:
    """Extract the five posting maps for one part (vectorized)."""
    n_docs = offsets.shape[0] - 1
    lens = np.diff(offsets)
    doc_of = np.repeat(np.arange(n_docs, dtype=np.int64) + doc0, lens)
    pos_of = np.arange(tokens.shape[0], dtype=np.int64) - np.repeat(
        offsets[:-1], lens
    )
    l1, l2 = lexicon.lemmatize(tokens)
    cls1 = lexicon.classes_of(l1)
    known = lexicon.is_known(tokens)

    out: Dict[str, Dict[int, np.ndarray]] = {}

    # 1) ordinary known-lemma index: ALL known lemmas (paper 6.3: "keys are
    #    lemmas" — stop and frequent lemmas included; the additional indexes
    #    are the fast paths, not a replacement).  Secondary lemmas included.
    m = known
    keys = [l1[m]]
    docs = [doc_of[m]]
    poss = [pos_of[m]]
    m2 = l2 >= 0
    keys.append(l2[m2])
    docs.append(doc_of[m2])
    poss.append(pos_of[m2])
    out["known"] = group_by_key(
        np.concatenate(keys), np.concatenate(docs), np.concatenate(poss)
    )

    # 2) ordinary unknown-word index
    mu = ~known
    out["unknown"] = group_by_key(l1[mu], doc_of[mu], pos_of[mu])

    # 3+4) extended (w, v): w is a FREQUENT lemma reading of a token, v is a
    #    lemma reading of any token within max_distance.  Both lemma
    #    readings of ambiguous tokens are indexed (lemmatized search).
    cls2 = lexicon.classes_of(l2)
    c1 = np.nonzero(known & (cls1 == FREQUENT))[0]
    c2 = np.nonzero(known & (l2 >= 0) & (cls2 == FREQUENT))[0]
    centers = np.concatenate([c1, c2])
    w_lem = np.concatenate([l1[c1], l2[c2]])
    wk_keys: List[np.ndarray] = []
    wk_docs: List[np.ndarray] = []
    wk_poss: List[np.ndarray] = []
    wu_keys: List[np.ndarray] = []
    wu_docs: List[np.ndarray] = []
    wu_poss: List[np.ndarray] = []
    T = tokens.shape[0]
    for d in range(-max_distance, max_distance + 1):
        if d == 0 or centers.size == 0:
            continue
        j = centers + d
        ok = (j >= 0) & (j < T)
        i, jj, w0 = centers[ok], j[ok], w_lem[ok]
        same_doc = doc_of[i] == doc_of[jj]
        i, jj, w0 = i[same_doc], jj[same_doc], w0[same_doc]
        for vslot in (1, 2):
            if vslot == 1:
                vi, ji, wi = l1[jj], jj, w0
                ii = i
            else:
                has2 = l2[jj] >= 0
                vi, ji, wi = l2[jj][has2], jj[has2], w0[has2]
                ii = i[has2]
            if vi.size == 0:
                continue
            key = (wi << PAIR_SHIFT) | vi
            vk = known[ji]
            wk_keys.append(key[vk]); wk_docs.append(doc_of[ii][vk]); wk_poss.append(pos_of[ii][vk])
            vu = ~vk
            wu_keys.append(key[vu]); wu_docs.append(doc_of[ii][vu]); wu_poss.append(pos_of[ii][vu])
    out["wv_kk"] = group_by_key(
        np.concatenate(wk_keys) if wk_keys else np.zeros(0, np.int64),
        np.concatenate(wk_docs) if wk_docs else np.zeros(0, np.int64),
        np.concatenate(wk_poss) if wk_poss else np.zeros(0, np.int64),
    )
    out["wv_ku"] = group_by_key(
        np.concatenate(wu_keys) if wu_keys else np.zeros(0, np.int64),
        np.concatenate(wu_docs) if wu_docs else np.zeros(0, np.int64),
        np.concatenate(wu_poss) if wu_poss else np.zeros(0, np.int64),
    )

    # 5) stop-lemma sequences of length 2 and 3 (paper 6.3 index kind 3)
    stop = known & (cls1 == STOP)
    nxt_same = np.zeros(T, dtype=bool)
    if T > 1:
        nxt_same[:-1] = (doc_of[1:] == doc_of[:-1])
    p2 = np.nonzero(stop[:-1] & stop[1:] & nxt_same[:-1])[0] if T > 1 else np.zeros(0, np.int64)
    k2 = (SEQ2_FLAG | (l1[p2] << SEQ_SHIFT) | l1[p2 + 1]) if p2.size else np.zeros(0, np.int64)
    if T > 2:
        p3 = p2[(p2 + 2 < T)]
        p3 = p3[stop[p3 + 2] & nxt_same[p3 + 1]]
    else:
        p3 = np.zeros(0, np.int64)
    k3 = (
        (l1[p3] << (2 * SEQ_SHIFT)) | (l1[p3 + 1] << SEQ_SHIFT) | l1[p3 + 2]
    ) if p3.size else np.zeros(0, np.int64)
    out["stopseq"] = group_by_key(
        np.concatenate([k2, k3]),
        np.concatenate([doc_of[p2], doc_of[p3]]),
        np.concatenate([pos_of[p2], pos_of[p3]]),
    )

    # 6) ordinary-all (baseline for the search-speed experiment; NOT part of
    #    the paper's five measured indexes): every lemma reading of every
    #    token, so the baseline sees exactly what the additional indexes see.
    m2a = l2 >= 0
    out["ordinary_all"] = group_by_key(
        np.concatenate([l1, l2[m2a]]),
        np.concatenate([doc_of, doc_of[m2a]]),
        np.concatenate([pos_of, pos_of[m2a]]),
    )
    return out
