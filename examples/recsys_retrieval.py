"""Retrieval example: train a (reduced) two-tower model with in-batch
softmax, then score one query against a candidate store laid out as
contiguous S-strategy segments (one blocked matmul, no loop).

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import get_bundle
from repro.models import recsys as RS
from repro.train.optim import OptConfig, adamw_init, adamw_update


def main():
    bundle = get_bundle("two-tower-retrieval", reduced=True)
    # warmer softmax for from-scratch training (0.05 saturates at init)
    cfg = dataclasses.replace(bundle.config, temperature=0.2)
    params = bundle.init(jax.random.PRNGKey(0))

    def batch(seed):
        r = np.random.RandomState(seed)
        items = r.choice(cfg.n_items, 64, replace=False)
        return {
            "user_id": jnp.asarray(items % cfg.n_users),  # paired user<->item
            "user_ctx": jnp.asarray(items % cfg.n_context),
            "item_id": jnp.asarray(items),
            "item_cat": jnp.asarray(items % cfg.n_context),
        }

    loss_fn = lambda p, b: RS.twotower_loss(cfg, p, b)
    oc = OptConfig(lr=3e-3, schedule="const", warmup_steps=1, weight_decay=0.0)
    state = adamw_init(params)

    @jax.jit
    def step(p, s, b):
        loss, g = jax.value_and_grad(loss_fn)(p, b)
        p2, s2, _ = adamw_update(oc, g, s, p)
        return loss, p2, s2

    l0 = None
    for i in range(300):
        loss, params, state = step(params, state, batch(i))
        l0 = l0 or float(loss)
    print(f"two-tower in-batch softmax: loss {l0:.3f} -> {float(loss):.3f}")

    # candidate store: item-tower embeddings in one contiguous array
    # (the S-segment layout: sequential scan, no indirection)
    ids = jnp.arange(cfg.n_items)
    cands = RS.item_embed(cfg, params, ids, ids % cfg.n_context)
    q = {"user_id": jnp.asarray([17]),
         "user_ctx": jnp.asarray([17 % cfg.n_context])}
    scores = jnp.einsum("bd,nd->bn", RS.user_embed(cfg, params, q), cands)[0]
    rank = int((scores > scores[17]).sum())
    top = RS.twotower_retrieval(
        cfg, params, {**q, "candidate_embs": cands.astype(jnp.float32)}
    )
    print(f"query user 17 -> top-5 items {np.asarray(top)[:5].tolist()}, "
          f"paired item rank {rank}/{cfg.n_items}")
    assert rank < 10, "trained tower should rank the paired item at the top"
    print("retrieval sanity check passed")


if __name__ == "__main__":
    main()
