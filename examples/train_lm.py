"""End-to-end driver: train a (reduced) assigned LM for a few hundred
steps with the full production substrate — WSD schedule, gradient
accumulation, async checkpointing, crash-safe resume.

    PYTHONPATH=src python examples/train_lm.py [--arch minicpm-2b]
        [--steps 300] [--resume]
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import get_bundle
from repro.models.transformer import lm_loss
from repro.train.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    bundle = get_bundle(args.arch, reduced=True)
    cfg = bundle.config
    params = bundle.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{args.arch} (reduced): {n/1e6:.2f}M params, WSD schedule")

    vocab = cfg.vocab

    def batches(cursor: int):
        rng = np.random.RandomState(cursor)
        # skewed synthetic token stream (learnable bigram structure)
        toks = rng.zipf(1.5, size=(args.batch, args.seq)) % vocab
        toks = np.sort(toks, axis=1)  # sorted => predictable next token
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1
        return {
            "tokens": jnp.asarray(toks, jnp.int32),
            "labels": jnp.asarray(labels, jnp.int32),
        }

    trainer = Trainer(
        lambda p, b: lm_loss(cfg, p, b["tokens"], b["labels"])[0],
        params,
        TrainerConfig(
            opt=OptConfig(lr=3e-3, schedule="wsd", warmup_steps=20,
                          total_steps=args.steps, decay_fraction=0.2),
            microbatches=args.microbatches,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=100,
            log_every=25,
        ),
    )
    if trainer.try_resume():
        print(f"resumed from step {trainer.step_num}")

    t0 = time.time()
    trainer.fit(batches, args.steps)
    dt = time.time() - t0
    first = trainer.history[0]["loss"] if trainer.history else float("nan")
    last = trainer.history[-1]["loss"]
    print(f"steps {trainer.step_num}, loss {first:.3f} -> {last:.3f} "
          f"({dt:.1f}s, checkpoints in {args.ckpt_dir})")
    assert last < first, "training should reduce loss"


if __name__ == "__main__":
    main()
