"""Serve a small LM with continuous batching over the paged-KV substrate.

    PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

import jax

from repro.configs.registry import get_bundle
from repro.serve.engine import Request, ServeEngine


def main():
    bundle = get_bundle("granite-3-2b", reduced=True)
    cfg = bundle.config
    params = bundle.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=4, s_max=128,
                         page_size=16, chain_limit=4)

    rng = np.random.RandomState(0)
    prompt_len = 24
    for i in range(10):
        engine.submit(Request(
            req_id=i,
            prompt=rng.randint(0, cfg.vocab, prompt_len).astype(np.int32),
            max_new_tokens=12,
        ))
    done = engine.run_until_done(max_steps=200)
    for r in done[:5]:
        print(f"req {r.req_id}: generated {len(r.out_tokens)} tokens "
              f"{r.out_tokens[:8]}...")
    s = engine.stats()
    print(f"{len(done)} requests served in {s['steps']} engine steps")
    print(f"paged-KV: {s['kv']['pages_allocated']} pages allocated, "
          f"{s['kv']['compactions']} compactions, "
          f"max gather depth {s['kv']['max_gather_depth']} "
          f"(chain limit 4), fragmentation {s['fragmentation']:.2f}")
    assert len(done) == 10
    assert s["kv"]["max_gather_depth"] <= 4


if __name__ == "__main__":
    main()
