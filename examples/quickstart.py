"""Quickstart: build an easily updatable full-text index, update it in
place, and run proximity queries through the additional indexes — one at
a time through ``ProximityEngine``, then as a planned batch through
``SearchService`` (the multi-user serving path), then over a 4-shard
``ShardedTextIndexSet`` through the scatter/gather pipeline — then land
another collection part through the per-shard update streams WHILE the
same service keeps serving, scale reads across a replica fabric that
survives a replica killed mid-batch, and finally persist the collection
behind the durable WAL-fed store, crash it mid-part, and recover.

    PYTHONPATH=src python examples/quickstart.py

Before sending a change, run the invariant linter (it is also the first
step of ``scripts/tier1.sh``): ``scripts/lint.sh`` checks charge
accounting, trace schema, generation discipline, cache-tier
encapsulation and kernel purity over ``src/``; ``scripts/lint.sh
--changed-only`` lints just the files your working tree touches.  See
DESIGN_SEARCH.md §12.
"""

import numpy as np

from repro.core.lexicon import FREQUENT, OTHER, STOP, make_lexicon
from repro.core.proximity import ProximityEngine
from repro.core.sharded_set import ShardedTextIndexSet
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig, TextIndexSet
from repro.data.corpus import generate_part
from repro.search import Query, SearchService


def words_of(lex, cls, n=6):
    out = []
    for w in range(lex.n_words):
        l = lex.lemma1[w]
        if l >= 0 and lex.lemma_class[l] == cls:
            out.append(int(w))
            if len(out) == n:
                break
    return out


def main():
    # a synthetic collection with the paper's statistical shape
    lex = make_lexicon(n_words=20_000, n_lemmas=9_000, n_stop=50,
                       n_frequent=500, seed=1)
    part1 = generate_part(lex, n_docs=300, avg_doc_len=250, doc0=0, seed=10)
    part2 = generate_part(lex, n_docs=300, avg_doc_len=250, doc0=300, seed=11)

    # strategy set 3 = C1+EM+PART+S+FL+TAG + CH + SR + DS (paper 6.4)
    cfg = IndexSetConfig(
        strategy=StrategyConfig.set3(cluster_size=4096),
        build_ordinary_all=True,
    )
    ts = TextIndexSet(cfg, lex, seed=0)

    print("building index from part 1 ...")
    ts.add_documents(*part1, 0)
    print("updating IN PLACE with part 2 (no merge pass) ...")
    ts.add_documents(*part2, 300)

    for name, row in ts.table_rows().items():
        print(f"  {name:8s} construction I/O: {row['total_bytes']:>12,} bytes"
              f" in {row['total_ops']:>6,} ops")

    eng = ProximityEngine(ts, window=3)
    stop, freq, other = (words_of(lex, c) for c in (STOP, FREQUENT, OTHER))
    for q, label in [
        ([stop[0], stop[1]], "stop phrase      "),
        ([freq[0], other[0]], "frequent + other "),
        ([other[0], other[1]], "ordinary pair    "),
    ]:
        r = eng.search(q)
        rb = eng.search_ordinary(q)
        speedup = rb.postings_scanned / max(1, r.postings_scanned)
        print(f"  {label} -> {len(r.docs):4d} docs via {r.lookups[0][0]:11s}"
              f" scanning {r.postings_scanned:6,} postings"
              f" ({speedup:7.1f}x less than the ordinary index)")
        assert set(r.docs.tolist()) == set(rb.docs.tolist())
    print("all answers verified against the ordinary-index baseline")

    # batched serving: plan a whole query stream at once — one vectorized
    # classify pass, deduplicated lookups, bucketed jit-compiled joins
    svc = SearchService(ts, window=3, backend="jax")
    stream = [
        [stop[0], stop[1]], [freq[0], other[0]], [other[0], other[1]],
        [stop[2], stop[3]], [freq[1], other[2]], [stop[0], stop[1]],
    ]
    plan = svc.plan(stream)
    results = svc.search_batch(stream)
    svc.search_batch(stream)  # the repeat stream is served from the LRU
    census = plan.route_census()
    print(f"batched {len(stream)} queries: routes {census},"
          f" {plan.n_unique_lookups} unique lookups; repeat batch"
          f" cache hit rate {svc.reader.cache_stats.hit_rate:.0%}")
    for q, r in zip(stream, results):
        assert set(r.docs.tolist()) == set(eng.search(q).docs.tolist())
    print("batched results identical to the per-query engine")

    # phrase search through the multi-component (k-word) key index: one
    # key fetch returns exactly the phrase's occurrences — no join over
    # the ordinary posting lists at all
    toks, offs = part1
    phrase = tuple(int(t) for t in toks[offs[0] : offs[0] + 3])
    r = svc.search(phrase, phrase=True)
    r_ord = SearchService(ts, window=3, use_multi=False).search(
        phrase, phrase=True
    )
    assert set(r.docs.tolist()) == set(r_ord.docs.tolist())
    print(f"phrase {phrase} -> {len(r.docs)} docs via route '{r.route}',"
          f" scanning {r.postings_scanned:,} postings"
          f" (ordinary join path: {r_ord.postings_scanned:,})")

    # best-k serving: Query(top_k=N) streams each key's postings through
    # lazy chunked cursors in (doc, start) order and STOPS fetching once
    # the N best documents are provably settled — the head is element-wise
    # identical to the exhaustive result's first N docs, at a fraction of
    # the read bytes (last_trace reports chunks and bytes skipped).  A hot
    # stop pair matches hundreds of docs, so top-3 settles almost at once.
    hot = (stop[0], stop[1])
    svc_cold = SearchService(ts, window=3, cache_bytes=0)  # cold: real I/O
    r_all = svc_cold.search_batch([Query(hot)])[0]
    r_top = svc_cold.search_batch([Query(hot, top_k=3)])[0]
    assert np.array_equal(r_top.docs, r_all.docs[:3])
    tk = svc_cold.last_trace["topk"]
    print(f"top-3 of the hot stop pair -> docs {r_top.docs.tolist()} "
          f"(scores {r_top.scores.tolist()}) out of {len(r_all.docs)} "
          f"matching docs, skipping {tk['chunks_skipped']} posting chunks "
          f"({tk['bytes_skipped']:,} bytes never read)")

    # ranked best-k: rank="prox" makes top_k mean the k best-SCORED docs
    # (proximity-weighted saturated term frequency), not the k smallest
    # doc ids.  The executor carries a per-key score upper bound on each
    # cursor and stops fetching once the k-th best settled score provably
    # beats everything still unread (WAND-style threshold test).  The
    # head is ordered score desc, doc id asc — identical, ties included,
    # to exhaustively scoring every match and sorting.
    r_rank = svc_cold.search_batch([Query(hot, top_k=3, rank="prox")])[0]
    tk = svc_cold.last_trace["topk"]
    assert np.all(np.diff(r_rank.scores) <= 0)  # score-descending head
    print(f"ranked top-3 -> docs {r_rank.docs.tolist()} scoring "
          f"{r_rank.scores.tolist()} ({tk['threshold_stops']} threshold "
          f"stop(s), {tk['chunks_skipped']} chunks skipped)")

    # hot traffic through the cross-query chunk pool: many concurrent
    # queries over the same hot vocabulary drain each posting stream
    # ONCE per batch — the first cursor fetches, every other query
    # replays the pooled chunks at zero I/O, so read bytes scale with
    # unique chunks rather than with the query count.  The trace
    # ledgers replays as chunks_shared and check_trace_complete proves
    # every planned chunk was fetched, shared, or provably skipped.
    hot_batch = [Query(hot, top_k=3) for _ in range(12)]

    def batch_bytes(svc):
        b0 = sum(s.read_bytes for s in ts.search_io().values())
        out = svc.search_batch(hot_batch)
        return out, sum(s.read_bytes for s in ts.search_io().values()) - b0

    solo, solo_bytes = batch_bytes(
        SearchService(ts, window=3, cache_bytes=0, share_chunks=False)
    )
    svc_pool = SearchService(ts, window=3, cache_bytes=0)
    pooled, pooled_bytes = batch_bytes(svc_pool)
    for a, b in zip(solo, pooled):
        assert np.array_equal(a.docs, b.docs)
        assert np.array_equal(a.scores, b.scores)
    svc_pool.check_trace_complete()
    tk = svc_pool.last_trace["topk"]
    print(f"hot-traffic batch of {len(hot_batch)}: {tk['pool_streams']} "
          f"pooled stream(s), {tk['chunks_shared']} chunk replays "
          f"({tk['bytes_shared']:,} bytes served without re-reading) -> "
          f"{pooled_bytes:,} read bytes vs {solo_bytes:,} with per-query "
          f"cursors, identical answers")

    # production scale-out: the SAME collection partitioned by doc hash
    # across 4 shards, served by the scatter/gather SearchService — the
    # batch is planned once, fetches scatter to every shard behind one
    # namespaced posting cache with a pipelined prefetch stage, joins
    # from all shards share the jax buckets, and per-shard results
    # gather losslessly (disjoint doc sets)
    sts = ShardedTextIndexSet(cfg, lex, n_shards=4)
    print("building the same collection sharded 4 ways ...")
    sts.add_documents(*part1, 0)
    sts.add_documents(*part2, 300)
    svc_sharded = SearchService(sts, window=3, backend="jax")
    for ref, got in zip(results, svc_sharded.search_batch(stream)):
        assert np.array_equal(ref.docs, got.docs)
        assert np.array_equal(ref.witnesses, got.witnesses)
    tr = svc_sharded.last_trace
    per_shard = [row["known"].total_bytes for row in sts.build_io_per_shard()]
    print(f"sharded answers identical; last batch pipelined "
          f"{tr['prefetched_waves']}/{tr['waves']} fetch waves; per-shard "
          f"known-index build bytes {per_shard} "
          f"(aggregate {sts.build_io()['known'].total_bytes:,})")

    # live updates under serving: part 3 lands through the per-shard
    # update streams while the SAME service (warm readers, caches and
    # all) keeps answering.  Readers invalidate only the cache entries
    # the writers' touched-key digests name, and every batch pins the
    # per-shard generation vector it executed against.
    part3 = generate_part(lex, n_docs=150, avg_doc_len=250, doc0=600, seed=12)
    gens0 = sts.generation_vector()
    inv0 = svc_sharded.reader.cache.stats.invalidations
    print("landing part 3 through the live update streams ...")
    sts.add_documents(*part3, 600)
    live = svc_sharded.search_batch(stream)
    cold = SearchService(sts, window=3, backend="jax").search_batch(stream)
    for a, b in zip(live, cold):
        assert np.array_equal(a.docs, b.docs)
        assert np.array_equal(a.witnesses, b.witnesses)
    stats = svc_sharded.reader.cache_stats
    print(f"served live: shard generations {gens0} -> "
          f"{svc_sharded.last_trace['snapshot']}, "
          f"{stats.invalidations - inv0} cache entries invalidated "
          f"(targeted; {stats.full_drops} namespace sweeps), answers "
          f"identical to a cold reader over the updated collection")

    # replica read tier: N replica readers per shard — each with its OWN
    # posting cache and devices — behind the same single-owner writers,
    # kept current off the writers' touched-key digest stream.  Fetch
    # waves route to the least-loaded live replica; killing one
    # MID-BATCH fails its waves over to a sibling with answers
    # unchanged, and a revived replica catches up (targeted
    # invalidations, never a rebuild) before re-entering rotation.
    from repro.search import ReplicaSetReader

    fab = ReplicaSetReader(sts, n_replicas=3)
    svc_fab = SearchService(fab, window=3, backend="jax")
    for a, b in zip(live, svc_fab.search_batch(stream)):
        assert np.array_equal(a.docs, b.docs)

    victim = fab.replicas[0][0]
    served = [0]

    def die_soon(rep, op):  # the injectable fault seam
        served[0] += 1
        if served[0] > 2:
            rep.kill()

    victim.fault = die_soon
    failed_over = svc_fab.search_batch(stream)
    rb = svc_fab.last_trace["replicas"]
    for a, b in zip(live, failed_over):
        assert np.array_equal(a.docs, b.docs)
        assert np.array_equal(a.witnesses, b.witnesses)
    print(f"replica fabric ({rb['n_replicas']} per shard): replica s0r0 "
          f"killed mid-batch, {rb['failovers_batch']} failover(s) to live "
          f"siblings, answers unchanged")

    part4 = generate_part(lex, n_docs=100, avg_doc_len=250, doc0=750,
                          seed=13)
    sts.add_documents(*part4, 750)  # the dead replica misses this part
    lag = victim.lag()
    modes = victim.revive()  # catch up on the digest stream, then serve
    for a, b in zip(svc_fab.search_batch(stream),
                    SearchService(sts, window=3).search_batch(stream)):
        assert np.array_equal(a.docs, b.docs)
    print(f"revived s0r0 from {lag} generation(s) behind via modes "
          f"{sorted(set(modes))}; fabric answers match a cold reader "
          f"over the updated collection")

    # persist -> crash -> recover: the same substrate behind the durable
    # on-disk store (repro.store).  Every part is in the write-ahead log
    # before its generation advances; a crash tearing the WAL mid-record
    # recovers to the last PUBLISHED part — never a partial one — and
    # the store keeps serving and accepting updates afterwards.
    import shutil
    import tempfile

    from repro.store import DurableIndexStore

    root = tempfile.mkdtemp(prefix="repro-quickstart-")
    try:
        print("reindexing into a durable WAL-fed store ...")
        store = DurableIndexStore(root, cfg, lex, n_shards=2)
        store.add_documents(*part1, 0)
        store.add_documents(*part2, 300)
        store.compact()  # fold update streams + publish a checkpoint
        published = store.wal.tell()
        store.add_documents(*part3, 600)  # ... and CRASH mid-part-3:
        torn = store.wal.tell()
        store.close()
        with open(f"{root}/wal.log", "rb+") as fh:
            fh.truncate(published + (torn - published) // 2)

        store = DurableIndexStore(root, cfg, lex, n_shards=2)
        ri = store.recovery_info
        recovered = SearchService(store, window=3,
                                  backend="jax").search_batch(stream)
        two_parts = ShardedTextIndexSet(cfg, lex, n_shards=2)
        two_parts.add_documents(*part1, 0)
        two_parts.add_documents(*part2, 300)
        ref = SearchService(two_parts, window=3).search_batch(stream)
        for a, b in zip(recovered, ref):
            assert np.array_equal(a.docs, b.docs)
        print(f"crash recovery: torn tail truncated "
              f"({ri['truncated_bytes']:,} bytes discarded, "
              f"{'checkpoint' if ri['from_checkpoint'] else 'replay'} + "
              f"{ri['wal_records']} WAL record(s)); the torn part is "
              f"invisible, answers match the published two-part state")
        store.add_documents(*part3, 600)  # re-land the lost part durably
        final = SearchService(store, window=3,
                              backend="jax").search_batch(stream)
        for a, b in zip(final, cold):
            assert np.array_equal(a.docs, b.docs)
        st = store.stats()
        print(f"re-landed part 3 durably: {st['wal_bytes']:,} WAL bytes "
              f"({st['parts_since_checkpoint']} part(s) ahead of the "
              f"checkpoint); answers identical to the live in-memory "
              f"substrate")
        store.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
