#!/usr/bin/env bash
# Tier-1 verification — the single entry point CI and humans share.
# Keep in sync with ROADMAP.md ("Tier-1 verify").
set -euo pipefail
cd "$(dirname "$0")/.."
# invariant lint gate first: the repro.analysis passes (charge
# accounting, trace schema, generation discipline, cache tiers, kernel
# purity) fail in milliseconds, before any benchmark or test runs
scripts/lint.sh
# tiny-corpus smoke of the sharded scatter/gather serving path (--shards
# composes with --batched: both substrates run through search_batch):
# asserts sharded results stay identical to unsharded and read I/O does
# not inflate
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.search_speed \
  --shards 2 --batched --scale 0.05 --queries 16
# tiny-corpus smoke of the top-k streaming executor: asserts the best-k
# head stays element-wise identical to the exhaustive path (across
# backends and shard counts) while reading strictly fewer posting bytes
# with chunks actually skipped
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.search_speed \
  --topk 10 --scale 0.05 --queries 12
# tiny-corpus smoke of the score-ordered (rank='prox') top-k executor:
# asserts the WAND-threshold-pruned head stays element-wise identical —
# docs, scores, tie order — to the exhaustive ranked scan (across
# backends and shard counts) while skipping chunks and reading strictly
# fewer posting bytes
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.search_speed \
  --ranked 5 --scale 0.05 --queries 10
# tiny-corpus smoke of the cross-query chunk pool: a hot-vocabulary
# batch through pooled cursors must stay element-wise identical to the
# per-query-cursor baseline (across backends and shard counts, device
# decode on) at <= 0.5x read bytes, and N concurrent identical queries
# must read < 2x the bytes of one query — not Nx
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.search_speed \
  --hot-traffic 24 --scale 0.05
# tiny-corpus smoke of live per-shard update streams: interleaved
# update/search rounds must serve results identical to a from-scratch
# rebuild, with targeted (touched-key digest) invalidation dropping
# strictly fewer cache entries — and reading fewer bytes — than the
# whole-namespace baseline
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.update_speed \
  --scale 0.05 --queries 12 --parts 3 --shards 2
# tiny-corpus smoke of the replica serving tier: a 2-replica fabric
# must serve results element-wise identical to the single-reader path
# (across backends and shard counts, including one replica killed
# mid-batch by an injected fault, which must force a real failover)
# with balanced routing lifting serving capacity >= 1.2x
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.search_speed \
  --replicas 2 --scale 0.05 --queries 12 --backend numpy
# tiny-corpus smoke of the durable on-disk backend: the WAL-fed store
# must charge the simulated devices exactly like the in-memory
# substrate, recover to element-wise identical results (replay and
# checkpoint paths), and fold streams without ever reading more bytes
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.durability \
  --scale 0.05 --queries 12 --parts 3 --shards 2
# dev mode + DeprecationWarning-as-error: deprecations surface as
# failures here, not as breakage on the next interpreter upgrade
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -X dev \
  -W error::DeprecationWarning -m pytest -x -q "$@"
