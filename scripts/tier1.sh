#!/usr/bin/env bash
# Tier-1 verification — the single entry point CI and humans share.
# Keep in sync with ROADMAP.md ("Tier-1 verify").
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
