#!/usr/bin/env bash
# Tier-1 verification — the single entry point CI and humans share.
# Keep in sync with ROADMAP.md ("Tier-1 verify").
set -euo pipefail
cd "$(dirname "$0")/.."
# tiny-corpus smoke of the sharded scatter/gather serving path (--shards
# composes with --batched: both substrates run through search_batch):
# asserts sharded results stay identical to unsharded and read I/O does
# not inflate
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.search_speed \
  --shards 2 --batched --scale 0.05 --queries 16
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
