#!/usr/bin/env bash
# Invariant lint gate: runs the repro.analysis passes over src/ (or,
# with --changed-only, just the .py files the working tree touches
# relative to HEAD — the fast pre-commit mode).  Non-zero exit on any
# finding; wired into scripts/tier1.sh ahead of pytest because a lint
# failure is cheaper to surface than a test failure.
set -euo pipefail
cd "$(dirname "$0")/.."

targets=(src)
if [[ "${1:-}" == "--changed-only" ]]; then
    shift
    mapfile -t changed < <(
        { git diff --name-only HEAD; git ls-files --others --exclude-standard; } \
            | sort -u | grep '^src/.*\.py$' || true
    )
    if [[ ${#changed[@]} -eq 0 ]]; then
        echo "lint: no changed src/*.py files"
        exit 0
    fi
    targets=("${changed[@]}")
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    exec python -m repro.analysis "${targets[@]}" "$@"
