"""Multi-component (k-word) key index: brute-force oracle equivalence,
storage-tier coverage, key packing, and I/O accounting rows.

The token-stream oracle and lemma-reading helpers live in
``tests/oracles.py`` (shared with the service/sharded suites)."""

import numpy as np
import pytest

from repro.core.dictionary import K_EM
from repro.core.lexicon import make_lexicon
from repro.core.multi_key import (
    MultiKeyIndex,
    extract_multi_postings,
    lemma_bits,
    pack_components,
    unpack_components,
)
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig, TextIndexSet
from repro.data.corpus import generate_part
from repro.search import ROUTE_MULTI, Query, SearchService
from tests.oracles import oracle_phrase, readings, word_for_lemma


# a tiny, hot vocabulary: trigram keys repeat heavily, so with a tiny
# em_limit and cluster the hottest keys are pushed out of EM into
# PART/S/CH streams while the cold tail stays inline — the oracle runs
# across every storage tier
@pytest.fixture(scope="module")
def tiered_world():
    lex = make_lexicon(
        n_words=14, n_lemmas=10, n_stop=2, n_frequent=3,
        unknown_fraction=0.15, seed=7,
    )
    parts = [
        generate_part(lex, n_docs=40, avg_doc_len=120, doc0=0, seed=51),
        generate_part(lex, n_docs=40, avg_doc_len=120, doc0=40, seed=52),
    ]
    cfg = IndexSetConfig(
        strategy=StrategyConfig.set2(
            cluster_size=256, em_limit=8, tag_extract_bytes=512
        ),
        fl_area_clusters=64,
    )
    ts = TextIndexSet(cfg, lex, seed=0)
    doc0 = 0
    for toks, offs in parts:
        ts.add_documents(toks, offs, doc0)
        doc0 += offs.shape[0] - 1
    return lex, parts, ts


# ----------------------------------------------------------- oracle tests --
def test_multi_route_matches_bruteforce_oracle(tiered_world):
    lex, parts, ts = tiered_world
    svc = SearchService(ts, window=3)
    toks0, offs0 = parts[0]
    rng = np.random.RandomState(3)
    n_multi = 0
    for _ in range(40):
        start = int(rng.randint(0, toks0.shape[0] - 3))
        words = tuple(int(t) for t in toks0[start : start + 3])
        r = svc.search_batch([Query(words, phrase=True)])[0]
        # all-stop trigrams take the (equally phrase-exact) stopseq route
        n_multi += r.route == ROUTE_MULTI
        want = oracle_phrase(lex, parts, words)
        got = {tuple(x) for x in r.witnesses.tolist()}
        assert got == want, (r.route, words)
        assert r.docs.tolist() == sorted({d for d, _ in want})
    assert n_multi >= 15, f"only {n_multi}/40 queries took the multi route"


def test_oracle_holds_across_storage_tiers(tiered_world):
    """Query one key per storage tier the index actually populated —
    EM-resident keys AND stream-backed (PART/S/CH/TAG) keys must both
    return exactly the oracle's matches."""
    lex, parts, ts = tiered_world
    mi = ts.indexes["multi"]
    census = mi.mgr.state_census()
    streams_used = {s for s, n in census.items() if n > 0}
    kinds = {e.kind for e in mi.dict.entries.values()}
    assert K_EM in kinds, "tiny keys should stay inline in the dictionary"
    assert streams_used - {"em"}, f"no stream-backed tiers populated: {census}"

    inv = word_for_lemma(lex)
    svc = SearchService(ts, window=3)
    covered = set()
    for key, e in mi.dict.entries.items():
        if e.kind in covered or e.npostings == 0:
            continue
        lemmas = mi.unpack(key)
        if any(l not in inv for l in lemmas):
            continue  # key only reachable through secondary readings
        words = tuple(inv[l] for l in lemmas)
        lem_back, cls_back = lex.classify_words(np.asarray(words, np.int64))
        if tuple(int(x) for x in lem_back) != lemmas:
            continue
        if all(int(c) == 0 for c in cls_back):  # all-stop: stopseq wins
            continue
        r = svc.search_batch([Query(words, phrase=True)])[0]
        assert r.route == ROUTE_MULTI
        want = oracle_phrase(lex, parts, words)
        got = {tuple(x) for x in r.witnesses.tolist()}
        assert got == want, (e.kind, words)
        covered.add(e.kind)
    assert len(covered) >= 2, f"expected >= 2 storage tiers exercised: {covered}"


def test_absent_phrase_returns_empty(tiered_world):
    lex, parts, ts = tiered_world
    svc = SearchService(ts, window=3)
    # an unknown-word trigram that never occurs contiguously
    w = lex.n_words - 1
    r = svc.search_batch([Query((w, w, w), phrase=True)])[0]
    if r.route == ROUTE_MULTI:  # not all-stop, vocab-dependent
        assert oracle_phrase(lex, parts, (w, w, w)) == set()
        assert r.docs.size == 0 and r.witnesses.shape == (0, 2)


def test_longer_phrase_cover_matches_oracle(tiered_world):
    """Phrases longer than k are covered by overlapping k-word keys."""
    lex, parts, ts = tiered_world
    svc = SearchService(ts, window=3)
    toks0, _ = parts[0]
    rng = np.random.RandomState(9)
    for L in (4, 5):
        for _ in range(6):
            start = int(rng.randint(0, toks0.shape[0] - L))
            words = tuple(int(t) for t in toks0[start : start + L])
            r = svc.search_batch([Query(words, phrase=True)])[0]
            assert r.route == ROUTE_MULTI
            assert len(r.lookups) == L - ts.indexes["multi"].k + 1
            want = oracle_phrase(lex, parts, words)
            got = {tuple(x) for x in r.witnesses.tolist()}
            assert got == want, (L, words)


# ------------------------------------------------------- extraction/packing --
def test_pack_unpack_roundtrip():
    for k, bits in ((2, 21), (3, 17), (4, 15)):
        rng = np.random.RandomState(k)
        for _ in range(50):
            comps = tuple(int(x) for x in rng.randint(0, 1 << bits, size=k))
            key = pack_components(comps, bits)
            assert 0 <= key < 1 << 62
            assert unpack_components(key, k, bits) == comps
    with pytest.raises(ValueError):
        pack_components((1 << 17, 0, 0), 17)


def test_multi_key_index_validation():
    from repro.core.io_sim import BlockDevice

    dev = BlockDevice(cluster_size=1024)
    with pytest.raises(ValueError):
        MultiKeyIndex(StrategyConfig.set1(), dev, k=1)
    with pytest.raises(ValueError):
        MultiKeyIndex(StrategyConfig.set1(), dev, k=4, component_bits=17)
    mi = MultiKeyIndex(StrategyConfig.set1(), dev, k=3, component_bits=17)
    with pytest.raises(ValueError):
        mi.pack((1, 2))  # wrong arity


def test_extraction_postings_are_exact_windows():
    """Every extracted posting certifies a real k-window whose tokens can
    read the key's lemmas; counts match an exhaustive scan."""
    lex = make_lexicon(n_words=300, n_lemmas=150, n_stop=5, n_frequent=30, seed=13)
    toks, offs = generate_part(lex, n_docs=15, avg_doc_len=50, doc0=0, seed=17)
    bits = lemma_bits(lex)
    maps = extract_multi_postings(lex, toks, offs, 0, k=3, bits=bits)
    n_checked = 0
    for key, posts in list(maps.items())[:200]:
        lemmas = unpack_components(key, 3, bits)
        for doc, pos in posts.tolist():
            s = int(offs[doc])
            assert all(
                lemmas[j] in readings(lex, toks[s + pos + j]) for j in range(3)
            )
            n_checked += 1
        # sorted, unique rows
        assert posts.shape == np.unique(posts, axis=0).shape
    assert n_checked > 100
    # total coverage: every in-document window appears under >= 1 key
    n_windows = sum(
        max(0, int(offs[d + 1] - offs[d]) - 2) for d in range(offs.shape[0] - 1)
    )
    primary_only = sum(
        1
        for posts in maps.values()
        for _ in range(posts.shape[0])
    )
    assert primary_only >= n_windows


def test_multi_index_has_io_accounting_rows(tiered_world):
    lex, parts, ts = tiered_world
    assert "multi" in ts.build_io()
    assert "multi" in ts.search_io()
    # build moved real bytes for the hot (stream-backed) keys
    assert ts.build_io()["multi"].total_bytes > 0
    svc = SearchService(ts, window=3, cache_bytes=0)
    toks0, _ = parts[0]
    before = ts.search_io()["multi"].total_ops
    # first trigram that is not all-stop (those route to stopseq)
    for s in range(toks0.shape[0] - 3):
        words = tuple(int(t) for t in toks0[s : s + 3])
        _, cls = lex.classify_words(np.asarray(words, np.int64))
        if any(int(c) != 0 for c in cls):
            break
    r = svc.search_batch([Query(words, phrase=True)])[0]
    assert r.route == ROUTE_MULTI
    assert ts.search_io()["multi"].total_ops > before


def test_index_set_multi_disabled():
    lex = make_lexicon(n_words=500, n_lemmas=250, n_stop=5, n_frequent=30, seed=2)
    cfg = IndexSetConfig(strategy=StrategyConfig.set1(cluster_size=1024),
                         multi_k=None, fl_area_clusters=64)
    ts = TextIndexSet(cfg, lex, seed=0)
    toks, offs = generate_part(lex, n_docs=10, avg_doc_len=40, doc0=0, seed=1)
    ts.add_documents(toks, offs, 0)
    assert "multi" not in ts.indexes
    svc = SearchService(ts, window=3)
    assert svc.multi is None
    words = tuple(int(t) for t in toks[:3])
    r = svc.search_batch([Query(words, phrase=True)])[0]
    assert r.route == "ordinary"  # graceful fallback, phrase semantics kept
