"""Score-ordered top-k: the ranked streaming executor vs the exhaustive
score-then-sort oracle.

Pins the PR's contract from every side:

  * property: ``Query(top_k=N, rank="prox")`` returns the exhaustive
    ranked oracle head — docs, scores AND tie order — element-wise,
    across numpy/jax/pallas and n_shards {1, 2, 4};
  * monotonicity: the ranked k-head is a prefix of the (k+1)-head (the
    (score desc, doc id asc) order is total);
  * ties: on a corpus engineered so equal scores straddle the k
    boundary, the shared ``head_order`` helper — not ``np.unique``
    arrival order — decides who makes the head;
  * effectiveness: on the seeded hot corpus the WAND threshold test
    stops with ``chunks_skipped > 0`` and strictly fewer read bytes
    than the exhaustive drain;
  * liveness: ranked heads stay oracle-identical through live update
    rounds AND background compaction of the live substrate;
  * observability: the per-query stop partition
    (``queries == early_terminated + fully_drained``,
    ``early_terminated == threshold_stops + bound_stops``) is enforced
    by ``check_trace_complete`` on every ranked batch;
  * the ``QueryResult.__eq__`` tightening: a scoreless result never
    again compares equal to a scored one.
"""

import dataclasses

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.core.sharded_set import ShardedTextIndexSet
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig, TextIndexSet
from repro.data.corpus import generate_part
from repro.search import (
    Query,
    QueryResult,
    SearchService,
    TraceIncompleteError,
    head_order,
    score_docs,
    score_docs_jax,
    spec_for,
)
from repro.search.scoring import ScoreSpec, doc_counts
from tests.oracles import (
    QUERY_SPEC,
    assert_ranked_matches_oracle,
    core_queries,
    run_live_update_rounds,
    spec_to_query,
)
from tests.test_topk import (
    BACKENDS,
    SHARD_COUNTS,
    _equiv_services,
    _equiv_worlds,
    _hot_phrases,
    hot_world,
)


def _ranked(q: Query, k: int) -> Query:
    return dataclasses.replace(q, top_k=k, rank="prox")


# --------------------------------------------------------- property suite --
@settings(max_examples=10, deadline=None)
@given(
    st.lists(QUERY_SPEC, min_size=1, max_size=5),
    st.integers(1, 12),
)
def test_ranked_head_matches_oracle_all_backends_shards(specs, k):
    """The tentpole: ranked top-k == exhaustive score-then-sort oracle,
    element-wise (docs, scores, tie order, witnesses), for every
    backend and shard count."""
    lex, toks, pools, ts, sharded = _equiv_worlds()
    ref_svc, svcs = _equiv_services()
    queries = [spec_to_query(s, toks, pools) for s in specs]
    ranked = [_ranked(q, k) for q in queries]
    ref = ref_svc.search_batch(queries)
    for (n, b), svc in svcs.items():
        got = svc.search_batch(ranked)
        svc.check_trace_complete()
        tr = svc.last_trace["topk"]
        assert tr["ranked_queries"] == len(ranked)
        for qi, (r, g) in enumerate(zip(ref, got)):
            assert_ranked_matches_oracle(
                r, g, ranked[qi], ref_svc,
                ctx=("shards", n, "backend", b, "k", k, "query", qi),
            )


def test_ranked_monotone_in_k():
    """The ranked k-head is a strict prefix of every larger head — the
    (score desc, doc id asc) order is total, so growing k only appends."""
    lex, toks, pools, ts, sharded = _equiv_worlds()
    ref_svc, svcs = _equiv_services()
    svc = svcs[(2, "numpy")]
    for q in core_queries(toks, pools):
        prev = None
        for k in (1, 2, 3, 5, 9, 200):
            got = svc.search_batch([_ranked(q, k)])[0]
            if prev is not None:
                m = prev.docs.shape[0]
                assert np.array_equal(got.docs[:m], prev.docs), (q, k)
                assert np.array_equal(got.scores[:m], prev.scores), (q, k)
            prev = got


def test_docid_mode_unchanged_for_existing_callers():
    """``Query(top_k=N)`` without ``rank`` keeps doc-id-ordered
    semantics — and its stop is ledgered as a bound stop, never a
    threshold stop."""
    lex, toks, pools, ts, sharded = _equiv_worlds()
    ref_svc, svcs = _equiv_services()
    svc = svcs[(2, "numpy")]
    ref = ref_svc.search_batch(core_queries(toks, pools))
    qs = [dataclasses.replace(q, top_k=3)
          for q in core_queries(toks, pools)]
    got = svc.search_batch(qs)
    svc.check_trace_complete()
    tr = svc.last_trace["topk"]
    assert tr["ranked_queries"] == 0 and tr["threshold_stops"] == 0
    for r, g in zip(ref, got):
        assert np.array_equal(g.docs, r.docs[:3])
        assert np.array_equal(g.scores, r.scores[:3])


# ------------------------------------------------------------- tie breaks --
def _tie_world():
    """A corpus engineered so one stop pair's score ties straddle any
    small k: every document repeats the same two stop words in lockstep,
    so per-doc counts (hence scores) collide by construction."""
    from repro.core.lexicon import make_lexicon

    lex = make_lexicon(n_words=400, n_lemmas=200, n_stop=12,
                       n_frequent=40, seed=7)
    cfg = IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=512),
        fl_area_clusters=64,
    )
    rng = np.random.RandomState(11)
    from tests.oracles import class_pools
    from repro.core.lexicon import STOP

    stop = class_pools(lex)[STOP]
    a, b = stop[0], stop[1]
    toks, offs = [], [0]
    n_docs = 24
    for d in range(n_docs):
        # repeats cycle 1..6: with TF_CAP=4 docs with 4, 5 and 6 repeats
        # all saturate to the SAME score — ties guaranteed across k
        reps = 1 + d % 6
        doc = [a, b] * reps
        # pad with out-of-query filler so doc lengths differ too
        doc += [int(w) for w in rng.randint(100, 380, size=5)]
        toks.extend(doc)
        offs.append(len(toks))
    parts = [(np.asarray(toks, np.int64), np.asarray(offs, np.int64))]
    ts = TextIndexSet(cfg, lex, seed=0)
    ts.add_documents(*parts[0], 0)
    return lex, ts, (a, b), n_docs


def test_ranked_ties_straddling_k_use_shared_order():
    """Equal scores straddle the k boundary: the head must contain the
    LOWEST doc ids among the tied score class — the shared
    ``head_order`` rule — and must agree with the exhaustive oracle."""
    lex, ts, (a, b), n_docs = _tie_world()
    svc = SearchService(ts, window=3, backend="numpy")
    ref_svc = SearchService(ts, window=3, backend="numpy")
    ref = ref_svc.search_batch([Query((a, b))])[0]
    assert ref.docs.shape[0] == n_docs
    for k in range(1, n_docs + 2):
        q = Query((a, b), top_k=min(k, n_docs), rank="prox")
        got = svc.search_batch([q])[0]
        svc.check_trace_complete()
        assert_ranked_matches_oracle(ref, got, q, ref_svc, ctx=("tie", k))
        # scores non-increasing; doc ids ascending inside each tie class
        s, d = got.scores, got.docs
        assert np.all(np.diff(s) <= 0), k
        for lo in range(len(s)):
            same = s == s[lo]
            assert np.all(np.diff(d[same]) > 0), k
    # the saturating cap really did manufacture cross-doc ties
    assert np.unique(ref.scores).shape[0] < n_docs


def test_head_order_is_the_single_tie_rule():
    """Unit pin of the shared helper: ranked = (score desc, doc asc),
    doc-id mode = identity prefix."""
    docs = np.array([3, 5, 9, 12, 40], dtype=np.int64)
    scores = np.array([7, 9, 7, 9, 1], dtype=np.int64)
    order = head_order(docs, scores, 3, ranked=True)
    assert np.array_equal(docs[order], [5, 12, 3])
    assert np.array_equal(scores[order], [9, 9, 7])
    assert np.array_equal(head_order(docs, scores, 3, ranked=False),
                          [0, 1, 2])
    assert head_order(docs, scores, 99, ranked=True).shape[0] == 5


# ------------------------------------------------------- scoring algebra --
def test_score_forms_identical_numpy_vs_jax():
    rng = np.random.RandomState(0)
    for n_slots in (1, 2, 3):
        for n in (1, 2, 7, 33, 257):
            counts = [rng.randint(0, 12, size=n).astype(np.int64)
                      for _ in range(n_slots)]
            spec = ScoreSpec(weights=tuple(rng.randint(1, 13)
                                           for _ in range(n_slots)))
            a = score_docs(counts, spec)
            b = score_docs_jax(counts, spec)
            assert a.dtype == np.int64
            assert np.array_equal(a, b), (n_slots, n)


def test_spec_for_routes():
    """Route distances: phrase/multi/stopseq witness adjacency (d=1),
    wv is precomputed at max_distance, ordinary gets the window."""
    assert spec_for("stopseq", 1, 3, 3).weights == (12,)
    assert spec_for("multi", 2, 3, 3).weights == (12, 12)
    assert spec_for("ordinary", 2, 3, 3, phrase=True).weights == (12, 12)
    assert spec_for("wv", 1, 5, 3).weights == (6,)
    assert spec_for("ordinary", 3, 2, 3).weights == (8, 8, 8)
    spec = spec_for("ordinary", 2, 3, 3)
    assert spec.max_score == 2 * 6 * spec.tf_cap


def test_doc_counts_matches_bruteforce():
    rng = np.random.RandomState(4)
    posts = np.stack([np.sort(rng.randint(0, 20, size=200)),
                      rng.randint(0, 50, size=200)], axis=1).astype(np.int64)
    docs = np.unique(posts[:, 0])
    got = doc_counts(docs, posts)
    want = [int(np.sum(posts[:, 0] == d)) for d in docs]
    assert np.array_equal(got, want)
    assert doc_counts(np.zeros(0, np.int64), posts).shape == (0,)


# ------------------------------------------------- QueryResult tightening --
def test_scoreless_vs_scored_results_unequal():
    """Regression for the __eq__ escape hatch: an executor that silently
    drops scores must no longer compare equal to a scored result."""
    docs = np.array([1, 2], dtype=np.int64)
    wits = np.array([[1, 0], [2, 4]], dtype=np.int64)
    scored = QueryResult(docs, wits, [("known", 5)], 2,
                         scores=np.array([3, 1], np.int64))
    scoreless = QueryResult(docs, wits, [("known", 5)], 2, scores=None)
    assert scored != scoreless
    assert scoreless != scored
    assert scored == QueryResult(docs, wits, [("known", 5)], 2,
                                 scores=np.array([3, 1], np.int64))
    assert scoreless == QueryResult(docs, wits, [("known", 5)], 2)
    # and differing score VALUES are unequal too
    assert scored != QueryResult(docs, wits, [("known", 5)], 2,
                                 scores=np.array([3, 2], np.int64))


def test_facade_path_attaches_scores():
    """The single-query ProximityEngine facade now carries scores, so it
    is comparable against scored results under the tightened equality."""
    from repro.core.lexicon import OTHER, make_lexicon
    from repro.core.proximity import ProximityEngine
    from tests.oracles import class_pools

    lex = make_lexicon(n_words=2000, n_lemmas=900, n_stop=16,
                       n_frequent=90, seed=23)
    cfg = IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=512),
        build_ordinary_all=True,
        fl_area_clusters=64,
    )
    ts = TextIndexSet(cfg, lex, seed=0)
    ts.add_documents(*generate_part(lex, n_docs=40, avg_doc_len=100,
                                    doc0=0, seed=60), 0)
    pools = class_pools(lex)
    words = (pools[OTHER][1], pools[OTHER][2])
    r = ProximityEngine(ts, window=3).search_ordinary(words)
    assert r.scores is not None
    assert r.scores.shape == r.docs.shape
    # the scores ARE the per-doc witness counts, aligned with docs
    docs, counts = np.unique(r.witnesses[:, 0], return_counts=True)
    assert np.array_equal(r.docs, docs)
    assert np.array_equal(r.scores, counts)


# ------------------------------------------------------- trace invariants --
def test_early_terminated_counts_per_query(hot_world):
    """Regression for the bool-accumulation bug: a batch where EVERY
    query stops early must report early_terminated == len(batch), not 1."""
    lex, parts, ts = hot_world
    toks0 = parts[0][0]
    phrases = _hot_phrases(lex, toks0, n=4, ts=ts)
    assert len(phrases) >= 2
    svc = SearchService(ts, window=3, backend="numpy", cache_bytes=0)
    qs = [Query(w, phrase=True, top_k=1, rank="prox") for w in phrases]
    svc.search_batch(qs)
    svc.check_trace_complete()
    tr = svc.last_trace["topk"]
    assert tr["early_terminated"] == tr["threshold_stops"] > 1
    assert tr["queries"] == tr["early_terminated"] + tr["fully_drained"]


def test_trace_partition_enforced(hot_world):
    """check_trace_complete raises when the per-query stop partition is
    violated (mutating any one counter breaks a partition equation)."""
    lex, parts, ts = hot_world
    toks0 = parts[0][0]
    svc = SearchService(ts, window=3, backend="numpy", cache_bytes=0)
    words = _hot_phrases(lex, toks0, 1, ts=ts)[0]
    svc.search_batch([Query(words, phrase=True, top_k=1, rank="prox")])
    svc.check_trace_complete()
    for key in ("early_terminated", "fully_drained", "threshold_stops"):
        good = dict(svc.last_trace["topk"])
        svc.last_trace["topk"][key] += 1
        with pytest.raises(TraceIncompleteError):
            svc.check_trace_complete()
        svc.last_trace["topk"] = good
        svc.check_trace_complete()


# -------------------------------------------------- hot-corpus regression --
def test_hot_corpus_ranked_skips_chunks(hot_world):
    """The acceptance gate: under ranking the WAND threshold stop still
    skips chunks and reads strictly fewer bytes than the exhaustive
    drain, while the head stays oracle-identical."""
    lex, parts, ts = hot_world
    toks0 = parts[0][0]
    phrases = _hot_phrases(lex, toks0, n=8, ts=ts)
    svc = SearchService(ts, window=3, backend="numpy", cache_bytes=0)
    ref_svc = SearchService(ts, window=3, backend="numpy", cache_bytes=0)
    ranked = [Query(w, phrase=True, top_k=2, rank="prox") for w in phrases]
    ref = ref_svc.search_batch([Query(w, phrase=True) for w in phrases])
    got = svc.search_batch(ranked)
    svc.check_trace_complete()
    for qi, (r, g) in enumerate(zip(ref, got)):
        assert_ranked_matches_oracle(r, g, ranked[qi], ref_svc, ctx=qi)
    tr = svc.last_trace["topk"]
    assert tr["threshold_stops"] > 0
    assert tr["chunks_skipped"] > 0
    assert tr["bytes_fetched"] < tr["bytes_planned"]
    assert (
        tr["bytes_fetched"] + tr["bytes_skipped"] + tr["bytes_shared"]
        == tr["bytes_planned"]
    )


# ------------------------------------------------- live updates + compaction --
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_ranked_through_updates_and_compaction(n_shards):
    """Ranked heads stay oracle-identical while parts land on a LIVE
    substrate that is compacted mid-run (the rebuild reference never is):
    per-key max_doc_count, cursors and scores are all
    update/compaction-transparent."""
    from repro.core.lexicon import make_lexicon
    from tests.oracles import class_pools

    lex = make_lexicon(n_words=2000, n_lemmas=900, n_stop=16,
                       n_frequent=90, seed=19)
    cfg = IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=512),
        fl_area_clusters=64,
    )
    parts = [
        generate_part(lex, n_docs=30, avg_doc_len=90, doc0=0, seed=50),
        generate_part(lex, n_docs=30, avg_doc_len=90, doc0=30, seed=51),
        generate_part(lex, n_docs=30, avg_doc_len=90, doc0=60, seed=52),
    ]
    pools = class_pools(lex)
    toks = parts[0][0]
    queries = []
    for q in core_queries(toks, pools):
        queries.append(_ranked(q, 3))
        queries.append(q)  # exhaustive twin keeps the mixed batch honest

    def make_substrate():
        if n_shards == 1:
            return TextIndexSet(cfg, lex, seed=0)
        return ShardedTextIndexSet(cfg, lex, n_shards=n_shards, seed=0)

    svcs = run_live_update_rounds(
        make_substrate, parts, [0, 30, 60], queries,
        backends=BACKENDS, ctx=("ranked-live", n_shards),
        compact_after=(1,),
    )
    for svc in svcs.values():
        svc.check_trace_complete()
        assert svc.last_trace["topk"]["ranked_queries"] == len(queries) // 2
