"""Sharded index substrate: document-hash builds partition the unsharded
postings exactly, scatter/gather serving is element-wise identical to the
unsharded set across all four planner routes and all three join backends,
the shared posting cache is namespaced by (shard, index, key), and the
pipelined prefetch stage changes scheduling — never results."""

import functools

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.core.lexicon import FREQUENT, OTHER, STOP, make_lexicon
from repro.core.sharded_set import (
    ShardedTextIndexSet,
    merge_shard_postings,
    shard_of,
    shard_of_docs,
)
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig, IndexSetLike, TextIndexSet
from repro.data.corpus import generate_part
from repro.search import (
    ROUTE_MULTI,
    ROUTE_ORDINARY,
    ROUTE_STOPSEQ,
    ROUTE_WV,
    Query,
    SearchService,
    ShardedIndexSetReader,
)
from tests.oracles import (
    QUERY_SPEC,
    assert_results_identical,
    class_pools,
    core_queries,
    mixed_queries,
    spec_to_query,
    words_of_class,
)

BACKENDS = ("numpy", "jax", "pallas")
SHARD_COUNTS = (1, 2, 4)


def _cfg(**kw):
    return IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=1024),
        fl_area_clusters=64,
        **kw,
    )


@functools.lru_cache(maxsize=None)
def _worlds():
    """One small two-part collection indexed unsharded and at every shard
    count (cached: the substrates are immutable across tests that only
    read)."""
    lex = make_lexicon(
        n_words=3000, n_lemmas=1300, n_stop=20, n_frequent=120, seed=40
    )
    parts = [
        generate_part(lex, n_docs=60, avg_doc_len=120, doc0=0, seed=60),
        generate_part(lex, n_docs=60, avg_doc_len=120, doc0=60, seed=61),
    ]
    ts = TextIndexSet(_cfg(), lex, seed=0)
    sharded = {
        n: ShardedTextIndexSet(_cfg(), lex, n_shards=n, seed=0)
        for n in SHARD_COUNTS
    }
    for s in [ts] + list(sharded.values()):
        s.add_documents(*parts[0], 0)
        s.add_documents(*parts[1], 60)
    toks = parts[0][0]
    pools = class_pools(lex)
    return lex, toks, pools, ts, sharded


@functools.lru_cache(maxsize=None)
def _services():
    """Reference numpy service over the unsharded set + one service per
    (shard count, backend) over the sharded substrates."""
    lex, toks, pools, ts, sharded = _worlds()
    ref = SearchService(ts, window=3, backend="numpy")
    svcs = {
        (n, b): SearchService(sharded[n], window=3, backend=b)
        for n in SHARD_COUNTS
        for b in BACKENDS
    }
    return ref, svcs


# ----------------------------------------------------------- the substrate --
def test_shard_hash_deterministic_and_in_range():
    docs = np.arange(5000, dtype=np.int64)
    for n in SHARD_COUNTS:
        vec = shard_of_docs(docs, n)
        assert vec.min() >= 0 and vec.max() < n
        for d in (0, 1, 2, 63, 64, 4999):
            assert shard_of(d, n) == vec[d]
        if n > 1:
            # the multiplicative mix must not starve any shard on the
            # contiguous doc-id ranges real collections produce
            counts = np.bincount(vec, minlength=n)
            assert counts.min() > 0


def test_sharded_set_implements_index_set_interface():
    _, _, _, ts, sharded = _worlds()
    assert isinstance(ts, IndexSetLike)
    for sts in sharded.values():
        assert isinstance(sts, IndexSetLike)
        assert sts.cfg is ts.cfg or sts.cfg == ts.cfg
        assert set(sts.indexes) == set(ts.indexes)


def test_sharded_build_partitions_unsharded_postings():
    """Every key's per-shard posting lists are exactly the doc-hash row
    subsets of the unsharded list, and their merge reconstructs it."""
    _, _, _, ts, sharded = _worlds()
    for n, sts in sharded.items():
        for name, idx in ts.indexes.items():
            keys = list(idx.dict.entries)[:25]
            assert keys, name
            for key in keys:
                ref = idx.lookup(key)
                per_shard = [sh.indexes[name].lookup(key)
                             for sh in sts.shards]
                owner = shard_of_docs(ref[:, 0], n)
                for s, arr in enumerate(per_shard):
                    assert np.array_equal(arr, ref[owner == s]), (n, name, key)
                assert np.array_equal(merge_shard_postings(per_shard), ref)


def test_whole_set_lookup_merges_across_shards():
    _, _, _, ts, sharded = _worlds()
    key = next(iter(ts.indexes["known"].dict.entries))
    ref = ts.indexes["known"].lookup(key)
    for sts in sharded.values():
        assert np.array_equal(sts.lookup("known", key), ref)


def test_per_shard_io_reports_sum_to_aggregate():
    _, _, _, _, sharded = _worlds()
    sts = sharded[4]
    per_shard = sts.build_io_per_shard()
    assert len(per_shard) == 4
    agg = sts.build_io()
    for name in sts.indexes:
        total = sum(d[name].total_bytes for d in per_shard)
        ops = sum(d[name].total_ops for d in per_shard)
        assert agg[name].total_bytes == total > 0
        assert agg[name].total_ops == ops > 0
    rows = sts.table_rows()
    by_shard = sts.table_rows_per_shard()
    for name, row in rows.items():
        for col, v in row.items():
            assert v == sum(r[name][col] for r in by_shard)


# --------------------------------------------- scatter/gather equivalence --
@settings(max_examples=12, deadline=None)
@given(st.lists(QUERY_SPEC, min_size=0, max_size=8))
def test_sharded_equivalence_all_routes_all_backends(specs):
    """Property: ShardedTextIndexSet(n_shards ∈ {1,2,4}) returns
    element-wise identical QueryResults to the unsharded set across all
    four routes and all three join backends.  Each batch carries a fixed
    core hitting every route plus the drawn random queries."""
    lex, toks, pools, ts, _ = _worlds()
    ref_svc, svcs = _services()
    queries = core_queries(toks, pools) + [
        spec_to_query(s, toks, pools) for s in specs
    ]
    ref = ref_svc.search_batch(queries)
    routes = {r.route for r in ref}
    assert routes >= {ROUTE_STOPSEQ, ROUTE_WV, ROUTE_ORDINARY, ROUTE_MULTI}
    for (n, backend), svc in svcs.items():
        got = svc.search_batch(queries)
        for q, r, g in zip(queries, ref, got):
            assert_results_identical(r, g, ctx=(n, backend, q))


def test_prefetch_changes_scheduling_not_results():
    """The pipelined fetch stage must be a pure scheduling optimization:
    identical results with prefetch on and off, and the trace shows every
    non-final wave was prefetched while the previous one landed."""
    lex, toks, pools, _, sharded = _worlds()
    queries = mixed_queries(lex, n=32, seed=9)
    on = SearchService(sharded[4], window=3, backend="jax", prefetch=True)
    off = SearchService(sharded[4], window=3, backend="jax", prefetch=False)
    got_on = on.search_batch(queries)
    got_off = off.search_batch(queries)
    for a, b in zip(got_on, got_off):
        assert np.array_equal(a.docs, b.docs)
        assert np.array_equal(a.witnesses, b.witnesses)
        assert a.lookups == b.lookups
    tr = on.last_trace
    assert tr["waves"] >= 2
    assert tr["prefetched_waves"] == tr["waves"] - 1
    assert len(tr["shard_fetch_s"]) == 4
    assert off.last_trace["prefetched_waves"] == 0
    # single-lookup and phrase routes finalized while later waves fetched
    assert tr["overlapped_finalizes"] > 0


def test_sharded_read_bytes_do_not_inflate():
    """Scatter-fetch across 4 shards must stay within 10% of the
    unsharded read bytes on the same query stream (cache disabled so the
    device deltas are the true posting traffic)."""
    lex, toks, pools, ts, sharded = _worlds()
    queries = mixed_queries(lex, n=48, seed=3)
    svc_u = SearchService(ts, window=3, backend="numpy", cache_bytes=0)
    svc_s = SearchService(sharded[4], window=3, backend="numpy",
                          cache_bytes=0)

    def read_bytes(index_set):
        return sum(s.read_bytes for s in index_set.search_io().values())

    b0 = read_bytes(ts)
    svc_u.search_batch(queries)
    unsharded = read_bytes(ts) - b0
    b0 = read_bytes(sharded[4])
    svc_s.search_batch(queries)
    sharded_bytes = read_bytes(sharded[4]) - b0
    assert unsharded > 0
    assert sharded_bytes <= 1.1 * unsharded, (sharded_bytes, unsharded)


# --------------------------------------------------- reader/cache fabric --
def test_shard_cache_namespacing():
    """One shared cache, keyed by (shard, index, key): shards never answer
    for each other, and dropping one shard's namespace leaves the rest."""
    _, _, _, ts, sharded = _worlds()
    sts = sharded[2]
    reader = sts.reader(cache_bytes=1 << 20)
    # a key with postings in both shards
    key = None
    for k in list(ts.indexes["known"].dict.entries)[:200]:
        if all(sh.indexes["known"].lookup(k).shape[0] for sh in sts.shards):
            key = k
            break
    assert key is not None
    a0 = reader.lookup_shard(0, "known", key)
    a1 = reader.lookup_shard(1, "known", key)
    assert not np.array_equal(a0, a1)
    assert len(reader.cache) == 2  # two slots for the same (index, key)
    h0 = reader.cache.stats.hits
    assert np.array_equal(reader.lookup_shard(0, "known", key), a0)
    assert np.array_equal(reader.lookup_shard(1, "known", key), a1)
    assert reader.cache.stats.hits == h0 + 2
    reader.cache.drop_index("s0:known")
    assert reader.cache.get("s0:known", key) is None
    assert np.array_equal(reader.cache.get("s1:known", key), a1)


def test_sharded_reader_refresh_and_read_your_writes():
    """A no-op refresh keeps every shard's cache entries; a real writer
    advance invalidates and re-reads fresh merged postings."""
    lex = make_lexicon(
        n_words=3000, n_lemmas=1300, n_stop=20, n_frequent=120, seed=41
    )
    sts = ShardedTextIndexSet(_cfg(multi_k=None), lex, n_shards=2, seed=0)
    t1, o1 = generate_part(lex, n_docs=50, avg_doc_len=120, doc0=0, seed=70)
    t2, o2 = generate_part(lex, n_docs=50, avg_doc_len=120, doc0=50, seed=71)
    sts.add_documents(t1, o1, 0)
    reader = sts.reader()
    assert isinstance(reader, ShardedIndexSetReader)
    key = next(iter(sts.shards[0].indexes["known"].dict.entries))
    before = reader.lookup("known", key).copy()
    reader.refresh()  # generations unchanged: caches must survive
    assert reader.cache.stats.invalidations == 0
    h0 = reader.cache.stats.hits
    reader.lookup("known", key)
    assert reader.cache.stats.hits > h0
    sts.add_documents(t2, o2, 50)  # writers advance: entries stale
    after = reader.lookup("known", key)
    assert reader.cache.stats.invalidations > 0
    fresh = merge_shard_postings(
        [sh.indexes["known"].lookup(key) for sh in sts.shards]
    )
    assert np.array_equal(after, fresh)
    assert after.shape[0] >= before.shape[0]


def test_merge_shard_postings_edge_cases():
    empty = np.zeros((0, 2), np.int64)
    assert merge_shard_postings([]).shape == (0, 2)
    assert merge_shard_postings([empty, empty]).shape == (0, 2)
    one = np.asarray([[3, 1], [5, 2]], np.int64)
    one.flags.writeable = False
    out = merge_shard_postings([empty, one, empty])
    assert out is one  # single survivor passes through (read-only intact)
    a = np.asarray([[0, 5], [2, 1], [2, 4]], np.int64)
    b = np.asarray([[1, 9], [3, 0]], np.int64)
    merged = merge_shard_postings([a, b])
    assert np.array_equal(
        merged,
        [[0, 5], [1, 9], [2, 1], [2, 4], [3, 0]],
    )


def test_bad_shard_counts_rejected():
    lex, *_ = _worlds()
    with pytest.raises(ValueError):
        ShardedTextIndexSet(_cfg(), lex, n_shards=0)
