"""Model-level behaviour: transformer decode==prefill, MACE equivariance,
recsys objectives finite + gradients flow."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import recsys as RS
from repro.models.mace import MACEConfig, mace_energy_mse, mace_forward, mace_init
from repro.models.moe import MoEConfig
from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    init_params,
    lm_loss,
    make_cache,
    prefill,
)

RNG = np.random.RandomState(3)


def _tf_cfg(moe=False):
    return TransformerConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=211, qkv_bias=not moe,
        loss_chunk=16, flash_chunk=16,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=48, n_shared_experts=1,
                      capacity_factor=16.0, group_tokens=64) if moe else None,
    )


@pytest.mark.parametrize("moe", [False, True])
def test_decode_matches_prefill(moe):
    cfg = _tf_cfg(moe)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.randint(0, 211, (2, 32)))
    logits_p, cache = prefill(cfg, params, toks)
    full = make_cache(cfg, 2, 48)
    full["k"] = full["k"].at[:, :, :32].set(cache["k"])
    full["v"] = full["v"].at[:, :, :32].set(cache["v"])
    full["len"] = cache["len"]
    nxt = jnp.argmax(logits_p, -1)
    logits_d, cache2 = decode_step(cfg, params, nxt, full)
    logits_p2, _ = prefill(
        cfg, params, jnp.concatenate([toks, nxt[:, None]], 1)
    )
    err = float(
        jnp.abs(logits_d - logits_p2).max() / (jnp.abs(logits_p2).max() + 1e-9)
    )
    assert err < 2e-2, err
    assert int(cache2["len"][0]) == 33


@pytest.mark.parametrize("moe", [False, True])
def test_lm_loss_and_grads_finite(moe):
    cfg = _tf_cfg(moe)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.randint(0, 211, (2, 32)))
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(cfg, p, toks, jnp.roll(toks, -1, 1))[0]
    )(params)
    assert jnp.isfinite(loss)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all())


def test_mace_e3_invariance():
    cfg = MACEConfig(d_hidden=16, n_out=4, d_feat=12, n_layers=2)
    p = mace_init(cfg, jax.random.PRNGKey(0))
    N, E = 40, 160
    feat = jnp.asarray(RNG.randn(N, 12), jnp.float32)
    pos = jnp.asarray(RNG.randn(N, 3), jnp.float32)
    src = jnp.asarray(RNG.randint(0, N, E), jnp.int32)
    dst = jnp.asarray(RNG.randint(0, N, E), jnp.int32)
    th = 0.9
    c, s = np.cos(th), np.sin(th)
    R = jnp.asarray(
        np.array([[c, -s, 0], [s, c, 0], [0, 0, 1]])
        @ np.array([[1, 0, 0], [0, 0.6, -0.8], [0, 0.8, 0.6]]),
        jnp.float32,
    )
    o1 = mace_forward(cfg, p, feat, pos, src, dst)
    o2 = mace_forward(cfg, p, feat, pos @ R.T + 2.5, src, dst)
    err = float(jnp.abs(o1 - o2).max() / (jnp.abs(o1).max() + 1e-9))
    assert err < 1e-4, err


def test_mace_energy_training_reduces_loss():
    rng = np.random.RandomState(3)  # test-local: order-independent
    cfg = MACEConfig(d_hidden=8, n_out=1, d_feat=0, n_species=4, n_layers=1)
    p = mace_init(cfg, jax.random.PRNGKey(1))
    N = 32
    batch = dict(
        species=jnp.asarray(rng.randint(0, 4, N)),
        pos=jnp.asarray(rng.randn(N, 3), jnp.float32),
        edges_src=jnp.asarray(rng.randint(0, N, 96), jnp.int32),
        edges_dst=jnp.asarray(rng.randint(0, N, 96), jnp.int32),
        graph_of=jnp.asarray(np.repeat(np.arange(4), 8), jnp.int32),
        energy=jnp.asarray(rng.randn(4), jnp.float32),
    )
    loss_fn = lambda pp: mace_energy_mse(cfg, pp, batch)
    l0 = float(loss_fn(p))
    grad_fn = jax.jit(jax.grad(loss_fn))
    for _ in range(30):
        g = grad_fn(p)
        # the correlation-3 (cubic) terms make the landscape stiff: without
        # a global-norm clip plain SGD at this lr diverges to NaN
        gn = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree_util.tree_leaves(g)))
        clip = jnp.minimum(1.0, 1.0 / (gn + 1e-9))
        p = jax.tree_util.tree_map(lambda a, b: a - 0.005 * clip * b, p, g)
    l1 = float(loss_fn(p))
    assert l1 < 0.2 * l0, (l0, l1)


def test_recsys_losses_and_retrieval():
    dl = RS.DLRMConfig(table_rows=tuple([50] * 26), embed_dim=16,
                       bot_mlp=(32, 16), top_mlp=(32, 1))
    pd = RS.dlrm_init(dl, jax.random.PRNGKey(0))
    b = dict(
        dense=jnp.asarray(RNG.rand(8, 13), jnp.float32),
        sparse=jnp.asarray(RNG.randint(0, 50, (8, 26))),
        label=jnp.asarray(RNG.randint(0, 2, 8), jnp.float32),
    )
    assert jnp.isfinite(RS.dlrm_loss(dl, pd, b))
    top = RS.dlrm_retrieval(
        dl, pd, dict(dense=b["dense"][:1], sparse=b["sparse"][:1],
                     candidates=jnp.arange(50)),
    )
    assert top.shape == (50,) and len(set(np.asarray(top).tolist())) == 50

    tt = RS.TwoTowerConfig(n_users=100, n_items=80, n_context=10,
                           embed_dim=16, tower_mlp=(32, 16))
    pt = RS.twotower_init(tt, jax.random.PRNGKey(1))
    bt = dict(
        user_id=jnp.asarray(RNG.randint(0, 100, 16)),
        user_ctx=jnp.asarray(RNG.randint(0, 10, 16)),
        item_id=jnp.asarray(RNG.randint(0, 80, 16)),
        item_cat=jnp.asarray(RNG.randint(0, 10, 16)),
    )
    assert jnp.isfinite(RS.twotower_loss(tt, pt, bt))
    # in-batch softmax should beat chance after a few steps
    loss_fn = lambda pp: RS.twotower_loss(tt, pp, bt)
    l0 = float(loss_fn(pt))
    for _ in range(30):
        pt = jax.tree_util.tree_map(
            lambda a, g: a - 0.1 * g, pt, jax.grad(loss_fn)(pt)
        )
    assert float(loss_fn(pt)) < l0
