"""Fixture suite for the ``repro.analysis`` invariant linter.

One known-bad snippet per pass (asserted to flag), one pragma-suppressed
variant (asserted clean), pass-precision checks against the idioms the
real tree uses, and the meta-test: the full ``src/`` tree lints clean at
HEAD — the acceptance bar the tier-1 gate enforces.
"""

import subprocess
import sys
from pathlib import Path

from repro.analysis import all_passes, lint_paths, lint_source
from repro.analysis.passes import (
    CacheTierPass,
    ChargeAccountingPass,
    GenerationDisciplinePass,
    KernelPurityPass,
    TraceSchemaPass,
)

REPO = Path(__file__).resolve().parent.parent


def findings(src, path="x.py", passes=None):
    return lint_source(src, path, passes or all_passes())


def ids(fs):
    return {f.pass_id for f in fs}


# --------------------------------------------------------- charge pass --
BAD_CHARGE = """
def sneak_read(dev, n):
    return dev.read_small(n)  # uncharged I/O
"""


def test_charge_flags_direct_device_read():
    fs = findings(BAD_CHARGE, passes=[ChargeAccountingPass()])
    assert len(fs) == 1 and fs[0].pass_id == "charge-accounting"
    assert "read_small" in fs[0].message


def test_charge_flags_iostats_poke():
    fs = findings(
        "def f(st):\n    st.read_bytes += 4096\n",
        passes=[ChargeAccountingPass()],
    )
    assert ids(fs) == {"charge-accounting"}


def test_charge_allows_chokepoint_modules():
    fs = findings(
        BAD_CHARGE,
        path="src/repro/core/stream.py",
        passes=[ChargeAccountingPass()],
    )
    assert fs == []


def test_charge_pragma_suppresses():
    src = (
        "def f(dev, n):\n"
        "    return dev.read_small(n)"
        "  # repro-lint: allow(charge-accounting) test harness\n"
    )
    assert findings(src, passes=[ChargeAccountingPass()]) == []


# ---------------------------------------------------------- trace pass --
BAD_TRACE_KEY = """
class S:
    def f(self):
        self.last_trace["wavez"] = 3
"""

BAD_TRACE_BOOL = """
class S:
    def f(self, trace, stopped):
        trace["early_terminated"] += any(stopped)
"""


def test_trace_flags_undeclared_key():
    fs = findings(BAD_TRACE_KEY, passes=[TraceSchemaPass()])
    assert len(fs) == 1 and "wavez" in fs[0].message


def test_trace_flags_bool_counter():
    fs = findings(BAD_TRACE_BOOL, passes=[TraceSchemaPass()])
    assert len(fs) == 1 and "early_terminated" in fs[0].message


def test_trace_tracks_local_bound_to_block():
    src = (
        "class S:\n"
        "    def f(self):\n"
        "        t = {'queries': 1, 'bogus_key': 2}\n"
        "        self.last_trace['topk'] = t\n"
    )
    fs = findings(src, passes=[TraceSchemaPass()])
    assert len(fs) == 1 and "bogus_key" in fs[0].message


def test_trace_tracks_subscript_write_through_binding():
    # the rt = route_trace(); rt[k] = ...; last_trace['replicas'] = rt idiom
    src = (
        "class S:\n"
        "    def f(self):\n"
        "        rt = self.reader.route_trace()\n"
        "        rt['failovers_batch'] = 1\n"
        "        rt['not_a_replica_key'] = 2\n"
        "        self.last_trace['replicas'] = rt\n"
    )
    fs = findings(src, passes=[TraceSchemaPass()])
    assert len(fs) == 1 and "not_a_replica_key" in fs[0].message


def test_trace_conditional_key_checks_both_arms():
    src = (
        "class S:\n"
        "    def f(self, trace, ranked):\n"
        "        trace['threshold_stops' if ranked else 'bogus_stop'] += 1\n"
    )
    fs = findings(src, passes=[TraceSchemaPass()])
    assert len(fs) == 1 and "bogus_stop" in fs[0].message


def test_trace_declared_keys_clean():
    src = (
        "class S:\n"
        "    def f(self, trace):\n"
        "        trace['waves'] += 1\n"
        "        self.last_trace['snapshot'] = [1]\n"
    )
    assert findings(src, passes=[TraceSchemaPass()]) == []


def test_trace_pragma_suppresses():
    src = (
        "class S:\n"
        "    def f(self):\n"
        "        self.last_trace['wavez'] = 3"
        "  # repro-lint: allow(trace-schema) migration shim\n"
    )
    assert findings(src, passes=[TraceSchemaPass()]) == []


def test_runtime_and_static_registries_cannot_drift():
    # the runtime checker imports THE SAME schema object the static pass
    # reads, so a key added in one place only is caught on both sides
    from repro.search import service
    from repro.search.schema import validate_trace

    assert service.validate_trace is validate_trace
    assert validate_trace({"bogus": 1})
    assert validate_trace({"snapshot": [1], "topk": {"queries": 0}}) == ""


# ----------------------------------------------------- generation pass --
BAD_GENERATION_WRITE = """
def hijack(idx):
    idx.generation = 7
"""

BAD_NPARTS_SNAPSHOT = """
def pin(idx):
    snapshot_gen = idx.n_parts
    return snapshot_gen
"""


def test_generation_flags_outside_write():
    fs = findings(BAD_GENERATION_WRITE, passes=[GenerationDisciplinePass()])
    assert len(fs) == 1 and ".generation" in fs[0].message


def test_generation_allows_inverted_index():
    fs = findings(
        "class I:\n    def add_part(self):\n        self.generation += 1\n",
        path="src/repro/core/inverted_index.py",
        passes=[GenerationDisciplinePass()],
    )
    assert fs == []


def test_generation_flags_n_parts_as_snapshot():
    fs = findings(BAD_NPARTS_SNAPSHOT, passes=[GenerationDisciplinePass()])
    assert len(fs) == 1 and "n_parts" in fs[0].message


def test_generation_flags_n_parts_compare_and_restore():
    src = (
        "def check(idx, snap_gen):\n"
        "    if idx.n_parts != snap_gen:\n"
        "        idx.restore_generation(idx.n_parts)\n"
    )
    fs = findings(src, passes=[GenerationDisciplinePass()])
    assert len(fs) == 2


def test_generation_flags_persisted_n_parts():
    src = "def manifest(idx):\n    return {'generation_vector': [idx.n_parts]}\n"
    fs = findings(src, passes=[GenerationDisciplinePass()])
    assert len(fs) == 1 and "persisting" in fs[0].message


def test_generation_plain_part_count_is_fine():
    # n_parts used as a size, not a coordinate: no finding
    src = "def empty(idx):\n    return idx.n_parts == 0\n"
    assert findings(src, passes=[GenerationDisciplinePass()]) == []


def test_generation_pragma_suppresses():
    src = (
        "def hijack(idx):\n"
        "    idx.generation = 7"
        "  # repro-lint: allow(generation-discipline) test fixture\n"
    )
    assert findings(src, passes=[GenerationDisciplinePass()]) == []


# ---------------------------------------------------------- cache pass --
BAD_CACHE_POKE = """
def poke(cache, slot, arr):
    cache._map[slot] = arr
"""


def test_cache_flags_tier_poke_outside():
    fs = findings(BAD_CACHE_POKE, passes=[CacheTierPass()])
    assert fs and all(f.pass_id == "cache-tier" for f in fs)


def test_cache_flags_outside_admission():
    fs = findings(
        "def admit(cache, k, pre, tok):\n"
        "    cache.put_partial('ns', k, pre, tok)\n",
        passes=[CacheTierPass()],
    )
    assert len(fs) == 1 and "put_partial" in fs[0].message


def test_cache_inside_requires_frozen():
    src = (
        "class PostingCache:\n"
        "    def put(self, slot, arr):\n"
        "        self._map[slot] = arr\n"
    )
    fs = findings(
        src, path="src/repro/search/reader.py", passes=[CacheTierPass()]
    )
    assert len(fs) == 1 and "_frozen" in fs[0].message


def test_cache_inside_frozen_name_tracking_clean():
    src = (
        "class PostingCache:\n"
        "    def put(self, slot, arr):\n"
        "        arr = _frozen(arr.view())\n"
        "        self._map[slot] = arr\n"
    )
    fs = findings(
        src, path="src/repro/search/reader.py", passes=[CacheTierPass()]
    )
    assert fs == []


def test_cache_pragma_suppresses():
    src = (
        "def poke(cache, slot, arr):\n"
        "    cache._map[slot] = arr"
        "  # repro-lint: allow(cache-tier)白box test\n"
    )
    assert findings(src, passes=[CacheTierPass()]) == []


# --------------------------------------------------------- kernel pass --
BAD_KERNEL_TIME = """
import time

def kernel(x):
    return x * time.time()
"""


def test_kernel_flags_time_import_in_kernel_module():
    fs = findings(
        BAD_KERNEL_TIME,
        path="src/repro/kernels/foo/kernel.py",
        passes=[KernelPurityPass()],
    )
    assert fs and all(f.pass_id == "kernel-purity" for f in fs)


def test_kernel_flags_unsorted_dict_iteration():
    src = (
        "def decode(groups):\n"
        "    out = []\n"
        "    for k, v in groups.items():\n"
        "        out.append(v)\n"
        "    return out\n"
    )
    fs = findings(
        src, path="src/repro/kernels/foo/ops.py", passes=[KernelPurityPass()]
    )
    assert len(fs) == 1 and "items" in fs[0].message
    sorted_src = src.replace("groups.items()", "sorted(groups.items())")
    assert findings(
        sorted_src, path="src/repro/kernels/foo/ops.py",
        passes=[KernelPurityPass()],
    ) == []


def test_kernel_flags_traced_branch_in_jitted_fn():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    fs = findings(src, passes=[KernelPurityPass()])
    assert len(fs) == 1 and "traced value `x`" in fs[0].message


def test_kernel_static_args_exempt():
    # the flash_attention idiom: branch on a static_argnames parameter
    src = (
        "from functools import partial\n"
        "import jax\n"
        "@partial(jax.jit, static_argnames=('causal', 'bq'))\n"
        "def f(q, causal, bq):\n"
        "    if causal:\n"
        "        bq = min(bq, q.shape[0])\n"
        "    return q\n"
    )
    assert findings(src, passes=[KernelPurityPass()]) == []


def test_kernel_static_argnums_exempt():
    src = (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnums=2)\n"
        "def f(vals, segs, n):\n"
        "    if n > 4:\n"
        "        return vals\n"
        "    return segs\n"
    )
    assert findings(src, passes=[KernelPurityPass()]) == []


def test_kernel_shape_access_exempt():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x.shape[0] > 1:\n"
        "        return x\n"
        "    return x\n"
    )
    assert findings(src, passes=[KernelPurityPass()]) == []


def test_kernel_jit_wrap_expression_detected():
    # the scoring.py idiom: def f(...) ... return jax.jit(f)
    src = (
        "import jax\n"
        "def make(k):\n"
        "    def f(x):\n"
        "        if x > k:\n"
        "            return x\n"
        "        return -x\n"
        "    return jax.jit(f)\n"
    )
    fs = findings(src, passes=[KernelPurityPass()])
    assert len(fs) == 1 and "traced value `x`" in fs[0].message


def test_kernel_pragma_suppresses():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:"
        "  # repro-lint: allow(kernel-purity) concrete under vmap\n"
        "        return x\n"
        "    return -x\n"
    )
    assert findings(src, passes=[KernelPurityPass()]) == []


# -------------------------------------------------- engine & interface --
def test_pragma_is_pass_scoped():
    # an allow() for one pass must not silence another on the same line
    src = (
        "def f(dev, idx, n):\n"
        "    idx.generation = dev.read_small(n)"
        "  # repro-lint: allow(charge-accounting) half excuse\n"
    )
    fs = findings(src)
    assert ids(fs) == {"generation-discipline"}


def test_pragma_star_silences_all():
    src = (
        "def f(dev, idx, n):\n"
        "    idx.generation = dev.read_small(n)"
        "  # repro-lint: allow(*) fixture\n"
    )
    assert findings(src) == []


def test_finding_render_format():
    fs = findings(BAD_CHARGE, path="pkg/mod.py")
    assert fs[0].render().startswith("pkg/mod.py:3 charge-accounting ")


def test_syntax_error_reported_not_raised():
    fs = findings("def broken(:\n")
    assert len(fs) == 1 and fs[0].pass_id == "parse-error"


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_CHARGE)
    env_src = str(REPO / "src")
    for target, expect in ((str(bad), 1), (str(tmp_path / "none"), 0)):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", target],
            capture_output=True, text=True,
            env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"},
        )
        assert proc.returncode == expect, proc.stderr
    assert "charge-accounting" in subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad)],
        capture_output=True, text=True,
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"},
    ).stdout


# ------------------------------------------------------------ meta-test --
def test_full_src_tree_lints_clean():
    fs = lint_paths([str(REPO / "src")])
    assert fs == [], "\n".join(f.render() for f in fs)
