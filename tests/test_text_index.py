"""Text index kinds + proximity engine (paper section 6)."""

import numpy as np
import pytest

from repro.core.lexicon import FREQUENT, OTHER, STOP, make_lexicon
from repro.core.proximity import (
    ProximityEngine,
    jax_window_join,
    numpy_phrase_join,
    numpy_window_join,
)
from repro.core.strategies import StrategyConfig
from repro.core.text_index import INDEX_NAMES, IndexSetConfig, TextIndexSet
from repro.data.corpus import extract_postings, generate_part


@pytest.fixture(scope="module")
def small_world():
    lex = make_lexicon(
        n_words=8000, n_lemmas=3500, n_stop=30, n_frequent=200, seed=11
    )
    t1, o1 = generate_part(lex, n_docs=150, avg_doc_len=250, doc0=0, seed=1)
    t2, o2 = generate_part(lex, n_docs=150, avg_doc_len=250, doc0=150, seed=2)
    cfg = IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=2048),
        build_ordinary_all=True,
        fl_area_clusters=128,
    )
    ts = TextIndexSet(cfg, lex, seed=0)
    ts.add_documents(t1, o1, 0)
    ts.add_documents(t2, o2, 150)
    return lex, ts


def words_of_class(lex, cls, n=8):
    out = []
    for w in range(lex.n_words):
        l = lex.lemma1[w]
        if l >= 0 and lex.lemma_class[l] == cls:
            out.append(int(w))
            if len(out) == n:
                break
    return out


def test_extraction_covers_all_tokens():
    lex = make_lexicon(n_words=2000, n_lemmas=900, n_stop=10, n_frequent=50, seed=3)
    toks, offs = generate_part(lex, n_docs=20, avg_doc_len=60, doc0=0, seed=5)
    maps = extract_postings(lex, toks, offs, 0)
    l1, l2 = lex.lemmatize(toks)
    known = lex.is_known(toks)
    n_readings = toks.shape[0] + int((l2 >= 0).sum())
    total_ord = sum(len(v) for v in maps["ordinary_all"].values())
    assert total_ord == n_readings
    # the known index covers every reading of every known token
    total_known = sum(len(v) for v in maps["known"].values())
    assert total_known == int(known.sum()) + int((l2 >= 0).sum())
    primary = sum((v.shape[0] for v in maps["unknown"].values()), 0)
    assert primary == int((~known).sum())


def test_wv_postings_are_proximity_pairs():
    lex = make_lexicon(n_words=2000, n_lemmas=900, n_stop=10, n_frequent=80, seed=4)
    toks, offs = generate_part(lex, n_docs=10, avg_doc_len=80, doc0=0, seed=6)
    maps = extract_postings(lex, toks, offs, 0, max_distance=2)
    l1, l2 = lex.lemmatize(toks)

    def readings(i):
        r = [int(l1[i])]
        if l2[i] >= 0:
            r.append(int(l2[i]))
        return r

    # verify a handful of keys by brute force (both lemma readings count)
    checked = 0
    for key, posts in list(maps["wv_kk"].items())[:20]:
        w, v = key >> 32, key & ((1 << 32) - 1)
        for doc, pos in posts[:5]:
            start = offs[doc]
            assert w in readings(start + pos)
            near = [
                r
                for d in range(-2, 3)
                if d != 0 and 0 <= pos + d < offs[doc + 1] - offs[doc]
                for r in readings(start + pos + d)
            ]
            assert v in near
            checked += 1
    assert checked > 10


def test_paths_agree_with_ordinary_baseline(small_world):
    lex, ts = small_world
    eng = ProximityEngine(ts, window=3)
    stop = words_of_class(lex, STOP)
    freq = words_of_class(lex, FREQUENT)
    other = words_of_class(lex, OTHER)
    queries = [
        [stop[0], stop[1]],
        [stop[2], stop[3], stop[4]],
        [freq[0], other[0]],
        [freq[1], freq[2]],
        [other[1], other[2]],
        [other[3], stop[0]],
    ]
    for q in queries:
        r1 = eng.search(q)
        r2 = eng.search_ordinary(q)
        assert set(r1.docs.tolist()) == set(r2.docs.tolist()), q


def test_additional_indexes_scan_less(small_world):
    """Paper 6.1: queries with frequent words touch orders of magnitude
    fewer postings through the additional indexes."""
    lex, ts = small_world
    eng = ProximityEngine(ts, window=3)
    stop = words_of_class(lex, STOP)
    freq = words_of_class(lex, FREQUENT)
    other = words_of_class(lex, OTHER)
    wins = []
    for q in ([stop[0], stop[1]], [freq[0], other[0]], [freq[1], freq[2]]):
        r1 = eng.search(q)
        r2 = eng.search_ordinary(q)
        wins.append(r2.postings_scanned / max(1, r1.postings_scanned))
    assert min(wins) > 3 and max(wins) > 20, wins


def test_window_join_implementations_agree():
    rng = np.random.RandomState(0)
    a = np.stack([np.sort(rng.randint(0, 50, 300)), rng.randint(0, 400, 300)], 1)
    b = np.stack([np.sort(rng.randint(0, 50, 200)), rng.randint(0, 400, 200)], 1)
    a = a[np.lexsort((a[:, 1], a[:, 0]))]
    b = b[np.lexsort((b[:, 1], b[:, 0]))]
    for w in (0, 1, 3, 10):
        ref = numpy_window_join(a, b, w)
        jx = jax_window_join(a, b, w)
        assert ref.shape == jx.shape and (ref == jx).all()


def test_phrase_join():
    a = np.asarray([[1, 5], [1, 9], [2, 0]], np.int64)
    b = np.asarray([[1, 6], [2, 2], [3, 1]], np.int64)
    got = numpy_phrase_join(a, b, 1)
    assert (got == np.asarray([[1, 5]], np.int64)).all()


def test_build_io_isolated_from_search_io(small_world):
    lex, ts = small_world
    build_before = {n: s.total_ops for n, s in ts.build_io().items()}
    eng = ProximityEngine(ts, window=3)
    freq = words_of_class(lex, FREQUENT)
    other = words_of_class(lex, OTHER)
    eng.search([freq[0], other[0]])
    build_after = {n: s.total_ops for n, s in ts.build_io().items()}
    assert build_before == build_after, "search charged to the build device"
