"""Reader/Planner/Executor stack: batched results identical to per-query
search, cache hits free, joins exact beyond int32 packing, and all four
planner routes element-wise identical across join backends.

Query streams, the hypothesis query strategy and the element-wise
equivalence assertion live in ``tests/oracles.py`` (shared with the
multi-key and sharded suites)."""

import functools

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.core.lexicon import FREQUENT, OTHER, STOP, make_lexicon
from repro.core.proximity import ProximityEngine
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig, TextIndexSet
from repro.data.corpus import generate_part
from repro.search import (
    ROUTE_MULTI,
    ROUTE_ORDINARY,
    ROUTE_STOPSEQ,
    ROUTE_WV,
    IndexReader,
    PostingCache,
    Query,
    SearchService,
    jax_window_join,
    numpy_window_join,
    pos_scale,
)
from tests.oracles import (
    QUERY_SPEC,
    assert_results_identical,
    class_pools,
    core_queries,
    mixed_queries,
    spec_to_query,
    words_of_class,
)

BACKENDS = ("numpy", "jax", "pallas")


@pytest.fixture(scope="module")
def small_world():
    lex = make_lexicon(
        n_words=8000, n_lemmas=3500, n_stop=30, n_frequent=200, seed=11
    )
    t1, o1 = generate_part(lex, n_docs=150, avg_doc_len=250, doc0=0, seed=1)
    t2, o2 = generate_part(lex, n_docs=150, avg_doc_len=250, doc0=150, seed=2)
    cfg = IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=2048),
        build_ordinary_all=True,
        fl_area_clusters=128,
    )
    ts = TextIndexSet(cfg, lex, seed=0)
    ts.add_documents(t1, o1, 0)
    ts.add_documents(t2, o2, 150)
    return lex, ts


# ------------------------------------------------------------ the planner --
def test_planner_routes_and_grouping(small_world):
    lex, ts = small_world
    svc = SearchService(ts, window=3)
    qs = mixed_queries(lex, n=64)
    plan = svc.plan(qs)
    census = plan.route_census()
    assert census[ROUTE_STOPSEQ] >= 16
    assert census[ROUTE_WV] >= 8
    assert census[ROUTE_ORDINARY] >= 8
    # grouped lookups are unique and keyed by real dictionary groups
    total = sum(len(v) for v in plan.grouped.values())
    flat = {(lk.index, lk.key) for v in plan.grouped.values() for lk in v}
    assert len(flat) == total == plan.n_unique_lookups
    per_query = sum(len(pq.lookups) for pq in plan.queries)
    assert total < per_query, "batch planning must dedupe repeated keys"
    for (index, group), lks in plan.grouped.items():
        for lk in lks:
            assert lk.group == group == ts.indexes[index].dict.group_of(lk.key)


def test_query_validation():
    with pytest.raises(ValueError):
        Query((1,))
    with pytest.raises(ValueError):
        Query((1, 2, 3, 4))


# ----------------------------------------------- batched == per-query loop --
@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_batched_identical_to_per_query(small_world, backend):
    lex, ts = small_world
    eng = ProximityEngine(ts, window=3)
    svc = SearchService(ts, window=3, backend=backend)
    qs = mixed_queries(lex, n=64)
    batch = svc.search_batch(qs)
    assert len(batch) == 64
    routes = set()
    for q, r in zip(qs, batch):
        ref = eng.search(q)
        routes.add(r.route)
        assert_results_identical(ref, r, ctx=(backend, q))
    assert routes == {ROUTE_STOPSEQ, ROUTE_WV, ROUTE_ORDINARY}


def test_batched_agrees_with_ordinary_baseline(small_world):
    lex, ts = small_world
    eng = ProximityEngine(ts, window=3)
    svc = SearchService(ts, window=3, backend="jax")
    qs = mixed_queries(lex, n=16)
    for q, r in zip(qs, svc.search_batch(qs)):
        rb = eng.search_ordinary(q)
        assert set(r.docs.tolist()) == set(rb.docs.tolist()), q


# ------------------------------------------------------- reader I/O + LRU --
def test_cache_hits_charge_zero_io(small_world):
    lex, ts = small_world
    svc = SearchService(ts, window=3)
    qs = mixed_queries(lex, n=32)
    svc.search_batch(qs)
    warm = {n: s.total_ops for n, s in ts.search_io().items()}
    stats0 = svc.reader.cache_stats
    h0, b0 = stats0.hits, stats0.bytes_used
    svc.search_batch(qs)  # every lookup now a cache hit
    after = {n: s.total_ops for n, s in ts.search_io().items()}
    assert warm == after, "cache hits must charge zero search-device I/O"
    assert svc.reader.cache_stats.hits > h0
    assert svc.reader.cache_stats.bytes_used == b0


def test_reader_refreshes_after_writer_update(small_world):
    lex, _ = small_world
    cfg = IndexSetConfig(strategy=StrategyConfig.set1(cluster_size=2048))
    ts = TextIndexSet(cfg, lex, seed=0)
    t1, o1 = generate_part(lex, n_docs=60, avg_doc_len=200, doc0=0, seed=21)
    t2, o2 = generate_part(lex, n_docs=60, avg_doc_len=200, doc0=60, seed=22)
    ts.add_documents(t1, o1, 0)
    reader = ts.reader()
    key = next(iter(ts.indexes["known"].dict.entries))
    before = reader.lookup("known", key).copy()
    ts.add_documents(t2, o2, 60)  # writer advances: cached postings stale
    after = reader.lookup("known", key)
    fresh = ts.indexes["known"].lookup(key)
    assert np.array_equal(after, fresh)
    assert after.shape[0] >= before.shape[0]


def test_per_query_window_clamped_to_max_distance(small_world):
    """A Query window beyond cfg.max_distance must clamp: the stopseq/wv
    indexes are precomputed at max_distance, so a wider ordinary join
    would give route-dependent proximity semantics."""
    lex, ts = small_world
    svc = SearchService(ts, window=3)
    other = words_of_class(lex, OTHER)
    q = [other[1], other[2]]
    wide = svc.search_batch([Query(tuple(q), window=50)])[0]
    default = svc.search_batch([q])[0]
    assert np.array_equal(wide.docs, default.docs)
    assert np.array_equal(wide.witnesses, default.witnesses)


def test_refresh_noop_preserves_cache(small_world):
    """Regression: refresh() used to drop every cached posting even when
    the writer's generation was unchanged, turning periodic refresh
    sweeps into full cache cold-starts.  A no-op refresh must keep cache
    hits alive and charge zero new device I/O."""
    lex, ts = small_world
    reader = ts.reader()
    key = next(iter(ts.indexes["known"].dict.entries))
    first = reader.lookup("known", key)
    io0 = {n: s.total_ops for n, s in reader.io_stats().items()}
    reader.refresh()  # no writer advance: must be a no-op
    assert len(reader.cache) > 0
    assert reader.cache.stats.invalidations == 0
    h0 = reader.cache.stats.hits
    again = reader.lookup("known", key)
    assert np.array_equal(again, first)
    assert not again.flags.writeable  # served from the immutable cache slot
    assert reader.cache.stats.hits == h0 + 1
    assert {n: s.total_ops for n, s in reader.io_stats().items()} == io0


def test_drop_index_counts_invalidations_and_reclaims_floor():
    """Regression: drop_index used to shrink the cache silently — no
    stats trace — which skewed eviction-rate dashboards.  Invalidations
    are counted separately from capacity evictions, and every dropped
    entry reclaims the same MIN_CHARGE-floored charge it was admitted
    at (bytes_used returns exactly to zero, even for floor-charged
    negative-cache entries)."""
    cache = PostingCache(budget_bytes=1 << 16)
    empty = np.zeros((0, 2), np.int64)      # floor-charged entries
    small = np.zeros((4, 2), np.int64)      # real-charge entries
    for k in range(3):
        cache.put("a", k, empty)
        cache.put("b", k, small)
    assert cache.stats.bytes_used == 3 * cache.MIN_CHARGE + 3 * small.nbytes
    cache.drop_index("a")
    assert cache.stats.invalidations == 3
    assert cache.stats.evictions == 0, "drops are not capacity evictions"
    assert cache.stats.bytes_used == 3 * small.nbytes
    assert len(cache) == 3
    cache.drop_index("b")
    assert cache.stats.invalidations == 6
    assert cache.stats.bytes_used == 0
    assert len(cache) == 0


def test_negative_cache_entries_stay_bounded():
    cache = PostingCache(budget_bytes=PostingCache.MIN_CHARGE * 8)
    empty = np.zeros((0, 2), np.int64)
    for k in range(100):  # a stream of distinct absent keys
        cache.put("i", k, empty)
    assert len(cache) <= 8, "zero-byte entries must respect the budget"
    assert cache.stats.evictions > 0


def test_cache_budget_evicts():
    cache = PostingCache(budget_bytes=1024)
    a = np.zeros((32, 2), np.int64)  # 512 B each
    cache.put("i", 1, a)
    cache.put("i", 2, a)
    cache.put("i", 3, a)  # evicts key 1 (LRU)
    assert cache.get("i", 1) is None
    assert cache.get("i", 3) is not None
    assert cache.stats.bytes_used <= 1024
    assert cache.stats.evictions == 1
    # oversized values are passed through, never cached
    cache.put("i", 4, np.zeros((200, 2), np.int64))
    assert cache.get("i", 4) is None


def test_cache_keys_namespaced_by_index():
    """Regression: a numerically equal packed key in two different
    indexes (e.g. an extended (w, v) key and a 2-word multi key) must
    occupy distinct cache slots and never answer for each other."""
    cache = PostingCache(budget_bytes=1 << 16)
    key = (7 << 32) | 42  # same integer under both index names
    wv = np.asarray([[1, 2]], np.int64)
    multi = np.asarray([[3, 4], [5, 6]], np.int64)
    cache.put("wv_kk", key, wv)
    cache.put("multi", key, multi)
    assert np.array_equal(cache.get("wv_kk", key), wv)
    assert np.array_equal(cache.get("multi", key), multi)
    cache.drop_index("wv_kk")
    assert cache.get("wv_kk", key) is None
    assert np.array_equal(cache.get("multi", key), multi)


def test_cached_postings_are_readonly(small_world):
    lex, ts = small_world
    svc = SearchService(ts, window=3)
    stop = words_of_class(lex, STOP)
    # miss and hit share one buffer: both must be immutable, or the first
    # caller could silently corrupt every later cache hit
    r_miss = svc.search([stop[0], stop[1]])
    r_hit = svc.search([stop[0], stop[1]])
    for r in (r_miss, r_hit):
        with pytest.raises(ValueError):
            r.witnesses[:] = 0


# ----------------------------------------- join packing regression (int64) --
def test_jax_join_beyond_int24_doc_packing():
    """Doc ids past the old 24-bit packing range: the int32 truncation bug
    made the jax join silently wrong there (scale picked off the
    post-truncation dtype).  The packed-key scale is now data-driven."""
    rng = np.random.RandomState(1)
    # 3000 docs x positions < 400: packed keys need doc*512, far beyond
    # what doc * 2^24 could hold in int32 (overflow at doc 128)
    docs = np.sort(rng.randint(0, 3000, 500))
    a = np.stack([docs, rng.randint(0, 400, 500)], 1)
    docs_b = np.sort(rng.randint(0, 3000, 400))
    b = np.stack([docs_b, rng.randint(0, 400, 400)], 1)
    a = a[np.lexsort((a[:, 1], a[:, 0]))]
    b = b[np.lexsort((b[:, 1], b[:, 0]))]
    for w in (0, 1, 3, 7):
        ref = numpy_window_join(a, b, w)
        jx = jax_window_join(a, b, w)
        assert ref.shape == jx.shape and (ref == jx).all(), w


def test_jax_join_padding_near_dtype_limit():
    """Packed keys just under the int32 admission line must not window-match
    the padding rows (b pads above every real key + window)."""
    M = np.iinfo(np.int32).max
    w = 3
    scale = 16  # pos < 16 - w - 1 keeps pos_scale at 16
    doc = (M - 5) // scale  # akey lands at M - 5 + pos adjustments
    a = np.asarray([[doc, 10], [doc, 11]], np.int64)
    # 3 rows pad to 4: the padded slot sits right past the real keys
    b = np.asarray([[1, 0], [2, 0], [3, 0]], np.int64)
    for arr in (a, b):
        assert arr[:, 0].max() * scale + arr[:, 1].max() + w < M
    ref = numpy_window_join(a, b, w)
    jx = jax_window_join(a, b, w)
    assert ref.shape == jx.shape == (0, 2)


def test_jax_join_falls_back_when_keys_exceed_int32():
    # doc ids so large the packed keys cannot fit int32: exact host fallback
    a = np.asarray([[2 ** 40, 5], [2 ** 40 + 1, 9]], np.int64)
    b = np.asarray([[2 ** 40, 7], [2 ** 41, 1]], np.int64)
    ref = numpy_window_join(a, b, 3)
    jx = jax_window_join(a, b, 3)
    assert np.array_equal(ref, jx)
    assert jx.shape == (1, 2) and jx[0, 0] == 2 ** 40


def test_pos_scale_headroom():
    for max_pos, w in [(0, 0), (5, 3), (511, 0), (511, 3), (1000, 7)]:
        s = pos_scale(max_pos, w)
        assert s > max_pos + w, (max_pos, w, s)
        assert s & (s - 1) == 0


# ------------------------------------------------- route census regression --
def test_route_census_regression(small_world):
    """Pin the planner's route per query shape so future planner edits
    cannot silently reroute traffic.  Columns: query, route, #lookups."""
    lex, ts = small_world
    svc = SearchService(ts, window=3)
    stop = words_of_class(lex, STOP)
    freq = words_of_class(lex, FREQUENT)
    other = words_of_class(lex, OTHER)
    P = True  # phrase
    table = [
        (Query((stop[0], stop[1])), ROUTE_STOPSEQ, 1),
        (Query((stop[0], stop[1], stop[2])), ROUTE_STOPSEQ, 1),
        (Query((stop[0], stop[1]), phrase=P), ROUTE_STOPSEQ, 1),
        (Query((freq[0], other[0])), ROUTE_WV, 1),
        (Query((other[0], freq[0])), ROUTE_WV, 1),
        (Query((other[0], other[1])), ROUTE_ORDINARY, 2),
        (Query((other[0], other[1], other[2])), ROUTE_ORDINARY, 3),
        (Query((stop[0], other[0])), ROUTE_ORDINARY, 2),
        (Query((freq[0], freq[1], other[0])), ROUTE_ORDINARY, 3),
        # k-word-covered phrase queries: one key per k-window of the cover
        (Query((other[0], other[1], other[2]), phrase=P), ROUTE_MULTI, 1),
        (Query((other[0], freq[0], stop[0]), phrase=P), ROUTE_MULTI, 1),
        (Query((other[0], other[1], other[2], other[3]), phrase=P), ROUTE_MULTI, 2),
        (Query((stop[0], stop[1], stop[2], stop[0]), phrase=P), ROUTE_MULTI, 2),
        # 2-word phrases: too short for a k=3 key, and (w, v) records
        # cannot reconstruct a phrase — ordinary phrase joins
        (Query((freq[0], other[0]), phrase=P), ROUTE_ORDINARY, 2),
        (Query((other[0], other[1]), phrase=P), ROUTE_ORDINARY, 2),
    ]
    plan = svc.plan([q for q, _, _ in table])
    for pq, (q, route, n_lookups) in zip(plan.queries, table):
        assert pq.route == route, (q, pq.route)
        assert len(pq.lookups) == n_lookups, (q, pq.lookups)
    census = plan.route_census()
    assert census == {
        ROUTE_STOPSEQ: 3, ROUTE_MULTI: 4, ROUTE_WV: 2, ROUTE_ORDINARY: 6,
    }
    # opting out of the multi index reroutes phrases down ordinary
    svc_no_multi = SearchService(ts, window=3, use_multi=False)
    plan2 = svc_no_multi.plan([Query((other[0], other[1], other[2]), phrase=P)])
    assert plan2.queries[0].route == ROUTE_ORDINARY
    assert len(plan2.queries[0].lookups) == 3


def test_wv_route_honors_narrow_window(small_world):
    """A per-query window NARROWER than max_distance cannot be applied to
    the precomputed (w, v) records (they carry only w's position), so
    those queries must take the ordinary route — and return exactly the
    narrow-window oracle, not max_distance false positives."""
    lex, ts = small_world
    svc = SearchService(ts, window=3)
    freq = words_of_class(lex, FREQUENT)
    other = words_of_class(lex, OTHER)
    md = ts.cfg.max_distance
    for q in ([freq[0], other[0]], [freq[1], freq[2]]):
        narrow = svc.plan([Query(tuple(q), window=1)]).queries[0]
        assert narrow.route == ROUTE_ORDINARY, q
        wide = svc.plan([Query(tuple(q), window=md)]).queries[0]
        assert wide.route == ROUTE_WV, q
        # execution agrees with the narrow-window join over raw postings
        r = svc.search_batch([Query(tuple(q), window=1)])[0]
        lemmas, _ = lex.classify_words(np.asarray(q, np.int64))
        posts = [ts.indexes["known"].lookup(int(l)) for l in lemmas]
        ref = numpy_window_join(posts[0], posts[1], 1)
        assert np.array_equal(r.docs, np.unique(ref[:, 0])), q


# --------------------------------- cross-backend equivalence (all 4 routes) --
@functools.lru_cache(maxsize=None)
def _equiv_world(seed: int):
    """A small random collection + per-class word pools + services for
    every join backend (cached: worlds are immutable across examples)."""
    lex = make_lexicon(
        n_words=3000, n_lemmas=1300, n_stop=20, n_frequent=120, seed=40 + seed
    )
    toks, offs = generate_part(lex, n_docs=60, avg_doc_len=120, doc0=0,
                               seed=60 + seed)
    cfg = IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=1024),
        fl_area_clusters=64,
    )
    ts = TextIndexSet(cfg, lex, seed=0)
    ts.add_documents(toks, offs, 0)
    pools = class_pools(lex)
    services = {b: SearchService(ts, window=3, backend=b) for b in BACKENDS}
    return lex, toks, pools, services


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from((0, 1)),
    st.lists(QUERY_SPEC, min_size=0, max_size=10),
)
def test_cross_backend_equivalence_all_routes(world_seed, specs):
    """Property: numpy, jax and pallas return element-wise identical
    docs/witnesses/lookups for every planner route.  Each batch carries a
    fixed core hitting all four routes plus the drawn random queries."""
    lex, toks, pools, services = _equiv_world(world_seed)
    queries = core_queries(toks, pools) + [
        spec_to_query(s, toks, pools) for s in specs
    ]
    results = {b: services[b].search_batch(queries) for b in BACKENDS}
    routes = set()
    for qi, q in enumerate(queries):
        ref = results["numpy"][qi]
        routes.add(ref.route)
        for b in ("jax", "pallas"):
            assert_results_identical(ref, results[b][qi], ctx=(b, q))
    assert routes >= {ROUTE_STOPSEQ, ROUTE_WV, ROUTE_ORDINARY, ROUTE_MULTI}


def test_index_reader_own_device(small_world):
    """A standalone IndexReader charges its own device, not the writer's."""
    lex, ts = small_world
    idx = ts.indexes["known"]
    build_before = idx.mgr.device.stats.total_ops
    reader = IndexReader(idx)
    key = next(iter(idx.dict.entries))
    posts = reader.lookup(key)
    assert posts.shape[0] > 0
    assert idx.mgr.device.stats.total_ops == build_before
    assert reader.io_stats().total_ops > 0
