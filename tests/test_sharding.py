"""Sharding policy engine: rule matching, divisibility degradation,
sanitization — the machinery every dry-run cell depends on."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    LM_RULES,
    RECSYS_RULES,
    batch_spec,
    resolve_spec,
    sanitize_shardings,
    shard_by_rules,
)


@pytest.fixture(scope="module")
def mesh():
    # single host device reshaped into a logical (1,1) mesh is enough to
    # exercise the rule engine; axis sizes matter only via divisibility,
    # covered by resolve_spec tests with fake meshes below
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Duck-typed mesh for divisibility tests without real devices."""

    def __init__(self, sizes):
        self._sizes = dict(sizes)

    @property
    def axis_names(self):
        return tuple(self._sizes)

    @property
    def shape(self):
        return dict(self._sizes)


def test_resolve_spec_exact_divisibility():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    # divisible: kept
    assert resolve_spec(m, ("model", None), (32, 7)) == P("model", None)
    # not divisible by the tuple, but by a prefix
    spec = resolve_spec(m, (("pod", "data", "model"), None), (64, 5))
    assert spec == P(("pod", "data"), None)  # 64 % 512 != 0, 64 % 32 == 0
    # prime dimension: replicated
    assert resolve_spec(m, ("model",), (122753,)) == P(None)
    # missing axis name: dropped
    assert resolve_spec(m, ("nonexistent",), (16,)) == P(None)


def test_resolve_spec_single_axis_fallback():
    m = FakeMesh({"data": 16, "model": 16})
    # 48 % 256 != 0 and 48 % 16 == 0 -> falls back to one axis
    spec = resolve_spec(m, (("data", "model"),), (48,))
    assert spec == P("data")


def test_lm_rules_cover_transformer_params(mesh):
    from repro.configs.registry import get_bundle

    b = get_bundle("granite-3-2b", reduced=True)
    shapes = jax.eval_shape(b.init, jax.random.PRNGKey(0))
    shard = shard_by_rules(shapes, mesh, LM_RULES)
    flat, _ = jax.tree_util.tree_flatten(shard)
    assert all(isinstance(s, NamedSharding) for s in flat)


def test_sanitize_pads_short_specs(mesh):
    # a spec with fewer entries than the rank must be right-padded, and
    # sanitize must return a legal NamedSharding for any input
    sds = jax.ShapeDtypeStruct((4, 8, 3), jnp.float32)
    short = NamedSharding(mesh, P("data"))
    fixed = sanitize_shardings(short, sds, mesh)
    assert len(tuple(fixed.spec)) == 3
    # degradation logic itself is covered via FakeMesh in
    # test_resolve_spec_exact_divisibility (needs axis sizes > 1)


def test_sanitize_preserves_legal_shardings(mesh):
    sds = jax.ShapeDtypeStruct((16, 8), jnp.float32)
    good = NamedSharding(mesh, P("data", None))
    fixed = sanitize_shardings(good, sds, mesh)
    assert fixed.spec == P("data", None)  # 16 % 1 == 0 on the host mesh


def test_batch_spec_uses_available_axes(mesh):
    assert batch_spec(mesh)[0] == "data"
