"""Pallas kernels vs their pure-jnp oracles: shape/dtype sweeps in
interpret mode (the kernel bodies execute in Python on CPU)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.ops import embedding_bag_fixed
from repro.kernels.embedding_bag.ref import embedding_bag_fixed_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.intersect.ops import intersect_sorted
from repro.kernels.intersect.ref import intersect_sorted_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.core.postings import PostingDecoder, encode_postings, encode_varint
from repro.kernels.posting_decode.ops import (
    DECODE_BACKENDS,
    DeviceDecoder,
    decode_member_prefilter,
    from_device_rows,
    to_device_rows,
    unpack_varints,
)
from repro.kernels.posting_decode.ref import (
    as_byte_array,
    complete_prefix,
    decode_block_ref,
    unpack_varints_np,
)

RNG = np.random.RandomState(7)


@pytest.mark.parametrize(
    "B,H,S,D,bq,bk",
    [
        (1, 1, 64, 32, 32, 32),
        (2, 3, 128, 64, 64, 32),
        (1, 2, 256, 128, 128, 128),
        (2, 1, 128, 16, 128, 64),  # D not lane-sized: interpret-mode check
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, S, D, bq, bk, dtype):
    q = jnp.asarray(RNG.randn(B, H, S, D), dtype)
    k = jnp.asarray(RNG.randn(B, H, S, D), dtype)
    v = jnp.asarray(RNG.randn(B, H, S, D), dtype)
    got = flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    want = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert got.dtype == dtype
    assert float(jnp.abs(got.astype(jnp.float32)
                         - want.astype(jnp.float32)).max()) < tol


def test_flash_attention_non_causal():
    q = jnp.asarray(RNG.randn(1, 2, 128, 32), jnp.float32)
    k = jnp.asarray(RNG.randn(1, 2, 128, 32), jnp.float32)
    v = jnp.asarray(RNG.randn(1, 2, 128, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=False, bq=64, bk=64)
    want = flash_attention_ref(q, k, v, causal=False)
    assert float(jnp.abs(got - want).max()) < 2e-5


@pytest.mark.parametrize(
    "B,H,D,page,n_pages,max_pages",
    [(2, 4, 32, 16, 12, 4), (3, 8, 64, 8, 30, 7), (1, 2, 128, 32, 6, 3)],
)
def test_paged_attention(B, H, D, page, n_pages, max_pages):
    q = jnp.asarray(RNG.randn(B, H, D), jnp.float32)
    kp = jnp.asarray(RNG.randn(n_pages, page, D), jnp.float32)
    vp = jnp.asarray(RNG.randn(n_pages, page, D), jnp.float32)
    bt = jnp.asarray(
        RNG.choice(n_pages, size=(B, max_pages)), jnp.int32
    )
    lens = jnp.asarray(
        RNG.randint(1, max_pages * page + 1, size=B), jnp.int32
    )
    got = paged_attention(q, kp, vp, bt, lens)
    want = paged_attention_ref(q, kp, vp, bt, lens)
    assert float(jnp.abs(got - want).max()) < 2e-5


def test_paged_attention_chain_limit_semantics():
    """max_pages bounds the indirections per read — the CH chain-limit
    invariant carried onto the device (paper 5.7.3)."""
    B, H, D, page = 2, 2, 32, 16
    for max_pages in (2, 5, 9):
        n_pages = max_pages * B
        q = jnp.asarray(RNG.randn(B, H, D), jnp.float32)
        kp = jnp.asarray(RNG.randn(n_pages, page, D), jnp.float32)
        vp = jnp.asarray(RNG.randn(n_pages, page, D), jnp.float32)
        bt = jnp.asarray(
            np.arange(B * max_pages).reshape(B, max_pages), jnp.int32
        )
        lens = jnp.full((B,), max_pages * page, jnp.int32)
        got = paged_attention(q, kp, vp, bt, lens)
        want = paged_attention_ref(q, kp, vp, bt, lens)
        assert float(jnp.abs(got - want).max()) < 2e-5


@pytest.mark.parametrize("V,D,B,K", [(64, 32, 4, 3), (256, 128, 16, 8),
                                     (1000, 64, 7, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag(V, D, B, K, dtype):
    tb = jnp.asarray(RNG.randn(V, D), dtype)
    ids = jnp.asarray(RNG.randint(0, V, (B, K)), jnp.int32)
    w = jnp.asarray(RNG.rand(B, K), jnp.float32)
    got = embedding_bag_fixed(tb, ids, w)
    want = embedding_bag_fixed_ref(tb, ids, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert float(jnp.abs(got.astype(jnp.float32)
                         - want.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("na,nb,bn,bm", [
    (100, 200, 32, 64), (1000, 50, 256, 32), (8, 8, 8, 8),
    (2000, 3000, 1024, 1024),
])
def test_intersect(na, nb, bn, bm):
    a = np.unique(RNG.randint(0, 10_000, na)).astype(np.int32)
    b = np.unique(RNG.randint(0, 10_000, nb)).astype(np.int32)
    got = np.asarray(intersect_sorted(a, b, bn=bn, bm=bm))
    want = np.asarray(intersect_sorted_ref(jnp.asarray(a), jnp.asarray(b)))
    assert (got == want).all()


def test_intersect_disjoint_and_identical():
    a = np.arange(0, 100, dtype=np.int32)
    b = np.arange(1000, 1100, dtype=np.int32)
    assert not np.asarray(intersect_sorted(a, b)).any()
    assert np.asarray(intersect_sorted(a, a)).all()


# ----------------------------------------------- posting decode parity --
def _posting_stream(n, seed, max_doc=50, max_pos=200_000):
    rng = np.random.RandomState(seed)
    arr = np.stack(
        [np.sort(rng.randint(0, max_doc, n)), rng.randint(0, max_pos, n)], 1
    ).astype(np.int64)
    arr = arr[np.lexsort((arr[:, 1], arr[:, 0]))]
    return arr, encode_postings(arr)


def _varint_buf(values):
    buf = bytearray()
    for v in values:
        encode_varint(int(v), buf)
    return bytes(buf)


@pytest.mark.parametrize("backend", DECODE_BACKENDS)
def test_unpack_varints_backend_parity(backend):
    """unpack_varints agrees bit-for-bit with the host oracle on every
    backend, across widths 1..5 bytes — the 5-byte sweep exceeds the
    int32 device gate, so jax/pallas must take the exact fallback."""
    rng = np.random.RandomState(21)
    for width in (1, 2, 3, 4, 5):
        vals = rng.randint(
            0, 1 << (7 * width), size=rng.randint(1, 400)
        ).astype(np.int64)
        buf = _varint_buf(vals)
        got = unpack_varints(buf, backend=backend)
        want = unpack_varints_np(as_byte_array(buf))
        assert got.dtype == np.int64
        assert (got == want).all() and (want == vals).all()


@pytest.mark.parametrize("backend", DECODE_BACKENDS)
def test_unpack_varints_wide_values_exact(backend):
    """Values past 28 payload bits (up to near 2^63) stay exact — the
    device paths detect the wide varint and defer to host int64."""
    wide = [3, 1 << 40, 127, (1 << 62) - 5, 0, 1 << 28]
    got = unpack_varints(_varint_buf(wide), backend=backend)
    assert got.tolist() == wide


def test_unpack_varints_unknown_backend_rejected():
    with pytest.raises(ValueError):
        unpack_varints(b"\x01", backend="cuda")
    with pytest.raises(ValueError):
        DeviceDecoder(backend="cuda")


@pytest.mark.parametrize("backend", DECODE_BACKENDS)
def test_device_decoder_matches_host_under_random_chunkings(backend):
    """DeviceDecoder == PostingDecoder bit-for-bit on the same stream fed
    through random chunk boundaries (including cuts inside varints), and
    their carry states stay interchangeable throughout."""
    arr, enc = _posting_stream(300, seed=31)
    rng = np.random.RandomState(hash(backend) % (1 << 31))
    raw = np.frombuffer(enc, np.uint8)
    for _ in range(4):
        cuts = np.sort(
            rng.choice(len(enc), size=rng.randint(0, 12), replace=False)
        )
        host, dev = PostingDecoder(), DeviceDecoder(backend=backend)
        hrows, drows = [], []
        for c in np.split(raw, cuts):
            hrows.append(host.feed(c.tobytes())[0])
            drows.append(dev.feed(c.tobytes())[0])
            assert host.state() == dev.state()
        h = np.concatenate(hrows)
        assert (h == np.concatenate(drows)).all()
        assert (h == arr).all()


def test_decoder_suspend_under_one_resume_under_other():
    """The carry tuple is decoder-portable: suspend a stream under the
    host decoder and resume under the device one (and vice versa) —
    the contract that lets cached partials be replayed by either."""
    arr, enc = _posting_stream(200, seed=37)
    cut = len(enc) // 2
    host = PostingDecoder()
    head = host.feed(enc[:cut])[0]
    dev = DeviceDecoder(backend="jax")
    dev.set_state(host.state())
    tail = dev.feed(enc[cut:])[0]
    assert (np.concatenate([head, tail]) == arr).all()
    dev2 = DeviceDecoder(backend="jax")
    head2 = dev2.feed(enc[:cut])[0]
    host2 = PostingDecoder()
    host2.set_state(dev2.state())
    tail2 = host2.feed(enc[cut:])[0]
    assert (np.concatenate([head2, tail2]) == arr).all()


def test_decode_block_ref_matches_scalar_decoder():
    """The byte-parallel oracle (terminator scan → segmented sum → delta
    expansion) reproduces the scalar walk exactly, carry included."""
    arr, enc = _posting_stream(150, seed=41)
    cut = complete_prefix(as_byte_array(enc))
    assert cut == len(enc)  # encode ends on a record boundary
    mid = complete_prefix(as_byte_array(enc[: len(enc) // 2]))
    rows, carry = decode_block_ref(as_byte_array(enc[:mid]))
    host = PostingDecoder()
    want, _ = host.feed(enc[:mid])
    assert (rows == want).all()
    assert carry == host.state()[1:]
    rows2, carry2 = decode_block_ref(as_byte_array(enc[mid:]), *carry)
    assert (np.concatenate([rows, rows2]) == arr).all()
    assert carry2[2] is True


def test_pallas_routing_big_block_parity():
    """A feed past the pallas size gate actually launches the dense-tile
    kernel (interpret mode here); the rows must still equal the scalar
    decoder's bit-for-bit."""
    from repro.kernels.posting_decode.ops import _PALLAS_MIN_BYTES

    arr, enc = _posting_stream(
        4200, seed=43, max_doc=2000, max_pos=(1 << 27) - 1
    )
    assert len(enc) >= _PALLAS_MIN_BYTES
    dev = DeviceDecoder(backend="pallas")
    rows, _ = dev.feed(enc)
    want, _ = PostingDecoder().feed(enc)
    assert (rows == want).all()
    assert (rows == arr).all()


@pytest.mark.parametrize("backend", DECODE_BACKENDS)
def test_decode_member_prefilter_matches_separate_passes(backend):
    """The fused decode→intersect entry point returns exactly (host
    decode, membership test) on every backend, across chunked feeds."""
    arr, enc = _posting_stream(250, seed=53)
    docs = np.unique(arr[:, 0])
    other = np.concatenate([docs[::2], docs.max() + 7 + docs[:5]])
    state = (b"", 0, 0, False)
    posts_parts, mask_parts = [], []
    cut = len(enc) // 3
    for blob in (enc[:cut], enc[cut:]):
        posts, mask, state = decode_member_prefilter(
            blob, other, backend=backend, state=state
        )
        posts_parts.append(posts)
        mask_parts.append(mask)
    posts = np.concatenate(posts_parts)
    mask = np.concatenate(mask_parts)
    want, _ = PostingDecoder().feed(enc)
    assert (posts == want).all() and (posts == arr).all()
    assert (mask == np.isin(posts[:, 0], other)).all()
    assert state[0] == b""  # stream fully drained


def test_device_rows_roundtrip_and_width_gate():
    arr, _ = _posting_stream(100, seed=59)
    buf = to_device_rows(arr)
    back = from_device_rows(buf)
    assert back.dtype == np.int64
    assert (back == arr).all()
    assert not back.flags.writeable
    # values at/over int32 never reach the device tier (silent
    # truncation would corrupt — the gate returns None instead)
    big = np.array([[0, np.iinfo(np.int32).max]], dtype=np.int64)
    assert to_device_rows(big) is None
    empty = np.zeros((0, 2), dtype=np.int64)
    assert (from_device_rows(to_device_rows(empty)) == empty).all()
