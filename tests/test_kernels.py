"""Pallas kernels vs their pure-jnp oracles: shape/dtype sweeps in
interpret mode (the kernel bodies execute in Python on CPU)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.ops import embedding_bag_fixed
from repro.kernels.embedding_bag.ref import embedding_bag_fixed_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.intersect.ops import intersect_sorted
from repro.kernels.intersect.ref import intersect_sorted_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

RNG = np.random.RandomState(7)


@pytest.mark.parametrize(
    "B,H,S,D,bq,bk",
    [
        (1, 1, 64, 32, 32, 32),
        (2, 3, 128, 64, 64, 32),
        (1, 2, 256, 128, 128, 128),
        (2, 1, 128, 16, 128, 64),  # D not lane-sized: interpret-mode check
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, S, D, bq, bk, dtype):
    q = jnp.asarray(RNG.randn(B, H, S, D), dtype)
    k = jnp.asarray(RNG.randn(B, H, S, D), dtype)
    v = jnp.asarray(RNG.randn(B, H, S, D), dtype)
    got = flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    want = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert got.dtype == dtype
    assert float(jnp.abs(got.astype(jnp.float32)
                         - want.astype(jnp.float32)).max()) < tol


def test_flash_attention_non_causal():
    q = jnp.asarray(RNG.randn(1, 2, 128, 32), jnp.float32)
    k = jnp.asarray(RNG.randn(1, 2, 128, 32), jnp.float32)
    v = jnp.asarray(RNG.randn(1, 2, 128, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=False, bq=64, bk=64)
    want = flash_attention_ref(q, k, v, causal=False)
    assert float(jnp.abs(got - want).max()) < 2e-5


@pytest.mark.parametrize(
    "B,H,D,page,n_pages,max_pages",
    [(2, 4, 32, 16, 12, 4), (3, 8, 64, 8, 30, 7), (1, 2, 128, 32, 6, 3)],
)
def test_paged_attention(B, H, D, page, n_pages, max_pages):
    q = jnp.asarray(RNG.randn(B, H, D), jnp.float32)
    kp = jnp.asarray(RNG.randn(n_pages, page, D), jnp.float32)
    vp = jnp.asarray(RNG.randn(n_pages, page, D), jnp.float32)
    bt = jnp.asarray(
        RNG.choice(n_pages, size=(B, max_pages)), jnp.int32
    )
    lens = jnp.asarray(
        RNG.randint(1, max_pages * page + 1, size=B), jnp.int32
    )
    got = paged_attention(q, kp, vp, bt, lens)
    want = paged_attention_ref(q, kp, vp, bt, lens)
    assert float(jnp.abs(got - want).max()) < 2e-5


def test_paged_attention_chain_limit_semantics():
    """max_pages bounds the indirections per read — the CH chain-limit
    invariant carried onto the device (paper 5.7.3)."""
    B, H, D, page = 2, 2, 32, 16
    for max_pages in (2, 5, 9):
        n_pages = max_pages * B
        q = jnp.asarray(RNG.randn(B, H, D), jnp.float32)
        kp = jnp.asarray(RNG.randn(n_pages, page, D), jnp.float32)
        vp = jnp.asarray(RNG.randn(n_pages, page, D), jnp.float32)
        bt = jnp.asarray(
            np.arange(B * max_pages).reshape(B, max_pages), jnp.int32
        )
        lens = jnp.full((B,), max_pages * page, jnp.int32)
        got = paged_attention(q, kp, vp, bt, lens)
        want = paged_attention_ref(q, kp, vp, bt, lens)
        assert float(jnp.abs(got - want).max()) < 2e-5


@pytest.mark.parametrize("V,D,B,K", [(64, 32, 4, 3), (256, 128, 16, 8),
                                     (1000, 64, 7, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag(V, D, B, K, dtype):
    tb = jnp.asarray(RNG.randn(V, D), dtype)
    ids = jnp.asarray(RNG.randint(0, V, (B, K)), jnp.int32)
    w = jnp.asarray(RNG.rand(B, K), jnp.float32)
    got = embedding_bag_fixed(tb, ids, w)
    want = embedding_bag_fixed_ref(tb, ids, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert float(jnp.abs(got.astype(jnp.float32)
                         - want.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("na,nb,bn,bm", [
    (100, 200, 32, 64), (1000, 50, 256, 32), (8, 8, 8, 8),
    (2000, 3000, 1024, 1024),
])
def test_intersect(na, nb, bn, bm):
    a = np.unique(RNG.randint(0, 10_000, na)).astype(np.int32)
    b = np.unique(RNG.randint(0, 10_000, nb)).astype(np.int32)
    got = np.asarray(intersect_sorted(a, b, bn=bn, bm=bm))
    want = np.asarray(intersect_sorted_ref(jnp.asarray(a), jnp.asarray(b)))
    assert (got == want).all()


def test_intersect_disjoint_and_identical():
    a = np.arange(0, 100, dtype=np.int32)
    b = np.arange(1000, 1100, dtype=np.int32)
    assert not np.asarray(intersect_sorted(a, b)).any()
    assert np.asarray(intersect_sorted(a, a)).all()
