"""Background compaction: folding update parts into EM-tier segments.

Compaction is published as *just another generation advance* plus a
touched-key digest, so everything built for live updates — snapshot
pins, open cursors, targeted cache invalidation — keeps working with no
special cases.  The suite checks the contract from both sides:

  * folding never changes a lookup and never makes reading a stream
    MORE expensive (the charge gate: a scattered layout is folded only
    when the single tight segment reads back cheaper), on every
    strategy set;
  * a no-op cycle is a FULL no-op: no ``n_parts`` bump, no digest
    published, no generation movement — readers keep every cached byte;
  * cursors opened before a cycle drain their open-time snapshot;
  * a cycle landing mid-batch trips ``SnapshotViolationError`` exactly
    like a mid-batch part; between batches it is absorbed silently;
  * on the targeted reader a cycle invalidates ONLY the folded keys —
    zero whole-namespace sweeps, warm entries elsewhere survive.
"""

import functools

import numpy as np
import pytest

from repro.core.inverted_index import InvertedIndex
from repro.core.io_sim import BlockDevice
from repro.core.lexicon import OTHER, make_lexicon
from repro.core.sharded_set import ShardedTextIndexSet
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig, TextIndexSet
from repro.data.corpus import generate_part
from repro.search import (
    IndexReader,
    PostingCache,
    Query,
    SearchService,
    SnapshotViolationError,
)
from repro.search.join import numpy_window_join
from tests.oracles import assert_results_identical, class_pools, core_queries


def _cfg(setname="set2", **kw):
    # tag_extract_bytes low enough that hot keys own dedicated streams
    # at this corpus scale — compaction folds K_OWN streams only
    strat = getattr(StrategyConfig, setname)(
        cluster_size=1024, tag_extract_bytes=512
    )
    return IndexSetConfig(strategy=strat, fl_area_clusters=64, **kw)


@functools.lru_cache(maxsize=None)
def _world():
    lex = make_lexicon(
        n_words=3000, n_lemmas=1300, n_stop=20, n_frequent=120, seed=47
    )
    parts = [
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=0, seed=90),
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=40, seed=91),
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=80, seed=92),
    ]
    doc_starts = [0, 40, 80]
    pools = class_pools(lex)
    queries = core_queries(parts[0][0], pools)
    return lex, parts, doc_starts, queries


def _read_charges(ts: TextIndexSet):
    """Full posting scan through the search devices: (bytes, ops) per
    index — the cost of reading EVERYTHING in the current layout."""
    before = {n: (st.read_bytes, st.read_ops)
              for n, st in ts.search_io().items()}
    for name, idx in ts.indexes.items():
        for key in idx.dict.entries:
            ts.lookup(name, key)
    after = {n: (st.read_bytes, st.read_ops)
             for n, st in ts.search_io().items()}
    return {n: (after[n][0] - before[n][0], after[n][1] - before[n][1])
            for n in after}


# ----------------------------------------------- folding the posting state --
@pytest.mark.parametrize("setname", ("set1", "set2", "set3"))
def test_compaction_preserves_lookups_and_never_costs_more(setname):
    """On every strategy set: folding changes no posting list, publishes
    exactly the folded keys, and the whole-index read charge afterwards
    is never higher in bytes OR ops (the charge gate at work)."""
    lex, parts, doc_starts, _ = _world()
    ts = TextIndexSet(_cfg(setname), lex, seed=0)
    for (toks, offs), d0 in zip(parts, doc_starts):
        ts.add_documents(toks, offs, d0)

    expect = {
        name: {k: np.asarray(idx.lookup(k)) for k in idx.dict.entries}
        for name, idx in ts.indexes.items()
    }
    cost0 = _read_charges(ts)
    gen0 = ts.generation

    digests = {}
    for name, idx in ts.indexes.items():
        d = idx.compact()
        if d is not None:
            digests[name] = d
    assert digests, "three update parts must leave something to fold"
    assert ts.generation > gen0

    for name, idx in ts.indexes.items():
        for k, posts in expect[name].items():
            assert np.array_equal(np.asarray(idx.lookup(k)), posts), (
                setname, name, k,
            )
    cost1 = _read_charges(ts)
    for name in cost0:
        assert cost1[name][0] <= cost0[name][0], (setname, name, "bytes")
        assert cost1[name][1] <= cost0[name][1], (setname, name, "ops")
    assert sum(len(d) for d in digests.values()) == sum(
        idx.compacted_streams for idx in ts.indexes.values()
    )


def test_noop_cycle_is_a_full_noop():
    """Satellite regression: a cycle that folds nothing must not bump
    ``n_parts``, publish a digest, or move any generation — at the
    index, set and sharded-set layers."""
    lex, parts, doc_starts, _ = _world()
    sts = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    for (toks, offs), d0 in zip(parts, doc_starts):
        sts.add_documents(toks, offs, d0)

    first = sts.compact()
    assert any(d for d in first), "first cycle must fold something"
    gen = sts.generation_vector()
    stats = sts.compaction_stats()
    n_parts = [
        {n: idx.n_parts for n, idx in sh.indexes.items()} for sh in sts.shards
    ]
    digest_log = [
        {n: list(idx._part_digests) for n, idx in sh.indexes.items()}
        for sh in sts.shards
    ]

    second = sts.compact()
    assert second == [{} for _ in range(sts.n_shards)]
    assert sts.generation_vector() == gen
    assert sts.compaction_stats() == stats
    for sh, parts_before, digests_before in zip(
        sts.shards, n_parts, digest_log
    ):
        for n, idx in sh.indexes.items():
            assert idx.n_parts == parts_before[n]
            assert list(idx._part_digests) == digests_before[n]
        assert sh.compaction_stats()["compactions"] == sum(
            idx.n_compactions for idx in sh.indexes.values()
        )
    for s, us in enumerate(sts.update_streams):
        # only a first cycle that folded on that shard counted; the
        # no-op second cycle never did
        assert us.compactions_applied == (1 if first[s] else 0)


# ------------------------------------------------------- serving under load --
def test_cursor_opened_before_cycle_drains_open_time_snapshot():
    """A lazy cursor partially drained when compaction folds its stream
    keeps delivering the open-time snapshot; the next lookup sees the
    folded (identical) list fresh."""
    cfg = StrategyConfig.set1(cluster_size=256, em_limit=8,
                              tag_extract_bytes=512)
    idx = InvertedIndex(cfg, BlockDevice(cluster_size=256), n_groups=2,
                        fl_area_clusters=8)

    def rows(lo, hi, positions=6):
        docs = np.repeat(np.arange(lo, hi, dtype=np.int64), positions)
        pos = np.tile(np.arange(positions, dtype=np.int64), hi - lo)
        return np.stack([docs, pos], 1)

    for i in range(4):  # several parts: "hot" grows a scattered layout
        idx.add_part({"hot": rows(i * 30, i * 30 + 30)})
    full = np.asarray(idx.lookup("hot"))

    reader = IndexReader(idx, cache=PostingCache(1 << 20))
    cur = reader.open_cursor("hot", chunk_clusters=1)
    head = cur.next_chunk()
    assert head is not None and head.shape[0] < full.shape[0]

    digest = idx.compact()
    assert digest is not None and "hot" in digest

    chunks = [head]
    while True:
        c = cur.next_chunk()
        if c is None:
            break
        chunks.append(c)
    assert np.array_equal(np.concatenate(chunks, axis=0), full)
    assert np.array_equal(reader.lookup("hot"), full)


def test_mid_batch_compaction_raises_snapshot_violation():
    """A compaction cycle is a generation advance: landing mid-batch it
    must trip the snapshot guard exactly like a mid-batch part."""
    lex, parts, doc_starts, _ = _world()
    ts = TextIndexSet(_cfg(), lex, seed=0)
    for (toks, offs), d0 in zip(parts, doc_starts):
        ts.add_documents(toks, offs, d0)
    pools = class_pools(lex)

    def evil_join(a, b, w):
        if ts.generation == evil_join.gen0:  # fire once, mid-batch
            evil_join.digests = ts.compact()
        return numpy_window_join(a, b, w)

    evil_join.gen0 = ts.generation
    evil_join.digests = None
    svc = SearchService(ts, window=3, backend=evil_join)
    q = Query((pools[OTHER][0], pools[OTHER][1]))
    with pytest.raises(SnapshotViolationError):
        svc.search_batch([q])
    # the guard fired because the cycle actually advanced a generation
    assert evil_join.digests, "compaction must have folded something"


def test_between_batch_compaction_absorbed_with_identical_results():
    """Between batches the same cycle is absorbed like any part: no
    violation, and the warm service stays element-wise identical to a
    from-scratch rebuild that never compacted."""
    lex, parts, doc_starts, queries = _world()
    sts = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    fresh = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    for sub in (sts, fresh):
        for (toks, offs), d0 in zip(parts, doc_starts):
            sub.add_documents(toks, offs, d0)

    svc = SearchService(sts, window=3, backend="numpy")
    svc.search_batch(queries)  # pin + warm
    sts.compact()
    got = svc.search_batch(queries)  # absorbed: no violation
    ref = SearchService(fresh, window=3, backend="numpy").search_batch(queries)
    for qi, (r, g) in enumerate(zip(ref, got)):
        assert_results_identical(r, g, ctx=("between-batch", qi))
    assert svc.last_trace["snapshot"] == sts.generation_vector()
    # the batch trace surfaces the cycle (satellite: ops visibility)
    assert svc.last_trace["compactions"]["compactions"] >= 1
    assert svc.last_trace["cache"]["full_drops"] == 0


def test_compaction_invalidates_only_folded_keys():
    """On the targeted reader a cycle drops ONLY (shard, index, key)
    entries named by the compaction digests — zero namespace sweeps,
    every other warm entry survives."""
    lex, parts, doc_starts, queries = _world()
    sts = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    for (toks, offs), d0 in zip(parts, doc_starts):
        sts.add_documents(toks, offs, d0)
    svc = SearchService(sts, window=3, backend="numpy")
    svc.search_batch(queries)  # warm the cache
    cache = svc.reader.cache
    warm = set(cache._map)
    assert warm

    digests = sts.compact()
    assert any(d for d in digests)
    allowed = {
        (f"s{s}:{name}", key)
        for s, per_shard in enumerate(digests)
        for name, keys in per_shard.items()
        for key in keys
    }
    svc.reader.refresh()
    dropped = warm - set(cache._map)
    assert dropped <= allowed, dropped - allowed
    assert cache.stats.full_drops == 0

    got = svc.search_batch(queries)
    fresh = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    for (toks, offs), d0 in zip(parts, doc_starts):
        fresh.add_documents(toks, offs, d0)
    ref = SearchService(fresh, window=3, backend="numpy").search_batch(queries)
    for qi, (r, g) in enumerate(zip(ref, got)):
        assert_results_identical(r, g, ctx=("targeted-compaction", qi))
