"""Paged-KV manager: chain limit, SR full-page invariant, compaction,
and integration with the paged attention kernel."""

import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.paged_kv import PagedKVManager
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


def test_bounded_gather_depth():
    m = PagedKVManager(n_pages=1024, page_size=16, chain_limit=4)
    # interleave appends across sequences to force fragmentation
    for s in range(8):
        m.new_sequence(s)
    rng = np.random.RandomState(0)
    for _ in range(400):
        s = int(rng.randint(8))
        m.append_tokens(s, int(rng.randint(1, 40)))
        assert m.gather_depth(s) <= 4, "chain limit violated"
    assert m.stats.compactions > 0, "test should exercise compaction"


def test_sr_full_page_invariant():
    """Published pages are always full: length is a multiple of page_size
    and the remainder lives in the tail buffer."""
    m = PagedKVManager(n_pages=128, page_size=16, chain_limit=9)
    m.new_sequence(0)
    total = 0
    rng = np.random.RandomState(1)
    for _ in range(50):
        n = int(rng.randint(1, 23))
        m.append_tokens(0, n)
        total += n
        st_ = m.seqs[0]
        assert st_.length % 16 == 0
        assert st_.length + st_.tail == total
        assert st_.tail < 16


def test_free_and_reuse():
    m = PagedKVManager(n_pages=64, page_size=8, chain_limit=3)
    for s in range(4):
        m.new_sequence(s)
        m.append_tokens(s, 64)
    used_before = m.free_pages
    for s in range(4):
        m.free_sequence(s)
    assert m.free_pages == 64
    m.new_sequence(9)
    m.append_tokens(9, 64 * 8)  # can use the whole pool again
    assert m.seqs[9].length == 64 * 8


@settings(max_examples=25, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 5), st.integers(1, 33)),
        min_size=1, max_size=80,
    ),
    st.integers(2, 9),
)
def test_property_invariants(appends, limit):
    m = PagedKVManager(n_pages=4096, page_size=8, chain_limit=limit)
    seen = set()
    totals = {}
    for s, n in appends:
        if s not in seen:
            m.new_sequence(s)
            seen.add(s)
            totals[s] = 0
        m.append_tokens(s, n)
        totals[s] += n
    # no page owned by two sequences
    owned = []
    for s in seen:
        owned.extend(m.page_ids(s))
    assert len(owned) == len(set(owned)), "page double-ownership"
    for s in seen:
        assert m.gather_depth(s) <= limit
        assert m.seqs[s].length + m.seqs[s].tail == totals[s]


def test_block_table_feeds_kernel():
    rng = np.random.RandomState(3)
    page, D, H = 8, 32, 2
    m = PagedKVManager(n_pages=64, page_size=page, chain_limit=3)
    for s in range(3):
        m.new_sequence(s)
        m.append_tokens(s, int(rng.randint(page, 20 * page)))
    seqs = [0, 1, 2]
    max_pages = max(len(m.page_ids(s)) for s in seqs) + 1
    bt = m.block_table(seqs, max_pages)
    lens = m.lengths(seqs)
    kp = jnp.asarray(rng.randn(64, page, D), jnp.float32)
    vp = jnp.asarray(rng.randn(64, page, D), jnp.float32)
    q = jnp.asarray(rng.randn(3, H, D), jnp.float32)
    got = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray(lens))
    want = paged_attention_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(lens))
    assert float(jnp.abs(got - want).max()) < 2e-5
