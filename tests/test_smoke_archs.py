"""Per-architecture smoke tests (deliverable f): every assigned arch is
instantiated at a REDUCED config and runs one real step per shape cell on
CPU, asserting output shapes and finiteness.  The FULL configs are
exercised by the dry-run only (ShapeDtypeStruct, no allocation)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_bundle, shape_cells
from repro.train.optim import adamw_init

RNG = np.random.RandomState(11)


def materialize(tree):
    def one(sds):
        if np.issubdtype(sds.dtype, np.integer):
            return jnp.asarray(
                RNG.randint(0, 2, size=sds.shape), sds.dtype
            )
        if sds.dtype == jnp.bool_:
            return jnp.zeros(sds.shape, sds.dtype)
        return jnp.asarray(RNG.rand(*sds.shape) * 0.1, jnp.float32).astype(
            sds.dtype
        )

    return jax.tree_util.tree_map(one, tree)


def _finite(tree) -> bool:
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            if not bool(jnp.isfinite(leaf).all()):
                return False
    return True


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    bundle = get_bundle(arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    for shape in shape_cells(arch):
        cell = bundle.cells[shape]
        if hasattr(bundle, "cell_inits"):
            params = bundle.cell_inits[shape](rng)
        else:
            params = bundle.init(rng)
        batch = materialize(cell.inputs["batch"])
        if cell.kind == "train":
            opt = adamw_init(params)
            new_params, new_opt, metrics = cell.fn(params, opt, batch)
            assert _finite(metrics), (arch, shape, metrics)
            assert jnp.isfinite(metrics["loss"]), (arch, shape)
            # parameters actually moved
            moved = jax.tree_util.tree_reduce(
                lambda acc, ab: acc
                + float(jnp.abs(ab).sum()),
                jax.tree_util.tree_map(
                    lambda a, b: (a - b).astype(jnp.float32),
                    new_params, params,
                ),
                0.0,
            )
            assert moved > 0, (arch, shape, "no parameter update")
        else:
            out = cell.fn(params, batch)
            assert _finite(out), (arch, shape)


def test_registry_covers_all_cells():
    cells = [(a, s) for a in ARCH_IDS for s in shape_cells(a)]
    assert len(cells) == 40, len(cells)
