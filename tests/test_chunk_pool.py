"""Cross-query chunk pool + the posting cache's partial/device tiers.

Pins the PR's perf contract from every side:

  * the pool: one physical drain per (shard, index, key) identity per
    batch — replaying views cost zero device I/O, physical bytes are
    charged to exactly one view, and every view's three-term ledger
    (fetched + shared + skipped == planned) stays exact however the
    views interleave;
  * the service: a hot-vocabulary batch through pooled cursors is
    element-wise identical to per-query cursors while ledgering
    ``chunks_shared``/``bytes_shared`` and passing the extended
    ``check_trace_complete`` invariant;
  * the partial tier (streaming-cache asymmetry fix): back-to-back
    identical batches re-fetch STRICTLY fewer bytes because early
    stops now admit their settled prefix + resume token, and a resumed
    cursor decodes exactly what a cold full drain would;
  * the device tier: a drained hot key pinned as a device buffer keeps
    serving identical rows after its host entry is gone, at zero
    storage reads;
  * invalidation: a writer update sweeps partial and device entries
    alongside host lists — a stale resume token or device buffer is as
    poisonous as a stale list.
"""

import numpy as np
import pytest

from repro.core.io_sim import BlockDevice
from repro.search import Query, SearchService
from repro.search.pool import ChunkPool
from repro.search.reader import CacheStats
from tests.oracles import assert_results_identical
from tests.test_topk import _hot_phrases


@pytest.fixture(scope="module")
def hot_world():
    """The bench's own hot-vocabulary corpus and geometry (multi keys are
    multi-chunk stream-backed lists — the regime where sharing and
    partial resume have something to save)."""
    from benchmarks.common import HOT_GEOMETRY, build_index_set, make_hot_world

    world = make_hot_world(scale=0.05)
    ts = build_index_set(world, "set2", **HOT_GEOMETRY)
    return world.lexicon, world.parts, ts


def _read_bytes(ts) -> int:
    return sum(s.read_bytes for s in ts.search_io().values())


def _stream_keys(lex, toks, ts, n=4):
    """Multi-index keys whose posting lists span several chunks."""
    mi = ts.indexes["multi"]
    keys = []
    for words in _hot_phrases(lex, toks, n=n, ts=ts):
        lemmas, _ = lex.classify_words(np.asarray(words, np.int64))
        keys.append(mi.pack([int(x) for x in lemmas]))
    return keys


# ------------------------------------------------------- pool mechanics --
def test_pool_one_physical_drain_many_views(hot_world):
    lex, parts, ts = hot_world
    key = _stream_keys(lex, parts[0][0], ts, n=1)[0]
    reader = ts.reader(cache_bytes=0)  # cache off: every byte is physical
    stats = CacheStats()
    pool = ChunkPool(stats=stats)
    ident = (0, "multi", key)

    def opener():
        return reader.open_cursor_shard(0, "multi", key)

    views = [pool.cursor(ident, opener) for _ in range(3)]
    assert len(pool) == 1  # one shared stream behind all three

    b0 = _read_bytes(ts)
    first = views[0].read_all()
    drained = _read_bytes(ts) - b0
    assert views[0].chunks_fetched > 1  # genuinely multi-chunk
    assert drained > 0

    # the other views replay the recorded chunks at ZERO device I/O
    b0 = _read_bytes(ts)
    for v in views[1:]:
        assert (v.read_all() == first).all()
    assert _read_bytes(ts) - b0 == 0
    for v in views[1:]:
        assert v.chunks_fetched == 0 and v.bytes_fetched == 0
        assert v.chunks_shared == views[0].chunks_fetched
        assert v.bytes_shared == views[0].bytes_fetched

    # physical charges land on exactly one view; the pool ledgers the rest
    phys = pool.streams()[0]
    assert sum(v.chunks_fetched for v in views) == phys.chunks_fetched
    assert sum(v.bytes_fetched for v in views) == phys.bytes_fetched
    assert stats.pool_hits == sum(v.chunks_shared for v in views)
    # per-view three-term invariant — the trace's partition, per cursor
    for v in views:
        assert v.exhausted
        assert v.chunks_fetched + v.chunks_shared + v.chunks_skipped \
            == v.chunks_total
        assert v.bytes_fetched + v.bytes_shared + v.bytes_skipped \
            == v.bytes_total


def test_pool_interleaved_views_charge_each_fetch_once(hot_world):
    """Round-robin advancement rotates frontier ownership across views:
    whoever advances the shared frontier pays the fetch, everyone else
    replays — summed per-view charges equal the physical cursor's, and
    every view still sees the identical full chunk sequence."""
    lex, parts, ts = hot_world
    key = _stream_keys(lex, parts[0][0], ts, n=1)[0]
    reader = ts.reader(cache_bytes=0)
    pool = ChunkPool()
    views = [
        pool.cursor((0, "multi", key),
                    lambda: reader.open_cursor_shard(0, "multi", key))
        for _ in range(3)
    ]
    seqs = [[] for _ in views]
    done = [False] * len(views)
    r = 0
    while not all(done):
        order = list(range(len(views)))
        order = order[r % 3:] + order[: r % 3]
        for i in order:
            if done[i]:
                continue
            chunk = views[i].next_chunk()
            if chunk is None:
                done[i] = True
            elif chunk.shape[0]:
                seqs[i].append(chunk)
        r += 1
    phys = pool.streams()[0]
    assert sum(v.chunks_fetched for v in views) == phys.chunks_fetched
    assert sum(v.bytes_fetched for v in views) == phys.bytes_fetched
    # rotation spread ownership: no single view paid for everything
    assert sum(1 for v in views if v.chunks_fetched > 0) >= 2
    rows = [np.concatenate(s) for s in seqs]
    assert all((r_ == rows[0]).all() for r_ in rows[1:])
    for v in views:
        assert v.chunks_shared > 0
        assert v.chunks_fetched + v.chunks_shared + v.chunks_skipped \
            == v.chunks_total


# ---------------------------------------------------- service-level pool --
def test_service_hot_batch_shares_chunks_identical_results(hot_world):
    lex, parts, ts = hot_world
    phrases = _hot_phrases(lex, parts[0][0], n=4, ts=ts)
    queries = [
        Query(phrases[i % len(phrases)], phrase=True, top_k=3)
        for i in range(16)
    ]
    base = SearchService(ts, window=3, cache_bytes=0, share_chunks=False,
                         device_decode=False)
    pooled = SearchService(ts, window=3, cache_bytes=0, share_chunks=True,
                           device_decode=False)

    b0 = _read_bytes(ts)
    ref = base.search_batch(queries)
    base_bytes = _read_bytes(ts) - b0
    base.check_trace_complete()

    b0 = _read_bytes(ts)
    got = pooled.search_batch(queries)
    pooled_bytes = _read_bytes(ts) - b0
    pooled.check_trace_complete()

    for q, r, g in zip(queries, ref, got):
        assert_results_identical(r, g, ctx=q)
    tk = pooled.last_trace["topk"]
    assert tk["chunks_shared"] > 0 and tk["bytes_shared"] > 0
    assert 0 < tk["pool_streams"] < len(queries)
    assert pooled_bytes < base_bytes, (pooled_bytes, base_bytes)


# ------------------------------------------------------- partial tier --
def test_partial_admission_cuts_refetch_on_repeat_batch(hot_world):
    """Satellite regression (streaming-cache asymmetry): an identical
    batch repeated back-to-back re-fetches STRICTLY fewer bytes, because
    early-terminated cursors now admit their settled prefix + resume
    token instead of discarding the work."""
    lex, parts, ts = hot_world
    phrases = _hot_phrases(lex, parts[0][0], n=5, ts=ts)
    queries = [Query(w, phrase=True, top_k=2) for w in phrases]
    svc = SearchService(ts, window=3, backend="jax", cache_bytes=1 << 20)

    b0 = _read_bytes(ts)
    r1 = svc.search_batch(queries)
    pass1 = _read_bytes(ts) - b0
    st = svc.reader.cache.stats
    assert st.partial_admits > 0, "early stops must settle their prefixes"

    b0 = _read_bytes(ts)
    r2 = svc.search_batch(queries)
    pass2 = _read_bytes(ts) - b0
    for q, a, b in zip(queries, r1, r2):
        assert_results_identical(a, b, ctx=q)
    assert pass2 < pass1, (pass2, pass1)
    svc.check_trace_complete()


def test_resumed_cursor_matches_cold_full_drain(hot_world):
    lex, parts, ts = hot_world
    key = _stream_keys(lex, parts[0][0], ts, n=2)[1]
    reader = ts.reader(cache_bytes=1 << 20)
    ir = reader.readers["multi"]

    cur = ir.open_cursor(key)
    head = cur.next_chunk()
    assert head is not None and not cur.exhausted
    full_total = cur.bytes_total
    consumed = cur.bytes_fetched
    assert 0 < consumed < full_total
    assert cur.settle()  # early stop: admit prefix + resume token
    assert reader.cache.stats.partial_admits == 1

    cur2 = ir.open_cursor(key)
    assert cur2.resumed  # served from the partial tier
    rows = cur2.read_all()
    cold = ts.indexes["multi"].lookup(
        key, device=BlockDevice(cluster_size=256)
    )
    assert (rows == cold).all()
    # the prefix replays as a zero-charge thunk: the resumed plan covers
    # only the remainder, and the two drains together pay the stream's
    # bytes exactly once
    assert cur2.bytes_total == full_total - consumed
    assert cur2.bytes_fetched == cur2.bytes_total
    # the completed resume drain admitted the FULL list: third open is
    # a pure cache hit serving one zero-I/O chunk
    cur3 = ir.open_cursor(key)
    assert (cur3.read_all() == cold).all()
    assert cur3.bytes_fetched == 0


# -------------------------------------------------------- device tier --
def test_device_tier_serves_after_host_entry_dropped(hot_world):
    lex, parts, ts = hot_world
    key = _stream_keys(lex, parts[0][0], ts, n=3)[2]
    reader = ts.reader(cache_bytes=1 << 20)
    ir = reader.readers["multi"]
    full = ir.open_cursor(key, device_tier=True).read_all()

    # the eviction order drops host lists before device buffers; model
    # that pressure by clearing the host tier directly
    reader.cache._map.clear()

    b0 = _read_bytes(ts)
    cur = ir.open_cursor(key, device_tier=True)
    rows = cur.read_all()
    assert reader.cache.stats.device_hits == 1
    assert (rows == full).all()
    assert rows.dtype == np.int64
    assert _read_bytes(ts) - b0 == 0  # rematerialized, not re-read


# ------------------------------------------------------- invalidation --
def test_writer_update_invalidates_partial_and_device_tiers():
    from benchmarks.common import HOT_GEOMETRY, bench_index_config
    from benchmarks.common import make_hot_world
    from repro.core.text_index import TextIndexSet

    world = make_hot_world(scale=0.05, seed=1)
    ts = TextIndexSet(bench_index_config("set2", **HOT_GEOMETRY),
                      world.lexicon, seed=0)
    ts.add_documents(*world.parts[0], world.doc_starts[0])
    reader = ts.reader(cache_bytes=1 << 20)
    ir = reader.readers["multi"]
    keys = _stream_keys(world.lexicon, world.parts[0][0], ts, n=2)

    # admit one device entry (full drain) and one partial (early stop)
    ir.open_cursor(keys[0], device_tier=True).read_all()
    cur = ir.open_cursor(keys[1])
    cur.next_chunk()
    assert cur.settle()
    cache = reader.cache
    assert ("multi", keys[0]) in cache._device
    assert ("multi", keys[1]) in cache._partials

    ts.add_documents(*world.parts[1], world.doc_starts[1])
    ir.refresh()
    # hot keys are touched by every hot part: both entries must be gone
    # (via digest or namespace sweep), counted as invalidations
    assert ("multi", keys[0]) not in cache._device
    assert ("multi", keys[1]) not in cache._partials
    assert cache.stats.invalidations > 0

    # and the re-read serves the NEW generation, not a stale replay
    fresh = ts.indexes["multi"].lookup(
        keys[1], device=BlockDevice(cluster_size=256)
    )
    got = ir.open_cursor(keys[1]).read_all()
    assert not got.flags.writeable
    assert (got == fresh).all()


# ------------------------------------------- pool over resumed streams --
def test_pooled_view_prepays_resumed_prefix(hot_world):
    """Satellite regression (pool-over-resume bound seeding): a pooled
    view over a warm RESUMED stream must not sit at ``settled_bound ==
    -inf`` until the executor happens to poll it — the resumed prefix
    replays as prepaid (zero-device-byte) chunks, so draining while
    ``prepaid`` seeds ``last_doc`` from the prefix at zero I/O, exactly
    like a private ReaderCursor gets seeded.  The bound itself stays
    delivery-based: only delivered rows back it."""
    lex, parts, ts = hot_world
    key = _stream_keys(lex, parts[0][0], ts, n=4)[3]
    reader = ts.reader(cache_bytes=1 << 20)
    ir = reader.readers["multi"]
    cold = ir.open_cursor(key).read_all()  # admits the full list…
    reader.cache._map.clear()              # …forget it again

    # settle a genuine partial: one chunk in, early stop
    cur = ir.open_cursor(key)
    head = cur.next_chunk()
    assert head is not None and not cur.exhausted
    assert cur.settle()

    pool = ChunkPool()
    view = pool.cursor((0, "multi", key), lambda: ir.open_cursor(key))
    assert view.resumed
    assert view.prepaid                      # the prefix costs nothing
    assert view.settled_bound == float("-inf")  # …but is NOT yet a bound

    b0 = _read_bytes(ts)
    while not view.exhausted and view.prepaid:
        view.next_chunk()
    assert _read_bytes(ts) - b0 == 0
    assert view.bytes_fetched == 0           # prepaid drain is free
    assert view.last_doc is not None
    assert view.settled_bound > float("-inf")  # seeded through delivery
    assert view.settled_bound == float(head[-1, 0])

    # a second view of the same stream replays the prefix prepaid too
    view2 = pool.cursor((0, "multi", key), lambda: ir.open_cursor(key))
    assert view2.prepaid
    while not view2.exhausted and view2.prepaid:
        view2.next_chunk()
    assert view2.bytes_fetched == 0
    assert view2.settled_bound == view.settled_bound

    # drained to the end, the pooled view reproduces the cold drain
    rest = view.read_all()
    assert (np.concatenate([head, rest]) == cold).all()


def test_pooled_warm_batch_parity_and_fewer_bytes(hot_world):
    """Pool over resume at the service level: the SAME pooled batch
    repeated back-to-back — pass 2 rides resumed prefixes through
    prepaid pre-pull — stays element-wise identical and reads no more
    device bytes than pass 1."""
    lex, parts, ts = hot_world
    phrases = _hot_phrases(lex, parts[0][0], n=5, ts=ts)
    queries = [
        Query(phrases[i % len(phrases)], phrase=True, top_k=2)
        for i in range(10)
    ]
    svc = SearchService(ts, window=3, cache_bytes=1 << 20,
                        share_chunks=True, device_decode=False)
    b0 = _read_bytes(ts)
    r1 = svc.search_batch(queries)
    pass1 = _read_bytes(ts) - b0
    assert svc.reader.cache.stats.partial_admits > 0

    b0 = _read_bytes(ts)
    r2 = svc.search_batch(queries)
    pass2 = _read_bytes(ts) - b0
    for q, a, b in zip(queries, r1, r2):
        assert_results_identical(a, b, ctx=q)
    assert pass2 < pass1, (pass2, pass1)
    svc.check_trace_complete()
