"""Live per-shard update streams with snapshot-consistent serving.

The paper's defining property — in-place updatability — exercised at
SERVING time: one live substrate keeps answering (with warm readers,
caches and open cursors) while collection parts land, and every answer
must be element-wise identical to a from-scratch rebuild of the same
prefix.  Plus the regression suite for the stale-cache hazards of the
old refresh path:

  * cursor cache admission re-checks the writer generation at admit
    time (an open-at-gen-G cursor drained after an update must never
    publish its pre-update list);
  * drained-cursor results and cursor-admitted cache entries are
    immutable, exactly like ``IndexReader.lookup`` results;
  * a part that hashes no rows to a shard leaves that shard's
    generation (and its readers' caches) untouched;
  * targeted (touched-key digest) invalidation drops strictly fewer
    entries than the whole-namespace baseline, with identical results;
  * the bounded digest history falls back to a full namespace drop for
    readers too far behind;
  * a mid-batch writer advance trips ``SnapshotViolationError`` instead
    of returning torn results.
"""

import functools

import numpy as np
import pytest

from repro.core.inverted_index import InvertedIndex
from repro.core.io_sim import BlockDevice
from repro.core.lexicon import make_lexicon
from repro.core.sharded_set import ShardedTextIndexSet, shard_of
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig, TextIndexSet
from repro.data.corpus import generate_part
from repro.search import (
    IndexReader,
    PostingCache,
    Query,
    SearchService,
    SnapshotViolationError,
)
from repro.search.join import numpy_window_join
from tests.oracles import class_pools, core_queries, run_live_update_rounds

SHARD_COUNTS = (1, 2, 4)
BACKENDS = ("numpy", "jax", "pallas")


def _cfg(**kw):
    return IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=1024),
        fl_area_clusters=64,
        **kw,
    )


@functools.lru_cache(maxsize=None)
def _world():
    """A three-part collection (small enough that every round's
    from-scratch rebuild stays cheap) plus the canonical query batch."""
    lex = make_lexicon(
        n_words=3000, n_lemmas=1300, n_stop=20, n_frequent=120, seed=41
    )
    parts = [
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=0, seed=70),
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=40, seed=71),
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=80, seed=72),
    ]
    doc_starts = [0, 40, 80]
    toks = parts[0][0]
    pools = class_pools(lex)
    queries = core_queries(toks, pools)
    # best-k result mode rides the same update stream: streaming cursors
    # over a live substrate, plus a proximity top-k
    queries += [
        Query(tuple(int(t) for t in toks[5:8]), phrase=True, top_k=2),
        Query(queries[0].words, top_k=3),
    ]
    return lex, parts, doc_starts, queries


# -------------------------------------------- the incremental-update oracle --
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_incremental_updates_match_rebuild(n_shards):
    """Interleaved add_documents/search rounds: every backend's live
    service stays element-wise identical to a from-scratch rebuild, on
    every shard count, across all planner routes including top-k."""
    lex, parts, doc_starts, queries = _world()

    def make():
        if n_shards == 1:
            return TextIndexSet(_cfg(), lex, seed=0)
        return ShardedTextIndexSet(_cfg(), lex, n_shards=n_shards, seed=0)

    svcs = run_live_update_rounds(
        make, parts, doc_starts, queries, backends=BACKENDS,
        ctx=("shards", n_shards),
    )
    for svc in svcs.values():
        # every batch pinned its snapshot; the final vector must agree
        # with the reader's current generations
        assert svc.last_trace["snapshot"] == list(
            svc.reader.generation_vector()
        )


def test_update_streams_apply_parts_independently():
    """Per-shard UpdateStreams replaying each shard's own queue at its
    own pace (shard 1 lags a part behind) serve exactly the rows that
    landed — the same per-shard results an all-shards add_documents
    produces once the laggard catches up."""
    lex, parts, doc_starts, queries = _world()
    from repro.core.text_index import MULTI_INDEX
    from repro.data.corpus import extract_postings
    from repro.core.sharded_set import shard_of_docs

    ref = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    live = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)

    def scattered(sts, toks, offs, d0):
        maps = extract_postings(lex, toks, offs, d0, sts.cfg.max_distance)
        maps[MULTI_INDEX] = sts.indexes[MULTI_INDEX].extract_part(
            lex, toks, offs, d0
        )
        out = [{name: {} for name in maps} for _ in range(sts.n_shards)]
        for name, by_key in maps.items():
            for key, arr in by_key.items():
                owner = shard_of_docs(arr[:, 0], sts.n_shards)
                for s in range(sts.n_shards):
                    rows = arr[owner == s]
                    if rows.size:
                        out[s][name][key] = rows
        return out

    for (toks, offs), d0 in zip(parts[:2], doc_starts[:2]):
        ref.add_documents(toks, offs, d0)
    # live: shard 0 applies both parts, shard 1 lags one part behind,
    # then catches up — generations advance per shard, independently
    queues = [scattered(live, t, o, d)
              for (t, o), d in zip(parts[:2], doc_starts[:2])]
    live.update_streams[0].apply(queues[0][0])
    live.update_streams[0].apply(queues[1][0])
    live.update_streams[1].apply(queues[0][1])
    assert live.shards[0].generation == ref.shards[0].generation
    assert live.shards[1].generation < ref.shards[1].generation
    live.update_streams[1].apply(queues[1][1])
    assert live.generation_vector() == ref.generation_vector()

    got = SearchService(live, window=3, backend="numpy").search_batch(queries)
    want = SearchService(ref, window=3, backend="numpy").search_batch(queries)
    for r, g in zip(want, got):
        assert np.array_equal(r.docs, g.docs)
        assert np.array_equal(r.witnesses, g.witnesses)


# ------------------------------------------------- cursor admit-time checks --
def _small_index(**kw):
    cfg = StrategyConfig.set1(cluster_size=256, em_limit=8, **kw)
    idx = InvertedIndex(cfg, BlockDevice(cluster_size=256), n_groups=2,
                        fl_area_clusters=8)
    return idx


def _rows(lo, hi, positions=6):
    docs = np.repeat(np.arange(lo, hi, dtype=np.int64), positions)
    pos = np.tile(np.arange(positions, dtype=np.int64), hi - lo)
    return np.stack([docs, pos], 1)


def test_cursor_admit_rechecks_generation():
    """Satellite regression: open cursor -> add_part -> (reader refresh)
    -> drain.  The drain delivers the open-time snapshot but must NOT
    admit it; the next lookup must see the fresh postings."""
    idx = _small_index()
    idx.add_part({"hot": _rows(0, 40), "other": _rows(0, 3)})
    reader = IndexReader(idx, cache=PostingCache(1 << 20))
    old = np.asarray(idx.lookup("hot"))

    cur = reader.open_cursor("hot", chunk_clusters=1)
    assert cur.generation == idx.n_parts
    idx.add_part({"hot": _rows(40, 60), "other": _rows(3, 5)})
    # a lookup on another key moves the reader to the new generation
    # BEFORE the cursor drains — the exact window where the old code
    # admitted the pre-update list into the post-update cache
    reader.lookup("other")
    drained = cur.read_all()
    assert np.array_equal(drained, old)  # open-time snapshot served
    fresh = reader.lookup("hot")
    assert np.array_equal(fresh, np.asarray(idx.lookup("hot")))
    assert fresh.shape[0] > old.shape[0]


def test_completed_cursor_still_admits():
    """The admit path still warms the cache when no update intervened:
    the drain's list lands in the LRU and the next lookup is a hit."""
    idx = _small_index()
    idx.add_part({"hot": _rows(0, 40)})
    cache = PostingCache(1 << 20)
    reader = IndexReader(idx, cache=cache)
    drained = reader.open_cursor("hot", chunk_clusters=1).read_all()
    h0 = cache.stats.hits
    hit = reader.lookup("hot")
    assert cache.stats.hits == h0 + 1
    assert np.array_equal(hit, drained)


def test_drained_cursor_results_frozen():
    """Satellite regression: drained-cursor results and cursor-admitted
    cache entries are immutable — in-place mutation AND re-enabling the
    writeable flag both fail loudly, exactly like lookup results."""
    idx = _small_index()
    # "em" stays a tiny single-chunk (dictionary-resident) list — the
    # single-chunk drain is the case whose cache entry used to share a
    # writeable buffer with the caller's result
    idx.add_part({"hot": _rows(0, 40), "em": np.array([[0, 1]])})
    reader = IndexReader(idx, cache=PostingCache(1 << 20))
    for key in ("hot", "em"):
        drained = reader.open_cursor(key, chunk_clusters=1).read_all()
        assert not drained.flags.writeable
        with pytest.raises((ValueError, RuntimeError)):
            drained[0, 0] = 99
        hit = reader.lookup(key)  # served from the admitted entry
        assert not hit.flags.writeable
        with pytest.raises(ValueError):
            hit.flags.writeable = True


def test_tag_cursor_mid_update_drain_serves_snapshot():
    """A lazy cursor over a TAG bucket pins the bucket bytes at open:
    an update (or a bucket rewrite) landing before the drain must not
    leak post-snapshot rows into the delivered list."""
    idx = _small_index(tag_extract_bytes=4096)
    keys = {f"t{i}": _rows(i, i + 2) for i in range(8)}
    idx.add_part(keys)
    from repro.core.dictionary import K_TAG
    tag_keys = [k for k in keys if idx.dict.get(k).kind == K_TAG]
    assert tag_keys, "config must drive small keys into TAG buckets"
    key = tag_keys[0]
    reader = IndexReader(idx, cache=PostingCache(1 << 20))
    old = np.asarray(idx.lookup(key))

    cur = reader.open_cursor(key)
    idx.add_part({key: _rows(100, 104)})
    drained = cur.read_all()
    assert np.array_equal(drained, old)  # open-time snapshot, not the
    fresh = reader.lookup(key)           # rewritten bucket
    assert fresh.shape[0] > old.shape[0]
    assert np.array_equal(fresh, np.asarray(idx.lookup(key)))


# ------------------------------------------------ per-shard generations -----
def test_untouched_shard_keeps_generation_and_cache():
    """Satellite regression: a part whose docs all hash to one shard
    must not advance any other shard's generation (previously every
    shard's every index got an add_part call, forcing full cache drops
    on untouched shards)."""
    lex, parts, doc_starts, queries = _world()
    sts = ShardedTextIndexSet(_cfg(), lex, n_shards=4, seed=0)
    sts.add_documents(*parts[0], 0)
    svc = SearchService(sts, window=3, backend="numpy")
    svc.search_batch(queries)  # warm every shard's cache

    doc0 = 40
    target = shard_of(doc0, 4)
    gens = sts.generation_vector()
    cache = svc.reader.cache
    warm_elsewhere = {
        slot for slot in cache._map if not slot[0].startswith(f"s{target}:")
    }
    toks, offs = generate_part(lex, n_docs=1, avg_doc_len=80, doc0=doc0,
                               seed=99)
    sts.add_documents(toks, offs, doc0)

    now = sts.generation_vector()
    for s in range(4):
        if s == target:
            assert now[s] > gens[s]
            assert sts.update_streams[s].parts_applied == 2
        else:
            assert now[s] == gens[s]
            assert sts.update_streams[s].parts_applied == 1
    svc.search_batch(queries)
    # refresh invalidated at most the touched shard's touched keys:
    # every other shard's warm entry survived, and no namespace was
    # swept wholesale
    assert warm_elsewhere <= set(cache._map)
    assert cache.stats.full_drops == 0


def test_targeted_invalidation_fewer_drops_same_results():
    """Two readers over ONE live substrate — targeted digests vs the
    whole-namespace baseline: identical results, strictly fewer cache
    invalidations, no full drops on the digest path."""
    lex, parts, doc_starts, queries = _world()
    sts = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    sts.add_documents(*parts[0], 0)
    svc_t = SearchService(sts.reader(targeted=True), window=3,
                          backend="numpy")
    svc_b = SearchService(sts.reader(targeted=False), window=3,
                          backend="numpy")
    for (toks, offs), d0 in zip(parts[1:], doc_starts[1:]):
        svc_t.search_batch(queries)
        svc_b.search_batch(queries)
        sts.add_documents(toks, offs, d0)
    got_t = svc_t.search_batch(queries)
    got_b = svc_b.search_batch(queries)
    for r, g in zip(got_b, got_t):
        assert np.array_equal(r.docs, g.docs)
        assert np.array_equal(r.witnesses, g.witnesses)
    st_t, st_b = svc_t.reader.cache.stats, svc_b.reader.cache.stats
    assert st_t.invalidations < st_b.invalidations
    assert st_t.full_drops == 0
    assert st_b.full_drops > 0
    # fewer invalidations must buy actual warmth: the targeted reader
    # re-reads less, so it can only have MORE cache hits
    assert st_t.hits >= st_b.hits


def test_digest_history_fallback():
    """A reader further behind than the writer's bounded digest history
    falls back to the whole-namespace drop — and still reads fresh."""
    idx = InvertedIndex(
        StrategyConfig.set1(cluster_size=256, em_limit=8),
        BlockDevice(cluster_size=256), n_groups=2, fl_area_clusters=8,
        digest_history=2,
    )
    idx.add_part({"a": _rows(0, 4)})
    cache = PostingCache(1 << 20)
    reader = IndexReader(idx, cache=cache)
    reader.lookup("a")
    reader.lookup("b")  # negative-cache entry
    # three parts exceed the 2-part history: digests_since(1) is None
    idx.add_part({"a": _rows(4, 8)})
    idx.add_part({"c": _rows(8, 9)})
    idx.add_part({"a": _rows(9, 12)})
    assert idx.digests_since(1) is None
    assert len(idx.digests_since(2)) == 2
    fresh = reader.lookup("a")
    assert cache.stats.full_drops == 1
    assert np.array_equal(fresh, np.asarray(idx.lookup("a")))


def test_oversized_digest_falls_back_to_namespace_drop():
    """A part touching more keys than the digest size cap records a
    sentinel: readers behind it take the whole-namespace drop (cheaper
    than a vocabulary-sized targeted scan) and still read fresh."""
    idx = InvertedIndex(
        StrategyConfig.set1(cluster_size=256, em_limit=8),
        BlockDevice(cluster_size=256), n_groups=2, fl_area_clusters=8,
        digest_max_keys=3,
    )
    touched = idx.add_part({"a": _rows(0, 4)})
    assert touched == frozenset({"a"})
    cache = PostingCache(1 << 20)
    reader = IndexReader(idx, cache=cache)
    reader.lookup("a")
    big = {f"k{i}": _rows(10 + i, 11 + i) for i in range(4)}
    assert len(idx.add_part(big)) == 4  # the return still names every key
    assert idx.digests_since(1) is None
    fresh = reader.lookup("a")
    assert cache.stats.full_drops == 1
    assert np.array_equal(fresh, np.asarray(idx.lookup("a")))


def test_empty_part_does_not_advance_generation():
    idx = _small_index()
    idx.add_part({"a": _rows(0, 2)})
    gen = idx.n_parts
    idx.add_part({})
    idx.add_part({"zero": np.zeros((0, 2), dtype=np.int64)})
    assert idx.n_parts == gen
    assert idx.digests_since(gen) == []


# --------------------------------------------------- snapshot consistency --
def test_mid_batch_update_raises_snapshot_violation():
    """A writer advancing any shard's generation mid-batch must trip the
    snapshot guard, never return torn results."""
    lex, parts, doc_starts, _ = _world()
    ts = TextIndexSet(_cfg(), lex, seed=0)
    ts.add_documents(*parts[0], 0)
    pools = class_pools(lex)
    from repro.core.lexicon import OTHER

    def evil_join(a, b, w):
        if ts.generation == evil_join.gen0:  # fire once, mid-batch
            ts.add_documents(*parts[1], 40)
        return numpy_window_join(a, b, w)

    evil_join.gen0 = ts.generation
    svc = SearchService(ts, window=3, backend=evil_join)
    q = Query((pools[OTHER][0], pools[OTHER][1]))
    with pytest.raises(SnapshotViolationError):
        svc.search_batch([q])


def test_batch_trace_records_pinned_snapshot():
    lex, parts, doc_starts, queries = _world()
    sts = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    sts.add_documents(*parts[0], 0)
    svc = SearchService(sts, window=3, backend="numpy")
    svc.search_batch(queries)
    assert svc.last_trace["snapshot"] == sts.generation_vector()
    sts.add_documents(*parts[1], 40)
    svc.search_batch(queries)
    assert svc.last_trace["snapshot"] == sts.generation_vector()
