"""Training substrate: optimizer schedules, grad accumulation equivalence,
checkpoint/restore exactness, crash-restart resume, compression error."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.distributed.compression import (
    compress_tree,
    dequantize_int8,
    quantize_int8,
)
from repro.train.optim import OptConfig, adamw_init, adamw_update, schedule_lr
from repro.train.trainer import Trainer, TrainerConfig, build_train_step

RNG = np.random.RandomState(5)


def quad_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_problem(n=256, d=8):
    w_true = RNG.randn(d, 1)
    x = RNG.randn(n, d)
    y = x @ w_true + 0.01 * RNG.randn(n, 1)
    params = {
        "w": jnp.zeros((d, 1), jnp.float32),
        "b": jnp.zeros((1,), jnp.float32),
    }
    return params, {
        "x": jnp.asarray(x, jnp.float32),
        "y": jnp.asarray(y, jnp.float32),
    }


def test_wsd_schedule_shape():
    cfg = OptConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                    total_steps=100, decay_fraction=0.2, min_lr_ratio=0.1)
    lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[5] < lrs[10]                      # warmup rises
    assert abs(lrs[40] - 1.0) < 1e-6             # stable plateau
    assert abs(lrs[79] - 1.0) < 1e-6             # still stable at 79 < 80
    assert lrs[95] < 0.5                         # decaying
    assert abs(lrs[100] - 0.1) < 1e-2            # ends at min ratio


def test_adamw_converges():
    params, batch = make_problem()
    cfg = OptConfig(lr=0.05, schedule="const", warmup_steps=1,
                    weight_decay=0.0)
    state = adamw_init(params)
    l0 = float(quad_loss(params, batch))
    for _ in range(150):
        grads = jax.grad(quad_loss)(params, batch)
        params, state, _ = adamw_update(cfg, grads, state, params)
    assert float(quad_loss(params, batch)) < 0.01 * l0


def test_grad_accumulation_matches_full_batch():
    params, batch = make_problem(n=64)
    cfg1 = TrainerConfig(opt=OptConfig(lr=0.01, schedule="const",
                                       warmup_steps=1), microbatches=1)
    cfg4 = TrainerConfig(opt=OptConfig(lr=0.01, schedule="const",
                                       warmup_steps=1), microbatches=4)
    s1 = build_train_step(quad_loss, cfg1)
    s4 = build_train_step(quad_loss, cfg4)
    p1, o1, m1 = s1(params, adamw_init(params), batch)
    p4, o4, m4 = s4(params, adamw_init(params), batch)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        assert float(jnp.abs(a - b).max()) < 1e-5


def test_checkpoint_roundtrip(tmp_path):
    params, _ = make_problem()
    opt = adamw_init(params)
    path = save_checkpoint(str(tmp_path), 7, params, opt, data_cursor=123)
    assert os.path.exists(os.path.join(path, "manifest.json"))
    p2, o2, step, cursor = load_checkpoint(str(tmp_path), params, opt)
    assert step == 7 and cursor == 123
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_detects_corruption(tmp_path):
    params, _ = make_problem()
    save_checkpoint(str(tmp_path), 1, params)
    # corrupt one shard
    d = os.path.join(str(tmp_path), "step_00000001")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(50)
        f.write(b"\xff\xff\xff")
    with pytest.raises(AssertionError, match="hash mismatch"):
        load_checkpoint(str(tmp_path), params)


def test_crash_restart_resumes_exactly(tmp_path):
    """Fault tolerance: train 10 steps straight vs train 5, 'crash',
    restore, train 5 — identical parameters (deterministic data order)."""
    params, batch = make_problem()

    def batches(cursor):  # deterministic per-cursor batch
        rng = np.random.RandomState(cursor)
        idx = rng.choice(batch["x"].shape[0], 32, replace=False)
        return {"x": batch["x"][idx], "y": batch["y"][idx]}

    def mk(ckpt_dir):
        return Trainer(
            quad_loss, params,
            TrainerConfig(
                opt=OptConfig(lr=0.01, schedule="const", warmup_steps=1),
                ckpt_dir=ckpt_dir, ckpt_every=5, log_every=100,
            ),
        )

    t_straight = mk(str(tmp_path / "a"))
    t_straight.fit(batches, 10)

    t_crash = mk(str(tmp_path / "b"))
    t_crash.fit(batches, 5)            # checkpoint lands at step 5
    t_crash.ckpt.wait()

    t_resumed = mk(str(tmp_path / "b"))   # fresh process analogue
    assert t_resumed.try_resume()
    assert t_resumed.step_num == 5
    t_resumed.fit(batches, 10)

    for a, b in zip(jax.tree_util.tree_leaves(t_straight.params),
                    jax.tree_util.tree_leaves(t_resumed.params)):
        assert float(jnp.abs(a - b).max()) < 1e-6


def test_int8_compression_error_bounded():
    x = jnp.asarray(RNG.randn(128, 64) * 3, jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    # max error is half a quantization step
    assert float(jnp.abs(back - x).max()) <= float(s) * 0.5 + 1e-7
    tree = {"a": x, "b": jnp.asarray(RNG.randn(4), jnp.float32)}
    ct = compress_tree(tree)
    assert jax.tree_util.tree_structure(ct) == jax.tree_util.tree_structure(tree)


def test_compressed_training_still_converges():
    params, batch = make_problem()
    cfg = TrainerConfig(
        opt=OptConfig(lr=0.05, schedule="const", warmup_steps=1,
                      weight_decay=0.0),
        compress_grads=True,
    )
    step = build_train_step(quad_loss, cfg)
    opt = adamw_init(params)
    l0 = float(quad_loss(params, batch))
    for _ in range(150):
        params, opt, m = step(params, opt, batch)
    assert float(m["loss"]) < 0.05 * l0
