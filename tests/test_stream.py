"""Stream lifecycle invariants (paper sections 4, 5, Fig. 8)."""

import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

from repro.core.io_sim import BlockDevice
from repro.core.strategies import StrategyConfig
from repro.core.stream import CH, EM, PART, S, SR0, StreamManager


def mk(cfg_name="set1", cluster=1024, **kw):
    cfg = getattr(StrategyConfig, cfg_name)(cluster_size=cluster, **kw)
    dev = BlockDevice(cluster_size=cluster)
    mgr = StreamManager(cfg, dev, n_groups=2, fl_area_clusters=8)
    return cfg, dev, mgr


def feed(mgr, sid, chunks):
    st_ = mgr.streams[sid]
    for c in chunks:
        mgr.append_stream(sid, c)
    return st_


def test_lifecycle_set1_em_part_s():
    cfg, dev, mgr = mk("set1")
    mgr.begin_phase(0)
    sid = mgr.new_stream(0)
    s = feed(mgr, sid, [b"x" * 32])
    assert s.state == EM
    s = feed(mgr, sid, [b"x" * 100])
    assert s.state == PART
    s = feed(mgr, sid, [b"x" * 300])
    assert s.state == PART and s.part_size >= 432
    s = feed(mgr, sid, [b"x" * 600])  # > cluster/2 = 512
    assert s.state == S
    mgr.end_phase()
    assert s.total_bytes == 32 + 100 + 300 + 600


def test_lifecycle_set2_em_sr_ch_s():
    cfg, dev, mgr = mk("set2", chain_limit=3)
    mgr.begin_phase(0)
    sid = mgr.new_stream(0)
    s = feed(mgr, sid, [b"a" * 64])
    assert s.state == EM
    s = feed(mgr, sid, [b"a" * 200])
    assert s.state == SR0 and s.sr_bytes == 264
    s = feed(mgr, sid, [b"a" * 1000])  # > cluster: cluster states
    assert s.state == CH
    mgr.end_phase()
    # SR invariant: every chain byte is in full clusters; tail in SR
    assert s.segment_bytes() + s.sr_bytes == s.total_bytes
    assert s.sr_bytes <= cfg.cluster_size


def test_chain_limit_conversion():
    cfg, dev, mgr = mk("set2", chain_limit=3)
    sid = None
    s = None
    # append across many phases so the chain grows one segment per phase
    for phase in range(8):
        mgr.begin_phase(0)
        if sid is None:
            sid = mgr.new_stream(0)
        feed(mgr, sid, [b"z" * 900])
        s = mgr.streams[sid]
        assert len(s.segments) <= s.chain_limit, "chain limit violated"
        mgr.end_phase()
    # the chain must have converted to S at least once
    assert mgr.transitions.get((CH, S), 0) >= 1


def test_data_accounting_invariant():
    for setname in ("set1", "set2"):
        cfg, dev, mgr = mk(setname)
        rng = np.random.RandomState(0)
        mgr.begin_phase(0)
        sids = [mgr.new_stream(0) for _ in range(10)]
        for _ in range(50):
            sid = sids[rng.randint(len(sids))]
            feed(mgr, sid, [bytes(rng.randint(1, 400))])
        mgr.end_phase()
        for sid in sids:
            s = mgr.streams[sid]
            if s.state in (EM, SR0, PART):
                assert not s.segments
            else:
                tail = s.sr_bytes if s.has_sr else (
                    s.fl_bytes if s.has_fl else 0
                )
                assert s.segment_bytes() + tail == s.total_bytes


def test_read_stream_returns_exact_bytes():
    cfg, dev, mgr = mk("set2")
    mgr.begin_phase(0)
    sid = mgr.new_stream(0)
    payload = b"".join(bytes([i % 251]) * 397 for i in range(20))
    feed(mgr, sid, [payload[i : i + 397] for i in range(0, len(payload), 397)])
    mgr.end_phase()
    assert mgr.read_stream(sid) == payload


def test_segment_contiguity():
    """S segments must be physically contiguous (one read op each)."""
    cfg, dev, mgr = mk("set1")
    mgr.begin_phase(0)
    sid = mgr.new_stream(0)
    feed(mgr, sid, [b"q" * 4096] * 8)
    mgr.end_phase()
    s = mgr.streams[sid]
    assert s.state == S
    before = dev.stats.read_ops
    mgr.read_stream(sid)
    # ops == number of segments (+1 if FL tail)
    expect = len(s.segments) + (1 if (s.has_fl and s.fl_bytes) else 0)
    assert dev.stats.read_ops - before == expect


def test_sr_no_tail_reads_on_update():
    """The SR strategy's whole point: updating never re-reads tail clusters."""
    results = {}
    for setname in ("set1", "set2"):
        cfg, dev, mgr = mk(setname, cluster=1024)
        # disable FL coverage so set1 shows the raw read-modify-write cost
        mgr.fl_area_clusters = 0
        sid = None
        for phase in range(6):
            mgr.begin_phase(0)
            if sid is None:
                sid = mgr.new_stream(0)
            feed(mgr, sid, [b"m" * 700])
            mgr.end_phase()
        results[setname] = dev.stats.read_ops
    assert results["set2"] < results["set1"], results


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.integers(min_value=1, max_value=3000), min_size=1, max_size=40),
    st.sampled_from(["set1", "set2", "set3"]),
)
def test_property_total_bytes_preserved(sizes, setname):
    cfg, dev, mgr = mk(setname)
    mgr.begin_phase(1)
    sid = mgr.new_stream(1)
    total = 0
    for i, n in enumerate(sizes):
        feed(mgr, sid, [bytes([i % 256]) * n])
        total += n
    mgr.end_phase()
    s = mgr.streams[sid]
    assert s.total_bytes == total
    assert len(mgr.read_stream(sid)) == total
    if s.state == CH:
        assert len(s.segments) <= s.chain_limit
