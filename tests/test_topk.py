"""Top-k early-termination search: the streaming (lazy cursor) executor.

Pins the tentpole contract from every side:

  * property: ``Query(top_k=N)`` returns the exhaustive executor's sorted
    head — docs, witnesses AND scores — element-wise, across
    numpy/jax/pallas and n_shards {1, 2, 4};
  * monotonicity: raising ``top_k`` only extends the result list;
  * effectiveness: on a seeded hot corpus the streaming stage skips
    chunks and reads strictly fewer device bytes than the exhaustive
    path (the optimization cannot silently degrade to a full scan);
  * observability: the trace-completeness invariant (every planned fetch
    wave / lookup / cursor chunk executed or explicitly skipped) holds
    and is enforced loudly;
  * the cursor substrate: chunked reads reconstruct ``lookup`` exactly at
    identical drained byte cost, and the cache only ever learns complete
    lists.
"""

import dataclasses
import functools

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.core.io_sim import BlockDevice
from repro.core.lexicon import make_lexicon
from repro.core.sharded_set import ShardedTextIndexSet, merge_shard_chunks
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig, TextIndexSet
from repro.data.corpus import generate_part
from repro.search import Query, SearchService, TraceIncompleteError
from tests.oracles import (
    QUERY_SPEC,
    assert_results_identical,
    assert_topk_matches_head,
    class_pools,
    core_queries,
    mixed_queries,
    spec_to_query,
)

BACKENDS = ("numpy", "jax", "pallas")
SHARD_COUNTS = (1, 2, 4)


# ------------------------------------------------------------- the worlds --
@functools.lru_cache(maxsize=None)
def _equiv_worlds():
    """A small mixed-route collection, unsharded + sharded {1,2,4}."""
    lex = make_lexicon(
        n_words=3000, n_lemmas=1300, n_stop=20, n_frequent=120, seed=43
    )
    cfg = IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=1024),
        fl_area_clusters=64,
    )
    parts = [
        generate_part(lex, n_docs=60, avg_doc_len=120, doc0=0, seed=80),
        generate_part(lex, n_docs=60, avg_doc_len=120, doc0=60, seed=81),
    ]
    ts = TextIndexSet(cfg, lex, seed=0)
    sharded = {
        n: ShardedTextIndexSet(cfg, lex, n_shards=n, seed=0)
        for n in SHARD_COUNTS
    }
    for s in [ts] + list(sharded.values()):
        s.add_documents(*parts[0], 0)
        s.add_documents(*parts[1], 60)
    return lex, parts[0][0], class_pools(lex), ts, sharded


@functools.lru_cache(maxsize=None)
def _equiv_services():
    lex, toks, pools, ts, sharded = _equiv_worlds()
    ref = SearchService(ts, window=3, backend="numpy")
    svcs = {
        (n, b): SearchService(sharded[n], window=3, backend=b)
        for n in SHARD_COUNTS
        for b in BACKENDS
    }
    return ref, svcs


@pytest.fixture(scope="module")
def hot_world():
    """A tiny, hot vocabulary: every trigram repeats across many docs, so
    multi keys are stream-backed multi-chunk lists and a small top_k
    settles long before the lists end — the early-termination regime.
    The corpus AND index geometry are the bench's own
    (``benchmarks.common.make_hot_world`` / ``HOT_GEOMETRY``), so this
    regression and ``search_speed --topk`` can never drift into pinning
    different regimes."""
    from benchmarks.common import HOT_GEOMETRY, build_index_set, make_hot_world

    world = make_hot_world(scale=0.05)
    ts = build_index_set(world, "set2", **HOT_GEOMETRY)
    return world.lexicon, world.parts, ts


def _hot_phrases(lex, toks, n=8, width=3, seed=3, ts=None):
    """Non-all-stop phrases lifted from the hot token stream.  With
    ``ts`` given, only phrases whose multi key is a multi-chunk
    stream-backed list are kept — the lists early termination can
    actually stop inside."""
    rng = np.random.RandomState(seed)
    out, seen = [], set()
    for _ in range(4000):
        if len(out) >= n:
            break
        s = int(rng.randint(0, toks.shape[0] - width))
        words = tuple(int(t) for t in toks[s : s + width])
        if words in seen:
            continue
        seen.add(words)
        _, cls = lex.classify_words(np.asarray(words, np.int64))
        if all(int(c) == 0 for c in cls):
            continue  # all-stop: stopseq route, single tiny lookup
        if ts is not None:
            mi = ts.indexes["multi"]
            lemmas, _ = lex.classify_words(np.asarray(words, np.int64))
            key = mi.pack([int(x) for x in lemmas])
            probe = mi.open_cursor(
                key, device=BlockDevice(cluster_size=256)
            )
            if probe.chunks_total <= 2:
                continue
        out.append(words)
    assert len(out) >= min(n, 2), "hot corpus produced too few candidates"
    return out


# --------------------------------------------------------- property suite --
@settings(max_examples=10, deadline=None)
@given(
    st.lists(QUERY_SPEC, min_size=0, max_size=6),
    st.integers(1, 12),
)
def test_topk_equals_exhaustive_head_all_backends_all_shards(specs, k):
    """Property: the top-k result set (docs, witnesses AND scores) equals
    the exhaustive executor's sorted head, for every drawn query, across
    numpy/jax/pallas x n_shards {1,2,4}."""
    lex, toks, pools, ts, _ = _equiv_worlds()
    ref_svc, svcs = _equiv_services()
    queries = core_queries(toks, pools) + [
        spec_to_query(s, toks, pools) for s in specs
    ]
    ref = ref_svc.search_batch(queries)
    topk = [dataclasses.replace(q, top_k=k) for q in queries]
    for (n, backend), svc in svcs.items():
        got = svc.search_batch(topk)
        for q, r, g in zip(queries, ref, got):
            assert_topk_matches_head(r, g, k, ctx=(n, backend, q))
            assert g.docs.shape[0] <= k


def test_topk_monotonic_in_k():
    """Raising top_k only EXTENDS the result list: docs, witnesses and
    scores of a smaller k are an exact prefix of every larger k."""
    lex, toks, pools, ts, _ = _equiv_worlds()
    svc = SearchService(ts, window=3)
    queries = core_queries(toks, pools)
    for q in queries:
        prev = None
        for k in (1, 2, 4, 8, 64, 10_000):
            r = svc.search_batch([dataclasses.replace(q, top_k=k)])[0]
            if prev is not None:
                n = prev.docs.shape[0]
                assert r.docs.shape[0] >= n, (q, k)
                assert np.array_equal(r.docs[:n], prev.docs), (q, k)
                assert np.array_equal(r.scores[:n], prev.scores), (q, k)
                m = prev.witnesses.shape[0]
                assert np.array_equal(r.witnesses[:m], prev.witnesses), (q, k)
            prev = r


def test_topk_fallback_when_k_exceeds_matches():
    """top_k >= total matches degenerates to the exhaustive answer (all
    cursors drain; identical docs/witnesses/scores)."""
    lex, toks, pools, ts, _ = _equiv_worlds()
    svc = SearchService(ts, window=3)
    for q in core_queries(toks, pools):
        ref = svc.search_batch([q])[0]
        got = svc.search_batch([dataclasses.replace(q, top_k=100_000)])[0]
        assert_results_identical(ref, got, ctx=q)


def test_topk_fallback_with_duplicated_cover_keys(hot_world):
    """Regression: a periodic phrase covers itself with a REPEATED multi
    key ([A, B, A]); the streaming stage opens one cursor per unique key
    but must still report postings_scanned per lookup occurrence, so the
    full-drain result is `==` to the exhaustive one."""
    lex, parts, ts = hot_world
    svc = SearchService(ts, window=3, cache_bytes=0)
    q = Query((1, 4, 2, 4, 1), phrase=True)
    ref = svc.search_batch([q])[0]
    assert ref.lookups[0] == ref.lookups[2], "phrase should repeat a key"
    got = svc.search_batch([dataclasses.replace(q, top_k=10_000)])[0]
    assert ref == got


def test_topk_query_validation():
    with pytest.raises(ValueError):
        Query((1, 2), top_k=0)
    with pytest.raises(ValueError):
        Query((1, 2), top_k=-3)


# ------------------------------------- early-termination effectiveness --
def test_early_termination_skips_chunks_and_bytes(hot_world):
    """Tier-1 regression: on the seeded hot corpus the streaming stage
    must actually skip chunks, and its device read bytes must come in
    STRICTLY below the exhaustive multi-route path — so the optimization
    cannot silently degrade to a full scan."""
    lex, parts, ts = hot_world
    toks0 = parts[0][0]
    phrases = _hot_phrases(lex, toks0, n=8, ts=ts)

    def read_bytes():
        return sum(s.read_bytes for s in ts.search_io().values())

    svc_topk = SearchService(ts, window=3, cache_bytes=0)
    svc_ex = SearchService(ts, window=3, cache_bytes=0)

    b0 = read_bytes()
    topk_res = svc_topk.search_batch(
        [Query(w, phrase=True, top_k=2) for w in phrases]
    )
    topk_bytes = read_bytes() - b0
    tk = svc_topk.last_trace["topk"]

    b0 = read_bytes()
    ex_res = svc_ex.search_batch([Query(w, phrase=True) for w in phrases])
    ex_bytes = read_bytes() - b0

    # identical heads first — a fast wrong answer would be worse
    for w, r, g in zip(phrases, ex_res, topk_res):
        assert_topk_matches_head(r, g, 2, ctx=w)

    assert tk["chunks_skipped"] > 0, tk
    assert tk["early_terminated"] > 0, tk
    assert tk["bytes_skipped"] > 0, tk
    assert topk_bytes < ex_bytes, (topk_bytes, ex_bytes)
    # the trace's own ledger agrees with the device accounting
    assert tk["bytes_fetched"] <= topk_bytes


def test_topk_trace_reports_savings(hot_world):
    """The per-batch trace carries the full chunks/bytes ledger."""
    lex, parts, ts = hot_world
    toks0 = parts[0][0]
    svc = SearchService(ts, window=3, cache_bytes=0)
    svc.search_batch(
        [Query(w, phrase=True, top_k=1) for w in _hot_phrases(lex, toks0, 4)]
    )
    tk = svc.last_trace["topk"]
    assert tk["queries"] == 4
    assert tk["chunks_planned"] == (
        tk["chunks_fetched"] + tk["chunks_skipped"] + tk["chunks_shared"]
    )
    assert tk["bytes_planned"] == (
        tk["bytes_fetched"] + tk["bytes_skipped"] + tk["bytes_shared"]
    )


# ----------------------------------------------- trace completeness guard --
def test_trace_completeness_invariant_holds(hot_world):
    """Every planned fetch wave and lookup is accounted for — executed or
    explicitly skipped/deferred — on pure-batch, pure-streaming and mixed
    batches (search_batch runs the check itself; re-run it here too)."""
    lex, parts, ts = hot_world
    toks0 = parts[0][0]
    svc = SearchService(ts, window=3)
    phrases = _hot_phrases(lex, toks0, 4)
    batches = [
        [Query(w, phrase=True) for w in phrases],
        [Query(w, phrase=True, top_k=2) for w in phrases],
        [Query(phrases[0], phrase=True),
         Query(phrases[0], phrase=True, top_k=1),
         Query(phrases[1], phrase=True, top_k=3)],
    ]
    for batch in batches:
        plan = svc.plan(batch)
        svc.search_batch(batch)
        svc.check_trace_complete(plan)
        tr = svc.last_trace
        assert tr["waves"] == tr["executed_waves"] + tr["skipped_waves"]
        assert tr["lookups_planned"] == (
            tr["lookups_fetched"] + tr["lookups_deferred"]
        )
    # a shared (index, key) between a batch and a streaming query is
    # fetched by the wave (not deferred): the mixed batch above reuses
    # phrases[0] both ways
    assert svc.last_trace["lookups_deferred"] < svc.last_trace["lookups_planned"]


def test_trace_incompleteness_raises(hot_world):
    """Regression: a dropped wave / unaccounted cursor chunk must fail
    loudly, not masquerade as saved I/O."""
    lex, parts, ts = hot_world
    toks0 = parts[0][0]
    svc = SearchService(ts, window=3)
    phrases = _hot_phrases(lex, toks0, 2)
    svc.search_batch([Query(phrases[0], phrase=True),
                      Query(phrases[1], phrase=True, top_k=1)])
    svc.check_trace_complete()  # intact trace passes

    good = dict(svc.last_trace)
    svc.last_trace = dict(good, executed_waves=good["executed_waves"] - 1)
    with pytest.raises(TraceIncompleteError):
        svc.check_trace_complete()
    svc.last_trace = dict(good, lookups_fetched=good["lookups_fetched"] + 1)
    with pytest.raises(TraceIncompleteError):
        svc.check_trace_complete()
    tk = dict(good["topk"], chunks_skipped=good["topk"]["chunks_skipped"] + 1)
    svc.last_trace = dict(good, topk=tk)
    with pytest.raises(TraceIncompleteError):
        svc.check_trace_complete()


# --------------------------------------------------- the cursor substrate --
def test_cursor_chunks_reconstruct_lookup(hot_world):
    """Draining a cursor yields exactly lookup()'s rows at exactly its
    device read bytes, across every storage tier the corpus populated."""
    lex, parts, ts = hot_world
    kinds_covered = set()
    for name, idx in ts.indexes.items():
        for key, e in list(idx.dict.entries.items())[:40]:
            d_look = BlockDevice(cluster_size=256)
            d_cur = BlockDevice(cluster_size=256)
            ref = idx.lookup(key, device=d_look)
            cur = idx.open_cursor(key, device=d_cur)
            got = cur.read_all()
            assert np.array_equal(ref, got), (name, key, e.kind)
            assert cur.exhausted and cur.chunks_skipped == 0
            assert d_cur.stats.read_bytes == d_look.stats.read_bytes, (
                name, key, e.kind
            )
            kinds_covered.add(e.kind)
    assert len(kinds_covered) >= 2, kinds_covered


def test_cursor_early_stop_saves_bytes(hot_world):
    """Stopping a multi-chunk cursor early charges strictly fewer device
    bytes than the whole-list read."""
    lex, parts, ts = hot_world
    for name, idx in ts.indexes.items():
        for key in idx.dict.entries:
            probe = idx.open_cursor(key, device=BlockDevice(cluster_size=256))
            if probe.chunks_total <= 2:
                continue
            dev = BlockDevice(cluster_size=256)
            cur = idx.open_cursor(key, device=dev)
            cur.next_chunk()
            partial = dev.stats.read_bytes
            full_dev = BlockDevice(cluster_size=256)
            idx.lookup(key, device=full_dev)
            assert partial < full_dev.stats.read_bytes
            assert cur.bytes_skipped > 0
            return
    pytest.fail("hot corpus produced no multi-chunk posting list")


def test_reader_cursor_cache_integration(hot_world):
    """A fully drained reader cursor admits the complete list to the
    shared cache (the next reader pays zero I/O); an early-terminated
    cursor must NOT cache its partial list."""
    lex, parts, ts = hot_world
    mi = ts.indexes["multi"]
    key = None
    for k in mi.dict.entries:
        if mi.open_cursor(k, device=BlockDevice(cluster_size=256)).chunks_total > 1:
            key = k
            break
    assert key is not None

    reader = ts.reader(cache_bytes=1 << 20)
    cur = reader.readers["multi"].open_cursor(key)
    parts_got = []
    while True:
        c = cur.next_chunk()
        if c is None:
            break
        parts_got.append(c)
    full = np.concatenate([p for p in parts_got if p.shape[0]], axis=0)
    # drained: the cache now holds the complete list
    hit = reader.cache.get("multi", key)
    assert hit is not None and np.array_equal(hit, full)
    io0 = reader.readers["multi"].io_stats().total_ops
    cur2 = reader.readers["multi"].open_cursor(key)
    assert np.array_equal(cur2.next_chunk(), full)
    assert cur2.next_chunk() is None
    assert reader.readers["multi"].io_stats().total_ops == io0, (
        "cache-hit cursor must charge zero device I/O"
    )

    # early termination on a cold reader: nothing may be cached
    reader2 = ts.reader(cache_bytes=1 << 20)
    cur3 = reader2.readers["multi"].open_cursor(key)
    cur3.next_chunk()  # fetch one chunk, abandon
    assert reader2.cache.get("multi", key) is None
    # and the full list is still served correctly afterwards
    assert np.array_equal(reader2.lookup("multi", key), full)


def test_reader_cursor_read_all_after_partial_consumption(hot_world):
    """Regression: mixing next_chunk() with read_all() on a ReaderCursor
    must still admit the COMPLETE list to the cache — read_all drains
    through the same accumulation path, never the inner cursor's."""
    lex, parts, ts = hot_world
    mi = ts.indexes["multi"]
    key = next(
        k for k in mi.dict.entries
        if mi.open_cursor(k, device=BlockDevice(cluster_size=256)).chunks_total > 1
    )
    full = mi.lookup(key, device=BlockDevice(cluster_size=256))
    reader = ts.reader(cache_bytes=1 << 20)
    cur = reader.readers["multi"].open_cursor(key)
    first = cur.next_chunk()
    rest = cur.read_all()
    assert np.array_equal(np.concatenate([first, rest], axis=0), full)
    hit = reader.cache.get("multi", key)
    assert hit is not None and np.array_equal(hit, full), (
        "cache must hold the complete list, not a truncated one"
    )


def test_topk_full_drain_warms_cache(hot_world):
    """Regression: the streaming executor stops polling a cursor at
    `exhausted` (it never sees the trailing None), but a fully drained
    cursor must STILL admit the complete list to the shared cache — the
    repeat query serves entirely from it at zero device I/O."""
    lex, parts, ts = hot_world
    toks0 = parts[0][0]
    words = _hot_phrases(lex, toks0, 1, ts=ts)[0]
    svc = SearchService(ts, window=3)  # cache enabled
    q = Query(words, phrase=True, top_k=1_000_000)  # full drain
    r1 = svc.search_batch([q])[0]
    assert len(svc.reader.cache) > 0, "drained cursor must warm the cache"
    io0 = {n: s.total_ops for n, s in ts.search_io().items()}
    r2 = svc.search_batch([q])[0]
    assert {n: s.total_ops for n, s in ts.search_io().items()} == io0, (
        "repeat top-k over a warmed cache must charge zero device I/O"
    )
    assert r1 == r2


def test_topk_rides_batch_fetches_in_mixed_batch(hot_world):
    """Regression: a key shared by an exhaustive and a top-k query in the
    same batch is read from the device ONCE — the streaming stage streams
    the batch wave's rows instead of re-opening device cursors (pinned
    with the cache disabled, where re-reading would otherwise be
    invisible to everything but the byte counters)."""
    lex, parts, ts = hot_world
    toks0 = parts[0][0]
    words = _hot_phrases(lex, toks0, 1, ts=ts)[0]

    def read_bytes():
        return sum(s.read_bytes for s in ts.search_io().values())

    svc1 = SearchService(ts, window=3, cache_bytes=0)
    b0 = read_bytes()
    ref = svc1.search_batch([Query(words, phrase=True)])[0]
    solo = read_bytes() - b0

    svc2 = SearchService(ts, window=3, cache_bytes=0)
    b0 = read_bytes()
    both = svc2.search_batch([
        Query(words, phrase=True),
        Query(words, phrase=True, top_k=2),
    ])
    mixed = read_bytes() - b0
    assert mixed == solo, (mixed, solo)
    assert np.array_equal(both[1].docs, ref.docs[:2])


def test_merge_shard_chunks_gathers_in_doc_order():
    a1 = np.asarray([[0, 5], [2, 1]], np.int64)
    a2 = np.asarray([[2, 4], [6, 0]], np.int64)
    b1 = np.asarray([[1, 9]], np.int64)
    merged = merge_shard_chunks([[a1, a2], [b1], []])
    assert np.array_equal(
        merged, [[0, 5], [1, 9], [2, 1], [2, 4], [6, 0]]
    )
    assert merge_shard_chunks([[], []]).shape == (0, 2)
