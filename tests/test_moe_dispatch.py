"""MoE dispatch equivalence: the sort-based path must reproduce the
GShard one-hot path exactly — same outputs, same drop counts, same
priority semantics — under every capacity regime."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.moe import MoEConfig, moe_apply, moe_init


@pytest.fixture(scope="module")
def setup():
    rng = np.random.RandomState(0)
    base = MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared_experts=1,
                     capacity_factor=16.0, group_tokens=64)
    p = moe_init(jax.random.PRNGKey(0), 48, base)
    x = jnp.asarray(rng.randn(2, 64, 48), jnp.float32).astype(jnp.bfloat16)
    return base, p, x


@pytest.mark.parametrize("cf", [16.0, 2.0, 1.0, 0.5])
def test_sort_dispatch_matches_onehot(setup, cf):
    base, p, x = setup
    cfgc = dataclasses.replace(base, capacity_factor=cf)
    y1, a1 = moe_apply(p, x, dataclasses.replace(cfgc, dispatch="onehot"))
    y2, a2 = moe_apply(p, x, dataclasses.replace(cfgc, dispatch="sort"))
    rel = float(
        jnp.abs(y1.astype(jnp.float32) - y2.astype(jnp.float32)).max()
        / (jnp.abs(y1.astype(jnp.float32)).max() + 1e-9)
    )
    assert rel < 2e-2, rel
    assert float(a1["dropped_tokens"]) == float(a2["dropped_tokens"])


def test_sort_dispatch_grads_finite(setup):
    base, p, x = setup
    cfg = dataclasses.replace(base, dispatch="sort")

    def loss(pp):
        y, _ = moe_apply(pp, x, cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.isfinite(leaf).all())
