"""End-to-end system test: build, update in place, search, verify trends.

This is the whole paper in one test: a two-part collection is indexed
(part 2 as an in-place update), all five index kinds answer queries
consistently with an ordinary-index baseline, and the strategy sets
improve construction I/O in the directions Tables 2 and 3 claim.
"""

import numpy as np
import pytest

from repro.core.lexicon import FREQUENT, OTHER, STOP, make_lexicon
from repro.core.proximity import ProximityEngine
from repro.core.strategies import StrategyConfig
from repro.core.text_index import INDEX_NAMES, IndexSetConfig, TextIndexSet
from repro.data.corpus import generate_part


def _build(setname, lex, parts, cluster=2048):
    cfg = IndexSetConfig(
        strategy=getattr(StrategyConfig, setname)(cluster_size=cluster),
        build_ordinary_all=False,
        fl_area_clusters=128,
    )
    ts = TextIndexSet(cfg, lex, seed=0)
    doc0 = 0
    for toks, offs in parts:
        ts.add_documents(toks, offs, doc0)
        doc0 += offs.shape[0] - 1
    return ts


@pytest.fixture(scope="module")
def world():
    lex = make_lexicon(n_words=6000, n_lemmas=2500, n_stop=25, n_frequent=150, seed=21)
    parts = [
        generate_part(lex, n_docs=120, avg_doc_len=200, doc0=0, seed=31),
        generate_part(lex, n_docs=120, avg_doc_len=200, doc0=120, seed=32),
    ]
    return lex, parts


def test_end_to_end_strategy_trends(world):
    lex, parts = world
    per_set = {}
    for s in ("set1", "set2", "set3"):
        ts = _build(s, lex, parts)
        rows = ts.table_rows()
        per_set[s] = {
            "bytes": sum(r["total_bytes"] for r in rows.values()),
            "write_ops": sum(r["write_ops"] for r in rows.values()),
            "ops": sum(r["total_ops"] for r in rows.values()),
        }
    # Table 2 trend: CH+SR reduce total construction bytes
    assert per_set["set2"]["bytes"] < per_set["set1"]["bytes"], per_set
    # Table 3 trend: DS reduces operation counts further
    assert per_set["set3"]["write_ops"] < per_set["set2"]["write_ops"], per_set


def test_all_index_kinds_answer(world):
    lex, parts = world
    cfg = IndexSetConfig(
        strategy=StrategyConfig.set3(cluster_size=2048),
        build_ordinary_all=True,
        fl_area_clusters=128,
    )
    ts = TextIndexSet(cfg, lex, seed=0)
    doc0 = 0
    for toks, offs in parts:
        ts.add_documents(toks, offs, doc0)
        doc0 += offs.shape[0] - 1
    eng = ProximityEngine(ts, window=3)

    def words_of(cls, n):
        out = []
        for w in range(lex.n_words):
            l = lex.lemma1[w]
            if l >= 0 and lex.lemma_class[l] == cls:
                out.append(int(w))
                if len(out) == n:
                    break
        return out

    stop, freq, other = words_of(STOP, 5), words_of(FREQUENT, 5), words_of(OTHER, 5)
    used_paths = set()
    for q in (
        [stop[0], stop[1]],
        [stop[1], stop[2], stop[3]],
        [freq[0], other[0]],
        [freq[1], freq[2]],
        [other[0], other[1]],
        [stop[0], other[2]],
    ):
        r = eng.search(q)
        rb = eng.search_ordinary(q)
        assert set(r.docs.tolist()) == set(rb.docs.tolist()), q
        used_paths.add(r.lookups[0][0])
    assert {"stopseq", "wv_kk", "known"} <= used_paths
