"""Hypothesis shim: use the real library when installed, otherwise run a
small deterministic random-example fallback.

The CI image has no network access and ships without ``hypothesis``;
importing it at module scope used to ERROR four test modules out of
collection.  This shim keeps the property tests meaningful offline: the
fallback draws ``max_examples`` pseudo-random examples from the same
strategy expressions (the subset used in this repo: ``integers``,
``lists``, ``tuples``, ``sampled_from``, ``booleans``) with a fixed seed,
so failures are reproducible.  With hypothesis installed, behaviour is
unchanged (no shrinking is lost).
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=None, **_kw):
            lo = min_value if min_value is not None else -(2 ** 31)
            hi = max_value if max_value is not None else 2 ** 31
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*parts):
            return _Strategy(lambda rng: tuple(p.draw(rng) for p in parts))

    strategies = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
        def mark(fn):
            fn._compat_max_examples = max_examples
            return fn

        return mark

    def given(*gargs, **gkw):
        def wrap(fn):
            @functools.wraps(fn)
            def runner(*args, **kw):
                # settings() may decorate either side of given(): the count
                # lands on whichever wrapper the attribute ended up on
                n = getattr(runner, "_compat_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(f"{fn.__module__}.{fn.__name__}")
                for i in range(n):
                    drawn = [s.draw(rng) for s in gargs]
                    named = {k: s.draw(rng) for k, s in gkw.items()}
                    try:
                        fn(*args, *drawn, **named, **kw)
                    except Exception:
                        print(
                            f"falsifying example ({fn.__name__}, run {i}): "
                            f"args={drawn!r} kwargs={named!r}"
                        )
                        raise

            # pytest must not see the wrapped signature, or it would treat
            # the strategy-supplied parameters as fixtures
            del runner.__wrapped__
            # surface settings() applied after given() in decorator order
            runner._compat_max_examples = getattr(
                fn, "_compat_max_examples", _DEFAULT_EXAMPLES
            )
            return runner

        return wrap
