"""InvertedIndex build/update vs an exact oracle, for all strategy sets."""

import numpy as np
import pytest

from repro.core.inverted_index import InvertedIndex
from repro.core.io_sim import BlockDevice, PackedWriteDevice
from repro.core.strategies import StrategyConfig


def gen_parts(n_keys=200, n_parts=3, docs_per_part=150, seed=0):
    rng = np.random.RandomState(seed)
    parts, doc0 = [], 0
    for _ in range(n_parts):
        part = {}
        for k in range(n_keys):
            n = max(1, int(2000 / (k + 1)))
            d = np.sort(rng.randint(doc0, doc0 + docs_per_part, n))
            p = rng.randint(0, 3000, n)
            a = np.stack([d, p], 1)
            part[("k", k)] = a[np.lexsort((a[:, 1], a[:, 0]))]
        parts.append(part)
        doc0 += docs_per_part
    return parts


def build(setname, parts, cluster=2048, fl_area_clusters=64, **kw):
    cfg = getattr(StrategyConfig, setname)(cluster_size=cluster, **kw)
    dev = (
        PackedWriteDevice(cluster_size=cluster)
        if cfg.use_ds
        else BlockDevice(cluster_size=cluster)
    )
    idx = InvertedIndex(cfg, dev, n_groups=4, fl_area_clusters=fl_area_clusters)
    for part in parts:
        idx.add_part(part)
    return idx, dev


def oracle_of(parts):
    acc = {}
    for part in parts:
        for k, v in part.items():
            acc.setdefault(k, []).append(v)
    out = {}
    for k, vs in acc.items():
        a = np.concatenate(vs, 0)
        out[k] = a[np.lexsort((a[:, 1], a[:, 0]))]
    return out


@pytest.mark.parametrize("setname", ["set1", "set2", "set3"])
def test_lookup_matches_oracle(setname):
    parts = gen_parts()
    idx, _ = build(setname, parts)
    want = oracle_of(parts)
    for k, w in want.items():
        g = idx.lookup(k)
        g = g[np.lexsort((g[:, 1], g[:, 0]))]
        assert g.shape == w.shape, (k, g.shape, w.shape)
        assert (g == w).all(), k


def test_missing_key_empty():
    parts = gen_parts(n_keys=5, n_parts=1)
    idx, _ = build("set2", parts)
    assert idx.lookup(("nope", 404)).shape == (0, 2)


def test_update_is_in_place_no_merge():
    """Method 2 (paper 2.2): updating must not rewrite the whole index."""
    parts = gen_parts(n_keys=100, n_parts=4, seed=2)
    cfg = StrategyConfig.set2(cluster_size=2048)
    dev = BlockDevice(cluster_size=2048)
    idx = InvertedIndex(cfg, dev, n_groups=4, fl_area_clusters=64)
    idx.add_part(parts[0])
    build_bytes = dev.stats.total_bytes
    for p in parts[1:]:
        idx.add_part(p)
    update_bytes = dev.stats.total_bytes - build_bytes
    # if updates merged the whole index, update traffic would be
    # ~n_updates x index size; in-place updates keep it within a small
    # multiple of the data added
    assert update_bytes < 12 * build_bytes


def test_strategy_set_trends():
    """The paper's headline: set2 moves fewer bytes than set1; set3 does
    fewer write ops than set2 (Tables 2, 3)."""
    parts = gen_parts(n_keys=400, n_parts=3, seed=5)
    stats = {}
    for s in ("set1", "set2", "set3"):
        idx, dev = build(s, parts, fl_area_clusters=16)
        stats[s] = dev.stats.snapshot()
    assert stats["set2"].total_bytes < stats["set1"].total_bytes
    assert stats["set3"].write_ops < stats["set2"].write_ops


def test_tag_extraction_preserves_postings():
    rng = np.random.RandomState(1)
    cfg = StrategyConfig.set2(cluster_size=2048, tag_extract_bytes=256)
    dev = BlockDevice(cluster_size=2048)
    idx = InvertedIndex(cfg, dev, n_groups=2, fl_area_clusters=16)
    parts = gen_parts(n_keys=50, n_parts=3, seed=9)
    want = oracle_of(parts)
    for p in parts:
        idx.add_part(p)
    assert idx.n_extractions > 0, "test should exercise extraction"
    for k, w in want.items():
        g = idx.lookup(k)
        g = g[np.lexsort((g[:, 1], g[:, 0]))]
        assert (g == w).all(), k


def test_search_ops_bounded_by_chain_limit():
    cfg = StrategyConfig.set2(cluster_size=1024, chain_limit=5)
    dev = BlockDevice(cluster_size=1024)
    idx = InvertedIndex(cfg, dev, n_groups=2, fl_area_clusters=8)
    parts = gen_parts(n_keys=30, n_parts=6, seed=3)
    for p in parts:
        idx.add_part(p)
    for k in parts[0]:
        e = idx.dict.get(k)
        if e is not None and e.kind == "own":
            s = idx.mgr.streams[e.sid]
            if s.state == "ch":
                assert len(s.segments) <= s.chain_limit
