"""HLO call-graph analyzer: exactness on hand-computable programs.

This analyzer produces the roofline numbers (EXPERIMENTS.md), so its
trip-count multiplication and flop counting must be exact where XLA's
cost_analysis is not (while bodies counted once)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_graph import analyze


def _compile(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops_exact():
    M, N, K = 64, 128, 256
    hlo = _compile(lambda a, b: a @ b, (M, K), (K, N))
    r = analyze(hlo, 1)
    assert abs(r["dot_flops"] / (2 * M * N * K) - 1) < 1e-9


def test_scan_trip_count_multiplied():
    L, Mm = 17, 32

    def scanfn(x, ws):
        def body(c, w):
            return c @ w, None

        return jax.lax.scan(body, x, ws)[0]

    hlo = _compile(scanfn, (Mm, Mm), (L, Mm, Mm))
    r = analyze(hlo, 1)
    assert abs(r["dot_flops"] / (L * 2 * Mm**3) - 1) < 1e-9


def test_nested_scan():
    L, Mm, outer = 5, 16, 3

    def nested(x, ws):
        def outer_body(c, _):
            def body(cc, w):
                return cc @ w, None

            return jax.lax.scan(body, c, ws)[0], None

        return jax.lax.scan(outer_body, x, None, length=outer)[0]

    hlo = _compile(nested, (Mm, Mm), (L, Mm, Mm))
    r = analyze(hlo, 1)
    assert abs(r["dot_flops"] / (outer * L * 2 * Mm**3) - 1) < 1e-9


def test_grad_of_scan_is_3x_forward():
    L, Mm = 8, 16

    def lossfn(x, ws):
        def body(c, w):
            return c @ w, None

        return jnp.sum(jax.lax.scan(body, x, ws)[0])

    hlo = _compile(jax.grad(lossfn, argnums=1), (Mm, Mm), (L, Mm, Mm))
    r = analyze(hlo, 1)
    fwd = L * 2 * Mm**3
    assert abs(r["dot_flops"] / (3 * fwd) - 1) < 0.01


def test_collective_detection_and_wire_bytes():
    import os

    devices = jax.devices()
    if len(devices) < 2:
        pytest.skip("needs >1 device (run under forced host device count)")
    mesh = jax.make_mesh((len(devices),), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    xs = NamedSharding(mesh, P("d", None))
    f = jax.jit(
        lambda x: jnp.sum(x, axis=0),
        in_shardings=(xs,),
        out_shardings=NamedSharding(mesh, P()),
    )
    with mesh:
        hlo = f.lower(
            jax.ShapeDtypeStruct((len(devices) * 8, 32), jnp.float32)
        ).compile().as_text()
    r = analyze(hlo, len(devices))
    assert r["collectives"]["counts"].get("all-reduce", 0) >= 1
    n = len(devices)
    res = r["collectives"]["result_bytes"]["all-reduce"]
    wire = r["collectives"]["wire_bytes"]["all-reduce"]
    assert abs(wire - 2 * (n - 1) / n * res) < 1e-6


def test_memory_bytes_slicing_not_overcounted():
    """A scan that slices a big stacked array must charge slice windows,
    not the whole array per iteration."""
    L, Mm = 64, 32

    def scanfn(x, ws):
        def body(c, w):
            return c + w, None

        return jax.lax.scan(body, x, ws)[0]

    hlo = _compile(scanfn, (Mm, Mm), (L, Mm, Mm))
    r = analyze(hlo, 1)
    full = L * Mm * Mm * 4
    # bytes should be O(L x slice) ~ a small multiple of the array size,
    # NOT O(L x full array) = L x full
    assert r["hbm_bytes"] < 8 * full, (r["hbm_bytes"], full)
