"""Extent allocator + block device accounting properties."""

import numpy as np
from tests._hypothesis_compat import given, settings, strategies as st

from repro.core.cluster_store import ExtentAllocator
from repro.core.io_sim import BlockDevice, PackedWriteDevice


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=32), min_size=1, max_size=60))
def test_alloc_no_overlap(lengths):
    alloc = ExtentAllocator()
    live = []
    for i, ln in enumerate(lengths):
        start = alloc.alloc(ln)
        for s, l in live:
            assert start + ln <= s or start >= s + l, "overlapping extents"
        live.append((start, ln))
        if i % 3 == 2:  # free every third allocation
            s, l = live.pop(len(live) // 2)
            alloc.free(s, l)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=16), min_size=2, max_size=40))
def test_free_then_realloc_reuses(lengths):
    alloc = ExtentAllocator()
    starts = [alloc.alloc(l) for l in lengths]
    hw = alloc.capacity_high_water
    for s, l in zip(starts, lengths):
        alloc.free(s, l)
    # everything freed and coalesced: next alloc of total size fits in-place
    total = sum(lengths)
    s = alloc.alloc(total)
    assert s == 0, "coalescing failed"
    assert alloc.capacity_high_water == hw


def test_device_contiguity_accounting():
    dev = BlockDevice(cluster_size=1024)
    dev.read_clusters([5, 6, 7, 10, 11, 42])  # 3 runs
    assert dev.stats.read_ops == 3
    assert dev.stats.read_bytes == 6 * 1024
    dev.write_clusters(range(100, 164))  # 1 run
    assert dev.stats.write_ops == 1
    assert dev.stats.write_bytes == 64 * 1024


def test_packed_device_elides_small_writes():
    dev = PackedWriteDevice(cluster_size=1024, small_threshold=1024, buffer_size=8192)
    for cid in range(0, 64, 2):  # 32 scattered single-cluster writes
        dev.write_clusters([cid])
    dev.flush()
    # 32 KB of small writes in 8 KB buffers -> 4 flush ops, not 32
    assert dev.stats.write_ops == 4
    assert dev.stats.write_bytes == 32 * 1024
    assert len(dev.mapping) == 32  # the paper's A->a mapping table

    big = BlockDevice(cluster_size=1024)
    for cid in range(0, 64, 2):
        big.write_clusters([cid])
    assert big.stats.write_ops == 32  # what DS saves
