"""Shared correctness scaffolding for the read-stack test suites.

One home for the oracles and equivalence helpers that were duplicated
across ``test_multi_key.py``, ``test_search_service.py`` and
``test_sharded_set.py`` — so every future route/executor lands
pre-verified against the same brute-force references:

  * :func:`oracle_phrase` — the token-stream phrase oracle: scans the raw
    corpus, no index involved, honoring every lemma reading;
  * :func:`words_of_class` / :func:`mixed_queries` — per-class word pools
    and the canonical mixed multi-route query stream;
  * :func:`spec_to_query` / :data:`QUERY_SPEC` — the hypothesis query
    strategy shared by the cross-backend and cross-shard property suites;
  * :func:`assert_results_identical` — the element-wise QueryResult
    equivalence check (docs, witnesses, lookups, scanned, route, scores);
  * :func:`topk_head` — the exhaustive executor's sorted head, i.e. what
    a ``Query(top_k=N)`` result must equal element-wise;
  * :func:`run_live_update_rounds` — the incremental-update oracle: one
    LIVE substrate served while collection parts land, checked after
    every part against a from-scratch rebuild of the same prefix.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from tests._hypothesis_compat import strategies as st

from repro.core.lexicon import FREQUENT, OTHER, STOP
from repro.search import Query, QueryResult


# --------------------------------------------------------- lemma readings --
def readings(lex, token) -> set:
    """Every lemma id a token can read as (primary, secondary, unknown)."""
    token = int(token)
    if token >= lex.known_cutoff:
        return {lex.n_lemmas + token}
    out = {int(lex.lemma1[token])}
    if lex.lemma2[token] >= 0:
        out.add(int(lex.lemma2[token]))
    return out


def word_for_lemma(lex) -> dict:
    """lemma id -> some word whose PRIMARY reading is that lemma."""
    inv = {}
    for w in range(lex.n_words):
        l = int(lex.lemma1[w])
        if l >= 0 and l not in inv:
            inv[l] = w
    for w in range(lex.known_cutoff, lex.n_words):
        inv[lex.n_lemmas + w] = w
    return inv


# --------------------------------------------------- brute-force oracles --
def oracle_phrase(lex, parts, words, doc0: int = 0) -> set:
    """Scan the raw token stream: every (doc, start) where word j's
    primary lemma is among the readings of token start+j."""
    lemmas, _ = lex.classify_words(np.asarray(words, np.int64))
    hits = set()
    base = doc0
    for toks, offs in parts:
        for d in range(offs.shape[0] - 1):
            s, e = int(offs[d]), int(offs[d + 1])
            for p in range(e - s - len(words) + 1):
                if all(
                    int(lemmas[j]) in readings(lex, toks[s + p + j])
                    for j in range(len(words))
                ):
                    hits.add((base + d, p))
        base += offs.shape[0] - 1
    return hits


# ---------------------------------------------------------- query streams --
def words_of_class(lex, cls, n: int = 12) -> List[int]:
    out = []
    for w in range(lex.n_words):
        l = lex.lemma1[w]
        if l >= 0 and lex.lemma_class[l] == cls:
            out.append(int(w))
            if len(out) == n:
                break
    return out


def class_pools(lex) -> dict:
    """The {STOP, FREQUENT, OTHER} word pools the query builders draw on."""
    return {cls: words_of_class(lex, cls) for cls in (STOP, FREQUENT, OTHER)}


def mixed_queries(lex, n: int = 64, seed: int = 5) -> List[List[int]]:
    """>= n queries hitting all three proximity planner routes, with
    repeats so a batch exercises lookup dedup and the posting cache."""
    rng = np.random.RandomState(seed)
    stop = words_of_class(lex, STOP)
    freq = words_of_class(lex, FREQUENT)
    other = words_of_class(lex, OTHER)
    qs = []
    while len(qs) < n:
        kind = len(qs) % 4
        if kind == 0:
            qs.append([rng.choice(stop), rng.choice(stop)])
        elif kind == 1:
            qs.append([rng.choice(stop), rng.choice(stop), rng.choice(stop)])
        elif kind == 2:
            qs.append([rng.choice(freq), rng.choice(other)])
        else:
            pool = rng.choice(other, size=rng.randint(2, 4), replace=False)
            qs.append([int(w) for w in pool])
    return [[int(w) for w in q] for q in qs]


# hypothesis strategy for one drawn query: (kind, pool picks, phrase
# anchor, window, phrase-kind randomizer) — decoded by spec_to_query
QUERY_SPEC = st.tuples(
    st.integers(0, 5),        # query kind
    st.integers(0, 11),       # word pool picks
    st.integers(0, 11),
    st.integers(0, 11),
    st.integers(0, 100_000),  # phrase anchor in the token stream
    st.integers(1, 3),        # window
    st.integers(0, 1),        # phrase-kind randomizer
)


def spec_to_query(spec, toks, pools) -> Query:
    """Decode one :data:`QUERY_SPEC` draw against a corpus + word pools.

    Kinds 0-3 are the proximity routes (stop pair/triple, freq+other,
    other pair/triple); kinds 4-5 lift 3-5 word phrases from the real
    token stream so they have occurrences."""
    kind, i, j, l, tpos, win, ph = spec
    stop, freq, other = pools[STOP], pools[FREQUENT], pools[OTHER]
    window = win if ph == 0 else None
    if kind == 0:
        return Query((stop[i], stop[j]), window)
    if kind == 1:
        return Query((stop[i], stop[j], stop[l]), window)
    if kind == 2:
        return Query((freq[i], other[j]), window)
    if kind == 3:
        return Query((other[i], other[j], other[l]), window)
    L = 3 + (kind == 5) * (1 + l % 2)  # 3, 4 or 5 words
    s = tpos % (toks.shape[0] - L)
    return Query(tuple(int(t) for t in toks[s : s + L]), phrase=True)


def core_queries(toks, pools) -> List[Query]:
    """The fixed batch core guaranteeing all four planner routes appear."""
    stop, freq, other = pools[STOP], pools[FREQUENT], pools[OTHER]
    return [
        Query((stop[0], stop[1])),
        Query((stop[2], stop[3], stop[4])),
        Query((freq[0], other[0])),
        Query((other[1], other[2])),
        Query(tuple(int(t) for t in toks[5:8]), phrase=True),
        Query(tuple(int(t) for t in toks[9:13]), phrase=True),
    ]


# --------------------------------------------------- equivalence helpers --
def assert_results_identical(
    ref: QueryResult, got: QueryResult, ctx=None, check_route: bool = True,
    check_scanned: bool = True,
) -> None:
    """Element-wise QueryResult identity: docs, witnesses, lookups,
    postings_scanned, route and (when both carry them) scores.

    ``check_scanned=False`` relaxes only the postings_scanned count —
    needed when comparing a warm-cache streaming (top-k) execution to a
    cold one: a cache hit serves a whole list as one chunk, so early
    termination skips different amounts, while docs/witnesses/scores
    must stay identical."""
    if check_route:
        assert got.route == ref.route, (ctx, ref.route, got.route)
    assert np.array_equal(ref.docs, got.docs), ctx
    assert np.array_equal(ref.witnesses, got.witnesses), ctx
    assert ref.lookups == got.lookups, ctx
    if check_scanned:
        assert ref.postings_scanned == got.postings_scanned, ctx
    # scores are mandatory on every executor path: a side missing them
    # is a bug, not a comparison to skip
    assert (ref.scores is None) == (got.scores is None), ctx
    if ref.scores is not None:
        assert np.array_equal(ref.scores, got.scores), ctx


def topk_head(
    ref: QueryResult, k: int
) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """The exhaustive executor's sorted head: what ``Query(top_k=k)``
    must return — the first k docs (ascending doc id), their witness
    rows, and their per-doc scores."""
    docs = ref.docs[:k]
    wits = ref.witnesses[np.isin(ref.witnesses[:, 0], docs)]
    scores = None if ref.scores is None else ref.scores[:k]
    return docs, wits, scores


def assert_topk_matches_head(
    ref: QueryResult, got: QueryResult, k: int, ctx=None
) -> None:
    """``got`` (a top-k result) equals the exhaustive ``ref``'s head."""
    docs, wits, scores = topk_head(ref, k)
    assert got.route == ref.route, (ctx, ref.route, got.route)
    assert np.array_equal(got.docs, docs), (ctx, k)
    assert np.array_equal(got.witnesses, wits), (ctx, k)
    assert (scores is None) == (got.scores is None), (ctx, k)
    if scores is not None:
        assert np.array_equal(got.scores, scores), (ctx, k)
    assert got.lookups == ref.lookups, (ctx, k)


def ranked_oracle_head(
    ref: QueryResult, ranked_q: Query, ref_svc, k: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Exhaustive score-then-sort oracle for ``Query(top_k=k, rank=...)``.

    Scores EVERY matched doc of the exhaustive (unranked) result ``ref``
    from whole-list lookups — no cursors, no streaming, no pruning — and
    selects the head with the shared deterministic (score desc, doc id
    asc) rule.  The only code shared with the executor is the scoring
    arithmetic and the tie rule (both are THE definition); the counting
    path is independent (binary searches over raw ``reader.lookup``
    lists vs the executor's settled regions)."""
    from repro.search.scoring import doc_counts, head_order, score_docs

    pq = ref_svc.plan([ranked_q]).queries[0]
    assert pq.score_spec is not None
    counts = [
        doc_counts(ref.docs, ref_svc.reader.lookup(lk.index, lk.key))
        for lk in pq.lookups
    ]
    scores = score_docs(counts, pq.score_spec)
    order = head_order(ref.docs, scores, k, ranked=True)
    docs = ref.docs[order]
    wits = ref.witnesses[np.isin(ref.witnesses[:, 0], docs)]
    return docs, wits, scores[order]


def assert_ranked_matches_oracle(
    ref: QueryResult, got: QueryResult, ranked_q: Query, ref_svc, ctx=None
) -> None:
    """``got`` (a ranked top-k result) is element-wise identical — docs,
    scores, tie order, witnesses — to the exhaustive ranked oracle."""
    k = ranked_q.top_k
    docs, wits, scores = ranked_oracle_head(ref, ranked_q, ref_svc, k)
    assert got.route == ref.route, (ctx, ref.route, got.route)
    assert np.array_equal(got.docs, docs), (ctx, k)
    assert got.scores is not None, (ctx, k)
    assert np.array_equal(got.scores, scores), (ctx, k)
    assert np.array_equal(got.witnesses, wits), (ctx, k)
    assert got.lookups == ref.lookups, (ctx, k)


# ------------------------------------------------ incremental-update oracle --
def run_live_update_rounds(
    make_substrate,
    parts,
    doc_starts,
    queries: Sequence[Query],
    backends: Sequence[str] = ("numpy",),
    cache_bytes: int = 1 << 20,
    window: int = 3,
    ctx=None,
    compact_after: Sequence[int] = (),
):
    """The incremental-update oracle (the paper's *easily updatable*
    property exercised at serving time).

    ONE live substrate is served by a persistent ``SearchService`` per
    backend — its readers, posting cache and cursors survive every
    update — while collection parts land one at a time through
    ``add_documents``.  After EVERY part, each live service's batch must
    be element-wise identical to a from-scratch rebuild of the same
    prefix served cold (docs, witnesses, lookups, routes, scores; the
    postings_scanned count is relaxed only for ``top_k`` queries, where
    a warm cache legitimately changes how much the streaming stage
    fetches before terminating).

    ``compact_after`` lists part indexes after which the LIVE substrate
    is compacted (the fresh rebuild never is) — identity across the
    asymmetry proves results, scores and ranked heads are transparent to
    background compaction.

    Returns the live services keyed by backend (callers can inspect
    their traces/cache stats afterwards)."""
    from repro.search import SearchService

    live = make_substrate()
    svcs = {
        b: SearchService(live, window=window, backend=b,
                         cache_bytes=cache_bytes)
        for b in backends
    }
    compact_after = set(compact_after)
    for i, ((toks, offs), d0) in enumerate(zip(parts, doc_starts)):
        live.add_documents(toks, offs, d0)
        if i in compact_after:
            live.compact()
        fresh = make_substrate()
        for (t2, o2), dd in zip(parts[: i + 1], doc_starts[: i + 1]):
            fresh.add_documents(t2, o2, dd)
        ref_svc = SearchService(fresh, window=window, backend="numpy",
                                cache_bytes=cache_bytes)
        ref = ref_svc.search_batch(queries)
        for b, svc in svcs.items():
            got = svc.search_batch(queries)
            for qi, (r, g) in enumerate(zip(ref, got)):
                assert_results_identical(
                    r, g,
                    ctx=(ctx, "backend", b, "part", i, "query", qi),
                    check_scanned=queries[qi].top_k is None,
                )
        # durable substrates hold a WAL file open; release each round's
        # throwaway rebuild (and the live one below) so dev-mode runs
        # stay ResourceWarning-clean
        closer = getattr(fresh, "close", None)
        if closer is not None:
            closer()
    closer = getattr(live, "close", None)
    if closer is not None:
        closer()
    return svcs
