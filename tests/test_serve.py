"""Serving engine: continuous batching, slot reuse, bounded paged-KV."""

import numpy as np

import jax

from repro.configs.registry import get_bundle
from repro.serve.engine import Request, ServeEngine


def test_engine_serves_all_requests_with_bounded_kv():
    bundle = get_bundle("granite-3-2b", reduced=True)
    cfg = bundle.config
    params = bundle.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=3, s_max=96,
                         page_size=8, chain_limit=3)
    rng = np.random.RandomState(0)
    n_req = 7
    for i in range(n_req):
        engine.submit(Request(
            req_id=i,
            prompt=rng.randint(0, cfg.vocab, 16).astype(np.int32),
            max_new_tokens=8,
        ))
    done = engine.run_until_done(max_steps=200)
    assert len(done) == n_req
    for r in done:
        assert len(r.out_tokens) == 8
    s = engine.stats()
    assert s["kv"]["max_gather_depth"] <= 3
    # continuous batching actually multiplexed the slots
    assert s["steps"] < n_req * 8


def test_engine_deterministic_outputs():
    bundle = get_bundle("granite-3-2b", reduced=True)
    cfg = bundle.config
    params = bundle.init(jax.random.PRNGKey(0))
    prompt = np.arange(12, dtype=np.int32) % cfg.vocab

    def serve_once():
        e = ServeEngine(cfg, params, batch_slots=2, s_max=64, page_size=8)
        e.submit(Request(req_id=0, prompt=prompt, max_new_tokens=6))
        done = e.run_until_done(max_steps=50)
        return done[0].out_tokens

    assert serve_once() == serve_once()
