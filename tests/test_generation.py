"""Published-generation accounting: the aliasing regressions.

The snapshot coordinate every reader, cursor, trace and manifest pins
used to be the physical part counter ``n_parts`` — which ALIASES: a
checkpoint reopen bulk-applies collapsed state (one physical part
standing in for the whole checkpointed history), so a reopened
substrate reported generation coordinates that collided with ancient
pre-checkpoint ones.  These tests pin the fixed contract:

  * ``generation`` is a PUBLISHED monotone counter decoupled from
    ``n_parts`` — a checkpoint reopen restores it from the manifest, so
    snapshot coordinates survive close/reopen exactly;
  * ``generation_vector`` is per-index (and per-shard per-index on a
    sharded set): a summed scalar cannot distinguish WHICH index moved,
    the vector can;
  * a mid-batch advance — an update or a single index's background
    compaction — trips ``SnapshotViolationError``;
  * ``IndexReader.refresh()`` keyed on the published generation stays
    targeted across compact-then-update sequences (each advance's
    digest lands in the same history, so the reader invalidates exactly
    the touched keys twice instead of falling back);
  * ``restore_generation`` is forward-only and clears the digest
    history (the collapsed span has no per-generation digests), so the
    first refresh across a restore is the namespace drop — never a
    bogus targeted pass against mismatched digests.
"""

import functools

import numpy as np
import pytest

from repro.core.lexicon import make_lexicon
from repro.core.sharded_set import ShardedTextIndexSet
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig, TextIndexSet
from repro.data.corpus import generate_part
from repro.search import SearchService, SnapshotViolationError
from repro.search.join import numpy_window_join
from repro.store import DurableIndexStore
from tests.oracles import assert_results_identical, class_pools, core_queries


def _cfg():
    return IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=1024,
                                     tag_extract_bytes=512),
        fl_area_clusters=64,
    )


@functools.lru_cache(maxsize=None)
def _world():
    lex = make_lexicon(
        n_words=3000, n_lemmas=1300, n_stop=20, n_frequent=120, seed=53
    )
    parts = [
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=0, seed=70),
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=40, seed=71),
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=80, seed=72),
    ]
    queries = core_queries(parts[0][0], class_pools(lex))
    return lex, parts, queries


# --------------------------------------------------- reopen restoration --
@pytest.mark.parametrize("n_shards", (1, 2))
def test_reopen_restores_published_generation_vector(tmp_path, n_shards):
    """THE aliasing regression: a checkpoint reopen collapses physical
    part counts (the bulk apply is one part), but the PUBLISHED
    generation vector must come back from the manifest exactly — a
    reader or replica holding pre-close snapshot coordinates would
    otherwise observe colliding generations."""
    lex, parts, _ = _world()
    store = DurableIndexStore(tmp_path / "s", _cfg(), lex,
                              n_shards=n_shards, fsync=False)
    store.add_documents(*parts[0], 0)
    store.add_documents(*parts[1], 40)
    store.compact()
    store.add_documents(*parts[2], 80)
    gens = store.generation_vector()
    # several generations published per index by now
    assert all(g >= 3 for row in gens for g in row)
    store.checkpoint()
    store.close()

    reopened = DurableIndexStore(tmp_path / "s", _cfg(), lex,
                                 n_shards=n_shards, fsync=False,
                                 recovery="checkpoint")
    assert reopened.generation_vector() == gens
    # the physical counter really did collapse — the published counter
    # is the thing doing the work here, not an n_parts mirror
    for shard in getattr(reopened.set, "shards", [reopened.set]):
        for idx in shard.indexes.values():
            assert idx.n_parts < idx.generation
    # and publication continues monotonically past the restored point
    reopened.add_documents(*parts[0], 120)
    after = reopened.generation_vector()
    assert all(
        a == g + 1 for row_a, row_g in zip(after, gens)
        for a, g in zip(row_a, row_g)
    )
    reopened.close()


def test_restore_generation_is_forward_only_and_clears_digests():
    lex, parts, _ = _world()
    ts = TextIndexSet(_cfg(), lex, seed=0)
    ts.add_documents(*parts[0], 0)
    idx = next(iter(ts.indexes.values()))
    g = idx.generation
    with pytest.raises(ValueError, match="backwards"):
        idx.restore_generation(g - 1)
    idx.restore_generation(g)  # no-op restore keeps the digest history
    assert idx.digests_since(g - 1) is not None
    idx.restore_generation(g + 5)  # a jump clears it: the collapsed
    assert idx.generation == g + 5  # span has no per-generation digests
    assert idx.digests_since(g) is None
    assert idx.digests_since(g + 5) == []


# ------------------------------------------------------ per-index vector --
def test_vector_distinguishes_which_index_moved():
    """A summed scalar says only THAT something advanced; the per-index
    vector says WHICH index — the difference between dropping one cache
    namespace and guessing."""
    lex, parts, _ = _world()
    ts = TextIndexSet(_cfg(), lex, seed=0)
    ts.add_documents(*parts[0], 0)
    ts.add_documents(*parts[1], 40)
    names = list(ts.indexes.keys())
    v0 = ts.generation_vector()
    assert len(v0) == len(names)

    # advance exactly ONE index: a part carrying rows for it alone (the
    # live-update primitive — indexes with empty maps are never touched)
    moved = 0
    idx = ts.indexes[names[moved]]
    key = next(iter(idx.dict.entries))
    rows = np.array([[100_000, 1], [100_000, 5]], dtype=np.int64)
    assert idx.add_part({key: rows}) == frozenset([key])
    v1 = ts.generation_vector()
    assert v1[moved] == v0[moved] + 1
    assert [g for i, g in enumerate(v1) if i != moved] == [
        g for i, g in enumerate(v0) if i != moved
    ]
    # the scalar sum sees +1 and cannot name the index
    assert sum(v1) == sum(v0) + 1


@pytest.mark.parametrize("n_shards", (1, 2))
def test_mid_batch_advance_raises_snapshot_violation(n_shards):
    """A writer advancing ANY index of ANY shard while a batch executes
    against its pinned snapshot must refuse to return torn results."""
    lex, parts, queries = _world()
    if n_shards == 1:
        sub = TextIndexSet(_cfg(), lex, seed=0)
    else:
        sub = ShardedTextIndexSet(_cfg(), lex, n_shards=n_shards, seed=0)
    sub.add_documents(*parts[0], 0)

    fired = [False]

    def mutating_join(a, b, w):
        if not fired[0]:
            fired[0] = True
            # concurrent advance + compact across two indexes: the sum
            # moves, the vector names both moved indexes
            sub.add_documents(*parts[1], 40)
            for shard in getattr(sub, "shards", [sub]):
                for idx in shard.indexes.values():
                    idx.compact()
        return numpy_window_join(a, b, w)

    svc = SearchService(sub, window=3, backend=mutating_join)
    with pytest.raises(SnapshotViolationError):
        svc.search_batch(queries)
    assert fired[0]


# ------------------------------------------- refresh stays targeted --
def test_compact_then_update_refresh_stays_targeted():
    """Two advances between refreshes — a compaction cycle, then an
    update part — must both resolve through the digest history: the
    reader invalidates exactly the touched keys (twice), never the
    whole namespace, and serves the post-update truth."""
    lex, parts, queries = _world()
    ts = TextIndexSet(_cfg(), lex, seed=0)
    ts.add_documents(*parts[0], 0)
    svc = SearchService(ts, window=3, backend="numpy")
    svc.search_batch(queries)  # warm the cache at generation v0

    ts.compact()
    ts.add_documents(*parts[1], 40)

    reader = svc.reader
    cs = reader.cache_stats
    drops0, inv0 = cs.full_drops, cs.invalidations
    modes = [r.refresh() for r in reader.readers.values()]
    assert set(modes) == {"targeted"}, modes
    assert cs.full_drops == drops0
    assert cs.invalidations > inv0  # touched keys really were dropped

    got = svc.search_batch(queries)
    ref = SearchService(ts, window=3, backend="numpy").search_batch(queries)
    for qi, (a, b) in enumerate(zip(ref, got)):
        assert_results_identical(a, b, ctx=("compact-then-update", qi),
                                 check_scanned=False)


def test_refresh_across_generation_restore_is_full_drop():
    """A reader pinned BELOW a restored generation has no digest
    coverage (the restore cleared the history): refresh must take the
    namespace drop, not a bogus targeted pass."""
    lex, parts, queries = _world()
    ts = TextIndexSet(_cfg(), lex, seed=0)
    ts.add_documents(*parts[0], 0)
    svc = SearchService(ts, window=3, backend="numpy")
    svc.search_batch(queries)
    for idx in ts.indexes.values():
        idx.restore_generation(idx.generation + 3)
    modes = [r.refresh() for r in svc.reader.readers.values()]
    assert set(modes) == {"full_drop"}, modes
