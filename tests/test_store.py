"""Durable on-disk storage backend (``repro.store``).

The storage-oracle suite: ONE op script (update parts, a background
compaction cycle, a crash that tears the WAL tail mid-record) drives
both the plain ``io_sim``-backed substrate and the disk-backed
:class:`DurableIndexStore`, and the two must serve element-wise
identical results with identical simulated read-byte charges across all
four planner routes at every shard count — the disk backend's replay
recovery reproduces the crashed substrate's physical stream layout, so
the charge model is preserved exactly.  Plus:

  * WAL framing: torn tails truncated at the first bad frame, never a
    partially visible record; appends continue after recovery;
  * segment files: CRC-verified snapshot roundtrip, corruption detected,
    checkpoint writing charges NO simulated device I/O;
  * crash-recovery property test: random truncation offsets land the
    reopened store exactly on the last published prefix (checkpoint +
    intact WAL tail), element-wise identical to a rebuild;
  * the store is a drop-in live substrate for
    :func:`tests.oracles.run_live_update_rounds`;
  * durability is charge-neutral: WAL + checkpoints never touch the
    simulated devices.
"""

import functools
import itertools

import numpy as np
import pytest

from repro.core.lexicon import make_lexicon
from repro.core.sharded_set import ShardedTextIndexSet
from repro.core.strategies import StrategyConfig
from repro.core.text_index import IndexSetConfig
from repro.data.corpus import generate_part
from repro.search import (
    ROUTE_MULTI,
    ROUTE_ORDINARY,
    ROUTE_STOPSEQ,
    ROUTE_WV,
    Query,
    SearchService,
)
from repro.store import (
    DurableIndexStore,
    SegmentCorruptError,
    WriteAheadLog,
    read_segment,
    snapshot_state,
    write_segment,
)
from repro.store.format import (
    decode_key,
    decode_part_maps,
    decode_part_tokens,
    encode_key,
    encode_part_maps,
    encode_part_tokens,
)
from repro.store.wal import HEADER_BYTES
from tests.oracles import (
    assert_results_identical,
    class_pools,
    core_queries,
    run_live_update_rounds,
)

SHARD_COUNTS = (1, 2, 4)


def _cfg(**kw):
    # tag_extract_bytes low enough that hot keys own dedicated streams at
    # this corpus scale, so the op scripts' compaction cycles really fold
    return IndexSetConfig(
        strategy=StrategyConfig.set2(cluster_size=1024,
                                     tag_extract_bytes=512),
        fl_area_clusters=64,
        **kw,
    )


@functools.lru_cache(maxsize=None)
def _world():
    lex = make_lexicon(
        n_words=3000, n_lemmas=1300, n_stop=20, n_frequent=120, seed=43
    )
    parts = [
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=0, seed=80),
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=40, seed=81),
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=80, seed=82),
        generate_part(lex, n_docs=40, avg_doc_len=110, doc0=120, seed=83),
    ]
    doc_starts = [0, 40, 80, 120]
    pools = class_pools(lex)
    queries = core_queries(parts[0][0], pools)
    return lex, parts, doc_starts, queries


def _io_sig(report):
    """An IOStats report as a comparable value."""
    return {
        name: (st.read_bytes, st.read_ops, st.write_bytes, st.write_ops)
        for name, st in report.items()
    }


# ------------------------------------------------------------ format codecs --
def test_key_codec_roundtrip():
    keys = [
        0, 7, -3, (1 << 62), np.int64(12345),
        "word", b"\x00\xff raw", (1, 2, 3), ("mixed", 5, b"x"), (),
    ]
    for k in keys:
        buf = encode_key(k)
        got, off = decode_key(buf, 0)
        assert off == len(buf)
        expect = int(k) if isinstance(k, np.integer) else k
        assert got == expect and type(got) is type(expect)
    with pytest.raises(TypeError):
        encode_key(1.5)


def test_part_codecs_roundtrip():
    a = np.array([[1, 4], [1, 9], [5, 0]], dtype=np.int64)
    b = np.array([[0, 2]], dtype=np.int64)
    maps = {"known": {5: a, (1, 2): b}, "unknown": {}}
    got = decode_part_maps(encode_part_maps(maps))
    assert set(got) == {"known", "unknown"} and set(got["known"]) == {5, (1, 2)}
    assert np.array_equal(got["known"][5], a)
    assert np.array_equal(got["known"][(1, 2)], b)
    assert got["unknown"] == {}

    toks = np.arange(37, dtype=np.int64)
    offs = np.array([0, 10, 37], dtype=np.int64)
    d0, t2, o2 = decode_part_tokens(encode_part_tokens(9, toks, offs))
    assert d0 == 9 and np.array_equal(t2, toks) and np.array_equal(o2, offs)


# ------------------------------------------------------------------- the WAL --
def _recover(path, start=0):
    """Open a throwaway WAL, recover, and CLOSE it (dev-mode runs treat
    a leaked BufferedWriter as a ResourceWarning)."""
    w = WriteAheadLog(path, fsync=False)
    try:
        return w.recover(start)
    finally:
        w.close()


def test_wal_torn_tail_truncated_and_appendable(tmp_path):
    path = tmp_path / "wal.log"
    w = WriteAheadLog(path, fsync=False)
    offs = [w.append(1, bytes([i]) * (20 + 7 * i)) for i in range(5)]
    w.close()

    recs, good, torn = _recover(path)
    assert len(recs) == 5 and not torn and good == offs[-1]

    # crash tore the last record: every cut inside it yields the same
    # recovered prefix — records 0..3, file truncated to their end
    with open(path, "rb+") as fh:
        fh.truncate(offs[-1] - 3)
    w3 = WriteAheadLog(path, fsync=False)
    recs, good, torn = w3.recover(0)
    assert [p for _, p in recs] == [bytes([i]) * (20 + 7 * i) for i in range(4)]
    assert torn and good == offs[3] == path.stat().st_size

    # the log keeps working after recovery: appends land at the cut
    end = w3.append(2, b"after")
    assert end == offs[3] + HEADER_BYTES + 5 == w3.tell()
    w3.close()
    recs, _, torn = _recover(path)
    assert [t for t, _ in recs] == [1, 1, 1, 1, 2] and not torn

    # a start offset beyond the physical end reports torn, yields nothing
    recs, good, torn = _recover(path, end + 100)
    assert recs == [] and good == end and torn


def test_wal_rejects_corrupted_payload(tmp_path):
    path = tmp_path / "wal.log"
    w = WriteAheadLog(path, fsync=False)
    w.append(1, b"a" * 50)
    mid = w.append(1, b"b" * 50)
    w.append(1, b"c" * 50)
    w.close()
    # flip one payload byte of the middle record: it AND everything after
    # must be discarded (a bad CRC means the tail cannot be trusted)
    with open(path, "rb+") as fh:
        fh.seek(mid - 10)
        fh.write(b"X")
    recs, good, torn = _recover(path)
    assert [p for _, p in recs] == [b"a" * 50] and torn
    assert good == path.stat().st_size


# -------------------------------------------------------------- segment files --
def test_segment_roundtrip_crc_and_charge_neutrality(tmp_path):
    lex, parts, doc_starts, _ = _world()
    sts = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    sts.add_documents(*parts[0], doc_starts[0])
    build0, search0 = _io_sig(sts.build_io()), _io_sig(sts.search_io())

    state = snapshot_state(sts)
    seg = tmp_path / "snap.seg"
    write_segment(seg, state)
    got = read_segment(seg)

    # checkpointing reads the substrate directly — zero simulated charges
    assert _io_sig(sts.build_io()) == build0
    assert _io_sig(sts.search_io()) == search0

    assert len(got) == 2
    for shard_state, got_state in zip(state, got):
        assert set(shard_state) == set(got_state)
        for name, by_key in shard_state.items():
            assert set(by_key) == set(got_state[name])
            for key, posts in by_key.items():
                assert np.array_equal(posts, got_state[name][key]), (name, key)

    # corruption and truncation are both detected by the CRC trailer
    data = seg.read_bytes()
    (tmp_path / "bad.seg").write_bytes(
        data[:100] + bytes([data[100] ^ 0xFF]) + data[101:]
    )
    with pytest.raises(SegmentCorruptError):
        read_segment(tmp_path / "bad.seg")
    (tmp_path / "short.seg").write_bytes(data[:-20])
    with pytest.raises(SegmentCorruptError):
        read_segment(tmp_path / "short.seg")
    with pytest.raises(SegmentCorruptError):
        read_segment(tmp_path / "missing.seg")


# -------------------------------------------------------- the storage oracle --
def _apply_ops(sub, ops, parts, doc_starts):
    for op in ops:
        if op[0] == "part":
            sub.add_documents(*parts[op[1]], doc_starts[op[1]])
        else:
            sub.compact()
    return sub


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_storage_oracle_sim_vs_disk(tmp_path, n_shards):
    """THE parity gate: the same op script — parts, one background
    compaction cycle, a mid-stream crash tearing the final part's WAL
    record — served by the io_sim substrate and by the disk backend
    must produce element-wise identical results AND identical simulated
    read charges on all four planner routes."""
    lex, parts, doc_starts, queries = _world()
    script = [("part", 0), ("part", 1), ("compact",), ("part", 2),
              ("part", 3)]
    published = script[:-1]  # the crash tears the final part's record

    # io_sim backend: its crash+reopen IS a replay of the published ops
    sim = _apply_ops(
        ShardedTextIndexSet(_cfg(), lex, n_shards=n_shards, seed=0),
        published, parts, doc_starts,
    )

    # disk backend: live through the WHOLE script, crash, replay-reopen
    store = _apply_ops(
        DurableIndexStore(tmp_path / "store", _cfg(), lex,
                          n_shards=n_shards, fsync=False),
        published, parts, doc_starts,
    )
    end_published = store.wal.tell()
    _apply_ops(store, script[-1:], parts, doc_starts)
    end_torn = store.wal.tell()
    store.close()
    wal = tmp_path / "store" / "wal.log"
    with open(wal, "rb+") as fh:  # the crash: a torn tail mid-record
        fh.truncate(end_published + (end_torn - end_published) // 2)
    store = DurableIndexStore(tmp_path / "store", _cfg(), lex,
                              n_shards=n_shards, fsync=False,
                              recovery="replay")
    assert store.recovery_info["torn"]
    assert store.recovery_info["truncated_bytes"] > 0

    # replay reproduces the published substrate's physical layout: same
    # generations, same stream-state census, same build charges
    assert store.generation_vector() == sim.generation_vector()
    assert store.census() == sim.census()
    assert _io_sig(store.build_io()) == _io_sig(sim.build_io())

    qs = list(queries) + [Query(queries[0].words, top_k=3)]

    def serve(sub):
        svc = SearchService(sub, window=3, backend="numpy",
                            cache_bytes=1 << 20)
        before = _io_sig(sub.search_io())
        res = svc.search_batch(qs)
        after = _io_sig(sub.search_io())
        charges = {
            n: tuple(a - b for a, b in zip(after[n], before[n]))
            for n in after
        }
        return res, charges

    r_sim, c_sim = serve(sim)
    r_disk, c_disk = serve(store)
    assert {ROUTE_ORDINARY, ROUTE_STOPSEQ, ROUTE_WV, ROUTE_MULTI} <= {
        r.route for r in r_sim
    }
    for qi, (a, b) in enumerate(zip(r_sim, r_disk)):
        assert_results_identical(
            a, b, ctx=("storage-oracle", n_shards, qi),
            check_scanned=qs[qi].top_k is None,
        )
    assert c_sim == c_disk, (n_shards, c_sim, c_disk)
    store.close()


# --------------------------------------------- crash-recovery property test --
@pytest.mark.parametrize("trial", range(4))
def test_crash_recovery_random_truncation(tmp_path, trial):
    """Truncate the WAL at a RANDOM byte offset: the reopened store must
    land exactly on the last published prefix — every fully appended
    part before the cut, plus everything an earlier checkpoint folded —
    element-wise identical to a from-scratch rebuild of that prefix."""
    rng = np.random.RandomState(900 + trial)
    lex, parts, doc_starts, queries = _world()
    root = tmp_path / "store"

    store = DurableIndexStore(root, _cfg(), lex, n_shards=2, fsync=False)
    part_ends = []
    ckpt_parts = 0
    for i, ((toks, offs), d0) in enumerate(zip(parts, doc_starts)):
        store.add_documents(toks, offs, d0)
        part_ends.append(store.wal.tell())
        if trial % 2 == 1 and i == 1:
            # odd trials compact (and so checkpoint) mid-stream: cuts
            # before the fold point must still recover parts 0..1
            store.compact()
            ckpt_parts = 2
    wal_size = store.wal.tell()
    store.close()

    cut = int(rng.randint(0, wal_size + 1))
    with open(root / "wal.log", "rb+") as fh:
        fh.truncate(cut)

    reopened = DurableIndexStore(root, _cfg(), lex, n_shards=2, fsync=False)
    wal_parts = sum(1 for e in part_ends if e <= cut)
    expected = max(ckpt_parts, wal_parts)

    fresh = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    for (toks, offs), d0 in zip(parts[:expected], doc_starts[:expected]):
        fresh.add_documents(toks, offs, d0)

    ref = SearchService(fresh, window=3, backend="numpy").search_batch(queries)
    got = SearchService(reopened, window=3, backend="numpy").search_batch(queries)
    for qi, (a, b) in enumerate(zip(ref, got)):
        assert_results_identical(
            a, b, check_route=True,
            ctx=("crash-recovery", trial, cut, expected, qi),
        )

    # the recovered store keeps serving updates: land the lost parts
    # again and it must agree with the full rebuild
    for (toks, offs), d0 in zip(parts[expected:], doc_starts[expected:]):
        reopened.add_documents(toks, offs, d0)
    full = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    for (toks, offs), d0 in zip(parts, doc_starts):
        full.add_documents(toks, offs, d0)
    ref = SearchService(full, window=3, backend="numpy").search_batch(queries)
    got = SearchService(reopened, window=3, backend="numpy").search_batch(queries)
    for qi, (a, b) in enumerate(zip(ref, got)):
        assert_results_identical(a, b, ctx=("post-recovery", trial, qi))
    reopened.close()


def test_corrupt_checkpoint_falls_back_to_full_replay(tmp_path):
    lex, parts, doc_starts, queries = _world()
    root = tmp_path / "store"
    store = DurableIndexStore(root, _cfg(), lex, n_shards=2, fsync=False)
    for (toks, offs), d0 in zip(parts[:3], doc_starts[:3]):
        store.add_documents(toks, offs, d0)
    store.compact()  # publishes a checkpoint
    assert store.n_checkpoints == 1
    store.close()

    seg = next((root / "segments").glob("ckpt-*.seg"))
    data = bytearray(seg.read_bytes())
    data[len(data) // 2] ^= 0xFF
    seg.write_bytes(bytes(data))

    reopened = DurableIndexStore(root, _cfg(), lex, n_shards=2, fsync=False)
    assert reopened.recovery_info["checkpoint_fallback"]
    assert not reopened.recovery_info["from_checkpoint"]
    # the fallback replay must reconstruct the full published state, and
    # the store re-publishes a good checkpoint for the next open
    assert reopened.n_checkpoints == 1
    fresh = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    for (toks, offs), d0 in zip(parts[:3], doc_starts[:3]):
        fresh.add_documents(toks, offs, d0)
    ref = SearchService(fresh, window=3, backend="numpy").search_batch(queries)
    got = SearchService(reopened, window=3, backend="numpy").search_batch(queries)
    for a, b in zip(ref, got):
        assert_results_identical(a, b, ctx="checkpoint-fallback")
    reopened.close()

    again = DurableIndexStore(root, _cfg(), lex, n_shards=2, fsync=False)
    assert again.recovery_info["from_checkpoint"]
    assert not again.recovery_info["checkpoint_fallback"]
    again.close()


def test_wal_shorter_than_manifest_offset_is_repaired(tmp_path):
    """A WAL physically shorter than the manifest's folded offset (all
    surviving records are already in the checkpoint) must recover to the
    checkpoint state, NOT double-apply the survivors — and re-publish a
    consistent (manifest, WAL) pair."""
    lex, parts, doc_starts, queries = _world()
    root = tmp_path / "store"
    store = DurableIndexStore(root, _cfg(), lex, n_shards=2, fsync=False)
    store.add_documents(*parts[0], doc_starts[0])
    store.add_documents(*parts[1], doc_starts[1])
    store.checkpoint()
    store.close()

    with open(root / "wal.log", "rb+") as fh:
        fh.truncate(40)  # far before the manifest's wal_offset

    reopened = DurableIndexStore(root, _cfg(), lex, n_shards=2, fsync=False)
    assert reopened.recovery_info["from_checkpoint"]
    assert reopened.recovery_info["wal_records"] == 0
    fresh = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    fresh.add_documents(*parts[0], doc_starts[0])
    fresh.add_documents(*parts[1], doc_starts[1])
    ref = SearchService(fresh, window=3, backend="numpy").search_batch(queries)
    got = SearchService(reopened, window=3, backend="numpy").search_batch(queries)
    for a, b in zip(ref, got):
        assert_results_identical(a, b, ctx="wal-behind-manifest")
    # invariant restored: a further clean reopen takes the checkpoint path
    reopened.close()
    again = DurableIndexStore(root, _cfg(), lex, n_shards=2, fsync=False)
    assert again.recovery_info["from_checkpoint"]
    assert not again.recovery_info["torn"]
    got = SearchService(again, window=3, backend="numpy").search_batch(queries)
    for a, b in zip(ref, got):
        assert_results_identical(a, b, ctx="wal-behind-manifest-reopen")
    again.close()


# ----------------------------------------------------- live-serving substrate --
def test_store_serves_live_update_rounds(tmp_path):
    """The durable store is a drop-in substrate for the shared
    incremental-update oracle: parts land through the WAL while a live
    service keeps answering, element-wise identical to rebuilds."""
    lex, parts, doc_starts, queries = _world()
    seq = itertools.count()

    def make():
        return DurableIndexStore(
            tmp_path / f"w{next(seq)}", _cfg(), lex, n_shards=2, fsync=False
        )

    run_live_update_rounds(
        make, parts[:3], doc_starts[:3], queries, backends=("numpy",),
        ctx=("durable-store",),
    )


def test_durability_is_charge_neutral(tmp_path):
    """WAL appends, fsyncs and checkpoints never touch the simulated
    devices: a store and a plain substrate fed the same parts report
    identical build and search charges."""
    lex, parts, doc_starts, _ = _world()
    sim = ShardedTextIndexSet(_cfg(), lex, n_shards=2, seed=0)
    store = DurableIndexStore(tmp_path / "s", _cfg(), lex, n_shards=2,
                              fsync=True)
    for sub in (sim, store):
        sub.add_documents(*parts[0], doc_starts[0])
        sub.add_documents(*parts[1], doc_starts[1])
    store.checkpoint()
    assert _io_sig(store.build_io()) == _io_sig(sim.build_io())
    keys = sorted(
        k for k, e in store.indexes["known"].dict.entries.items()
    )[:25]
    for sub in (sim, store):
        for k in keys:
            sub.lookup("known", k)
    assert _io_sig(store.search_io()) == _io_sig(sim.search_io())
    store.close()
